"""L2 — JAX model definitions for the §4.2 vision benchmarks.

A configurable classifier whose fully connected core layers are low-rank
factored ``U S Vᵀ`` (the paper trains the FC head of ResNet18 / AlexNet /
VGG16 / ViT with FeDLRT; the convolutional features are emulated by a
trainable dense backbone — see DESIGN.md §Substitutions):

    h = relu(x @ W_b + b_b)            for each backbone layer
    h = relu(h + lowrank(h) + bias)    for each low-rank core layer
    logits = h @ W_h + b_h

``lowrank`` runs through the Pallas kernels (L1) via
:func:`compile.kernels.lowrank.lowrank_layer`, so the AOT-lowered HLO
contains our kernels on the hot path, with the fused Pallas VJP on the
backward pass.

Exported functions per model configuration (all shapes static; the
dynamic-rank scheme zero-pads factors to ``r_pad`` — padding is exact,
see DESIGN.md §Static-shape AOT):

* ``grad_factors``  — loss + grads for every parameter, factored layers
  producing ``(G_U, G_S, G_V)`` (Algorithm 1 line 3).
* ``grad_coeff``    — loss + grads for dense params and ``G_S̃`` only
  (Algorithm 1 line 9 / the eq. 7-8 inner loop).
* ``grad_dense``    — FedAvg/FedLin baseline: core layers as dense ``W``.
* ``eval_factors`` / ``eval_dense`` — summed loss + correct-prediction
  count on an evaluation batch.

Parameter order is fixed and recorded in the AOT manifest; the Rust
runtime flattens/unflattens by that record.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.lowrank import lowrank_layer


@dataclass(frozen=True)
class ModelConfig:
    """Static description of one model variant (one AOT artifact set)."""

    name: str
    d_in: int
    backbone: Tuple[int, ...]  # dense widths; last must equal n_core
    n_core: int
    num_lr: int
    classes: int
    r_pad: int  # padded factor rank (= 2 × coordinator max_rank)
    batch: int
    eval_batch: int
    # Optional convolutional stem: inputs are images (h, w, c_in) and a
    # stride-2 3×3 conv with `conv_channels` filters runs before the
    # dense backbone (closer to the paper's CNN bodies). The kernel is
    # carried as a 2-D (9·c_in, conv_channels) parameter so the Rust
    # coordinator stays matrix-only; the model reshapes internally.
    conv_channels: int = 0
    img_hw: Tuple[int, int, int] = (8, 8, 3)
    # Transformer mode (the paper's ViT benchmark trains every 512×512
    # attention weight matrix with FeDLRT): the input splits into
    # `num_patches` tokens, the backbone embeds each token to `n_core`,
    # and the low-rank layers are consumed in groups of four per
    # attention block — (W_q, W_k, W_v, W_o), each n_core×n_core,
    # `attn_heads` heads — followed by mean-pooling into the head.
    attention: bool = False
    attn_heads: int = 2
    num_patches: int = 16

    def __post_init__(self):
        assert self.backbone[-1] == self.n_core, "backbone must end at n_core"
        assert self.batch % 2 == 0 and self.eval_batch % 2 == 0
        if self.conv_channels:
            h, w, c = self.img_hw
            assert h * w * c == self.d_in, "img_hw must flatten to d_in"
        if self.attention:
            assert self.d_in % self.num_patches == 0, "patches must tile d_in"
            assert self.num_lr % 4 == 0, "attention consumes lr layers in groups of 4"
            assert self.n_core % self.attn_heads == 0

    def conv_flat_dim(self) -> int:
        """Flattened feature dim after the stride-2 conv stem."""
        h, w, _ = self.img_hw
        return (h // 2) * (w // 2) * self.conv_channels

    # ---- parameter templates ------------------------------------------------

    def _stem_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        if not self.conv_channels:
            return []
        _, _, c_in = self.img_hw
        return [
            ("conv0.w", (9 * c_in, self.conv_channels)),
            ("conv0.b", (1, self.conv_channels)),
        ]

    def _backbone_input(self) -> int:
        if self.attention:
            return self.d_in // self.num_patches  # per-token dim
        return self.conv_flat_dim() if self.conv_channels else self.d_in

    def param_spec_factored(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """(name, shape) in exact argument order — factored variant."""
        spec = self._stem_spec()
        prev = self._backbone_input()
        for i, w in enumerate(self.backbone):
            spec.append((f"backbone{i}.w", (prev, w)))
            spec.append((f"backbone{i}.b", (1, w)))
            prev = w
        for l in range(self.num_lr):
            n, r = self.n_core, self.r_pad
            spec.append((f"lr{l}.u", (n, r)))
            spec.append((f"lr{l}.s", (r, r)))
            spec.append((f"lr{l}.v", (n, r)))
            spec.append((f"lr{l}.b", (1, n)))
        spec.append(("head.w", (self.n_core, self.classes)))
        spec.append(("head.b", (1, self.classes)))
        return spec

    def param_spec_dense(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """(name, shape) in argument order — dense-baseline variant."""
        spec = self._stem_spec()
        prev = self._backbone_input()
        for i, w in enumerate(self.backbone):
            spec.append((f"backbone{i}.w", (prev, w)))
            spec.append((f"backbone{i}.b", (1, w)))
            prev = w
        for l in range(self.num_lr):
            n = self.n_core
            spec.append((f"lr{l}.w", (n, n)))
            spec.append((f"lr{l}.b", (1, n)))
        spec.append(("head.w", (self.n_core, self.classes)))
        spec.append(("head.b", (1, self.classes)))
        return spec

    def init_params(self, key, factored: bool = True):
        """He-scaled random parameters (tests / python-side sanity)."""
        spec = self.param_spec_factored() if factored else self.param_spec_dense()
        params = []
        for name, shape in spec:
            key, sub = jax.random.split(key)
            if name.endswith(".b"):
                params.append(jnp.zeros(shape, jnp.float32))
            elif name.endswith(".s"):
                # Diagonal, descending, only the top-left r_pad/2 block
                # active — mimics the coordinator's initialization.
                r = shape[0]
                diag = jnp.where(
                    jnp.arange(r) < r // 2,
                    1.0 / (1.0 + jnp.arange(r, dtype=jnp.float32)),
                    0.0,
                ) / jnp.sqrt(self.n_core)
                params.append(jnp.diag(diag).astype(jnp.float32))
            elif name.endswith((".u", ".v")):
                n, r = shape
                q, _ = jnp.linalg.qr(jax.random.normal(sub, (n, r), jnp.float32))
                active = jnp.where(jnp.arange(r) < r // 2, 1.0, 0.0)
                params.append((q * active[None, :]).astype(jnp.float32))
            else:
                fan_in = shape[0]
                params.append(
                    jax.random.normal(sub, shape, jnp.float32)
                    * jnp.sqrt(2.0 / fan_in)
                )
        return params


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------


def _split(cfg: ModelConfig, params, factored: bool):
    """Split the flat param list into (stem, backbone, core, head)."""
    i = 0
    stem = None
    if cfg.conv_channels:
        stem = (params[0], params[1])
        i = 2
    backbone = []
    for _ in cfg.backbone:
        backbone.append((params[i], params[i + 1]))
        i += 2
    core = []
    per = 4 if factored else 2
    for _ in range(cfg.num_lr):
        core.append(tuple(params[i : i + per]))
        i += per
    head = (params[i], params[i + 1])
    assert i + 2 == len(params), f"param count mismatch: {i + 2} vs {len(params)}"
    return stem, backbone, core, head


def _apply_stem(cfg: ModelConfig, stem, x):
    """Stride-2 3×3 conv stem (NHWC) + relu + flatten."""
    if stem is None:
        return x
    w2d, b = stem
    h_dim, w_dim, c_in = cfg.img_hw
    kernel = w2d.reshape(3, 3, c_in, cfg.conv_channels)
    img = x.reshape(-1, h_dim, w_dim, c_in)
    out = jax.lax.conv_general_dilated(
        img,
        kernel,
        window_strides=(2, 2),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = jax.nn.relu(out + b.reshape(1, 1, 1, -1))
    return out.reshape(out.shape[0], -1)


def _attention_block(cfg: ModelConfig, tokens, wq, wk, wv, wo):
    """Multi-head self-attention over tokens, projections given as
    callables mapping (B·T, n) → (B·T, n) (low-rank or dense)."""
    b, t, n = tokens.shape
    heads = cfg.attn_heads
    dh = n // heads
    flat = tokens.reshape(b * t, n)

    def split_heads(z):
        return z.reshape(b, t, heads, dh).transpose(0, 2, 1, 3)  # B,H,T,dh

    q = split_heads(wq(flat))
    k = split_heads(wk(flat))
    v = split_heads(wv(flat))
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(float(dh))
    attn = jax.nn.softmax(scores, axis=-1)
    mixed = jnp.einsum("bhts,bhsd->bhtd", attn, v)
    mixed = mixed.transpose(0, 2, 1, 3).reshape(b * t, n)
    out = wo(mixed).reshape(b, t, n)
    return tokens + out  # residual


def _forward_attention(cfg: ModelConfig, backbone, core, head, x, proj):
    """Shared transformer path; `proj(layer)` builds the projection fn."""
    bsz = x.shape[0]
    p_dim = cfg.d_in // cfg.num_patches
    tokens = x.reshape(bsz, cfg.num_patches, p_dim)
    # Per-token embedding through the dense backbone.
    flat = tokens.reshape(bsz * cfg.num_patches, p_dim)
    h = flat
    for w, b in backbone:
        h = jax.nn.relu(h @ w + b)
    tokens = h.reshape(bsz, cfg.num_patches, cfg.n_core)
    # Attention blocks: 4 low-rank layers each (W_q, W_k, W_v, W_o).
    for blk in range(len(core) // 4):
        fns = [proj(core[4 * blk + i]) for i in range(4)]
        tokens = _attention_block(cfg, tokens, *fns)
    pooled = tokens.mean(axis=1)
    w, b = head
    return pooled @ w + b


def forward_factored(cfg: ModelConfig, params, x):
    stem, backbone, core, head = _split(cfg, params, factored=True)
    if cfg.attention:
        def proj(layer):
            u, s, v, b = layer
            return lambda z: lowrank_layer(z, u, s, v) + b
        return _forward_attention(cfg, backbone, core, head, x, proj)
    h = _apply_stem(cfg, stem, x)
    for w, b in backbone:
        h = jax.nn.relu(h @ w + b)
    for u, s, v, b in core:
        # Residual keeps gradient flow alive at very low rank.
        h = jax.nn.relu(h + lowrank_layer(h, u, s, v) + b)
    w, b = head
    return h @ w + b


def forward_dense(cfg: ModelConfig, params, x):
    stem, backbone, core, head = _split(cfg, params, factored=False)
    if cfg.attention:
        def proj(layer):
            w, b = layer
            return lambda z: z @ w + b
        return _forward_attention(cfg, backbone, core, head, x, proj)
    h = _apply_stem(cfg, stem, x)
    for w, b in backbone:
        h = jax.nn.relu(h @ w + b)
    for w, b in core:
        h = jax.nn.relu(h + h @ w + b)
    w, b = head
    return h @ w + b


def _ce_loss(logits, y):
    """Mean softmax cross-entropy; ``y`` int32 labels."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


# ---------------------------------------------------------------------------
# Exported functions (one AOT artifact each).
# ---------------------------------------------------------------------------


def make_grad_factors(cfg: ModelConfig):
    """(params…, x, y) → (loss, *grads) — all parameters, factored."""

    def fn(*args):
        params, x, y = list(args[:-2]), args[-2], args[-1]

        def loss_fn(ps):
            return _ce_loss(forward_factored(cfg, ps, x), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss, *grads)

    return fn


def make_grad_coeff(cfg: ModelConfig):
    """(params…, x, y) → (loss, *grads-without-U/V) — the inner loop.

    U and V are constants here (the shared augmented bases); only dense
    parameters and the coefficient matrices S̃ receive gradients, which is
    exactly the client-compute saving of Table 1.
    """
    spec = cfg.param_spec_factored()
    diff_idx = [i for i, (name, _) in enumerate(spec) if not name.endswith((".u", ".v"))]

    def fn(*args):
        params, x, y = list(args[:-2]), args[-2], args[-1]
        diff = [params[i] for i in diff_idx]

        def loss_fn(dps):
            full = list(params)
            for slot, val in zip(diff_idx, dps):
                full[slot] = val
            return _ce_loss(forward_factored(cfg, full, x), y)

        loss, grads = jax.value_and_grad(loss_fn)(diff)
        return (loss, *grads)

    return fn


def make_grad_dense(cfg: ModelConfig):
    """(params…, x, y) → (loss, *grads) — dense baseline."""

    def fn(*args):
        params, x, y = list(args[:-2]), args[-2], args[-1]

        def loss_fn(ps):
            return _ce_loss(forward_dense(cfg, ps, x), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss, *grads)

    return fn


def make_eval(cfg: ModelConfig, factored: bool):
    """(params…, x, y) → (summed loss, correct count) on an eval batch."""
    fwd = forward_factored if factored else forward_dense

    def fn(*args):
        params, x, y = list(args[:-2]), args[-2], args[-1]
        logits = fwd(cfg, params, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        loss_sum = jnp.sum(logz - picked)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return (loss_sum, correct)

    return fn


# ---------------------------------------------------------------------------
# Model registry — the experiment configurations of Table 2, scaled for a
# CPU-only testbed (DESIGN.md §Substitutions). Layer *structure* mirrors
# the paper's heads: ResNet18 has a single FC layer; AlexNet/VGG16 have
# multi-layer FC heads; the ViT variant is wider and 100-class.
# ---------------------------------------------------------------------------

CONFIGS = {
    "test_tiny": ModelConfig(
        name="test_tiny", d_in=12, backbone=(16,), n_core=16, num_lr=1,
        classes=4, r_pad=8, batch=16, eval_batch=32,
    ),
    "resnet18_conv": ModelConfig(
        name="resnet18_conv", d_in=192, backbone=(256,), n_core=256, num_lr=1,
        classes=10, r_pad=64, batch=64, eval_batch=256,
        conv_channels=16, img_hw=(8, 8, 3),
    ),
    "resnet18_head": ModelConfig(
        name="resnet18_head", d_in=192, backbone=(256,), n_core=256, num_lr=1,
        classes=10, r_pad=64, batch=64, eval_batch=256,
    ),
    "alexnet_head": ModelConfig(
        name="alexnet_head", d_in=192, backbone=(256,), n_core=256, num_lr=2,
        classes=10, r_pad=64, batch=64, eval_batch=256,
    ),
    "vgg16_head": ModelConfig(
        name="vgg16_head", d_in=192, backbone=(512,), n_core=512, num_lr=2,
        classes=10, r_pad=64, batch=64, eval_batch=256,
    ),
    "vit_attn": ModelConfig(
        name="vit_attn", d_in=192, backbone=(256,), n_core=256, num_lr=4,
        classes=100, r_pad=64, batch=64, eval_batch=256,
        attention=True, attn_heads=2, num_patches=16,
    ),
    "vit_head": ModelConfig(
        name="vit_head", d_in=192, backbone=(512,), n_core=512, num_lr=3,
        classes=100, r_pad=64, batch=64, eval_batch=256,
    ),
}
