"""AOT pipeline: lower every model function to HLO text artifacts.

Python runs ONCE, at build time (`make artifacts`); the Rust coordinator
loads the emitted `artifacts/*.hlo.txt` through PJRT and never calls back
into Python. HLO **text** is the interchange format — jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1
rejects, while the text parser reassigns ids (see
/opt/xla-example/README.md).

Emits, per model config:
    artifacts/<config>.<fn>.hlo.txt     fn ∈ {grad_factors, grad_coeff,
                                              grad_dense, eval_factors,
                                              eval_dense}
    artifacts/manifest.json             shapes + parameter order + outputs

Usage: python -m compile.aot --out ../artifacts [--configs a,b,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

FUNCTIONS = ("grad_factors", "grad_coeff", "grad_dense", "eval_factors", "eval_dense")


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(cfg: M.ModelConfig, factored: bool, batch: int):
    spec = cfg.param_spec_factored() if factored else cfg.param_spec_dense()
    args = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in spec]
    args.append(jax.ShapeDtypeStruct((batch, cfg.d_in), jnp.float32))
    args.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    return args


def build_fn(cfg: M.ModelConfig, fn_name: str):
    if fn_name == "grad_factors":
        return M.make_grad_factors(cfg), True, cfg.batch
    if fn_name == "grad_coeff":
        return M.make_grad_coeff(cfg), True, cfg.batch
    if fn_name == "grad_dense":
        return M.make_grad_dense(cfg), False, cfg.batch
    if fn_name == "eval_factors":
        return M.make_eval(cfg, factored=True), True, cfg.eval_batch
    if fn_name == "eval_dense":
        return M.make_eval(cfg, factored=False), False, cfg.eval_batch
    raise ValueError(fn_name)


def output_spec(cfg: M.ModelConfig, fn_name: str):
    """Names+shapes of each tuple element the artifact returns."""
    fspec = cfg.param_spec_factored()
    dspec = cfg.param_spec_dense()
    if fn_name == "grad_factors":
        return [("loss", [])] + [(f"g:{n}", list(s)) for n, s in fspec]
    if fn_name == "grad_coeff":
        kept = [(n, s) for n, s in fspec if not n.endswith((".u", ".v"))]
        return [("loss", [])] + [(f"g:{n}", list(s)) for n, s in kept]
    if fn_name == "grad_dense":
        return [("loss", [])] + [(f"g:{n}", list(s)) for n, s in dspec]
    if fn_name in ("eval_factors", "eval_dense"):
        return [("loss_sum", []), ("correct", [])]
    raise ValueError(fn_name)


def lower_config(cfg: M.ModelConfig, out_dir: str, manifest: dict) -> None:
    entry = {
        "d_in": cfg.d_in,
        "backbone": list(cfg.backbone),
        "n_core": cfg.n_core,
        "num_lr": cfg.num_lr,
        "classes": cfg.classes,
        "r_pad": cfg.r_pad,
        "batch": cfg.batch,
        "eval_batch": cfg.eval_batch,
        "params_factored": [
            {"name": n, "shape": list(s)} for n, s in cfg.param_spec_factored()
        ],
        "params_dense": [
            {"name": n, "shape": list(s)} for n, s in cfg.param_spec_dense()
        ],
        "functions": {},
        "outputs": {},
    }
    for fn_name in FUNCTIONS:
        fn, factored, batch = build_fn(cfg, fn_name)
        args = example_args(cfg, factored, batch)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}.{fn_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry["functions"][fn_name] = fname
        entry["outputs"][fn_name] = [
            {"name": n, "shape": s} for n, s in output_spec(cfg, fn_name)
        ]
        print(f"  {fname}: {len(text) / 1e3:.0f} kB, {len(args)} args")
    manifest["configs"][cfg.name] = entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--configs",
        default=",".join(M.CONFIGS),
        help="comma-separated subset of model configs",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    # Merge into an existing manifest so `--configs subset` re-lowers
    # only what changed without orphaning the other configs' entries.
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    else:
        manifest = {"version": 1, "configs": {}}
    for name in args.configs.split(","):
        cfg = M.CONFIGS[name]
        print(f"lowering {name} …")
        lower_config(cfg, args.out, manifest)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}/manifest.json ({len(manifest['configs'])} configs)")


if __name__ == "__main__":
    main()
