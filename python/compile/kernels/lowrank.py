"""Pallas kernels for the FeDLRT compute hot-spot (L1).

The client-side cost of FeDLRT is dominated by two primitives:

* the factored layer forward ``y = x · U · S · Vᵀ`` (eq. 7/8 inner loop),
* the Galerkin projection ``G_S̃ = Ũᵀ G Ṽ`` (eq. 5 coefficient dynamics).

Both are written as Pallas kernels below, plus a fused VJP kernel for the
backward pass, and wrapped in a ``jax.custom_vjp`` so the L2 model
differentiates *through our kernels* rather than through generic autodiff.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernels tile the batch
dimension with ``BlockSpec`` while keeping the basis panels ``U, V ∈
R^{n×R}`` and the coefficient block ``S ∈ R^{R×R}`` fully VMEM-resident —
for the paper's largest head (n=512, R=2·r_max=128) that is
2·512·128·4 B + 128²·4 B ≈ 0.57 MiB, far under the ~16 MiB VMEM budget,
so the only HBM traffic per grid step is one batch tile in and one out.
The matmul chain is MXU-shaped: every contraction has an operand with
≥128 columns when R = 128.

CPU execution uses ``interpret=True`` (the CPU PJRT plugin cannot run
Mosaic custom-calls); the grid/BlockSpec structure is identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch-tile size. 128 divides every batch size the AOT pipeline emits
# and matches the MXU sublane tiling on real TPUs.
DEFAULT_BLOCK_B = 128


def _pick_block(batch: int) -> int:
    """Largest power-of-two tile ≤ DEFAULT_BLOCK_B dividing ``batch``."""
    b = min(DEFAULT_BLOCK_B, batch)
    while batch % b != 0:
        b //= 2
    return max(b, 1)


# ---------------------------------------------------------------------------
# Forward kernel: y = x @ U @ S @ V.T, batch-tiled.
# ---------------------------------------------------------------------------


def _lowrank_fwd_kernel(x_ref, u_ref, s_ref, v_ref, o_ref):
    x = x_ref[...]
    # Skinny chain: (B×m)·(m×R) → (B×R)·(R×R) → (B×R)·(R×n).
    xu = jnp.dot(x, u_ref[...], preferred_element_type=jnp.float32)
    xus = jnp.dot(xu, s_ref[...], preferred_element_type=jnp.float32)
    y = jnp.dot(xus, v_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def lowrank_apply_kernel(x, u, s, v, *, interpret=True):
    """Pallas forward: ``x @ U @ S @ Vᵀ`` with batch-tiled grid."""
    batch, m = x.shape
    n, r = v.shape
    assert u.shape == (m, r) and s.shape == (r, r)
    bb = _pick_block(batch)
    grid = (batch // bb,)
    return pl.pallas_call(
        _lowrank_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),  # stream batch tiles
            pl.BlockSpec((m, r), lambda i: (0, 0)),   # U resident
            pl.BlockSpec((r, r), lambda i: (0, 0)),   # S resident
            pl.BlockSpec((n, r), lambda i: (0, 0)),   # V resident
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=interpret,
    )(x, u, s, v)


# ---------------------------------------------------------------------------
# Projection kernel: G_S = A.T @ G @ B  (A: k×p, G: k×q, B: q×r → p×r).
# Grid over the contraction dim k so arbitrarily large batches stream
# through VMEM; the p×r accumulator stays resident.
# ---------------------------------------------------------------------------


def _gram_project_kernel(a_ref, g_ref, b_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    atg = jnp.dot(a_ref[...].T, g_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(atg, b_ref[...], preferred_element_type=jnp.float32).astype(o_ref.dtype)


def gram_project_kernel(a, g, b, *, interpret=True):
    """Pallas projection ``Aᵀ G B`` — the ∇_S̃ computation."""
    k, p = a.shape
    k2, q = g.shape
    q2, r = b.shape
    assert k == k2 and q == q2
    bb = _pick_block(k)
    grid = (k // bb,)
    return pl.pallas_call(
        _gram_project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, p), lambda i: (i, 0)),
            pl.BlockSpec((bb, q), lambda i: (i, 0)),
            pl.BlockSpec((q, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((p, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, r), a.dtype),
        interpret=interpret,
    )(a, g, b)


# ---------------------------------------------------------------------------
# Fused backward kernel: all four cotangents in one pass over the batch.
# dx accumulates per batch tile (disjoint tiles); dU/dS/dV accumulate
# across the whole grid in resident VMEM blocks.
# ---------------------------------------------------------------------------


def _lowrank_bwd_kernel(x_ref, u_ref, s_ref, v_ref, dy_ref, dx_ref, du_ref, ds_ref, dv_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        du_ref[...] = jnp.zeros_like(du_ref)
        ds_ref[...] = jnp.zeros_like(ds_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    x = x_ref[...]
    dy = dy_ref[...]
    u = u_ref[...]
    s = s_ref[...]
    v = v_ref[...]
    dyv = jnp.dot(dy, v, preferred_element_type=jnp.float32)       # B×R
    xu = jnp.dot(x, u, preferred_element_type=jnp.float32)         # B×R
    dyvst = jnp.dot(dyv, s.T, preferred_element_type=jnp.float32)  # B×R
    dx_ref[...] = jnp.dot(dyvst, u.T, preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    du_ref[...] += jnp.dot(x.T, dyvst, preferred_element_type=jnp.float32).astype(du_ref.dtype)
    ds_ref[...] += jnp.dot(xu.T, dyv, preferred_element_type=jnp.float32).astype(ds_ref.dtype)
    dv_ref[...] += jnp.dot(dy.T, jnp.dot(xu, s, preferred_element_type=jnp.float32),
                           preferred_element_type=jnp.float32).astype(dv_ref.dtype)


def lowrank_vjp_kernel(x, u, s, v, dy, *, interpret=True):
    """Fused backward: returns ``(dx, dU, dS, dV)``."""
    batch, m = x.shape
    n, r = v.shape
    bb = _pick_block(batch)
    grid = (batch // bb,)
    out_shapes = (
        jax.ShapeDtypeStruct((batch, m), x.dtype),
        jax.ShapeDtypeStruct((m, r), u.dtype),
        jax.ShapeDtypeStruct((r, r), s.dtype),
        jax.ShapeDtypeStruct((n, r), v.dtype),
    )
    return pl.pallas_call(
        _lowrank_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x, u, s, v, dy)


# ---------------------------------------------------------------------------
# Differentiable wrapper: the L2 model calls this; JAX autodiff uses our
# fused backward kernel instead of tracing through the forward.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def lowrank_layer(x, u, s, v):
    """Differentiable factored layer ``x @ U S Vᵀ`` backed by Pallas."""
    return lowrank_apply_kernel(x, u, s, v)


def _lowrank_layer_fwd(x, u, s, v):
    return lowrank_apply_kernel(x, u, s, v), (x, u, s, v)


def _lowrank_layer_bwd(resid, dy):
    x, u, s, v = resid
    return lowrank_vjp_kernel(x, u, s, v, dy)


lowrank_layer.defvjp(_lowrank_layer_fwd, _lowrank_layer_bwd)


@functools.partial(jax.jit, static_argnames=())
def coeff_gradient(u, g, v):
    """Jitted ∇_S̃ projection ``Ũᵀ G Ṽ`` via the Pallas kernel."""
    return gram_project_kernel(u, g, v)
