"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
`python/tests/test_kernel.py` sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle. The oracles are also what the
L2 model would use if Pallas were unavailable — they define the
mathematical contract:

    lowrank_apply(x, U, S, V) = x @ U @ S @ V.T        (factored layer fwd)
    gram_project(A, G, B)     = A.T @ G @ B            (coefficient-gradient
                                                        projection, eq. 5 S-step)
"""

import jax.numpy as jnp


def lowrank_apply(x, u, s, v):
    """Factored low-rank layer forward: ``x @ (U S Vᵀ)``.

    Association order ``((x·U)·S)·Vᵀ`` keeps every intermediate skinny
    (batch×r), which is the client-compute argument of Table 1.
    """
    return ((x @ u) @ s) @ v.T


def gram_project(a, g, b):
    """Galerkin projection ``Aᵀ G B`` (with A=U, B=V this is ∇_S̃)."""
    return (a.T @ g) @ b


def lowrank_vjp(x, u, s, v, dy):
    """Reference cotangents of ``lowrank_apply`` wrt (x, u, s, v).

    dx = ((dy·V)·Sᵀ)·Uᵀ
    dU = xᵀ·(dy·V·Sᵀ)
    dS = (x·U)ᵀ·(dy·V)
    dV = dyᵀ·(x·U·S)
    """
    dyv = dy @ v
    xu = x @ u
    dx = (dyv @ s.T) @ u.T
    du = x.T @ (dyv @ s.T)
    ds = xu.T @ dyv
    dv = dy.T @ (xu @ s)
    return dx, du, ds, dv
