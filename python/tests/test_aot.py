"""AOT pipeline tests: manifest ↔ lowering consistency.

Verifies the contract the Rust runtime depends on: parameter order,
output tuple layout, and the HLO text's entry-computation signature.
Artifact-file checks are skipped until `make artifacts` has run.
"""

import json
import os
import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ARTIFACTS, "manifest.json"))

CFG = M.CONFIGS["test_tiny"]


class TestSpecs:
    def test_output_spec_matches_function_arity(self):
        """output_spec must agree with what each function returns."""
        params = CFG.init_params(jax.random.PRNGKey(0), factored=True)
        dparams = CFG.init_params(jax.random.PRNGKey(0), factored=False)
        import numpy as np

        rng = np.random.default_rng(0)
        for fn_name in aot.FUNCTIONS:
            fn, factored, batch = aot.build_fn(CFG, fn_name)
            ps = params if factored else dparams
            x = jnp.asarray(rng.normal(size=(batch, CFG.d_in)), jnp.float32)
            y = jnp.asarray(rng.integers(0, CFG.classes, size=batch), jnp.int32)
            out = fn(*ps, x, y)
            spec = aot.output_spec(CFG, fn_name)
            assert len(out) == len(spec), fn_name
            for val, (name, shape) in zip(out, spec):
                assert list(val.shape) == shape, f"{fn_name}/{name}"

    def test_param_specs_cover_all_functions(self):
        for cfg in M.CONFIGS.values():
            fspec = cfg.param_spec_factored()
            dspec = cfg.param_spec_dense()
            # factored has 4 tensors per lr layer, dense has 2.
            assert len(fspec) - len(dspec) == 2 * cfg.num_lr
            # Shapes are positive.
            for _, shape in fspec + dspec:
                assert all(d > 0 for d in shape)

    def test_example_args_shapes(self):
        args = aot.example_args(CFG, factored=True, batch=CFG.batch)
        # params + x + y
        assert len(args) == len(CFG.param_spec_factored()) + 2
        assert args[-2].shape == (CFG.batch, CFG.d_in)
        assert args[-1].dtype == jnp.int32


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_lists_all_functions_and_files_exist(self):
        m = self.manifest()
        assert "test_tiny" in m["configs"]
        for name, entry in m["configs"].items():
            for fn_name in aot.FUNCTIONS:
                assert fn_name in entry["functions"], (name, fn_name)
                path = os.path.join(ARTIFACTS, entry["functions"][fn_name])
                assert os.path.exists(path), path

    def test_hlo_entry_signature_matches_manifest(self):
        m = self.manifest()
        entry = m["configs"]["test_tiny"]
        path = os.path.join(ARTIFACTS, entry["functions"]["grad_coeff"])
        text = open(path).read()
        # The ENTRY computation must declare #params + 2 parameter
        # instructions (HLO text lists them as `= ty[] parameter(i)`).
        want_args = len(entry["params_factored"]) + 2
        entry_body = text[text.index("ENTRY") :]
        params = set(re.findall(r"parameter\((\d+)\)", entry_body))
        assert len(params) == want_args, f"{sorted(params)} vs {want_args}"

    def test_manifest_shapes_match_model(self):
        m = self.manifest()
        entry = m["configs"]["test_tiny"]
        spec = {s["name"]: s["shape"] for s in entry["params_factored"]}
        for name, shape in CFG.param_spec_factored():
            assert spec[name] == list(shape), name
