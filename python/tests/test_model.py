"""L2 correctness: model functions, gradient consistency, padding.

Verifies the exported functions the AOT pipeline lowers:
* forward shapes and finiteness for every registered config,
* grad_factors == autodiff of a kernel-free reference forward,
* grad_coeff outputs equal the corresponding subset of grad_factors,
* dense and factored forwards agree when W = U S Vᵀ,
* rank zero-padding leaves every gradient block exactly consistent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.CONFIGS["test_tiny"]


def data(cfg, batch, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch, cfg.d_in)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.classes, size=batch), jnp.int32)
    return x, y


def forward_factored_ref(cfg, params, x):
    """Kernel-free forward (jnp only) for autodiff cross-checks."""
    stem, backbone, core, head = M._split(cfg, params, factored=True)
    h = M._apply_stem(cfg, stem, x)
    for w, b in backbone:
        h = jax.nn.relu(h @ w + b)
    for u, s, v, b in core:
        h = jax.nn.relu(h + ref.lowrank_apply(h, u, s, v) + b)
    w, b = head
    return h @ w + b


class TestForward:
    @pytest.mark.parametrize("name", list(M.CONFIGS))
    def test_shapes_and_finite(self, name):
        cfg = M.CONFIGS[name]
        params = cfg.init_params(jax.random.PRNGKey(0), factored=True)
        x, _ = data(cfg, cfg.batch, seed=1)
        logits = M.forward_factored(cfg, params, x)
        assert logits.shape == (cfg.batch, cfg.classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_factored_equals_dense_when_w_matches(self):
        cfg = CFG
        fparams = cfg.init_params(jax.random.PRNGKey(1), factored=True)
        # Build dense params with W = U S Vᵀ.
        dparams = []
        i = 0
        for _ in cfg.backbone:
            dparams += [fparams[i], fparams[i + 1]]
            i += 2
        for _ in range(cfg.num_lr):
            u, s, v, b = fparams[i : i + 4]
            dparams += [u @ s @ v.T, b]
            i += 4
        dparams += [fparams[i], fparams[i + 1]]
        x, _ = data(cfg, cfg.batch, seed=2)
        lf = M.forward_factored(cfg, fparams, x)
        ld = M.forward_dense(cfg, dparams, x)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(ld), rtol=1e-4, atol=1e-4)


class TestGradients:
    def test_grad_factors_matches_reference_autodiff(self):
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(2), factored=True)
        x, y = data(cfg, cfg.batch, seed=3)
        out = M.make_grad_factors(cfg)(*params, x, y)
        loss, grads = out[0], out[1:]

        def ref_loss(ps):
            return M._ce_loss(forward_factored_ref(cfg, ps, x), y)

        want_loss, want_grads = jax.value_and_grad(ref_loss)(list(params))
        np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-5)
        spec = cfg.param_spec_factored()
        for g, w, (name, _) in zip(grads, want_grads, spec):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=3e-4, atol=3e-4, err_msg=name
            )

    def test_grad_coeff_is_subset_of_grad_factors(self):
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(3), factored=True)
        x, y = data(cfg, cfg.batch, seed=4)
        full = M.make_grad_factors(cfg)(*params, x, y)
        coeff = M.make_grad_coeff(cfg)(*params, x, y)
        np.testing.assert_allclose(float(full[0]), float(coeff[0]), rtol=1e-6)
        spec = cfg.param_spec_factored()
        kept = [i for i, (n, _) in enumerate(spec) if not n.endswith((".u", ".v"))]
        for out_i, full_i in enumerate(kept):
            np.testing.assert_allclose(
                np.asarray(coeff[1 + out_i]),
                np.asarray(full[1 + full_i]),
                rtol=1e-5,
                atol=1e-6,
                err_msg=spec[full_i][0],
            )

    def test_padded_rank_gradients_zero_in_padding(self):
        """Zero basis columns ⇒ exactly zero gradient blocks there — the
        invariant that makes static-shape AOT exact (DESIGN.md)."""
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(4), factored=True)
        x, y = data(cfg, cfg.batch, seed=5)
        out = M.make_grad_factors(cfg)(*params, x, y)
        grads = out[1:]
        spec = cfg.param_spec_factored()
        r_half = cfg.r_pad // 2  # init activates only the first half
        for g, (name, _) in zip(grads, spec):
            g = np.asarray(g)
            if name.endswith(".s"):
                # Padded rows AND columns of G_S must vanish.
                assert np.abs(g[r_half:, :]).max() == 0.0, name
                assert np.abs(g[:, r_half:]).max() == 0.0, name
            elif name.endswith((".u", ".v")):
                # G_U = G V Sᵀ: zero S-columns ⇒ zero grad columns.
                assert np.abs(g[:, r_half:]).max() == 0.0, name

    def test_grad_dense_matches_autodiff(self):
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(5), factored=False)
        x, y = data(cfg, cfg.batch, seed=6)
        out = M.make_grad_dense(cfg)(*params, x, y)

        def loss_fn(ps):
            return M._ce_loss(M.forward_dense(cfg, ps, x), y)

        want_loss, want = jax.value_and_grad(loss_fn)(list(params))
        np.testing.assert_allclose(float(out[0]), float(want_loss), rtol=1e-6)
        for g, w in zip(out[1:], want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)


class TestEval:
    def test_eval_counts(self):
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(6), factored=True)
        x, y = data(cfg, cfg.eval_batch, seed=7)
        loss_sum, correct = M.make_eval(cfg, factored=True)(*params, x, y)
        assert loss_sum > 0
        assert 0 <= float(correct) <= cfg.eval_batch
        # Cross-check against explicit argmax.
        logits = M.forward_factored(cfg, params, x)
        want = float(jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
        assert float(correct) == want

    def test_perfect_model_gets_everything_right(self):
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(7), factored=True)
        x, _ = data(cfg, cfg.eval_batch, seed=8)
        logits = M.forward_factored(cfg, params, x)
        y_self = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        _, correct = M.make_eval(cfg, factored=True)(*params, x, y_self)
        assert float(correct) == cfg.eval_batch


class TestConvStem:
    def test_conv_config_shapes_and_grads(self):
        cfg = M.CONFIGS["resnet18_conv"]
        assert cfg.conv_flat_dim() == 4 * 4 * 16 == 256
        params = cfg.init_params(jax.random.PRNGKey(9), factored=True)
        x, y = data(cfg, cfg.batch, seed=10)
        logits = M.forward_factored(cfg, params, x)
        assert logits.shape == (cfg.batch, cfg.classes)
        out = M.make_grad_factors(cfg)(*params, x, y)
        spec = cfg.param_spec_factored()
        assert spec[0][0] == "conv0.w"
        # Conv kernel gradient exists, is finite, and matches autodiff of
        # an explicit conv reference.
        g_conv = np.asarray(out[1])
        assert g_conv.shape == (27, 16)
        assert np.isfinite(g_conv).all()

        def ref_loss(w2d):
            ps = list(params)
            ps[0] = w2d
            return M._ce_loss(M.forward_factored(cfg, ps, x), y)

        want = jax.grad(ref_loss)(params[0])
        np.testing.assert_allclose(g_conv, np.asarray(want), rtol=3e-4, atol=3e-4)

    def test_conv_changes_output(self):
        cfg = M.CONFIGS["resnet18_conv"]
        params = cfg.init_params(jax.random.PRNGKey(11), factored=True)
        x, _ = data(cfg, cfg.batch, seed=12)
        base = M.forward_factored(cfg, params, x)
        ps = list(params)
        ps[0] = ps[0] + 0.5
        moved = M.forward_factored(cfg, ps, x)
        assert float(jnp.abs(base - moved).max()) > 1e-3


class TestAttention:
    def test_attention_forward_and_grads(self):
        cfg = M.CONFIGS["vit_attn"]
        params = cfg.init_params(jax.random.PRNGKey(13), factored=True)
        x, y = data(cfg, cfg.batch, seed=14)
        logits = M.forward_factored(cfg, params, x)
        assert logits.shape == (cfg.batch, cfg.classes)
        assert bool(jnp.all(jnp.isfinite(logits)))
        out = M.make_grad_factors(cfg)(*params, x, y)
        # All four attention matrices receive gradients.
        spec = cfg.param_spec_factored()
        s_idx = [i for i, (n, _) in enumerate(spec) if n.endswith(".s")]
        assert len(s_idx) == 4
        for i in s_idx:
            g = np.asarray(out[1 + i])
            assert np.isfinite(g).all()
            assert np.abs(g).max() > 0, spec[i][0]

    def test_attention_is_permutation_sensitive(self):
        # Mean-pooled single-block attention IS permutation-invariant in
        # tokens only if embeddings are identical; with distinct tokens
        # swapping two tokens changes intermediate attn but pooled output
        # stays close — instead verify attention actually mixes tokens:
        # zeroing one patch must change the logits.
        cfg = M.CONFIGS["vit_attn"]
        params = cfg.init_params(jax.random.PRNGKey(15), factored=True)
        x, _ = data(cfg, cfg.batch, seed=16)
        base = M.forward_factored(cfg, params, x)
        p_dim = cfg.d_in // cfg.num_patches
        x2 = x.at[:, :p_dim].set(0.0)
        moved = M.forward_factored(cfg, params, x2)
        assert float(jnp.abs(base - moved).max()) > 1e-4

    def test_attention_dense_factored_agree(self):
        cfg = M.CONFIGS["vit_attn"]
        fparams = cfg.init_params(jax.random.PRNGKey(17), factored=True)
        dparams = []
        i = 0
        for _ in cfg.backbone:
            dparams += [fparams[i], fparams[i + 1]]
            i += 2
        for _ in range(cfg.num_lr):
            u, s, v, b = fparams[i : i + 4]
            dparams += [u @ s @ v.T, b]
            i += 4
        dparams += [fparams[i], fparams[i + 1]]
        x, _ = data(cfg, cfg.batch, seed=18)
        lf = M.forward_factored(cfg, fparams, x)
        ld = M.forward_dense(cfg, dparams, x)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(ld), rtol=2e-3, atol=2e-3)


class TestTraining:
    def test_sgd_on_coeff_reduces_loss(self):
        """A few S̃-only SGD steps (the FeDLRT client inner loop) must
        reduce the training loss — end-to-end sanity of the L1+L2 stack."""
        cfg = CFG
        params = cfg.init_params(jax.random.PRNGKey(8), factored=True)
        x, y = data(cfg, cfg.batch, seed=9)
        grad_coeff = M.make_grad_coeff(cfg)
        spec = cfg.param_spec_factored()
        kept = [i for i, (n, _) in enumerate(spec) if not n.endswith((".u", ".v"))]
        losses = []
        ps = list(params)
        for _ in range(25):
            out = grad_coeff(*ps, x, y)
            losses.append(float(out[0]))
            for out_i, pi in enumerate(kept):
                ps[pi] = ps[pi] - 0.05 * out[1 + out_i]
        assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]
