"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value scales; every kernel output must match
the reference to f32 accumulation accuracy. This is the CORE correctness
signal for the compute layer — if these pass, the HLO artifacts contain
correct kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lowrank as K
from compile.kernels import ref

# Tolerance for f32 matmul-chain accumulation differences.
TOL = dict(rtol=2e-4, atol=2e-4)


def rand(rng, *shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


dims = st.integers(min_value=1, max_value=24)
batches = st.sampled_from([1, 2, 4, 8, 16, 64, 96, 128, 256])
scales = st.sampled_from([1e-3, 1.0, 1e3])


class TestLowrankApply:
    @settings(max_examples=40, deadline=None)
    @given(b=batches, m=dims, n=dims, r=dims, scale=scales, seed=st.integers(0, 2**31))
    def test_matches_ref(self, b, m, n, r, scale, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, b, m, scale=scale)
        u = rand(rng, m, r)
        s = rand(rng, r, r)
        v = rand(rng, n, r)
        got = K.lowrank_apply_kernel(x, u, s, v)
        want = ref.lowrank_apply(x, u, s, v)
        # f32 accumulation order differs between the tiled kernel and the
        # reference chain; tolerance scales with the contraction length.
        tol = 2e-4 * max(1.0, float(np.sqrt(r)))
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=5 * tol, atol=tol * scale
        )

    def test_odd_batch_falls_back_to_unit_block(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 7, 5)  # 7 is prime — exercises block=1
        u, s, v = rand(rng, 5, 3), rand(rng, 3, 3), rand(rng, 4, 3)
        got = K.lowrank_apply_kernel(x, u, s, v)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.lowrank_apply(x, u, s, v)), **TOL
        )

    def test_zero_padded_rank_is_exact(self):
        """Padding factors with zero columns must not change the output —
        the static-shape AOT contract (DESIGN.md)."""
        rng = np.random.default_rng(1)
        x = rand(rng, 32, 10)
        u, s, v = rand(rng, 10, 3), rand(rng, 3, 3), rand(rng, 12, 3)
        up = jnp.pad(u, ((0, 0), (0, 5)))
        sp = jnp.pad(s, ((0, 5), (0, 5)))
        vp = jnp.pad(v, ((0, 0), (0, 5)))
        a = K.lowrank_apply_kernel(x, u, s, v)
        b = K.lowrank_apply_kernel(x, up, sp, vp)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


class TestGramProject:
    @settings(max_examples=40, deadline=None)
    @given(k=batches, p=dims, q=dims, r=dims, seed=st.integers(0, 2**31))
    def test_matches_ref(self, k, p, q, r, seed):
        rng = np.random.default_rng(seed)
        a = rand(rng, k, p)
        g = rand(rng, k, q)
        b = rand(rng, q, r)
        got = K.gram_project_kernel(a, g, b)
        want = ref.gram_project(a, g, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_projection_of_basis_gradient(self):
        """With orthonormal U, V: gram_project(U, U @ C @ V.T, V) == C."""
        rng = np.random.default_rng(2)
        u, _ = np.linalg.qr(rng.normal(size=(20, 4)))
        v, _ = np.linalg.qr(rng.normal(size=(18, 4)))
        c = rng.normal(size=(4, 4)).astype(np.float32)
        g = jnp.asarray(u @ c @ v.T, jnp.float32)
        got = K.gram_project_kernel(
            jnp.asarray(u, jnp.float32), g, jnp.asarray(v, jnp.float32)
        )
        np.testing.assert_allclose(np.asarray(got), c, rtol=1e-4, atol=1e-4)


class TestVjp:
    @settings(max_examples=25, deadline=None)
    @given(b=st.sampled_from([2, 8, 64, 128]), m=dims, n=dims, r=dims,
           seed=st.integers(0, 2**31))
    def test_fused_bwd_matches_ref(self, b, m, n, r, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, b, m)
        u, s, v = rand(rng, m, r), rand(rng, r, r), rand(rng, n, r)
        dy = rand(rng, b, n)
        got = K.lowrank_vjp_kernel(x, u, s, v, dy)
        want = ref.lowrank_vjp(x, u, s, v, dy)
        for g, w, name in zip(got, want, ["dx", "du", "ds", "dv"]):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), err_msg=name, **TOL
            )

    def test_custom_vjp_equals_autodiff_of_ref(self):
        """jax.grad through the Pallas layer == jax.grad through jnp ref."""
        rng = np.random.default_rng(3)
        x = rand(rng, 16, 9)
        u, s, v = rand(rng, 9, 4), rand(rng, 4, 4), rand(rng, 11, 4)

        def loss_kernel(s_, u_, v_):
            return jnp.sum(jnp.tanh(K.lowrank_layer(x, u_, s_, v_)))

        def loss_ref(s_, u_, v_):
            return jnp.sum(jnp.tanh(ref.lowrank_apply(x, u_, s_, v_)))

        for argnum in range(3):
            gk = jax.grad(loss_kernel, argnums=argnum)(s, u, v)
            gr = jax.grad(loss_ref, argnums=argnum)(s, u, v)
            np.testing.assert_allclose(
                np.asarray(gk), np.asarray(gr), err_msg=f"arg{argnum}", **TOL
            )


class TestDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_forward_dtype_support(self, dtype):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(8, 6)), dtype)
        u = jnp.asarray(rng.normal(size=(6, 2)), dtype)
        s = jnp.asarray(rng.normal(size=(2, 2)), dtype)
        v = jnp.asarray(rng.normal(size=(5, 2)), dtype)
        got = K.lowrank_apply_kernel(x, u, s, v)
        want = ref.lowrank_apply(
            *(t.astype(jnp.float32) for t in (x, u, s, v))
        )
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
        )
