//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the API subset the workspace uses — `Error`, `Result`,
//! `anyhow!`, and the `Context` extension trait — with the same
//! semantics (message-carrying error, context prefixing, blanket
//! conversion from `std::error::Error` types). Swap the path dependency
//! for the real `anyhow = "1"` when registry access is available; no
//! call sites need to change.

use std::fmt;

/// A message-carrying error, convertible from any `std::error::Error`.
///
/// Deliberately does **not** implement `std::error::Error` itself — that
/// is what keeps the blanket `From` impl coherent, exactly as in the
/// real crate.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    fn push_context(mut self, context: impl fmt::Display) -> Error {
        self.msg = format!("{context}: {}", self.msg);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach human-readable context to errors (and `None`s).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        assert_eq!(format!("{e:?}"), "bad value 7");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "x must be positive, got 0");
        assert_eq!(f(11).unwrap_err().to_string(), "x too large: 11");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn inner() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("missing"));
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: missing");
        // Context also composes on an already-anyhow Result.
        let r2: Result<()> = Err(anyhow!("inner"));
        assert_eq!(r2.context("outer").unwrap_err().to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("no value").unwrap_err().to_string(), "no value");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }
}
