//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The build environment carries neither crates.io access nor an XLA
//! toolchain, so this vendored crate mirrors the API surface
//! `fedlrt::runtime` uses — `PjRtClient`, `PjRtLoadedExecutable`,
//! `PjRtBuffer`, `Literal`, `HloModuleProto`, `XlaComputation` — with
//! every backend entry point returning a descriptive error at runtime.
//! The library therefore builds and the pure-Rust coordinator stack
//! (convex experiments, benches, tests) runs everywhere; the NN path
//! reports "PJRT backend unavailable" until the real `xla` dependency is
//! swapped back in. All types here are plain data (`Send + Sync`),
//! which is what lets `NnProblem` satisfy the coordinators'
//! `FedProblem + Sync` bound. The real PJRT types wrap raw C handles
//! and are **not** `Sync` — when restoring the real bindings, wrap the
//! executables in `runtime::Executable` behind a `Mutex` (or hold one
//! executable per worker thread) to keep that bound satisfied.

use std::fmt;

/// Error type matching the real crate's role; implements
/// `std::error::Error` so `?` converts it into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(op: &str) -> Result<T> {
    Err(Error {
        msg: format!(
            "{op}: PJRT backend unavailable (offline `xla` stub crate; swap the path \
             dependency for the real `xla` bindings and run `make artifacts` to enable \
             the NN path)"
        ),
    })
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    len: usize,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { len: data.len() }
    }

    pub fn element_count(&self) -> usize {
        self.len
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// A device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A PJRT client (CPU in the real deployment).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(lit.element_count(), 3);
        assert!(lit.to_vec::<f32>().is_err());
    }
}
