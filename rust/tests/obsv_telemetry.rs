//! Telemetry invariants on real training runs (the observability
//! layer's integration contract):
//!
//! * per round, `sum(phase_s) ≤ wall_s` — only top-level spans
//!   accumulate, so phase attribution can never exceed the measured
//!   round;
//! * the exported round JSON always carries the complete phase
//!   taxonomy, and latency quantiles gate on data being present;
//! * quantiles are exact: a single-client round collapses
//!   p50 = p95 = max bitwise;
//! * `client_serial_s` equals the latency histogram's `sum_s` bitwise
//!   for single-executor-call serial rounds (FedAvg) — both fold the
//!   same per-task durations, read from the same monotonic clock, in
//!   the same order (tasks are planned in ascending client id).

use fedlrt::coordinator::{
    run_dense, run_fedlrt, DenseAlgo, RankConfig, TrainConfig, VarCorrection,
};
use fedlrt::engine::ExecutorKind;
use fedlrt::models::least_squares::LeastSquares;
use fedlrt::obsv::{Phase, ALL_PHASES};
use fedlrt::opt::LrSchedule;
use fedlrt::util::rng::Rng;

fn cfg(seed: u64, vc: VarCorrection) -> TrainConfig {
    TrainConfig {
        rounds: 5,
        local_iters: 6,
        lr: LrSchedule::Constant(5e-3),
        var_correction: vc,
        rank: RankConfig { initial_rank: 3, max_rank: 6, tau: 0.05 },
        seed,
        ..TrainConfig::default()
    }
}

#[test]
fn phase_sums_are_bounded_by_wall_clock() {
    let mut rng = Rng::new(201);
    let prob = LeastSquares::heterogeneous(8, 320, 4, &mut rng);
    for vc in [VarCorrection::None, VarCorrection::Simplified, VarCorrection::Full] {
        let rec = run_fedlrt(&prob, &cfg(201, vc), "obsv");
        for r in &rec.rounds {
            let sum = r.phase_s.sum();
            assert!(sum > 0.0, "{}: round {} recorded no phases", vc.label(), r.round);
            assert!(
                sum <= r.wall_s + 1e-6,
                "{}: round {} phase sum {} exceeds wall {}",
                vc.label(),
                r.round,
                sum,
                r.wall_s
            );
        }
    }
}

#[test]
fn fedlrt_phases_match_the_algorithm() {
    // The coordinator's round structure shows up in the attribution:
    // every FeDLRT round broadcasts, trains, aggregates, augments, and
    // truncates; variance correction is attributed only when enabled.
    let mut rng = Rng::new(203);
    let prob = LeastSquares::heterogeneous(8, 320, 4, &mut rng);
    let none = run_fedlrt(&prob, &cfg(203, VarCorrection::None), "obsv");
    let full = run_fedlrt(&prob, &cfg(203, VarCorrection::Full), "obsv");
    for r in &none.rounds {
        for ph in [
            Phase::Broadcast,
            Phase::ClientTrain,
            Phase::Aggregate,
            Phase::AugmentQr,
            Phase::TruncateSvd,
            Phase::Eval,
        ] {
            assert!(
                r.phase_s.get(ph) > 0.0,
                "round {}: phase '{}' never measured",
                r.round,
                ph.label()
            );
        }
    }
    let vc_none: f64 = none.rounds.iter().map(|r| r.phase_s.get(Phase::VarianceCorrection)).sum();
    let vc_full: f64 = full.rounds.iter().map(|r| r.phase_s.get(Phase::VarianceCorrection)).sum();
    // The None mode still assembles (empty) corrections, but the Full
    // mode's extra gradient round trip must dominate it clearly.
    assert!(vc_full > vc_none, "full vc {vc_full} should exceed none {vc_none}");
}

#[test]
fn round_json_carries_full_taxonomy_and_latency() {
    let mut rng = Rng::new(205);
    let prob = LeastSquares::homogeneous(8, 2, 240, 3, &mut rng);
    let rec = run_fedlrt(&prob, &cfg(205, VarCorrection::Simplified), "obsv");
    let json = rec.to_json();
    let rounds = json.get("rounds").and_then(|r| r.as_arr()).expect("rounds array");
    assert_eq!(rounds.len(), rec.rounds.len());
    for r in rounds {
        let ps = r.get("phase_s").expect("phase_s key");
        for p in ALL_PHASES {
            assert!(ps.get(p.label()).is_some(), "phase_s missing '{}'", p.label());
        }
        for key in ["lat_p50_s", "lat_p95_s", "lat_max_s", "straggler"] {
            assert!(r.get(key).is_some(), "round JSON missing '{key}'");
        }
    }
}

#[test]
fn single_client_collapses_quantiles_bitwise() {
    // Exact nearest-rank quantiles: with one sample, every quantile IS
    // that sample — p50 = p95 = max = sum, bitwise.
    let mut rng = Rng::new(207);
    let prob = LeastSquares::homogeneous(8, 2, 160, 1, &mut rng);
    let rec = run_fedlrt(&prob, &cfg(207, VarCorrection::Simplified), "obsv");
    for r in &rec.rounds {
        assert_eq!(r.latency.n, 1);
        assert_eq!(r.latency.p50_s.to_bits(), r.latency.p95_s.to_bits());
        assert_eq!(r.latency.p95_s.to_bits(), r.latency.max_s.to_bits());
        assert_eq!(r.latency.max_s.to_bits(), r.latency.sum_s.to_bits());
        assert_eq!(r.latency.straggler, 0);
    }
}

#[test]
fn latency_quantiles_are_ordered_and_populated() {
    let mut rng = Rng::new(209);
    let prob = LeastSquares::heterogeneous(8, 400, 6, &mut rng);
    let rec = run_fedlrt(&prob, &cfg(209, VarCorrection::Simplified), "obsv");
    for r in &rec.rounds {
        let l = &r.latency;
        assert_eq!(l.n, 6, "round {}: expected all 6 clients", r.round);
        assert!(l.p50_s > 0.0 && l.p50_s <= l.p95_s && l.p95_s <= l.max_s);
        assert!(l.sum_s >= l.max_s);
        assert!(l.straggler < 6);
        // Per-client latencies also bound the coordinator's aggregate
        // client-time accounting from below.
        assert!(l.sum_s <= r.client_serial_s + 1e-9);
    }
}

#[test]
fn client_serial_s_equals_histogram_sum_for_serial_fedavg() {
    // FedAvg does exactly one executor call per round; under the serial
    // executor `serial_s` is the task-order sum of per-task durations
    // and the histogram folds the same numbers in client-id order —
    // which IS task order (plans sort by client id). Bitwise equal.
    let mut rng = Rng::new(211);
    let prob = LeastSquares::homogeneous(8, 2, 320, 5, &mut rng);
    let mut c = cfg(211, VarCorrection::None);
    c.executor = ExecutorKind::Serial;
    let rec = run_dense(&prob, &c, DenseAlgo::FedAvg, "obsv");
    for r in &rec.rounds {
        assert_eq!(
            r.client_serial_s.to_bits(),
            r.latency.sum_s.to_bits(),
            "round {}: client_serial_s {} != histogram sum {}",
            r.round,
            r.client_serial_s,
            r.latency.sum_s
        );
    }
    // FedLin makes two executor calls per round; the totals then agree
    // only up to f64 fold order, not bitwise.
    let lin = run_dense(&prob, &c, DenseAlgo::FedLin, "obsv");
    for r in &lin.rounds {
        let diff = (r.client_serial_s - r.latency.sum_s).abs();
        assert!(
            diff <= 1e-9 * r.client_serial_s.max(1.0),
            "round {}: FedLin totals diverge: {} vs {}",
            r.round,
            r.client_serial_s,
            r.latency.sum_s
        );
    }
}

#[test]
fn client_speedup_is_consistent_with_latency_totals() {
    // `client_speedup()` = serial_s / wall_s over the whole run; under
    // the serial executor wall ≈ serial, so the ratio sits at 1 (from
    // below, up to loop overhead between tasks).
    let mut rng = Rng::new(213);
    let prob = LeastSquares::homogeneous(8, 2, 320, 4, &mut rng);
    let mut c = cfg(213, VarCorrection::Simplified);
    c.executor = ExecutorKind::Serial;
    let rec = run_fedlrt(&prob, &c, "obsv");
    let speedup = rec.client_speedup();
    assert!(
        speedup > 0.5 && speedup <= 1.0 + 1e-9,
        "serial client speedup should be ≈1 from below, got {speedup}"
    );
}
