//! Kernel-equivalence properties for the packed/parallel GEMM layer.
//!
//! Three contracts (see DESIGN.md §Kernel layer):
//!
//! 1. **Correctness** — the packed kernels agree with the naive
//!    triple-loop reference (and with the preserved seed kernel) across
//!    adversarial shapes: degenerate 1×k×1, prime dims, tall-skinny
//!    n×2r, and shapes straddling the small↔packed dispatch threshold.
//! 2. **Determinism** — serial ≡ threaded **bitwise** for every
//!    threaded kernel entry point and every thread count; the serial
//!    kernels are bitwise reproducible call-to-call.
//! 3. **Padding semantics** — all-zero A columns (static-shape rank
//!    padding) are skipped: results are bitwise identical to the
//!    unpadded product, and the B rows aligned with zero columns are
//!    never read (NaN garbage cannot leak).

use fedlrt::tensor::{
    gemm_into, gram, matmul, matmul_nt, matmul_nt_into, matmul_reference, matmul_tn,
    matmul_tn_into, matmul_tn_scaled_into, set_kernel_threads, Matrix, Op, Workspace,
};
use fedlrt::linalg::{orthonormality_error, qr_thin, qr_thin_ws};
use fedlrt::util::rng::Rng;

/// Naive triple-loop oracle.
fn naive(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[(i, p)] * b[(p, j)];
            }
            c[(i, j)] = acc;
        }
    }
    c
}

fn assert_close(got: &Matrix, want: &Matrix, k: usize, what: &str) {
    let tol = 1e-12 * (1.0 + k as f64) * (1.0 + want.max_abs());
    let diff = got.sub(want).max_abs();
    assert!(diff < tol, "{what}: diff {diff} > tol {tol}");
}

fn assert_bitwise(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shapes");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} differs ({x} vs {y})");
    }
}

/// Adversarial shapes: degenerate, prime, tall-skinny n×2r, edge tiles,
/// and both sides of the small↔packed dispatch boundary.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 17, 1),
    (7, 1, 7),
    (2, 3, 1),
    (5, 8, 13),
    (17, 19, 23),
    (31, 37, 29),
    (64, 2, 64),
    (512, 8, 16),
    (100, 3, 100),
    (33, 65, 9),
    (96, 96, 96),
    (101, 83, 97),
    (130, 260, 70),
];

#[test]
fn matmul_matches_naive_across_adversarial_shapes() {
    let mut rng = Rng::new(9001);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let got = matmul(&a, &b);
        let want = naive(&a, &b);
        assert_close(&got, &want, k, &format!("matmul ({m},{k},{n})"));
        let seed = matmul_reference(&a, &b);
        assert_close(&got, &seed, k, &format!("matmul vs seed kernel ({m},{k},{n})"));
    }
}

#[test]
fn transposed_kernels_match_naive_across_adversarial_shapes() {
    let mut rng = Rng::new(9003);
    for &(m, k, n) in SHAPES {
        // Aᵀ·B with A stored k×m.
        let a = Matrix::randn(k, m, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let got = matmul_tn(&a, &b);
        let want = naive(&a.t(), &b);
        assert_close(&got, &want, k, &format!("matmul_tn ({m},{k},{n})"));
        // A·Bᵀ with B stored n×k.
        let a2 = Matrix::randn(m, k, &mut rng);
        let b2 = Matrix::randn(n, k, &mut rng);
        let got2 = matmul_nt(&a2, &b2);
        let want2 = naive(&a2, &b2.t());
        assert_close(&got2, &want2, k, &format!("matmul_nt ({m},{k},{n})"));
    }
}

fn with_threads(aop: Op<'_>, bop: Op<'_>, threads: usize) -> Matrix {
    let mut c = Matrix::zeros(aop.rows(), bop.cols());
    gemm_into(aop, bop, c.view_mut(), 0.0, threads);
    c
}

#[test]
fn serial_equals_threaded_bitwise_for_all_entry_points() {
    // The row-panel determinism contract: every thread count yields the
    // serial result bit for bit, for NN, TN, and NT operand forms.
    let mut rng = Rng::new(9005);
    for &(m, k, n) in &[(64, 64, 64), (101, 83, 97), (260, 190, 170), (512, 16, 64)] {
        let a_nn = Matrix::randn(m, k, &mut rng);
        let a_tn = Matrix::randn(k, m, &mut rng);
        let b_nn = Matrix::randn(k, n, &mut rng);
        let b_nt = Matrix::randn(n, k, &mut rng);
        let cases: [(&str, Op<'_>, Op<'_>); 3] = [
            ("nn", Op::N(a_nn.view()), Op::N(b_nn.view())),
            ("tn", Op::T(a_tn.view()), Op::N(b_nn.view())),
            ("nt", Op::N(a_nn.view()), Op::T(b_nt.view())),
        ];
        for (label, aop, bop) in cases {
            let serial = with_threads(aop, bop, 1);
            for threads in [2usize, 3, 5, 16] {
                let par = with_threads(aop, bop, threads);
                assert_bitwise(
                    &serial,
                    &par,
                    &format!("{label} ({m},{k},{n}) threads={threads}"),
                );
            }
        }
    }
}

#[test]
fn global_thread_knob_does_not_change_results() {
    let mut rng = Rng::new(9007);
    let a = Matrix::randn(150, 140, &mut rng);
    let b = Matrix::randn(140, 160, &mut rng);
    set_kernel_threads(1);
    let serial = matmul(&a, &b);
    set_kernel_threads(4);
    let par = matmul(&a, &b);
    set_kernel_threads(1);
    assert_bitwise(&serial, &par, "global kernel-thread knob");
}

#[test]
fn padded_zero_columns_small_path_quad_aligned() {
    // Small-product path: quad-aligned zero padding is skipped, so the
    // result is bitwise the unpadded product and NaN rows of B under
    // the padding are never touched.
    let mut rng = Rng::new(9009);
    let (m, k, pad, n) = (10, 8, 8, 6);
    let a = Matrix::randn(m, k, &mut rng);
    let b = Matrix::randn(k, n, &mut rng);
    let a_pad = a.hcat(&Matrix::zeros(m, pad));
    let mut b_pad = Matrix::zeros(k + pad, n);
    b_pad.set_block(0, 0, &b);
    for i in k..k + pad {
        for v in b_pad.row_mut(i) {
            *v = f64::NAN;
        }
    }
    let got = matmul(&a_pad, &b_pad);
    assert!(got.is_finite(), "NaN leaked through quad-aligned padding");
    assert_bitwise(&got, &matmul(&a, &b), "small-path padded product");
}

#[test]
fn padded_zero_columns_packed_path_any_alignment() {
    // Packed path: the micro-kernel skips any all-zero A depth column
    // regardless of alignment (strictly stronger than the seed quad
    // skip) — NaN under non-quad-aligned padding stays quarantined.
    let mut rng = Rng::new(9011);
    let (m, k, pad, n) = (96, 61, 35, 96); // 61 is not a multiple of 4
    let a = Matrix::randn(m, k, &mut rng);
    let b = Matrix::randn(k, n, &mut rng);
    let a_pad = a.hcat(&Matrix::zeros(m, pad));
    let mut b_pad = Matrix::zeros(k + pad, n);
    b_pad.set_block(0, 0, &b);
    for i in k..k + pad {
        for v in b_pad.row_mut(i) {
            *v = f64::NAN;
        }
    }
    let got = matmul(&a_pad, &b_pad);
    assert!(got.is_finite(), "NaN leaked through non-aligned padding");
    assert_bitwise(&got, &matmul(&a, &b), "packed-path padded product");
    // Threaded over the padded input too.
    let par = with_threads(Op::N(a_pad.view()), Op::N(b_pad.view()), 3);
    assert_bitwise(&got, &par, "packed-path padded product, threaded");
}

#[test]
fn scaled_tn_kernel_matches_explicit_diag_and_is_deterministic() {
    let mut rng = Rng::new(9013);
    for &(rows, p, q) in &[(1usize, 1usize, 1usize), (17, 5, 9), (200, 20, 12)] {
        let a = Matrix::randn(rows, p, &mut rng);
        let b = Matrix::randn(rows, q, &mut rng);
        let mut s = rng.normal_vec(rows);
        if rows > 2 {
            s[1] = 0.0; // zero-weight rows are skipped
        }
        let alpha = 1.0 / rows as f64;
        let mut c1 = Matrix::zeros(p, q);
        matmul_tn_scaled_into(&a, &b, &s, alpha, &mut c1, 0.0);
        // Reference: scale B's rows explicitly, then Aᵀ·B.
        let mut sb = b.clone();
        for i in 0..rows {
            let w = alpha * s[i];
            for v in sb.row_mut(i) {
                *v *= w;
            }
        }
        assert_close(&c1, &matmul_tn(&a, &sb), rows, &format!("scaled_tn ({rows},{p},{q})"));
        // Serial kernel: repeated calls are bitwise reproducible.
        let mut c2 = Matrix::zeros(p, q);
        matmul_tn_scaled_into(&a, &b, &s, alpha, &mut c2, 0.0);
        assert_bitwise(&c1, &c2, "scaled_tn repeatability");
    }
}

#[test]
fn gram_matches_tn_and_handles_zero_columns() {
    let mut rng = Rng::new(9015);
    for &(m, n) in &[(1usize, 1usize), (40, 7), (13, 13), (5, 31)] {
        let mut a = Matrix::randn(m, n, &mut rng);
        if n > 2 {
            for i in 0..m {
                a[(i, n / 2)] = 0.0; // zero column exercises the skip
            }
        }
        let g = gram(&a);
        assert_close(&g, &matmul_tn(&a, &a), m, &format!("gram ({m},{n})"));
        for p in 0..n {
            for q in 0..n {
                assert_eq!(g[(p, q)].to_bits(), g[(q, p)].to_bits(), "gram symmetry");
            }
        }
    }
}

#[test]
fn beta_accumulation_is_consistent_across_paths() {
    // C = β·C + A·B must hold on both the small and packed paths.
    let mut rng = Rng::new(9017);
    for &(m, k, n) in &[(6usize, 7usize, 5usize), (120, 110, 90)] {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let c0 = Matrix::randn(m, n, &mut rng);
        let mut c = c0.clone();
        fedlrt::tensor::matmul_into(&a, &b, &mut c, 0.5);
        let want = c0.scale(0.5).add(&naive(&a, &b));
        assert_close(&c, &want, k, &format!("beta nn ({m},{k},{n})"));

        let at = Matrix::randn(k, m, &mut rng);
        let mut c = c0.clone();
        matmul_tn_into(&at, &b, &mut c, 1.0);
        let want = c0.add(&naive(&at.t(), &b));
        assert_close(&c, &want, k, &format!("beta tn ({m},{k},{n})"));

        let bt = Matrix::randn(n, k, &mut rng);
        let mut c = c0.clone();
        matmul_nt_into(&a, &bt, &mut c, 1.0);
        let want = c0.add(&naive(&a, &bt.t()));
        assert_close(&c, &want, k, &format!("beta nt ({m},{k},{n})"));
    }
}

#[test]
fn qr_flat_workspace_matches_fresh_and_stays_orthonormal() {
    // The flat-reflector QR must be insensitive to workspace reuse:
    // interleave shapes, rerun, compare bitwise against a fresh call.
    let mut rng = Rng::new(9019);
    let mut ws = Workspace::new();
    for &(m, n) in &[(30usize, 6usize), (64, 64), (9, 12), (30, 6), (200, 16)] {
        let a = Matrix::randn(m, n, &mut rng);
        let (q_fresh, r_fresh) = qr_thin(&a);
        let (q_ws, r_ws) = qr_thin_ws(&a, &mut ws);
        assert_bitwise(&q_fresh, &q_ws, &format!("qr Q ({m},{n})"));
        assert_bitwise(&r_fresh, &r_ws, &format!("qr R ({m},{n})"));
        assert!(orthonormality_error(&q_ws) < 1e-9, "({m},{n})");
    }
}
