//! Executor determinism: the thread-pool executor must reproduce the
//! serial executor's `RunRecord` **bitwise** — same loss, rank, and
//! communication trajectories — for every coordinator, across seeds and
//! scheduling stressors (partial participation, dropout, stragglers).
//!
//! This is the engine's core contract: parallelism may only change
//! wall-clock, never a single bit of the training trajectory.

use fedlrt::coordinator::{
    run_dense, run_fedlr, run_fedlrt, run_fedlrt_naive, DenseAlgo, RankConfig, TrainConfig,
    VarCorrection,
};
use fedlrt::engine::ExecutorKind;
use fedlrt::metrics::RunRecord;
use fedlrt::models::least_squares::LeastSquares;
use fedlrt::models::mlp::{MlpOptions, MlpProblem};
use fedlrt::opt::LrSchedule;
use fedlrt::util::rng::Rng;

/// Bitwise comparison of everything deterministic in a round record
/// (wall-clock fields are timing measurements and legitimately differ).
fn assert_trajectories_identical(a: &RunRecord, b: &RunRecord, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round counts differ");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            x.global_loss.to_bits(),
            y.global_loss.to_bits(),
            "{what}: loss differs at round {} ({} vs {})",
            x.round,
            x.global_loss,
            y.global_loss
        );
        assert_eq!(x.ranks, y.ranks, "{what}: ranks differ at round {}", x.round);
        assert_eq!(x.comm_floats, y.comm_floats, "{what}: comm differs at round {}", x.round);
        assert_eq!(
            x.comm_floats_lr, y.comm_floats_lr,
            "{what}: lr comm differs at round {}",
            x.round
        );
        assert_eq!(
            x.comm_floats_per_client.to_bits(),
            y.comm_floats_per_client.to_bits(),
            "{what}: per-client comm differs at round {}",
            x.round
        );
        assert_eq!(x.bytes_down, y.bytes_down, "{what}: bytes_down differs at round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "{what}: bytes_up differs at round {}", x.round);
        assert_eq!(x.fault, y.fault, "{what}: fault counters differ at round {}", x.round);
        match (x.dist_to_opt, y.dist_to_opt) {
            (Some(dx), Some(dy)) => assert_eq!(
                dx.to_bits(),
                dy.to_bits(),
                "{what}: dist-to-opt differs at round {}",
                x.round
            ),
            (None, None) => {}
            _ => panic!("{what}: dist-to-opt presence differs at round {}", x.round),
        }
    }
}

fn lsq_cfg(seed: u64, executor: ExecutorKind) -> TrainConfig {
    TrainConfig {
        rounds: 8,
        local_iters: 6,
        lr: LrSchedule::Constant(5e-3),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 3, max_rank: 6, tau: 0.05 },
        seed,
        executor,
        ..TrainConfig::default()
    }
}

#[test]
fn prop_fedlrt_serial_equals_thread_pool_across_seeds() {
    // The ISSUE's property: identical loss/rank/comm trajectories on a
    // small least-squares problem across ≥3 seeds and all vc modes.
    for seed in [11u64, 12, 13] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::homogeneous(10, 3, 400, 6, &mut rng);
        for vc in [VarCorrection::None, VarCorrection::Simplified, VarCorrection::Full] {
            let mut cfg_serial = lsq_cfg(seed, ExecutorKind::Serial);
            cfg_serial.var_correction = vc;
            let mut cfg_pool = cfg_serial.clone();
            cfg_pool.executor = ExecutorKind::ThreadPool { threads: 4 };
            let a = run_fedlrt(&prob, &cfg_serial, "det");
            let b = run_fedlrt(&prob, &cfg_pool, "det");
            assert_trajectories_identical(&a, &b, &format!("fedlrt/{}/seed{seed}", vc.label()));
        }
    }
}

#[test]
fn determinism_survives_scheduling_stressors() {
    // Partial participation + dropout + stragglers: the round plans are
    // irregular, yet serial and parallel execution still agree bitwise.
    for seed in [21u64, 22, 23] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::heterogeneous(8, 320, 8, &mut rng);
        let mut cfg_serial = lsq_cfg(seed, ExecutorKind::Serial);
        cfg_serial.participation = 0.6;
        cfg_serial.dropout = 0.25;
        cfg_serial.straggler_jitter = 0.5;
        let mut cfg_pool = cfg_serial.clone();
        cfg_pool.executor = ExecutorKind::ThreadPool { threads: 3 };
        let a = run_fedlrt(&prob, &cfg_serial, "det");
        let b = run_fedlrt(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &format!("fedlrt-stressed/seed{seed}"));
    }
}

#[test]
fn dense_baselines_serial_equals_thread_pool() {
    for seed in [31u64, 32, 33] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::homogeneous(8, 2, 320, 5, &mut rng);
        for algo in [DenseAlgo::FedAvg, DenseAlgo::FedLin] {
            let cfg_serial = lsq_cfg(seed, ExecutorKind::Serial);
            let cfg_pool = lsq_cfg(seed, ExecutorKind::ThreadPool { threads: 4 });
            let a = run_dense(&prob, &cfg_serial, algo, "det");
            let b = run_dense(&prob, &cfg_pool, algo, "det");
            assert_trajectories_identical(&a, &b, &format!("{}/seed{seed}", algo.label()));
        }
    }
}

#[test]
fn fedlr_baseline_serial_equals_thread_pool() {
    for seed in [41u64, 42, 43] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::homogeneous(8, 2, 320, 5, &mut rng);
        let cfg_serial = lsq_cfg(seed, ExecutorKind::Serial);
        let cfg_pool = lsq_cfg(seed, ExecutorKind::ThreadPool { threads: 2 });
        let a = run_fedlr(&prob, &cfg_serial, "det");
        let b = run_fedlr(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &format!("fedlr/seed{seed}"));
    }
}

#[test]
fn naive_baseline_serial_equals_thread_pool() {
    for seed in [51u64, 52, 53] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::homogeneous(8, 2, 320, 4, &mut rng);
        let cfg_serial = lsq_cfg(seed, ExecutorKind::Serial);
        let cfg_pool = lsq_cfg(seed, ExecutorKind::ThreadPool { threads: 8 });
        let a = run_fedlrt_naive(&prob, &cfg_serial, "det");
        let b = run_fedlrt_naive(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &format!("naive/seed{seed}"));
    }
}

#[test]
fn every_codec_preserves_executor_determinism() {
    // The wire codec runs on the coordinator thread in plan order, so
    // serial ≡ thread-pool must hold bitwise for lossy codecs too —
    // across all four coordinators, under scheduling stressors.
    use fedlrt::comm::ALL_CODECS;
    for codec in ALL_CODECS {
        let mut rng = Rng::new(91);
        let prob = LeastSquares::heterogeneous(8, 320, 6, &mut rng);
        let mut cfg_serial = lsq_cfg(91, ExecutorKind::Serial);
        cfg_serial.codec = codec;
        cfg_serial.participation = 0.7;
        cfg_serial.dropout = 0.2;
        cfg_serial.straggler_jitter = 0.3;
        let mut cfg_pool = cfg_serial.clone();
        cfg_pool.executor = ExecutorKind::ThreadPool { threads: 3 };
        let label = |algo: &str| format!("{algo}/codec={}", codec.label());

        let a = run_fedlrt(&prob, &cfg_serial, "det");
        let b = run_fedlrt(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &label("fedlrt"));

        for algo in [DenseAlgo::FedAvg, DenseAlgo::FedLin] {
            let a = run_dense(&prob, &cfg_serial, algo, "det");
            let b = run_dense(&prob, &cfg_pool, algo, "det");
            assert_trajectories_identical(&a, &b, &label(algo.label()));
        }

        let a = run_fedlr(&prob, &cfg_serial, "det");
        let b = run_fedlr(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &label("fedlr"));

        let a = run_fedlrt_naive(&prob, &cfg_serial, "det");
        let b = run_fedlrt_naive(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &label("fedlrt_naive"));
    }
}

fn tiny_mlp(seed: u64) -> MlpProblem {
    MlpProblem::new(MlpOptions {
        d_in: 16,
        hidden: vec![24, 16],
        classes: 4,
        num_clients: 4,
        train_n: 384,
        test_n: 96,
        eval_cap: 256,
        batch: 32,
        seed,
        augment: true,
        dirichlet_alpha: None,
    })
}

fn mlp_cfg(seed: u64, vc: VarCorrection) -> TrainConfig {
    TrainConfig {
        rounds: 4,
        local_iters: 4,
        lr: LrSchedule::Constant(0.05),
        var_correction: vc,
        rank: RankConfig { initial_rank: 4, max_rank: 8, tau: 0.05 },
        seed,
        eval_every: 2,
        ..TrainConfig::default()
    }
}

#[test]
fn mlp_backend_serial_equals_thread_pool_across_vc_modes() {
    // The native multi-layer backend is stochastic (mini-batches,
    // augmentation) AND carries dense params through the fast path —
    // serial vs thread-pool must still agree bitwise for every
    // variance-correction mode, and the trajectories must be finite.
    let prob = tiny_mlp(3);
    for vc in [VarCorrection::None, VarCorrection::Simplified, VarCorrection::Full] {
        let cfg_serial = mlp_cfg(3, vc);
        let mut cfg_pool = cfg_serial.clone();
        cfg_pool.executor = ExecutorKind::ThreadPool { threads: 3 };
        let a = run_fedlrt(&prob, &cfg_serial, "det");
        let b = run_fedlrt(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &format!("mlp-fedlrt/{}", vc.label()));
        for r in &a.rounds {
            assert!(r.global_loss.is_finite(), "{}: loss diverged", vc.label());
        }
    }
}

#[test]
fn mlp_backend_every_codec_preserves_executor_determinism() {
    use fedlrt::comm::ALL_CODECS;
    let prob = tiny_mlp(5);
    for codec in ALL_CODECS {
        let mut cfg_serial = mlp_cfg(5, VarCorrection::Simplified);
        cfg_serial.codec = codec;
        cfg_serial.straggler_jitter = 0.4;
        let mut cfg_pool = cfg_serial.clone();
        cfg_pool.executor = ExecutorKind::ThreadPool { threads: 4 };
        let a = run_fedlrt(&prob, &cfg_serial, "det");
        let b = run_fedlrt(&prob, &cfg_pool, "det");
        assert_trajectories_identical(&a, &b, &format!("mlp-fedlrt/codec={}", codec.label()));

        let c = run_dense(&prob, &cfg_serial, DenseAlgo::FedLin, "det");
        let d = run_dense(&prob, &cfg_pool, DenseAlgo::FedLin, "det");
        assert_trajectories_identical(&c, &d, &format!("mlp-fedlin/codec={}", codec.label()));
        assert!(c.final_loss().is_finite());
    }
}

#[test]
fn mlp_backend_descends_under_fedlrt_and_dense() {
    // Cross-backend sanity: both FeDLRT (any vc) and the dense
    // baselines make real progress on the MLP — descending, finite
    // losses and above-chance accuracy trends after a few rounds.
    let prob = tiny_mlp(7);
    let mut cfg = mlp_cfg(7, VarCorrection::Simplified);
    cfg.rounds = 10;
    cfg.local_iters = 8;
    cfg.eval_every = 1; // dense baselines only record losses at evals
    let lrt = run_fedlrt(&prob, &cfg, "descent");
    assert!(
        lrt.final_loss() < lrt.rounds[0].global_loss,
        "fedlrt did not descend: {} -> {}",
        lrt.rounds[0].global_loss,
        lrt.final_loss()
    );
    let avg = run_dense(&prob, &cfg, DenseAlgo::FedAvg, "descent");
    assert!(
        avg.final_loss() < avg.rounds[0].global_loss,
        "fedavg did not descend: {} -> {}",
        avg.rounds[0].global_loss,
        avg.final_loss()
    );
    for rec in [&lrt, &avg] {
        for r in &rec.rounds {
            assert!(r.global_loss.is_finite());
        }
    }
}

#[test]
fn thread_count_does_not_matter() {
    // Any worker count — including more workers than clients — yields
    // the serial trajectory.
    let mut rng = Rng::new(61);
    let prob = LeastSquares::homogeneous(10, 3, 400, 6, &mut rng);
    let reference = run_fedlrt(&prob, &lsq_cfg(61, ExecutorKind::Serial), "det");
    for threads in [0usize, 1, 2, 5, 16] {
        let cfg = lsq_cfg(61, ExecutorKind::ThreadPool { threads });
        let rec = run_fedlrt(&prob, &cfg, "det");
        assert_trajectories_identical(&reference, &rec, &format!("threads={threads}"));
    }
}

#[test]
fn telemetry_is_a_bitwise_noop_on_trajectories() {
    // Observability is observe-only: running the same training with the
    // no-op recorder, the default recorder, and full trace capture must
    // yield bitwise-identical trajectories — under both executors.
    use fedlrt::coordinator::run_fedlrt_obs;
    use fedlrt::obsv::Recorder;
    let mut rng = Rng::new(81);
    let prob = LeastSquares::heterogeneous(8, 320, 5, &mut rng);
    for executor in [ExecutorKind::Serial, ExecutorKind::ThreadPool { threads: 3 }] {
        let cfg = lsq_cfg(81, executor);
        let off = run_fedlrt_obs(&prob, &cfg, "det", &Recorder::disabled());
        let on = run_fedlrt_obs(&prob, &cfg, "det", &Recorder::new());
        let traced = run_fedlrt_obs(&prob, &cfg, "det", &Recorder::with_trace());
        assert_trajectories_identical(&off, &on, "telemetry off vs on");
        assert_trajectories_identical(&off, &traced, "telemetry off vs --trace");
        // The disabled recorder reports nothing; the others report
        // every round.
        assert!(off.rounds.iter().all(|r| r.phase_s.sum() == 0.0 && r.latency.n == 0));
        assert!(on.rounds.iter().all(|r| r.phase_s.sum() > 0.0 && r.latency.n == 5));
    }
}

fn async_cfg(seed: u64, schedule: fedlrt::coordinator::Schedule) -> TrainConfig {
    use fedlrt::engine::{Dist, TimingModel};
    let mut cfg = TrainConfig {
        rounds: 10,
        local_iters: 4,
        lr: LrSchedule::Constant(5e-3),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 3, max_rank: 6, tau: 0.05 },
        seed,
        schedule,
        ..TrainConfig::default()
    };
    cfg.async_cfg.buffer_k = 4;
    cfg.async_cfg.concurrency = 8;
    cfg.async_cfg.basis_every = 2;
    cfg.timing = TimingModel {
        arrival: Dist::Uniform { lo: 0.02, hi: 0.15 },
        compute: Dist::LogNormal { mu: 0.0, sigma: 0.5 },
        link: Dist::Uniform { lo: 0.01, hi: 0.05 },
        het_sigma: 0.4,
    };
    cfg
}

#[test]
fn async_server_serial_equals_thread_pool_across_seeds_and_policies() {
    // The tentpole's determinism contract: for both async aggregation
    // policies, a fixed seed yields bitwise-identical event traces,
    // loss/rank/byte trajectories, AND staleness histograms at any
    // executor — across ≥3 seeds.
    use fedlrt::coordinator::{run_async_traced, Schedule};
    use fedlrt::obsv::Recorder;
    for seed in [101u64, 102, 103] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::heterogeneous(8, 320, 6, &mut rng);
        for schedule in [Schedule::FedBuff, Schedule::AsyncStale] {
            let cfg_serial = async_cfg(seed, schedule);
            let mut cfg_pool = cfg_serial.clone();
            cfg_pool.executor = ExecutorKind::ThreadPool { threads: 3 };
            let what = format!("async/{}/seed{seed}", schedule.label());
            let (a, trace_a) = run_async_traced(&prob, &cfg_serial, "det", &Recorder::new());
            let (b, trace_b) = run_async_traced(&prob, &cfg_pool, "det", &Recorder::new());
            assert_eq!(trace_a, trace_b, "{what}: event traces diverged");
            assert!(!trace_a.is_empty(), "{what}: empty event trace");
            assert_trajectories_identical(&a, &b, &what);
            for (x, y) in a.rounds.iter().zip(&b.rounds) {
                assert_eq!(
                    x.staleness, y.staleness,
                    "{what}: staleness summary differs at aggregation {}",
                    x.round
                );
                assert_eq!(
                    x.virtual_s.to_bits(),
                    y.virtual_s.to_bits(),
                    "{what}: virtual clock differs at aggregation {}",
                    x.round
                );
                // Every aggregation consumed exactly K updates.
                assert_eq!(x.staleness.n, 4, "{what}: buffer size violated");
            }
        }
    }
}

#[test]
fn async_server_kernel_thread_count_does_not_matter() {
    // The event order is tie-broken on (time, seq), so even the kernel
    // thread pool setting (which reorders nothing but perturbs timing)
    // cannot move the trajectory.
    use fedlrt::coordinator::{run_async, Schedule};
    let mut rng = Rng::new(111);
    let prob = LeastSquares::homogeneous(10, 3, 400, 5, &mut rng);
    let reference = run_async(&prob, &async_cfg(111, Schedule::FedBuff), "det");
    for threads in [1usize, 2, 5] {
        let mut cfg = async_cfg(111, Schedule::FedBuff);
        cfg.executor = ExecutorKind::ThreadPool { threads };
        let rec = run_async(&prob, &cfg, "det");
        assert_trajectories_identical(&reference, &rec, &format!("async-threads={threads}"));
    }
}

#[test]
fn async_population_exceeding_shards_stays_deterministic() {
    // A population far beyond the problem's data shards (clients map
    // onto shards modulo num_clients) still satisfies the contract.
    use fedlrt::coordinator::{run_async, Schedule};
    let mut rng = Rng::new(121);
    let prob = LeastSquares::homogeneous(8, 2, 320, 4, &mut rng);
    let mut cfg_serial = async_cfg(121, Schedule::AsyncStale);
    cfg_serial.population = 50_000;
    let mut cfg_pool = cfg_serial.clone();
    cfg_pool.executor = ExecutorKind::ThreadPool { threads: 4 };
    let a = run_async(&prob, &cfg_serial, "det");
    let b = run_async(&prob, &cfg_pool, "det");
    assert_trajectories_identical(&a, &b, "async-population-50k");
    assert!(a.final_loss().is_finite());
}

#[test]
fn faulty_transport_serial_equals_thread_pool_across_seeds() {
    // The robustness layer's determinism contract: fault fates are drawn
    // from per-(round, client, attempt) streams on the coordinator, so
    // loss/corruption/duplication/retries/quorum skips must reproduce
    // bitwise — trajectories AND per-round fault counters — at any
    // executor, across ≥3 seeds.
    use fedlrt::comm::{FaultModel, NetPolicy};
    use fedlrt::engine::Dist;
    let cases: [(&str, FaultModel, NetPolicy); 3] = [
        (
            "loss+retry",
            FaultModel { loss_prob: 0.3, ..FaultModel::default() },
            NetPolicy { retries: 2, ..NetPolicy::default() },
        ),
        (
            "loss+corrupt+dup+jitter",
            FaultModel {
                loss_prob: 0.2,
                corrupt_prob: 0.15,
                dup_prob: 0.1,
                delay: Dist::Uniform { lo: 0.0, hi: 0.05 },
            },
            NetPolicy { retries: 3, ..NetPolicy::default() },
        ),
        (
            "blackout+quorum",
            FaultModel { loss_prob: 0.6, ..FaultModel::default() },
            NetPolicy { quorum: 3, ..NetPolicy::default() },
        ),
    ];
    for seed in [131u64, 132, 133] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::heterogeneous(8, 320, 6, &mut rng);
        for (name, fault, policy) in &cases {
            let mut cfg_serial = lsq_cfg(seed, ExecutorKind::Serial);
            cfg_serial.fault = *fault;
            cfg_serial.net_policy = *policy;
            if policy.quorum > 0 {
                // Enough rounds that "some round skips" and "some round
                // survives" both hold with overwhelming probability at
                // 60% loss over 6 clients.
                cfg_serial.rounds = 16;
            }
            let mut cfg_pool = cfg_serial.clone();
            cfg_pool.executor = ExecutorKind::ThreadPool { threads: 3 };
            let what = format!("fedlrt-fault/{name}/seed{seed}");
            let a = run_fedlrt(&prob, &cfg_serial, "det");
            let b = run_fedlrt(&prob, &cfg_pool, "det");
            assert_trajectories_identical(&a, &b, &what);
            // The injected fault rates make silence statistically
            // impossible over 6 clients × 8 rounds.
            assert!(a.total_msgs_dropped() > 0, "{what}: no drops booked");
            if fault.corrupt_prob > 0.0 {
                let corrupt: u64 = a.rounds.iter().map(|r| r.fault.msgs_corrupt).sum();
                assert!(corrupt > 0, "{what}: no checksum rejections booked");
            }
            if policy.retries > 0 {
                assert!(a.total_bytes_retx() > 0, "{what}: no retransmitted bytes billed");
            }
            if policy.quorum > 0 {
                assert!(a.skipped_rounds() > 0, "{what}: 60% loss never broke quorum");
                assert!(a.skipped_rounds() < a.rounds.len(), "{what}: every round skipped");
            }
            assert!(a.final_loss().is_finite(), "{what}: diverged");
        }
    }
}

#[test]
fn async_faulty_transport_serial_equals_thread_pool_with_traces() {
    // Same contract for the event-driven server: retransmissions are
    // ordinary queue events, so the full event trace — including Retry
    // rows — must be identical between executors, seed by seed.
    use fedlrt::comm::{FaultModel, NetPolicy};
    use fedlrt::coordinator::{run_async_traced, EventKind, Schedule};
    use fedlrt::obsv::Recorder;
    for seed in [141u64, 142, 143] {
        let mut rng = Rng::new(seed);
        let prob = LeastSquares::heterogeneous(8, 320, 6, &mut rng);
        for schedule in [Schedule::FedBuff, Schedule::AsyncStale] {
            let mut cfg_serial = async_cfg(seed, schedule);
            cfg_serial.fault = FaultModel {
                loss_prob: 0.25,
                corrupt_prob: 0.1,
                dup_prob: 0.1,
                ..FaultModel::default()
            };
            cfg_serial.net_policy = NetPolicy { retries: 2, ..NetPolicy::default() };
            let mut cfg_pool = cfg_serial.clone();
            cfg_pool.executor = ExecutorKind::ThreadPool { threads: 3 };
            let what = format!("async-fault/{}/seed{seed}", schedule.label());
            let (a, trace_a) = run_async_traced(&prob, &cfg_serial, "det", &Recorder::new());
            let (b, trace_b) = run_async_traced(&prob, &cfg_pool, "det", &Recorder::new());
            assert_eq!(trace_a, trace_b, "{what}: event traces diverged");
            assert_trajectories_identical(&a, &b, &what);
            assert!(
                trace_a.iter().any(|row| row.kind == EventKind::Retry),
                "{what}: 25% loss with a retry budget produced no Retry events"
            );
            assert!(
                a.total_msgs_dropped() + a.total_bytes_retx() > 0,
                "{what}: no fault traffic booked"
            );
            assert!(a.final_loss().is_finite(), "{what}: diverged");
        }
    }
}

#[test]
fn executor_choice_is_recorded_in_config_echo() {
    let mut rng = Rng::new(71);
    let prob = LeastSquares::homogeneous(8, 2, 200, 2, &mut rng);
    let cfg = lsq_cfg(71, ExecutorKind::ThreadPool { threads: 2 });
    let rec = run_fedlrt(&prob, &cfg, "det");
    let echoed = rec.config.get("executor").and_then(|v| v.as_str().map(str::to_string));
    assert_eq!(echoed.as_deref(), Some("threads:2"));
    // Client-time accounting is populated under both executors.
    assert!(rec.total_client_serial_s() > 0.0);
    assert!(rec.total_client_wall_s() > 0.0);
}
