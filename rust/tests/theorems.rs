//! Theorem-validation tests: the paper's analysis, checked numerically.
//!
//! These run the actual algorithm machinery on quadratic problems with
//! known smoothness constant L = 1 and verify the bounds of §3.2 hold
//! (they are *bounds*, so the tests check the inequality direction, not
//! tightness).

use fedlrt::lowrank::{augment_basis, LowRank};
use fedlrt::models::quadratic::Quadratic;
use fedlrt::models::{FedProblem, LrWant, LrWeight, Weights};
use fedlrt::tensor::Matrix;
use fedlrt::util::rng::Rng;

/// Manual FeDLRT round pieces on a quadratic, exposing internals the
/// round engine hides — mirrors Algorithm 1 exactly.
struct Round {
    prob: Quadratic,
    aug_u: Matrix,
    aug_v: Matrix,
    s_tilde: Matrix,
}

fn setup(n: usize, r: usize, c: usize, seed: u64) -> Round {
    let mut rng = Rng::new(seed);
    let prob = Quadratic::random(n, r, c, &mut rng);
    let fac = LowRank::random_init(n, n, r, &mut rng);
    // Aggregate basis gradients at the current point.
    let w_t = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
    let mut g_u = Matrix::zeros(n, r);
    let mut g_v = Matrix::zeros(n, r);
    for cc in 0..c {
        let g = prob.grad(cc, &w_t, LrWant::Factors, 0);
        if let fedlrt::models::LrGrad::Factors { g_u: gu, g_v: gv, .. } = &g.lr[0] {
            g_u.axpy(1.0 / c as f64, gu);
            g_v.axpy(1.0 / c as f64, gv);
        }
    }
    let aug = augment_basis(&fac, &g_u, &g_v, 2 * r);
    Round { prob, aug_u: aug.u_tilde.clone(), aug_v: aug.v_tilde.clone(), s_tilde: aug.s_tilde }
}

/// Variance-corrected inner iterations (eq. 8) for client `c`.
fn corrected_iterations(
    round: &Round,
    c: usize,
    s_star: usize,
    lambda: f64,
) -> (Vec<Matrix>, Matrix) {
    let num_clients = round.prob.num_clients();
    // Correction term V_c = G_S̃ − G_S̃,c at the augmented start point.
    let w0 = Weights {
        dense: vec![],
        lr: vec![LrWeight::Factored(LowRank {
            u: round.aug_u.clone(),
            s: round.s_tilde.clone(),
            v: round.aug_v.clone(),
        })],
    };
    let per: Vec<Matrix> = (0..num_clients)
        .map(|cc| round.prob.grad(cc, &w0, LrWant::Coeff, 0).lr[0].coeff().clone())
        .collect();
    let mut g_mean = Matrix::zeros(per[0].rows(), per[0].cols());
    for g in &per {
        g_mean.axpy(1.0 / num_clients as f64, g);
    }
    let v_c = g_mean.sub(&per[c]);

    let mut s_c = round.s_tilde.clone();
    let mut iterates = vec![s_c.clone()];
    for _ in 0..s_star {
        let w = Weights {
            dense: vec![],
            lr: vec![LrWeight::Factored(LowRank {
                u: round.aug_u.clone(),
                s: s_c.clone(),
                v: round.aug_v.clone(),
            })],
        };
        let g = round.prob.grad(c, &w, LrWant::Coeff, 0).lr[0].coeff().clone();
        let mut step = g;
        step.axpy(1.0, &v_c);
        s_c.axpy(-lambda, &step);
        iterates.push(s_c.clone());
    }
    (iterates, g_mean)
}

#[test]
fn theorem1_coefficient_drift_bound() {
    // ‖S̃_c^s − S̃‖ ≤ e·s*·λ·‖∇_S̃ L(Ũ S̃ Ṽᵀ)‖ for λ ≤ 1/(L s*), L = 1.
    for seed in [1, 2, 3] {
        let round = setup(12, 3, 4, seed);
        let s_star = 8;
        let lambda = 1.0 / s_star as f64; // exactly the theorem's edge
        for c in 0..4 {
            let (iterates, g_mean) = corrected_iterations(&round, c, s_star, lambda);
            let bound = std::f64::consts::E * s_star as f64 * lambda * g_mean.fro_norm();
            for (s, it) in iterates.iter().enumerate() {
                let drift = it.sub(&round.s_tilde).fro_norm();
                assert!(
                    drift <= bound + 1e-9,
                    "seed {seed} client {c} step {s}: drift {drift} > bound {bound}"
                );
            }
        }
    }
}

#[test]
fn theorem2_global_loss_descent() {
    // One full variance-corrected round must satisfy
    // L(W^{t+1}) − L(Wᵗ) ≤ −s*λ(1−12s*λL)‖∇_S̃L‖² + Lϑ.
    // We run the production engine with tiny λ and ϑ=0 (tau=0) and check
    // monotone descent round over round.
    use fedlrt::coordinator::{run_fedlrt, RankConfig, TrainConfig, VarCorrection};
    use fedlrt::opt::LrSchedule;
    let mut rng = Rng::new(77);
    let prob = Quadratic::random(10, 2, 4, &mut rng);
    let s_star = 5usize;
    let lambda = 1.0 / (12.0 * s_star as f64); // theorem's λ ≤ 1/(12 L s*)
    let cfg = TrainConfig {
        rounds: 20,
        local_iters: s_star,
        lr: LrSchedule::Constant(lambda),
        var_correction: VarCorrection::Full,
        rank: RankConfig { initial_rank: 2, max_rank: 4, tau: 0.0 },
        seed: 5,
        ..TrainConfig::default()
    };
    let rec = run_fedlrt(&prob, &cfg, "thm2");
    for w in rec.rounds.windows(2) {
        assert!(
            w[1].global_loss <= w[0].global_loss + 1e-12,
            "descent violated: {} -> {}",
            w[0].global_loss,
            w[1].global_loss
        );
    }
}

#[test]
fn theorem2_descent_magnitude_on_first_round() {
    // Quantitative check of the descent constant on one round, where we
    // can compute ‖∇_S̃ L(Ũ S̃ Ṽᵀ)‖ explicitly.
    let round = setup(10, 2, 3, 99);
    let s_star = 6;
    let lambda = 1.0 / (12.0 * s_star as f64);
    let num_clients = round.prob.num_clients();

    let loss_at = |s: &Matrix| -> f64 {
        round.prob.global_loss(&Weights {
            dense: vec![],
            lr: vec![LrWeight::Factored(LowRank {
                u: round.aug_u.clone(),
                s: s.clone(),
                v: round.aug_v.clone(),
            })],
        })
    };
    let l_before = loss_at(&round.s_tilde);
    // All clients iterate; server averages (no truncation, ϑ=0).
    let mut s_star_mean =
        Matrix::zeros(round.s_tilde.rows(), round.s_tilde.cols());
    let mut grad_norm = 0.0;
    for c in 0..num_clients {
        let (iterates, g_mean) = corrected_iterations(&round, c, s_star, lambda);
        grad_norm = g_mean.fro_norm();
        s_star_mean.axpy(1.0 / num_clients as f64, iterates.last().unwrap());
    }
    let l_after = loss_at(&s_star_mean);
    let s_lambda = s_star as f64 * lambda;
    let promised = s_lambda * (1.0 - 12.0 * s_lambda) * grad_norm * grad_norm;
    assert!(
        l_after - l_before <= -promised + 1e-9,
        "descent {} shallower than theorem's {}",
        l_after - l_before,
        -promised
    );
}

#[test]
fn theorem3_convergence_to_stationary_point() {
    // min_t ‖∇_S̃L‖² ≤ (48L/T)(L(W¹) − L(W^{T+1})) + 48L²ϑ.
    // With ϑ=0 and T→larger the best gradient norm must shrink; we track
    // the coefficient gradient through the engine indirectly via loss
    // plateau: run long, assert the final loss is within 1e-6 of the
    // best rank-capped approximation's loss.
    use fedlrt::coordinator::{run_fedlrt, RankConfig, TrainConfig, VarCorrection};
    use fedlrt::opt::LrSchedule;
    let mut rng = Rng::new(123);
    // Homogeneous quadratic: all targets equal, rank 2 ≤ cap ⇒ L* = 0.
    let base = Quadratic::random(10, 2, 1, &mut rng);
    let prob = Quadratic {
        targets: vec![base.targets[0].clone(); 3],
        alphas: vec![1.0; 3],
        n: 10,
    };
    let s_star = 4usize;
    let cfg = TrainConfig {
        rounds: 200,
        local_iters: s_star,
        lr: LrSchedule::Constant(1.0 / (12.0 * s_star as f64)),
        var_correction: VarCorrection::Full,
        rank: RankConfig { initial_rank: 2, max_rank: 4, tau: 0.0 },
        seed: 6,
        eval_every: 10,
        ..TrainConfig::default()
    };
    let rec = run_fedlrt(&prob, &cfg, "thm3");
    assert!(
        rec.final_loss() < 1e-6,
        "should converge to the stationary point (L*=0): {}",
        rec.final_loss()
    );
}

#[test]
fn mlp_full_batch_descent_with_variance_correction() {
    // Theorem-2-style descent on the native multi-layer backend: with
    // full-batch client gradients (batch = shard size, no augmentation
    // ⇒ deterministic), full variance correction, τ = 0 and a small
    // step size, FeDLRT's global loss must trend monotonically down —
    // ReLU kinks permit only tiny per-round upticks.
    use fedlrt::coordinator::{run_fedlrt, RankConfig, TrainConfig, VarCorrection};
    use fedlrt::models::mlp::{MlpOptions, MlpProblem};
    use fedlrt::opt::LrSchedule;
    let prob = MlpProblem::new(MlpOptions {
        d_in: 12,
        hidden: vec![16, 12],
        classes: 3,
        num_clients: 2,
        train_n: 128,
        test_n: 32,
        eval_cap: 128,
        batch: 64, // = shard size ⇒ one full batch per local step
        seed: 4,
        augment: false,
        dirichlet_alpha: None,
    });
    let cfg = TrainConfig {
        rounds: 12,
        local_iters: 4,
        lr: LrSchedule::Constant(0.02),
        var_correction: VarCorrection::Full,
        rank: RankConfig { initial_rank: 4, max_rank: 8, tau: 0.0 },
        seed: 2,
        eval_every: 1,
        ..TrainConfig::default()
    };
    let rec = run_fedlrt(&prob, &cfg, "mlp_descent");
    let first = rec.rounds[0].global_loss;
    let last = rec.final_loss();
    assert!(last.is_finite() && first.is_finite());
    assert!(last < 0.95 * first, "no real descent: {first} -> {last}");
    for w in rec.rounds.windows(2) {
        assert!(
            w[1].global_loss <= w[0].global_loss + 0.05 * first.abs() + 1e-9,
            "descent trend violated: {} -> {}",
            w[0].global_loss,
            w[1].global_loss
        );
    }
}

#[test]
fn truncation_bias_scales_with_theta() {
    // Theorems 2–4 carry a +Lϑ term: the loss floor should scale with
    // the truncation tolerance. Compare two runs differing only in τ.
    use fedlrt::coordinator::{run_fedlrt, RankConfig, TrainConfig, VarCorrection};
    use fedlrt::opt::LrSchedule;
    let mut rng = Rng::new(321);
    let base = Quadratic::random(12, 6, 1, &mut rng); // full-ish rank target
    let prob = Quadratic {
        targets: vec![base.targets[0].clone(); 2],
        alphas: vec![1.0; 2],
        n: 12,
    };
    let mk = |tau: f64| TrainConfig {
        rounds: 120,
        local_iters: 4,
        lr: LrSchedule::Constant(0.02),
        var_correction: VarCorrection::Full,
        rank: RankConfig { initial_rank: 3, max_rank: 6, tau },
        seed: 9,
        eval_every: 20,
        ..TrainConfig::default()
    };
    let tight = run_fedlrt(&prob, &mk(1e-4), "theta").final_loss();
    let loose = run_fedlrt(&prob, &mk(0.3), "theta").final_loss();
    assert!(
        loose > tight,
        "larger ϑ must leave a larger loss floor: τ=0.3 → {loose}, τ=1e-4 → {tight}"
    );
}

#[test]
fn assumption1_delta_small_near_convergence() {
    // Assumption 1 (simplified vc): near a stationary point the
    // augmented-block gradient norm is close to the S-block norm. Verify
    // on a nearly-converged factorization.
    let mut rng = Rng::new(555);
    let base = Quadratic::random(10, 2, 1, &mut rng);
    let prob =
        Quadratic { targets: vec![base.targets[0].clone(); 3], alphas: vec![1.0; 3], n: 10 };
    // Start FROM the minimizer's best rank-2 approximation: ∇ ≈ 0.
    let fac = LowRank::from_dense(&prob.minimizer(), 2);
    let w = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
    let g = prob.grad(0, &w, LrWant::Factors, 0);
    if let fedlrt::models::LrGrad::Factors { g_u, g_v, g_s } = &g.lr[0] {
        let aug = augment_basis(&fac, g_u, g_v, 4);
        let w_aug = Weights {
            dense: vec![],
            lr: vec![LrWeight::Factored(aug.as_factorization())],
        };
        let g_aug = prob.grad(0, &w_aug, LrWant::Coeff, 0);
        let full_norm = g_aug.lr[0].coeff().fro_norm();
        let s_block_norm = g_aug.lr[0].coeff().block(2, 2).fro_norm();
        // δ-small: the augmented part carries little extra gradient.
        assert!(
            full_norm - s_block_norm <= 0.2 * full_norm + 1e-12,
            "Assumption 1 violated near convergence: full {full_norm}, S-block {s_block_norm}"
        );
        let _ = g_s;
    } else {
        unreachable!()
    }
}
