//! Property and failure-injection tests on the coordinator.
//!
//! Beyond the unit tests inside each module, these exercise the round
//! engines as black boxes: aggregation identities, communication
//! accounting against Table 1's formulas, robustness to adversarial
//! clients, and long-run invariants.

use fedlrt::comm::{faults, Network, Payload};
use fedlrt::coordinator::{
    run_dense, run_fedlrt, Aggregator, DenseAlgo, RankConfig, RobustAccum, TrainConfig,
    VarCorrection,
};
use fedlrt::lowrank::LowRank;
use fedlrt::models::quadratic::Quadratic;
use fedlrt::models::{FedProblem, Grads, LrGrad, LrWant, ProblemSpec, Weights};
use fedlrt::opt::LrSchedule;
use fedlrt::tensor::Matrix;
use fedlrt::util::prop;
use fedlrt::util::rng::Rng;

fn quick_cfg(rounds: usize, iters: usize, vc: VarCorrection, seed: u64) -> TrainConfig {
    TrainConfig {
        rounds,
        local_iters: iters,
        lr: LrSchedule::Constant(2e-2),
        var_correction: vc,
        rank: RankConfig { initial_rank: 2, max_rank: 6, tau: 0.05 },
        seed,
        ..TrainConfig::default()
    }
}

#[test]
fn prop_aggregation_identity_eq10() {
    // With shared bases, mean_c(Ũ S̃_c Ṽᵀ) == Ũ (mean_c S̃_c) Ṽᵀ — the
    // reason FeDLRT's aggregation preserves rank (eq. 10).
    prop::check(
        "eq10: factored mean == mean of factored",
        8,
        |rng, size| {
            let n = 4 + size;
            let r = 2 + rng.below(3);
            let u = fedlrt::linalg::random_orthonormal(n, r, rng);
            let v = fedlrt::linalg::random_orthonormal(n, r, rng);
            let coeffs: Vec<Matrix> = (0..4).map(|_| Matrix::randn(r, r, rng)).collect();
            (u, v, coeffs)
        },
        |(u, v, coeffs)| {
            let c = coeffs.len() as f64;
            let mut mean_dense = Matrix::zeros(u.rows(), v.rows());
            let mut mean_s = Matrix::zeros(coeffs[0].rows(), coeffs[0].cols());
            for s in coeffs {
                mean_dense.axpy(1.0 / c, &fedlrt::tensor::usv(u, s, v));
                mean_s.axpy(1.0 / c, s);
            }
            let via_coeff = fedlrt::tensor::usv(u, &mean_s, v);
            let diff = via_coeff.sub(&mean_dense).max_abs();
            if diff < 1e-10 {
                Ok(())
            } else {
                Err(format!("aggregation mismatch {diff}"))
            }
        },
    );
}

#[test]
fn comm_volume_matches_table1_formula() {
    // Per-round floats of the FeDLRT engine must equal the closed-form
    // protocol sum given the rank trajectory (single-layer problem).
    let mut rng = Rng::new(42);
    let prob = Quadratic::random(10, 2, 3, &mut rng);
    let n = 10u64;
    let c = 3u64;
    let rec = run_fedlrt(&prob, &quick_cfg(6, 3, VarCorrection::Simplified, 1), "acct");
    let mut r_prev = 2u64.min(10 / 2); // initial rank (cfg.initial_rank capped)
    for round in &rec.rounds {
        let r = r_prev;
        let a = r; // augmentation adds a = r directions (2r total)
        let r2 = r + a;
        // Simplified vc, per round:
        //   down: U,V (2nr) + S_diag (r) + Ū,V̄ (2na) + G_S (r²)
        //   up:   C·(G_U,G_V = 2nr) + C·G_S (r²) + C·S̃_c (r2²)
        let down = 2 * n * r + r + 2 * n * a + r * r;
        let up = c * (2 * n * r) + c * (r * r) + c * (r2 * r2);
        let want = down + up;
        assert_eq!(
            round.comm_floats, want,
            "round {}: accounting mismatch (r={r})",
            round.round
        );
        r_prev = round.ranks[0] as u64;
    }
}

/// Run one slot of updates through a [`RobustAccum`] and return the
/// aggregate (accumulator starts at zero).
fn reduce(agg: Aggregator, updates: &[(f64, Matrix)]) -> Matrix {
    let mut acc = Matrix::zeros(updates[0].1.rows(), updates[0].1.cols());
    let mut robust = RobustAccum::new(agg, 1);
    for (w, x) in updates {
        robust.push(0, &mut acc, *w, x);
    }
    robust.finish(std::slice::from_mut(&mut acc));
    acc
}

fn all_aggregators() -> [Aggregator; 4] {
    [
        Aggregator::Mean,
        Aggregator::TrimmedMean { trim: 0.25 },
        Aggregator::Median,
        Aggregator::NormClip { mult: 2.0 },
    ]
}

#[test]
fn prop_aggregators_reduce_to_weighted_mean_without_outliers() {
    // Contract 1 (see aggregate.rs): on outlier-free inputs every
    // aggregator returns the weighted mean. Two regimes:
    //  * zero spread (all clients upload the same update): every rule
    //    must return exactly that update;
    //  * genuine spread but inactive defenses (trim cuts nobody, clip
    //    radius never binds): the robust fold must match the mean fold
    //    to floating-point reassociation accuracy.
    prop::check(
        "aggregators reduce to weighted mean",
        10,
        |rng, size| {
            let k = 2 + rng.below(5);
            let (r, c) = (1 + rng.below(3), 1 + size.min(4));
            let raw: Vec<f64> = (0..k).map(|_| rng.uniform_in(0.1, 1.0)).collect();
            let wsum: f64 = raw.iter().sum();
            let updates: Vec<(f64, Matrix)> =
                raw.iter().map(|w| (w / wsum, Matrix::randn(r, c, rng))).collect();
            updates
        },
        |updates| {
            // Zero-spread roster: everyone uploads the first update.
            let same: Vec<(f64, Matrix)> =
                updates.iter().map(|(w, _)| (*w, updates[0].1.clone())).collect();
            for agg in all_aggregators() {
                let diff = reduce(agg, &same).sub(&same[0].1).max_abs();
                if diff > 1e-9 {
                    return Err(format!("{} off identical uploads by {diff}", agg.label()));
                }
            }
            // Heterogeneous roster, defenses configured to be inactive.
            let mean = reduce(Aggregator::Mean, updates);
            for agg in
                [Aggregator::TrimmedMean { trim: 0.0 }, Aggregator::NormClip { mult: 1e12 }]
            {
                let diff = reduce(agg, updates).sub(&mean).max_abs();
                if diff > 1e-9 {
                    return Err(format!("inactive {} off the mean by {diff}", agg.label()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_robust_aggregators_are_permutation_invariant() {
    // Contract 2: client upload order must not change the aggregate.
    prop::check(
        "aggregation permutation invariance",
        10,
        |rng, size| {
            let k = 2 + rng.below(6);
            let (r, c) = (1 + rng.below(3), 1 + size.min(4));
            let updates: Vec<(f64, Matrix)> = (0..k)
                .map(|_| (rng.uniform_in(0.05, 1.0), Matrix::randn(r, c, rng)))
                .collect();
            // A Fisher–Yates shuffle of 0..k, derived from the same rng.
            let mut perm: Vec<usize> = (0..k).collect();
            for i in (1..k).rev() {
                perm.swap(i, rng.below(i + 1));
            }
            (updates, perm)
        },
        |(updates, perm)| {
            let shuffled: Vec<(f64, Matrix)> =
                perm.iter().map(|&i| updates[i].clone()).collect();
            for agg in all_aggregators() {
                let a = reduce(agg, updates);
                let b = reduce(agg, &shuffled);
                let diff = a.sub(&b).max_abs();
                if diff > 1e-9 {
                    return Err(format!(
                        "{} not permutation-invariant: diff {diff} under {perm:?}",
                        agg.label()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_checksum_frame_catches_every_single_byte_flip() {
    // CRC-32 detects every burst error of ≤ 32 bits, so corrupting any
    // single byte of the frame — header or payload — must fail verify,
    // while the intact frame round-trips.
    prop::check(
        "crc32 framing vs single-byte corruption",
        8,
        |rng, size| {
            let len = 1 + rng.below(32 * (1 + size));
            let payload: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            // One random nonzero XOR mask per byte position (a zero mask
            // would be no corruption at all).
            let masks: Vec<u8> =
                (0..payload.len() + faults::CHECKSUM_BYTES as usize)
                    .map(|_| 1 + (rng.next_u64() % 255) as u8)
                    .collect();
            (payload, masks)
        },
        |(payload, masks)| {
            let framed = faults::frame(payload);
            match faults::verify(&framed) {
                Some(got) if got == &payload[..] => {}
                _ => return Err("intact frame failed to verify".into()),
            }
            for (pos, mask) in masks.iter().enumerate() {
                let mut bad = framed.clone();
                bad[pos] ^= mask;
                if faults::verify(&bad).is_some() {
                    return Err(format!("flip of byte {pos} (mask {mask:#04x}) undetected"));
                }
            }
            // Truncated frames (shorter than the header) must also fail.
            if faults::verify(&framed[..faults::CHECKSUM_BYTES as usize - 1]).is_some() {
                return Err("truncated frame verified".into());
            }
            Ok(())
        },
    );
}

/// A problem wrapper that makes one client adversarial.
struct Adversarial<P: FedProblem> {
    inner: P,
    bad_client: usize,
    scale: f64,
}

impl<P: FedProblem> FedProblem for Adversarial<P> {
    fn spec(&self) -> ProblemSpec {
        self.inner.spec()
    }

    fn num_clients(&self) -> usize {
        self.inner.num_clients()
    }

    fn grad(&self, c: usize, w: &Weights, want: LrWant, step: u64) -> Grads {
        let mut g = self.inner.grad(c, w, want, step);
        if c == self.bad_client {
            for lr in &mut g.lr {
                match lr {
                    LrGrad::Dense(m) => m.scale_inplace(self.scale),
                    LrGrad::Coeff(m) => m.scale_inplace(self.scale),
                    LrGrad::Factors { g_u, g_v, g_s } => {
                        g_u.scale_inplace(self.scale);
                        g_v.scale_inplace(self.scale);
                        g_s.scale_inplace(self.scale);
                    }
                }
            }
        }
        g
    }

    fn global_loss(&self, w: &Weights) -> f64 {
        self.inner.global_loss(w)
    }
}

#[test]
fn failure_injection_scaled_client_stays_finite() {
    // One client reports 50× gradients (faulty preprocessing). The
    // protocol must stay numerically alive: orthonormal bases, finite
    // losses, rank within caps. (Robust *accuracy* under Byzantine
    // clients is out of the paper's scope — we assert no blow-up.)
    let mut rng = Rng::new(7);
    let prob = Adversarial {
        inner: Quadratic::random(10, 2, 4, &mut rng),
        bad_client: 2,
        scale: 50.0,
    };
    let mut cfg = quick_cfg(15, 4, VarCorrection::Full, 3);
    cfg.lr = LrSchedule::Constant(1e-3); // small enough for the 50× client
    let rec = run_fedlrt(&prob, &cfg, "inject");
    for r in &rec.rounds {
        assert!(r.global_loss.is_finite(), "loss diverged at round {}", r.round);
        assert!(r.ranks[0] >= 1 && r.ranks[0] <= 6);
    }
}

#[test]
fn failure_injection_zero_gradients_keep_orthonormal_bases() {
    // A stationary start (all-zero gradients): augmentation gets zero
    // new directions and must not corrupt the basis or crash the SVD.
    let mut rng = Rng::new(9);
    let base = Quadratic::random(8, 2, 1, &mut rng);
    let w_star = base.minimizer();
    // All clients share the same target => gradient at W* is exactly 0.
    let prob = Quadratic { targets: vec![w_star.clone(); 3], alphas: vec![1.0; 3], n: 8 };
    // Start AT the minimizer by initializing rank = rank(W*) via seed
    // search is fragile; instead run the engine and check late rounds
    // (it converges to the stationary point where gradients vanish).
    let mut cfg = quick_cfg(60, 4, VarCorrection::Full, 11);
    cfg.rank.tau = 1e-3;
    let rec = run_fedlrt(&prob, &cfg, "zero_grad");
    let final_loss = rec.final_loss();
    assert!(final_loss.is_finite());
    assert!(final_loss < 1e-4, "should be essentially converged: {final_loss}");
    // And the last rounds must not oscillate (stable at stationarity).
    let tail: Vec<f64> = rec.rounds.iter().rev().take(5).map(|r| r.global_loss).collect();
    for w in tail.windows(2) {
        assert!((w[0] - w[1]).abs() < 1e-4, "oscillation at stationarity: {tail:?}");
    }
}

#[test]
fn prop_engine_rank_and_orthonormality_invariants() {
    // Across random problems/configs: ranks always within [1, max_rank],
    // loss finite, comm strictly positive every round.
    prop::check(
        "engine invariants",
        6,
        |rng, size| {
            let n = 6 + size;
            let c = 1 + rng.below(4);
            let prob = Quadratic::random(n, 2, c, rng);
            let vc = match rng.below(3) {
                0 => VarCorrection::None,
                1 => VarCorrection::Simplified,
                _ => VarCorrection::Full,
            };
            let cfg = TrainConfig {
                rounds: 4 + rng.below(4),
                local_iters: 1 + rng.below(5),
                lr: LrSchedule::Constant(rng.uniform_in(1e-3, 3e-2)),
                var_correction: vc,
                rank: RankConfig {
                    initial_rank: 1 + rng.below(3),
                    max_rank: 2 + rng.below(4),
                    tau: rng.uniform_in(0.0, 0.2),
                },
                seed: rng.next_u64(),
                ..TrainConfig::default()
            };
            (prob, cfg)
        },
        |(prob, cfg)| {
            let rec = run_fedlrt(prob, cfg, "prop");
            for r in &rec.rounds {
                if !r.global_loss.is_finite() {
                    return Err(format!("round {}: non-finite loss", r.round));
                }
                if r.ranks[0] < 1 || r.ranks[0] > cfg.rank.max_rank {
                    return Err(format!("round {}: rank {} outside bounds", r.round, r.ranks[0]));
                }
                if r.comm_floats == 0 {
                    return Err("round with zero communication".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn single_client_fedlrt_equals_its_own_average() {
    // C=1: variance corrections are exactly zero (G = G_c), so all three
    // modes must produce identical trajectories.
    let mut rng = Rng::new(21);
    let prob = Quadratic::random(8, 2, 1, &mut rng);
    let a = run_fedlrt(&prob, &quick_cfg(8, 4, VarCorrection::None, 5), "c1");
    let b = run_fedlrt(&prob, &quick_cfg(8, 4, VarCorrection::Simplified, 5), "c1");
    let c = run_fedlrt(&prob, &quick_cfg(8, 4, VarCorrection::Full, 5), "c1");
    for ((x, y), z) in a.rounds.iter().zip(&b.rounds).zip(&c.rounds) {
        assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
        assert_eq!(x.global_loss.to_bits(), z.global_loss.to_bits());
    }
}

#[test]
fn fedavg_fedlin_identical_on_homogeneous_problem() {
    // Identical clients ⇒ corrections vanish ⇒ FedLin ≡ FedAvg except
    // communication (which doubles).
    let mut rng = Rng::new(23);
    let base = Quadratic::random(6, 2, 1, &mut rng);
    let prob = Quadratic { targets: vec![base.targets[0].clone(); 4], alphas: vec![1.0; 4], n: 6 };
    let cfg = quick_cfg(6, 3, VarCorrection::None, 2);
    let avg = run_dense(&prob, &cfg, DenseAlgo::FedAvg, "h");
    let lin = run_dense(&prob, &cfg, DenseAlgo::FedLin, "h");
    for (a, l) in avg.rounds.iter().zip(&lin.rounds) {
        assert!((a.global_loss - l.global_loss).abs() < 1e-12);
        assert!(l.comm_floats > a.comm_floats);
    }
}

#[test]
fn partial_participation_trains_and_cuts_upload() {
    // 50% participation: still converges on a homogeneous problem, and
    // the uplink volume halves (downlink broadcast is unchanged).
    let mut rng = Rng::new(71);
    let base = Quadratic::random(8, 2, 1, &mut rng);
    let prob = Quadratic { targets: vec![base.targets[0].clone(); 8], alphas: vec![1.0; 8], n: 8 };
    let mut cfg_full = quick_cfg(30, 4, VarCorrection::None, 4);
    cfg_full.lr = LrSchedule::Constant(3e-2);
    let mut cfg_half = cfg_full.clone();
    cfg_half.participation = 0.5;
    let full = run_fedlrt(&prob, &cfg_full, "part");
    let half = run_fedlrt(&prob, &cfg_half, "part");
    assert!(half.final_loss() < half.rounds[0].global_loss * 0.1, "half-participation must still train");
    assert!(
        (half.total_comm_floats() as f64) < full.total_comm_floats() as f64 * 0.85,
        "sampling should cut communication: {} vs {}",
        half.total_comm_floats(),
        full.total_comm_floats()
    );
}

#[test]
fn stragglers_do_not_break_convergence() {
    // Client-dependent s* (footnote 3): convergence survives 60% jitter.
    let mut rng = Rng::new(73);
    let base = Quadratic::random(8, 2, 1, &mut rng);
    let prob = Quadratic { targets: vec![base.targets[0].clone(); 4], alphas: vec![1.0; 4], n: 8 };
    let mut cfg = quick_cfg(40, 6, VarCorrection::Full, 4);
    cfg.lr = LrSchedule::Constant(3e-2);
    cfg.straggler_jitter = 0.6;
    let rec = run_fedlrt(&prob, &cfg, "straggle");
    assert!(rec.final_loss() < rec.rounds[0].global_loss * 0.05, "loss {}", rec.final_loss());
    // Determinism holds under the straggler model too.
    let rec2 = run_fedlrt(&prob, &cfg, "straggle");
    assert_eq!(rec.final_loss().to_bits(), rec2.final_loss().to_bits());
}

#[test]
fn network_round_trip_bookkeeping() {
    // Direct Network sanity over multiple interleavings.
    let mut net = Network::new(3);
    for _ in 0..4 {
        net.broadcast("a", &Payload::matrix(5, 2));
        net.aggregate("b", &Payload::matrix(5, 2));
        net.end_round_trip();
        net.aggregate("c", &Payload::Floats(7));
        net.end_round_trip();
        let round = net.end_round();
        assert_eq!(round.broadcast_floats, 10);
        assert_eq!(round.aggregate_floats, 30 + 21);
        assert_eq!(round.round_trips, 2);
        assert_eq!(round.floats_matching(|l| l == "c"), 21);
    }
    assert_eq!(net.rounds.len(), 4);
}

#[test]
fn padded_factorization_survives_round_trip_through_engine() {
    // Run the engine where max_rank collides with the problem dimension
    // — padding/unpadding edge cases (r = n/2).
    let mut rng = Rng::new(31);
    let prob = Quadratic::random(6, 3, 2, &mut rng);
    let mut cfg = quick_cfg(5, 2, VarCorrection::Full, 8);
    cfg.rank = RankConfig { initial_rank: 3, max_rank: 3, tau: 0.01 };
    let rec = run_fedlrt(&prob, &cfg, "edge");
    assert!(rec.final_loss().is_finite());
    assert!(rec.rounds.iter().all(|r| r.ranks[0] <= 3));
}

#[test]
fn lowrank_from_dense_roundtrip_under_engine_shapes() {
    // Supporting invariant used by the engines: LowRank::from_dense of
    // the engine's reconstruction reproduces the matrix (rank ≤ cap).
    prop::check(
        "from_dense∘to_dense == id on M_r",
        8,
        |rng, size| {
            let n = 4 + size;
            let r = 1 + rng.below(size.min(n / 2).max(1));
            LowRank::random_init(n, n, r, rng)
        },
        |f| {
            let back = LowRank::from_dense(&f.to_dense(), f.rank());
            let diff = back.to_dense().sub(&f.to_dense()).max_abs();
            if diff < 1e-8 {
                Ok(())
            } else {
                Err(format!("roundtrip diff {diff}"))
            }
        },
    );
}

/// Problem wrapper giving one client a larger aggregation weight.
struct Weighted<P: FedProblem> {
    inner: P,
    heavy: usize,
    weight: f64,
}

impl<P: FedProblem> FedProblem for Weighted<P> {
    fn spec(&self) -> ProblemSpec {
        self.inner.spec()
    }
    fn num_clients(&self) -> usize {
        self.inner.num_clients()
    }
    fn grad(&self, c: usize, w: &Weights, want: LrWant, step: u64) -> Grads {
        self.inner.grad(c, w, want, step)
    }
    fn global_loss(&self, w: &Weights) -> f64 {
        self.inner.global_loss(w)
    }
    fn distance_to_optimum(&self, w: &Weights) -> Option<f64> {
        self.inner.distance_to_optimum(w)
    }
    fn client_weight(&self, c: usize) -> f64 {
        if c == self.heavy {
            self.weight
        } else {
            1.0
        }
    }
}

#[test]
fn weighted_aggregation_pulls_toward_heavy_client() {
    // Heterogeneous quadratic: upweighting client 0's aggregation must
    // land closer to client 0's target than uniform weighting does.
    let mut rng = Rng::new(81);
    let inner = Quadratic::random(8, 2, 3, &mut rng);
    let target0 = inner.targets[0].clone();
    let uniform = run_fedlrt(&inner, &quick_cfg(40, 6, VarCorrection::Full, 4), "wt");
    let weighted_prob = Weighted { inner, heavy: 0, weight: 10.0 };
    let weighted = run_fedlrt(&weighted_prob, &quick_cfg(40, 6, VarCorrection::Full, 4), "wt");
    // Rebuild the final dense weight distance through the loss of client 0:
    // local loss at the final point = ½‖W − B₀‖², recovered via grad eval.
    let dist_to_target0 = |prob: &dyn Fn(usize) -> f64| prob(0);
    let _ = dist_to_target0;
    // Use the recorded distance-to-global-optimum as a proxy plus direct
    // construction: the weighted minimizer (10·B₀ + B₁ + B₂)/12 differs
    // from the uniform one; the weighted run must end closer to it.
    let w_uniform_min = weighted_prob.inner.minimizer();
    let mut heavy_min = target0.scale(10.0 / 12.0);
    heavy_min.axpy(1.0 / 12.0, &weighted_prob.inner.targets[1]);
    heavy_min.axpy(1.0 / 12.0, &weighted_prob.inner.targets[2]);
    // The recorded dist_to_opt is against the uniform minimizer, so:
    let d_uniform_run = uniform.rounds.last().unwrap().dist_to_opt.unwrap();
    let d_weighted_run = weighted.rounds.last().unwrap().dist_to_opt.unwrap();
    // The weighted run converges AWAY from the uniform minimizer…
    assert!(
        d_weighted_run > d_uniform_run + 1e-3,
        "weighted run should leave the uniform minimizer: {d_weighted_run} vs {d_uniform_run}"
    );
    // …by roughly the distance between the two minimizers.
    let gap = heavy_min.sub(&w_uniform_min).fro_norm();
    assert!(
        (d_weighted_run - gap).abs() < 0.5 * gap,
        "weighted run should sit near the weighted minimizer (gap {gap}, got {d_weighted_run})"
    );
}
