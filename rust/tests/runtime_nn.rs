//! Integration tests: Rust coordinator ↔ PJRT runtime ↔ AOT artifacts.
//!
//! These tests require `make artifacts` (they are the proof that all
//! three layers compose). They use the `test_tiny` model config so a
//! full federated round takes milliseconds.

use fedlrt::coordinator::{
    run_dense, run_fedlrt, DenseAlgo, RankConfig, TrainConfig, VarCorrection,
};
use fedlrt::models::{FedProblem, LrWant, LrWeight, Weights};
use fedlrt::nn::{NnOptions, NnProblem};
use fedlrt::opt::LrSchedule;
use fedlrt::runtime::Runtime;
use fedlrt::tensor::Matrix;
use fedlrt::util::rng::Rng;

/// The PJRT runtime, or `None` when the AOT artifacts have not been
/// built (or the `xla` backend is the offline stub). Tests *skip* in
/// that case rather than fail: these are the composition proofs for the
/// full three-layer stack, which only exists after `make artifacts`.
fn try_runtime() -> Option<Runtime> {
    match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime test — artifacts unavailable ({e})");
            None
        }
    }
}

fn tiny_problem(clients: usize, seed: u64) -> Option<NnProblem> {
    let mut rt = try_runtime()?;
    Some(
        NnProblem::new(
            &mut rt,
            NnOptions {
                config: "test_tiny".into(),
                num_clients: clients,
                train_n: 512,
                test_n: 128,
                eval_cap: 256,
                seed,
                augment: false,
                dirichlet_alpha: None,
            },
        )
        .expect("problem construction"),
    )
}

fn factored_weights(p: &NnProblem, rank: usize, seed: u64) -> Weights {
    let spec = p.spec();
    let mut rng = Rng::new(seed);
    let lr = spec
        .lr_shapes
        .iter()
        .map(|&(m, n)| {
            let mut f = fedlrt::lowrank::LowRank::random_init(m, n, rank, &mut rng);
            f.s.scale_inplace((1.0 / m as f64).sqrt());
            LrWeight::Factored(f)
        })
        .collect();
    let dense = spec
        .dense_shapes
        .iter()
        .map(|&(m, n)| {
            if m == 1 {
                Matrix::zeros(m, n)
            } else {
                Matrix::randn(m, n, &mut rng).scale((1.0 / m as f64).sqrt())
            }
        })
        .collect();
    Weights { dense, lr }
}

#[test]
fn artifact_gradients_match_finite_differences() {
    // The decisive cross-layer check: HLO-computed ∇_S̃ equals a finite
    // difference of the HLO-computed loss.
    let Some(p) = tiny_problem(2, 42) else { return };
    let w = factored_weights(&p, 3, 7);
    let g = p.grad(0, &w, LrWant::Coeff, 0);
    let g_s = g.lr[0].coeff().clone();
    assert_eq!(g_s.shape(), (3, 3));

    let eps = 1e-2_f64; // f32 artifacts ⇒ coarse step, relative check
    for &(i, j) in &[(0usize, 0usize), (1, 2), (2, 1)] {
        let perturb = |delta: f64| -> f64 {
            let mut wp = w.clone();
            if let LrWeight::Factored(f) = &mut wp.lr[0] {
                f.s[(i, j)] += delta;
            }
            p.grad(0, &wp, LrWant::Coeff, 0).loss
        };
        let fd = (perturb(eps) - perturb(-eps)) / (2.0 * eps);
        let an = g_s[(i, j)];
        assert!(
            (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
            "∂S[{i},{j}]: fd {fd} vs analytic {an}"
        );
    }
}

#[test]
fn factor_grads_respect_padding_invariant() {
    // Gradients beyond the active rank must be exactly zero (they are
    // sliced off, but the slice must equal the unpadded computation).
    let Some(p) = tiny_problem(2, 43) else { return };
    let w3 = factored_weights(&p, 3, 11);
    let g3 = p.grad(0, &w3, LrWant::Factors, 0);
    // Same factors padded by the coordinator to rank 4 (extra zero col).
    let w4 = Weights {
        dense: w3.dense.clone(),
        lr: w3
            .lr
            .iter()
            .map(|lw| LrWeight::Factored(lw.as_factored().pad_to(4)))
            .collect(),
    };
    let g4 = p.grad(0, &w4, LrWant::Factors, 0);
    assert!((g3.loss - g4.loss).abs() < 1e-6, "{} vs {}", g3.loss, g4.loss);
    match (&g3.lr[0], &g4.lr[0]) {
        (
            fedlrt::models::LrGrad::Factors { g_u: u3, g_s: s3, .. },
            fedlrt::models::LrGrad::Factors { g_u: u4, g_s: s4, .. },
        ) => {
            // Leading block matches; padded col of G_U is zero.
            assert!(u4.first_cols(3).sub(u3).max_abs() < 1e-5);
            assert!(s4.block(3, 3).sub(s3).max_abs() < 1e-5);
            for i in 0..u4.rows() {
                assert_eq!(u4[(i, 3)], 0.0, "padded G_U column must be 0");
            }
        }
        _ => unreachable!(),
    }
}

#[test]
fn fedlrt_trains_tiny_network_end_to_end() {
    let Some(p) = tiny_problem(4, 44) else { return };
    let cfg = TrainConfig {
        rounds: 12,
        local_iters: 8,
        lr: LrSchedule::Constant(5e-2),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 3, max_rank: p.max_rank(), tau: 0.03 },
        seed: 5,
        eval_every: 4,
        ..TrainConfig::default()
    };
    let rec = run_fedlrt(&p, &cfg, "it");
    let first = rec.rounds.first().unwrap().global_loss;
    let last = rec.final_loss();
    assert!(last < first, "loss should drop: {first} -> {last}");
    let acc = rec.final_metric().expect("accuracy metric");
    assert!(acc > 1.5 / 4.0, "accuracy {acc} ≤ chance (4 classes)");
    // Ranks stay within the artifact padding budget.
    for r in &rec.rounds {
        assert!(r.ranks.iter().all(|&x| x <= p.max_rank()));
    }
}

#[test]
fn dense_baseline_trains_through_artifacts() {
    let Some(p) = tiny_problem(2, 45) else { return };
    let cfg = TrainConfig {
        rounds: 8,
        local_iters: 8,
        lr: LrSchedule::Constant(5e-2),
        seed: 9,
        eval_every: 4,
        ..TrainConfig::default()
    };
    let rec = run_dense(&p, &cfg, DenseAlgo::FedLin, "it");
    assert!(rec.final_loss() < rec.rounds[0].global_loss);
    assert!(rec.final_metric().unwrap() > 0.25);
}

#[test]
fn eval_metric_bounded() {
    let Some(p) = tiny_problem(2, 46) else { return };
    let w = factored_weights(&p, 3, 3);
    let acc = p.eval_metric(&w).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn conv_stem_config_trains_through_artifacts() {
    // resnet18_conv: a convolutional stem lowered into the same HLO —
    // the closest structural analogue of the paper's CNN bodies.
    let Some(mut rt) = try_runtime() else { return };
    if !rt.manifest.configs.contains_key("resnet18_conv") {
        eprintln!("skipping: resnet18_conv not in manifest");
        return;
    }
    let p = NnProblem::new(
        &mut rt,
        NnOptions {
            config: "resnet18_conv".into(),
            num_clients: 2,
            train_n: 512,
            test_n: 256,
            eval_cap: 256,
            seed: 9,
            augment: false,
            dirichlet_alpha: None,
        },
    )
    .expect("conv problem");
    let cfg = TrainConfig {
        rounds: 6,
        local_iters: 4,
        lr: LrSchedule::Constant(3e-2),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 8, max_rank: p.max_rank(), tau: 0.02 },
        seed: 2,
        eval_every: 3,
        ..TrainConfig::default()
    };
    let rec = run_fedlrt(&p, &cfg, "conv");
    assert!(rec.final_loss() < rec.rounds[0].global_loss, "conv model should learn");
    assert!(rec.final_metric().unwrap() > 0.1);
}

#[test]
fn checkpoint_roundtrip_preserves_nn_evaluation() {
    // Save → load → identical loss through the PJRT artifacts.
    use fedlrt::models::checkpoint;
    let Some(p) = tiny_problem(2, 47) else { return };
    let w = factored_weights(&p, 3, 21);
    let loss_before = p.global_loss(&w);
    let dir = std::env::temp_dir().join("fedlrt_it_ckpt");
    let path = dir.join("w.json");
    checkpoint::save(&w, &path).unwrap();
    let back = checkpoint::load(&path).unwrap();
    let loss_after = p.global_loss(&back);
    assert_eq!(loss_before.to_bits(), loss_after.to_bits());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn attention_config_trains_through_artifacts() {
    // vit_attn: a real multi-head self-attention block whose four
    // projection matrices (W_q, W_k, W_v, W_o) are all FeDLRT low-rank
    // layers — the paper's ViT benchmark structure.
    let Some(mut rt) = try_runtime() else { return };
    if !rt.manifest.configs.contains_key("vit_attn") {
        eprintln!("skipping: vit_attn not in manifest");
        return;
    }
    let p = NnProblem::new(
        &mut rt,
        NnOptions {
            config: "vit_attn".into(),
            num_clients: 2,
            train_n: 512,
            test_n: 256,
            eval_cap: 256,
            seed: 31,
            augment: false,
            dirichlet_alpha: None,
        },
    )
    .expect("attention problem");
    assert_eq!(p.spec().lr_shapes.len(), 4, "one block = 4 low-rank matrices");
    let cfg = TrainConfig {
        rounds: 5,
        local_iters: 4,
        lr: LrSchedule::Constant(2e-2),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 8, max_rank: p.max_rank(), tau: 0.02 },
        seed: 3,
        eval_every: 5,
        ..TrainConfig::default()
    };
    let rec = run_fedlrt(&p, &cfg, "attn");
    assert!(
        rec.final_loss() < rec.rounds[0].global_loss,
        "attention model should learn: {} -> {}",
        rec.rounds[0].global_loss,
        rec.final_loss()
    );
    // Every attention matrix keeps an independent adaptive rank.
    assert_eq!(rec.rounds.last().unwrap().ranks.len(), 4);
}
