//! Regression and property tests for the client-update layer.
//!
//! The refactor's contract is bitwise: with `Correction::None` the
//! shared [`LocalUpdate`] driver must reproduce every coordinator's
//! pre-refactor hand-rolled loop exactly, and a strategy at its
//! neutral knob (μ = 0, α = 0, strength = 0) must be structurally
//! indistinguishable from `none` — across executors and wire codecs.
//! These tests pin that contract with inline copies of the legacy
//! loops, plus the SCAFFOLD byte-visibility and hostile-scenario
//! determinism guarantees from the issue.

use fedlrt::client::{ClientStates, Correction, GradMode, LocalUpdate};
use fedlrt::comm::{CodecKind, ALL_CODECS};
use fedlrt::coordinator::{
    run_async, run_dense, run_fedlr, run_fedlrt, run_fedlrt_naive, DenseAlgo, RankConfig,
    Schedule, TrainConfig, VarCorrection,
};
use fedlrt::engine::{ClientFault, ExecutorKind, RoundPlan, ScenarioConfig};
use fedlrt::lowrank::LowRank;
use fedlrt::metrics::RunRecord;
use fedlrt::models::quadratic::Quadratic;
use fedlrt::models::{FedProblem, LrWant, LrWeight, Weights};
use fedlrt::opt::{ClientOptimizer, LrSchedule, OptimizerKind, SgdConfig};
use fedlrt::tensor::Matrix;
use fedlrt::util::rng::Rng;

fn sgd() -> OptimizerKind {
    OptimizerKind::Sgd(SgdConfig::default())
}

fn neutral_local_update<'a>(
    mode: GradMode,
    iters: usize,
    step0: u64,
    vc_lr: &'a [Option<Matrix>],
) -> LocalUpdate<'a> {
    LocalUpdate {
        opt: sgd(),
        lr_t: 2e-2,
        iters,
        step0,
        mode,
        vc_lr,
        vc_dense: &[],
        g_bar: None,
        capture_first_grad: false,
        correction: Correction::None,
        drift_in: None,
        ctrl: None,
        fault: ClientFault::None,
        fault_seed: 0,
    }
}

/// The pre-refactor dense-mode client loop (FedAvg/FedLin/FeDLR),
/// verbatim: one `grad(Dense)` per step, low-rank layers step first.
fn legacy_dense_loop<P: FedProblem>(
    problem: &P,
    client: usize,
    w_c: &mut Weights,
    iters: usize,
    step0: u64,
    lr_t: f64,
    vc_lr: &[Option<Matrix>],
) -> f64 {
    let mut opts: Vec<ClientOptimizer> =
        (0..w_c.lr.len()).map(|_| ClientOptimizer::new(sgd())).collect();
    let mut first_loss = 0.0;
    for s in 0..iters {
        let g = problem.grad(client, w_c, LrWant::Dense, step0 + s as u64);
        if s == 0 {
            first_loss = g.loss;
        }
        for l in 0..w_c.lr.len() {
            let extra = vc_lr.get(l).and_then(|o| o.as_ref());
            opts[l].step(w_c.lr[l].as_dense_mut(), g.lr[l].dense(), lr_t, extra);
        }
    }
    first_loss
}

/// The pre-refactor coefficient-mode client loop (FeDLRT family),
/// verbatim: `grad_coeff_into` fast path with a `grad(Coeff)` fallback,
/// dense params step first, then the coefficients.
fn legacy_coeff_loop<P: FedProblem>(
    problem: &P,
    client: usize,
    w_c: &mut Weights,
    iters: usize,
    step0: u64,
    lr_t: f64,
    vc_lr: &[Option<Matrix>],
) -> f64 {
    let num_lr = w_c.lr.len();
    let mut g_coeff: Vec<Matrix> = (0..num_lr)
        .map(|l| {
            let s = &w_c.lr[l].as_factored().s;
            Matrix::zeros(s.rows(), s.cols())
        })
        .collect();
    let mut g_dense: Vec<Matrix> =
        w_c.dense.iter().map(|d| Matrix::zeros(d.rows(), d.cols())).collect();
    let mut opt_s: Vec<ClientOptimizer> =
        (0..num_lr).map(|_| ClientOptimizer::new(sgd())).collect();
    let mut opt_d: Vec<ClientOptimizer> =
        (0..w_c.dense.len()).map(|_| ClientOptimizer::new(sgd())).collect();
    let mut first_loss = 0.0;
    for s in 0..iters {
        let step = step0 + s as u64;
        let loss =
            match problem.grad_coeff_into(client, w_c, step, &mut g_coeff, &mut g_dense) {
                Some(l0) => l0,
                None => {
                    let g = problem.grad(client, w_c, LrWant::Coeff, step);
                    for (buf, gl) in g_coeff.iter_mut().zip(&g.lr) {
                        buf.copy_from(gl.coeff());
                    }
                    for (buf, gd) in g_dense.iter_mut().zip(&g.dense) {
                        buf.copy_from(gd);
                    }
                    g.loss
                }
            };
        if s == 0 {
            first_loss = loss;
        }
        for (dl, gd) in g_dense.iter().enumerate() {
            opt_d[dl].step(&mut w_c.dense[dl], gd, lr_t, None);
        }
        for l in 0..num_lr {
            let extra = vc_lr.get(l).and_then(|o| o.as_ref());
            let fac = w_c.lr[l].as_factored_mut();
            opt_s[l].step(&mut fac.s, &g_coeff[l], lr_t, extra);
        }
    }
    first_loss
}

fn assert_weights_eq(a: &Weights, b: &Weights, ctx: &str) {
    assert_eq!(a.lr.len(), b.lr.len(), "{ctx}: layer count");
    for (l, (wa, wb)) in a.lr.iter().zip(&b.lr).enumerate() {
        let (ma, mb) = match (wa, wb) {
            (LrWeight::Dense(x), LrWeight::Dense(y)) => (x, y),
            (LrWeight::Factored(x), LrWeight::Factored(y)) => (&x.s, &y.s),
            _ => panic!("{ctx}: weight kind mismatch at layer {l}"),
        };
        for (x, y) in ma.data().iter().zip(mb.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: layer {l} diverged");
        }
    }
    for (d, (xa, xb)) in a.dense.iter().zip(&b.dense).enumerate() {
        for (x, y) in xa.data().iter().zip(xb.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: dense {d} diverged");
        }
    }
}

#[test]
fn local_update_dense_mode_matches_inline_legacy_loop() {
    let mut rng = Rng::new(101);
    let prob = Quadratic::random(8, 2, 3, &mut rng);
    for (&client, &step0) in [0usize, 1, 2].iter().zip(&[0u64, 7, 31]) {
        // With and without a FedLin-style fixed extra.
        for vc in [vec![None], vec![Some(Matrix::randn(8, 8, &mut rng))]] {
            let w0 = Matrix::randn(8, 8, &mut rng);
            let mut w_legacy =
                Weights { dense: vec![], lr: vec![LrWeight::Dense(w0.clone())] };
            let mut w_new = Weights { dense: vec![], lr: vec![LrWeight::Dense(w0)] };
            let fl_legacy =
                legacy_dense_loop(&prob, client, &mut w_legacy, 5, step0, 2e-2, &vc);
            let upd = neutral_local_update(GradMode::Dense, 5, step0, &vc);
            let out = upd.run(&prob, client, &mut w_new);
            assert_eq!(fl_legacy.to_bits(), out.first_loss.to_bits());
            assert!(out.drift_out.is_none() && out.ctrl_delta.is_none());
            assert_weights_eq(&w_legacy, &w_new, "dense mode");
        }
    }
}

#[test]
fn local_update_coeff_mode_matches_inline_legacy_loop() {
    let mut rng = Rng::new(103);
    let prob = Quadratic::random(8, 2, 3, &mut rng);
    for (&client, &step0) in [0usize, 2].iter().zip(&[0u64, 13]) {
        for vc in [vec![None], vec![Some(Matrix::randn(3, 3, &mut rng))]] {
            let f0 = LowRank::random_init(8, 8, 3, &mut rng);
            let mut w_legacy =
                Weights { dense: vec![], lr: vec![LrWeight::Factored(f0.clone())] };
            let mut w_new = Weights { dense: vec![], lr: vec![LrWeight::Factored(f0)] };
            let fl_legacy =
                legacy_coeff_loop(&prob, client, &mut w_legacy, 4, step0, 2e-2, &vc);
            let upd = neutral_local_update(GradMode::Coeff, 4, step0, &vc);
            let out = upd.run(&prob, client, &mut w_new);
            assert_eq!(fl_legacy.to_bits(), out.first_loss.to_bits());
            assert_weights_eq(&w_legacy, &w_new, "coeff mode");
        }
    }
}

#[test]
fn client_states_pin_legacy_next_step_counters() {
    // The refactor replaced each coordinator's `vec![0u64; c]` cursor
    // array with ClientStates over the sharded registry. Replay the
    // legacy bookkeeping side by side through plans with sampling,
    // dropout, and stragglers: every client's step0 must agree at every
    // round.
    let c_num = 12;
    let cfg = TrainConfig {
        local_iters: 7,
        participation: 0.6,
        dropout: 0.2,
        straggler_jitter: 0.5,
        seed: 9,
        ..TrainConfig::default()
    };
    let mut legacy = vec![0u64; c_num];
    let mut states = ClientStates::new(c_num);
    for round in 0..8 {
        let plan = RoundPlan::build(&cfg, c_num, round, |_| 1.0);
        for task in &plan.tasks {
            assert_eq!(
                states.step0(task.client_id),
                legacy[task.client_id],
                "round {round}, client {}",
                task.client_id
            );
        }
        // Legacy loops advanced after aggregation, in task order.
        for task in &plan.tasks {
            legacy[task.client_id] += task.local_iters as u64;
        }
        states.advance(&plan);
    }
}

fn quick_cfg(codec: CodecKind, executor: ExecutorKind, correction: Correction) -> TrainConfig {
    TrainConfig {
        rounds: 3,
        local_iters: 3,
        lr: LrSchedule::Constant(2e-2),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 2, max_rank: 4, tau: 0.05 },
        seed: 5,
        codec,
        executor,
        correction,
        ..TrainConfig::default()
    }
}

fn assert_records_identical(a: &RunRecord, b: &RunRecord, ctx: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{ctx}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            x.global_loss.to_bits(),
            y.global_loss.to_bits(),
            "{ctx}: loss diverged at round {}",
            x.round
        );
        assert_eq!(x.ranks, y.ranks, "{ctx}: ranks diverged at round {}", x.round);
        assert_eq!(x.comm_floats, y.comm_floats, "{ctx}: floats diverged at {}", x.round);
        assert_eq!(x.bytes_down, y.bytes_down, "{ctx}: bytes_down diverged at {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "{ctx}: bytes_up diverged at {}", x.round);
    }
}

#[test]
#[allow(clippy::type_complexity)]
fn neutral_corrections_are_bitwise_noops_across_coordinators_executors_codecs() {
    // μ = 0 / α = 0 / strength = 0 must collapse structurally to the
    // `none` path: identical loss, rank, float, and byte trajectories —
    // for every coordinator, under both executors, through every codec.
    let mut rng = Rng::new(201);
    let prob = Quadratic::random(8, 2, 3, &mut rng);
    let runners: Vec<(&str, Box<dyn Fn(&TrainConfig) -> RunRecord + '_>)> = vec![
        ("fedlrt", Box::new(|c: &TrainConfig| run_fedlrt(&prob, c, "noop"))),
        ("fedlrt_naive", Box::new(|c: &TrainConfig| run_fedlrt_naive(&prob, c, "noop"))),
        ("fedlr", Box::new(|c: &TrainConfig| run_fedlr(&prob, c, "noop"))),
        ("fedavg", Box::new(|c: &TrainConfig| run_dense(&prob, c, DenseAlgo::FedAvg, "noop"))),
        ("fedlin", Box::new(|c: &TrainConfig| run_dense(&prob, c, DenseAlgo::FedLin, "noop"))),
        ("async", Box::new(|c: &TrainConfig| {
            let mut c = c.clone();
            c.schedule = Schedule::FedBuff;
            c.async_cfg.buffer_k = 3;
            c.async_cfg.concurrency = 4;
            run_async(&prob, &c, "noop")
        })),
    ];
    let neutrals = [
        Correction::FedProx { mu: 0.0 },
        Correction::FedDyn { alpha: 0.0 },
        Correction::Scaffold { strength: 0.0 },
    ];
    for (name, run) in &runners {
        for codec in ALL_CODECS {
            let baseline = run(&quick_cfg(codec, ExecutorKind::Serial, Correction::None));
            for executor in [ExecutorKind::Serial, ExecutorKind::ThreadPool { threads: 0 }] {
                for correction in neutrals {
                    let rec = run(&quick_cfg(codec, executor, correction));
                    let ctx = format!(
                        "{name}/{:?}/{:?}/{}",
                        codec,
                        executor,
                        correction.label()
                    );
                    assert_records_identical(&baseline, &rec, &ctx);
                }
            }
        }
    }
}

#[test]
fn active_corrections_change_heterogeneous_trajectories() {
    // Guard against a strategy silently compiling to a no-op: on a
    // heterogeneous problem every active correction must move the
    // trajectory (and still converge to something finite).
    let mut rng = Rng::new(301);
    let prob = Quadratic::random(8, 2, 4, &mut rng);
    let base_cfg = |correction| TrainConfig {
        rounds: 6,
        local_iters: 5,
        lr: LrSchedule::Constant(2e-2),
        var_correction: VarCorrection::None,
        rank: RankConfig { initial_rank: 2, max_rank: 4, tau: 0.05 },
        seed: 11,
        correction,
        ..TrainConfig::default()
    };
    let none = run_fedlrt(&prob, &base_cfg(Correction::None), "active");
    for correction in [
        Correction::FedProx { mu: 0.5 },
        Correction::FedDyn { alpha: 0.5 },
        Correction::Scaffold { strength: 1.0 },
    ] {
        let rec = run_fedlrt(&prob, &base_cfg(correction), "active");
        assert!(rec.final_loss().is_finite(), "{} diverged", correction.label());
        assert_ne!(
            rec.final_loss().to_bits(),
            none.final_loss().to_bits(),
            "{} left the trajectory untouched",
            correction.label()
        );
    }
}

#[test]
fn scaffold_control_variates_are_billed_on_the_wire() {
    // SCAFFOLD's broadcast `c` and uplink `Δc_c` ride the same codecs
    // as the model payloads, so its overhead must be visible in the
    // measured byte totals — both directions, sync and async.
    let mut rng = Rng::new(401);
    let prob = Quadratic::random(8, 2, 3, &mut rng);
    let sync_none = run_fedlrt(&prob, &quick_cfg(CodecKind::DenseF32, ExecutorKind::Serial, Correction::None), "bytes");
    let sync_scaf = run_fedlrt(
        &prob,
        &quick_cfg(CodecKind::DenseF32, ExecutorKind::Serial, Correction::Scaffold { strength: 1.0 }),
        "bytes",
    );
    assert!(
        sync_scaf.total_bytes_down() > sync_none.total_bytes_down(),
        "scaffold broadcast bytes invisible: {} vs {}",
        sync_scaf.total_bytes_down(),
        sync_none.total_bytes_down()
    );
    assert!(
        sync_scaf.total_bytes_up() > sync_none.total_bytes_up(),
        "scaffold uplink bytes invisible: {} vs {}",
        sync_scaf.total_bytes_up(),
        sync_none.total_bytes_up()
    );

    let async_cfg = |correction| {
        let mut c = quick_cfg(CodecKind::DenseF32, ExecutorKind::Serial, correction);
        c.schedule = Schedule::FedBuff;
        c.async_cfg.buffer_k = 3;
        c.async_cfg.concurrency = 4;
        c
    };
    let as_none = run_async(&prob, &async_cfg(Correction::None), "bytes");
    let as_scaf = run_async(&prob, &async_cfg(Correction::Scaffold { strength: 1.0 }), "bytes");
    assert!(as_scaf.total_bytes_down() > as_none.total_bytes_down());
    assert!(as_scaf.total_bytes_up() > as_none.total_bytes_up());
}

#[test]
fn hostile_scenarios_are_deterministic_and_fault_assignment_is_stable() {
    // Scenario presets must not break the engine's determinism
    // contract: identical seeds reproduce bitwise, serial ≡ thread
    // pool, and a client's fault assignment is a pure function of the
    // run seed.
    let scenario = ScenarioConfig::parse("byzantine").unwrap();
    for client in 0..16 {
        assert_eq!(
            scenario.fault_for(7, client),
            scenario.fault_for(7, client),
            "fault_for must be stable per (seed, client)"
        );
    }
    assert!(
        (0..64).any(|c| scenario.fault_for(7, c) != ClientFault::None),
        "byzantine preset assigned no faults in 64 clients"
    );
    let mut rng = Rng::new(501);
    let prob = Quadratic::random(8, 2, 4, &mut rng);
    for name in ["skew", "churn", "blackout", "byzantine", "noisy", "hellscape"] {
        let cfg = |executor| {
            let mut c = quick_cfg(CodecKind::DenseF32, executor, Correction::None);
            c.rounds = 4;
            c.scenario = ScenarioConfig::parse(name).unwrap();
            c
        };
        let a = run_fedlrt(&prob, &cfg(ExecutorKind::Serial), "hostile");
        let b = run_fedlrt(&prob, &cfg(ExecutorKind::Serial), "hostile");
        let c = run_fedlrt(&prob, &cfg(ExecutorKind::ThreadPool { threads: 0 }), "hostile");
        assert_records_identical(&a, &b, &format!("{name}: rerun"));
        assert_records_identical(&a, &c, &format!("{name}: thread pool"));
        assert!(a.final_loss().is_finite(), "{name}: loss diverged");
    }
}
