//! Client-timing distributions shared by the sync and async paths.
//!
//! One abstraction covers both timing models in the engine: the sync
//! round loop's straggler iteration scaling ([`Dist::StragglerScale`],
//! bit-for-bit the legacy `s*·(1 − jitter·u)` multiplier) and the
//! async event simulator's arrival / compute / link draws
//! ([`TimingModel`]). Every draw is a pure function of
//! `(run seed, salt, stream index)` through the same splittable RNG the
//! per-client task streams use, with *distinct* salts per purpose —
//! adding async timing draws cannot perturb any sync-path stream.

use crate::util::rng::Rng;

/// A one-dimensional sampling distribution for virtual client timing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Dist {
    /// Always `value`; consumes **no** randomness (so a constant
    /// distribution is stream-transparent, preserving legacy RNG
    /// consumption bitwise).
    Constant(f64),
    /// Uniform on `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// `exp(μ + σ·N(0,1))` — heavy-tailed latencies (median `e^μ`).
    LogNormal { mu: f64, sigma: f64 },
    /// The legacy straggler multiplier `1 − clamp(jitter,0,1)·u` with
    /// `u ~ U[0,1)`: kept as its own variant (not `Uniform`) because
    /// `lo + (hi−lo)·u` is **not** bitwise-equal to `1 − j·u` in
    /// floating point. `jitter ≤ 0` consumes no randomness.
    StragglerScale { jitter: f64 },
}

impl Dist {
    /// Draw one sample, advancing `rng` only when the distribution is
    /// actually random (constant draws are stream-transparent).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Constant(v) => v,
            Dist::Uniform { lo, hi } => rng.uniform_in(lo, hi),
            Dist::LogNormal { mu, sigma } => (mu + sigma * rng.normal()).exp(),
            Dist::StragglerScale { jitter } => {
                if jitter <= 0.0 {
                    1.0
                } else {
                    1.0 - jitter.clamp(0.0, 1.0) * rng.uniform()
                }
            }
        }
    }

    /// True when every sample is exactly `1.0` without touching the
    /// RNG — the "no timing skew" fast path (legacy `jitter ≤ 0`
    /// early-return, preserved bitwise).
    pub fn is_unit(&self) -> bool {
        matches!(*self, Dist::Constant(v) if v == 1.0)
            || matches!(*self, Dist::StragglerScale { jitter } if jitter <= 0.0)
    }

    /// Stable label, inverse of [`Dist::parse`].
    pub fn label(&self) -> String {
        match *self {
            Dist::Constant(v) => format!("constant:{v}"),
            Dist::Uniform { lo, hi } => format!("uniform:{lo},{hi}"),
            Dist::LogNormal { mu, sigma } => format!("lognormal:{mu},{sigma}"),
            Dist::StragglerScale { jitter } => format!("straggler:{jitter}"),
        }
    }

    /// Parse a CLI spelling: `constant:V`, `uniform:LO,HI`,
    /// `lognormal:MU,SIGMA`, or `straggler:J`. A bare number is
    /// shorthand for `constant:`.
    pub fn parse(s: &str) -> Result<Dist, String> {
        if let Ok(v) = s.parse::<f64>() {
            return Ok(Dist::Constant(v));
        }
        let (kind, args) = s
            .split_once(':')
            .ok_or_else(|| format!("bad distribution '{s}' (expected kind:args)"))?;
        let nums: Vec<f64> = args
            .split(',')
            .map(|a| a.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .map_err(|_| format!("bad distribution args in '{s}'"))?;
        match (kind, nums.as_slice()) {
            ("constant", [v]) => Ok(Dist::Constant(*v)),
            ("uniform", [lo, hi]) if lo <= hi => Ok(Dist::Uniform { lo: *lo, hi: *hi }),
            ("lognormal", [mu, sigma]) if *sigma >= 0.0 => {
                Ok(Dist::LogNormal { mu: *mu, sigma: *sigma })
            }
            ("straggler", [j]) => Ok(Dist::StragglerScale { jitter: *j }),
            _ => Err(format!(
                "bad distribution '{s}' (constant:V | uniform:LO,HI | lognormal:MU,SIGMA | straggler:J)"
            )),
        }
    }
}

// Purpose salts for the timing RNG streams. Distinct from every salt the
// sync path uses (`0x5E1E_C700` sampling, `0x57A6_6000` stragglers,
// `0xD809_0FF1` dropout, SplitMix task seeds), so async timing draws
// never alias a sync stream.
const SALT_ARRIVAL: u64 = 0xA11D_A7E5;
const SALT_COMPUTE: u64 = 0xC0FF_EE00;
const SALT_LINK: u64 = 0x11CC_4A7B;
const SALT_HET: u64 = 0x4E7E_0561;

/// The virtual-clock timing model of one simulated deployment: when
/// clients arrive, how long they compute, and how long their uplink
/// takes — plus an optional frozen per-client heterogeneity multiplier.
///
/// All times are virtual seconds; draws are deterministic functions of
/// `(seed, client, stream index)` so the event timeline is identical
/// under any executor or thread count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingModel {
    /// Gap between a slot freeing and its next client arriving.
    pub arrival: Dist,
    /// Client compute duration for one dispatch (whole local run).
    pub compute: Dist,
    /// Uplink latency of one update transfer.
    pub link: Dist,
    /// σ of the per-client lognormal speed multiplier `exp(σ·N(0,1))`,
    /// frozen at first contact (0 = homogeneous fleet). Multiplies the
    /// compute draw — the "per-client heterogeneous" distribution.
    pub het_sigma: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            arrival: Dist::Constant(1.0),
            compute: Dist::Constant(1.0),
            link: Dist::Constant(0.0),
            het_sigma: 0.0,
        }
    }
}

impl TimingModel {
    /// The client's frozen speed multiplier (1.0 when `het_sigma = 0`;
    /// consumes no randomness in that case).
    pub fn client_speed(&self, seed: u64, client: usize) -> f64 {
        if self.het_sigma <= 0.0 {
            return 1.0;
        }
        let mut rng = Rng::new(seed ^ SALT_HET).split(client as u64);
        (self.het_sigma * rng.normal()).exp()
    }

    /// Arrival gap before global dispatch number `dispatch`.
    pub fn arrival_gap(&self, seed: u64, dispatch: u64) -> f64 {
        let mut rng = Rng::new(seed ^ SALT_ARRIVAL).split(dispatch);
        self.arrival.sample(&mut rng).max(0.0)
    }

    /// Compute duration of dispatch `dispatch` on `client`, including
    /// the client's frozen heterogeneity multiplier.
    pub fn compute_time(&self, seed: u64, client: usize, dispatch: u64) -> f64 {
        let mut rng = Rng::new(seed ^ SALT_COMPUTE).split(dispatch);
        (self.compute.sample(&mut rng) * self.client_speed(seed, client)).max(0.0)
    }

    /// Uplink latency of dispatch `dispatch` from `client`.
    pub fn link_time(&self, seed: u64, client: usize, dispatch: u64) -> f64 {
        let _ = client;
        let mut rng = Rng::new(seed ^ SALT_LINK).split(dispatch);
        self.link.sample(&mut rng).max(0.0)
    }

    /// Stable label for config echoes.
    pub fn label(&self) -> String {
        format!(
            "arrival={};compute={};link={};het={}",
            self.arrival.label(),
            self.compute.label(),
            self.link.label(),
            self.het_sigma
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_consumes_no_randomness() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        assert_eq!(Dist::Constant(2.5).sample(&mut a), 2.5);
        // The stream is untouched: the next draw matches a fresh one.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn straggler_scale_matches_legacy_arithmetic_bitwise() {
        // The exact legacy expression, recomputed by hand with the same
        // RNG stream — the refactor's bitwise-preservation contract.
        for (seed, jitter) in [(3u64, 0.3f64), (11, 0.7), (42, 1.5)] {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let got = Dist::StragglerScale { jitter }.sample(&mut r1);
            let want = 1.0 - jitter.clamp(0.0, 1.0) * r2.uniform();
            assert_eq!(got.to_bits(), want.to_bits());
        }
        // jitter ≤ 0: unit sample, stream untouched.
        let d = Dist::StragglerScale { jitter: 0.0 };
        assert!(d.is_unit());
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        assert_eq!(d.sample(&mut a), 1.0);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_is_not_bitwise_straggler() {
        // Documents WHY StragglerScale exists: the algebraically equal
        // Uniform{1−j, 1} draw differs in the last bits for j < 0.5.
        let j = 0.3;
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let s = Dist::StragglerScale { jitter: j }.sample(&mut r1);
        let u = Dist::Uniform { lo: 1.0 - j, hi: 1.0 }.sample(&mut r2);
        assert!((s - u).abs() < 1e-15, "same value up to rounding");
        assert_ne!(s.to_bits(), u.to_bits(), "but not bitwise");
    }

    #[test]
    fn samples_land_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let u = Dist::Uniform { lo: 0.5, hi: 2.0 }.sample(&mut rng);
            assert!((0.5..2.0).contains(&u));
            let l = Dist::LogNormal { mu: 0.0, sigma: 0.5 }.sample(&mut rng);
            assert!(l > 0.0 && l.is_finite());
            let s = Dist::StragglerScale { jitter: 0.4 }.sample(&mut rng);
            assert!(s > 0.6 - 1e-12 && s <= 1.0);
        }
    }

    #[test]
    fn parse_label_roundtrip() {
        for s in ["constant:1.5", "uniform:0.5,2", "lognormal:0,0.5", "straggler:0.3"] {
            let d = Dist::parse(s).unwrap();
            assert_eq!(Dist::parse(&d.label()).unwrap(), d);
        }
        assert_eq!(Dist::parse("2.5").unwrap(), Dist::Constant(2.5));
        assert!(Dist::parse("uniform:2,1").is_err());
        assert!(Dist::parse("gamma:1,2").is_err());
        assert!(Dist::parse("uniform:a,b").is_err());
    }

    #[test]
    fn timing_model_is_deterministic_and_heterogeneous() {
        let tm = TimingModel {
            arrival: Dist::Uniform { lo: 0.1, hi: 0.5 },
            compute: Dist::LogNormal { mu: 0.0, sigma: 0.3 },
            link: Dist::Constant(0.05),
            het_sigma: 0.5,
        };
        // Same (seed, client, dispatch) → same draw, bitwise.
        assert_eq!(
            tm.compute_time(7, 3, 11).to_bits(),
            tm.compute_time(7, 3, 11).to_bits()
        );
        // Frozen speed: stable per client, varies across clients.
        let s3 = tm.client_speed(7, 3);
        assert_eq!(s3.to_bits(), tm.client_speed(7, 3).to_bits());
        let distinct = (0..20).any(|c| tm.client_speed(7, c).to_bits() != s3.to_bits());
        assert!(distinct);
        // Homogeneous fleet: multiplier is exactly 1.
        let hom = TimingModel { het_sigma: 0.0, ..tm };
        assert_eq!(hom.client_speed(7, 3), 1.0);
        assert!(tm.link_time(7, 0, 0) >= 0.0);
        assert!(tm.arrival_gap(7, 0) >= 0.0);
    }
}
