//! Virtual-clock discrete-event queue with a deterministic total order.
//!
//! The async federation simulator advances a **virtual clock**: events
//! carry a virtual timestamp, the queue pops them in nondecreasing time
//! order, and ties are broken by insertion sequence number — a total
//! order on `(time, seq)` that is a pure function of the pushes, never
//! of thread scheduling. A fixed seed therefore yields a fixed event
//! order at any `kernel_threads` or executor setting (the async leg of
//! the engine's determinism contract; `tests/engine_determinism.rs`).
//!
//! Timestamps are `f64` virtual seconds compared with `total_cmp`, so
//! exact ties (common under constant distributions) are well-defined
//! and NaNs cannot poison the heap order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event: when, in what push order, and what.
#[derive(Debug, Clone)]
pub struct Event<T> {
    /// Virtual timestamp (seconds).
    pub time: f64,
    /// Insertion sequence number — the deterministic tie-break.
    pub seq: u64,
    pub payload: T,
}

// Ordering is on (time, seq) ONLY — payloads never influence pop order.
impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.seq == other.seq
    }
}

impl<T> Eq for Event<T> {}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest
        // (time, seq) on top.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event queue over a virtual clock.
#[derive(Debug, Clone)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
    now: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, now: 0.0 }
    }

    /// Current virtual time: the timestamp of the last popped event
    /// (0.0 before any pop).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at virtual `time`, assigning the next
    /// sequence number; returns the event's `seq`. Scheduling in the
    /// past is a logic error in the simulator, not a recoverable
    /// condition.
    pub fn push(&mut self, time: f64, payload: T) -> u64 {
        debug_assert!(
            time.is_finite() && time >= self.now,
            "event scheduled in the past: {time} < {}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, payload });
        seq
    }

    /// Pop the earliest event (ties by `seq`) and advance the clock to
    /// its timestamp.
    pub fn pop(&mut self) -> Option<Event<T>> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        Some(ev)
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, ["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn exact_ties_break_by_insertion_seq() {
        let mut q = EventQueue::new();
        for i in 0..16 {
            q.push(1.0, i);
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_push_pop_is_deterministic() {
        // The same schedule of pushes produces the same pop order and
        // the same (time, seq) trace, run to run.
        let run = || {
            let mut q = EventQueue::new();
            let mut trace = Vec::new();
            q.push(0.5, 0u64);
            q.push(0.5, 1);
            while let Some(ev) = q.pop() {
                trace.push((ev.time.to_bits(), ev.seq, ev.payload));
                if ev.payload < 6 {
                    // Re-schedule at the SAME time: seq keeps ties stable.
                    q.push(ev.time, ev.payload + 2);
                }
            }
            trace
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(1.0, ());
        q.push(4.0, ());
        q.push(2.0, ());
        let mut last = 0.0;
        while let Some(ev) = q.pop() {
            assert!(ev.time >= last);
            last = ev.time;
            assert_eq!(q.now(), ev.time);
        }
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert_eq!(q.peek_time(), None);
    }
}
