//! Round scheduling: participation sampling, dropout, stragglers, and
//! per-client deterministic RNG streams, folded into one [`RoundPlan`].
//!
//! The paper analyses full participation with a uniform `s*` and notes
//! (footnote 3) that the analysis extends to client-dependent local
//! iteration counts; partial participation and per-round dropout are the
//! standard production relaxations [26, 6, 29]. Everything here is a
//! deterministic function of `(TrainConfig, round)` so runs stay
//! reproducible under any executor.

use crate::coordinator::config::TrainConfig;
use crate::engine::dist::Dist;
use crate::util::rng::Rng;

/// What a faulty client does to the update it uploads. Applied by the
/// client-update driver (`crate::client::LocalUpdate`) after the local
/// loop, so the fault corrupts exactly what travels on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ClientFault {
    /// Honest client.
    #[default]
    None,
    /// Additive Gaussian noise `σ·N(0,1)` on every trained entry
    /// (flaky sensors, lossy local storage).
    Noisy { sigma: f64 },
    /// Sign-flip attack: uploads `w₀ − scale·(w − w₀)`, i.e. walks the
    /// server *against* its own local progress.
    Byzantine { scale: f64 },
}

/// Hostile-scenario knobs layered on top of the base participation /
/// dropout / straggler model. The default (`calm`) is structurally
/// inactive: every guard below early-returns and round plans are
/// bitwise-identical to the pre-scenario builder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioConfig {
    /// Preset label for config echoes and result rows.
    pub name: &'static str,
    /// Epoch-correlated churn: with this probability per
    /// `(client, epoch)` — an epoch is [`CHURN_EPOCH_ROUNDS`]
    /// consecutive rounds — the client leaves the fleet for the whole
    /// epoch (device offline for days, not a per-round coin flip).
    pub churn: f64,
    /// Correlated dropout: clients are grouped into
    /// [`NUM_COHORTS`] cohorts (`client_id % NUM_COHORTS`, e.g. a
    /// shared cell tower); with this probability per `(round, cohort)`
    /// the *entire cohort* drops after the broadcast.
    pub correlated_dropout: f64,
    /// Fraction of the population that is faulty (stable per client
    /// across rounds — a compromised device stays compromised).
    pub fault_fraction: f64,
    /// What faulty clients do.
    pub fault: ClientFault,
    /// Dirichlet concentration for label-skew partitioning; consumed
    /// by problem builders (`data::partition`), not the round plan.
    /// `None` = uniform shards.
    pub dirichlet_alpha: Option<f64>,
}

/// Rounds per churn epoch (see [`ScenarioConfig::churn`]).
pub const CHURN_EPOCH_ROUNDS: usize = 5;
/// Number of correlated-dropout cohorts (see
/// [`ScenarioConfig::correlated_dropout`]).
pub const NUM_COHORTS: usize = 8;

const SALT_CHURN: u64 = 0xC4BB_A9E1;
const SALT_COHORT: u64 = 0xC0C0_D07A;
const SALT_FAULT: u64 = 0xFA17_717A;

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            name: "calm",
            churn: 0.0,
            correlated_dropout: 0.0,
            fault_fraction: 0.0,
            fault: ClientFault::None,
            dirichlet_alpha: None,
        }
    }
}

impl ScenarioConfig {
    /// Whether any knob is set (used to decide config echoing).
    pub fn is_active(&self) -> bool {
        self.churn > 0.0
            || self.correlated_dropout > 0.0
            || self.fault_fraction > 0.0
            || self.dirichlet_alpha.is_some()
    }

    /// The named preset matrix driven by `--scenario` and the
    /// drift-correction bench. `calm` is the inactive default.
    pub fn presets() -> Vec<ScenarioConfig> {
        let calm = ScenarioConfig::default();
        vec![
            calm,
            // Extreme Dirichlet label skew (α = 0.1): most clients see
            // one or two classes.
            ScenarioConfig { name: "skew", dirichlet_alpha: Some(0.1), ..calm },
            // Devices joining/leaving for whole epochs.
            ScenarioConfig { name: "churn", churn: 0.3, ..calm },
            // Whole cohorts vanish together after the broadcast.
            ScenarioConfig { name: "blackout", correlated_dropout: 0.3, ..calm },
            // A quarter of the fleet uploads sign-flipped updates.
            ScenarioConfig {
                name: "byzantine",
                fault_fraction: 0.25,
                fault: ClientFault::Byzantine { scale: 1.0 },
                ..calm
            },
            // A third of the fleet uploads noise-corrupted updates.
            ScenarioConfig {
                name: "noisy",
                fault_fraction: 0.3,
                fault: ClientFault::Noisy { sigma: 0.3 },
                ..calm
            },
            // Everything at once.
            ScenarioConfig {
                name: "hellscape",
                churn: 0.2,
                correlated_dropout: 0.2,
                fault_fraction: 0.2,
                fault: ClientFault::Byzantine { scale: 1.0 },
                dirichlet_alpha: Some(0.1),
            },
        ]
    }

    /// Look a preset up by name (the `--scenario` parser).
    pub fn parse(s: &str) -> Result<ScenarioConfig, String> {
        Self::presets().into_iter().find(|p| p.name == s).ok_or_else(|| {
            let names: Vec<&str> = Self::presets().iter().map(|p| p.name).collect();
            format!("unknown scenario '{s}' (expected one of: {})", names.join("|"))
        })
    }

    /// Whether client `c` is faulty, and how. Deterministic per
    /// `(seed, client)` and stable across rounds.
    pub fn fault_for(&self, seed: u64, client: usize) -> ClientFault {
        if self.fault_fraction <= 0.0 {
            return ClientFault::None;
        }
        let mut rng = Rng::new(seed ^ SALT_FAULT).split(client as u64);
        if rng.uniform() < self.fault_fraction.clamp(0.0, 1.0) {
            self.fault
        } else {
            ClientFault::None
        }
    }

    /// Whether client `c` has churned out for the epoch containing
    /// round `t`.
    fn churned_out(&self, seed: u64, round: usize, client: usize) -> bool {
        if self.churn <= 0.0 {
            return false;
        }
        let epoch = (round / CHURN_EPOCH_ROUNDS) as u64;
        let mut rng = Rng::new(seed ^ SALT_CHURN).split(epoch << 32 | client as u64);
        rng.uniform() < self.churn.clamp(0.0, 1.0)
    }

    /// Whether client `c`'s cohort suffers a correlated blackout in
    /// round `t`.
    fn cohort_drops(&self, seed: u64, round: usize, client: usize) -> bool {
        if self.correlated_dropout <= 0.0 {
            return false;
        }
        let cohort = (client % NUM_COHORTS) as u64;
        let mut rng = Rng::new(seed ^ SALT_COHORT).split((round as u64) << 16 | cohort);
        rng.uniform() < self.correlated_dropout.clamp(0.0, 1.0)
    }
}

/// The clients participating in round `t`: a uniformly random subset of
/// size `max(1, ⌈fraction·C⌉)`, sorted for deterministic iteration.
pub fn sample_active(c_num: usize, fraction: f64, seed: u64, round: usize) -> Vec<usize> {
    let take = ((fraction * c_num as f64).ceil() as usize).clamp(1, c_num);
    if take == c_num {
        return (0..c_num).collect();
    }
    let mut rng = Rng::new(seed ^ 0x5E1E_C700).split(round as u64);
    let mut perm = rng.permutation(c_num);
    perm.truncate(take);
    perm.sort_unstable();
    perm
}

/// Local iterations for client `c` in round `t` under the straggler
/// model: `s*` scaled by a draw from the shared timing-distribution
/// abstraction ([`Dist::StragglerScale`], i.e. `1 − jitter·u` with
/// `u ~ U[0,1)` per (round, client) — bitwise the historical model).
pub fn local_iters_for(cfg: &TrainConfig, round: usize, client: usize) -> usize {
    let dist = Dist::StragglerScale { jitter: cfg.straggler_jitter };
    if dist.is_unit() {
        return cfg.local_iters;
    }
    let mut rng =
        Rng::new(cfg.seed ^ 0x57A6_6000).split((round as u64) << 20 | client as u64);
    let scale = dist.sample(&mut rng);
    ((cfg.local_iters as f64 * scale).round() as usize).max(1)
}

/// Whether a sampled client drops out of round `t` *after* receiving the
/// broadcast (device churn, network loss). Deterministic per
/// `(seed, round, client)`.
fn drops_out(seed: u64, round: usize, client: usize, dropout: f64) -> bool {
    if dropout <= 0.0 {
        return false;
    }
    let mut rng = Rng::new(seed ^ 0xD809_0FF1).split((round as u64) << 20 | client as u64);
    rng.uniform() < dropout.clamp(0.0, 1.0)
}

/// Deterministic per-task RNG stream seed: a SplitMix64 finalizer over
/// `(run_seed, round, client)`. Distinct tasks get decorrelated streams;
/// the same task always gets the same stream regardless of executor.
/// Public because the async dispatcher derives per-client base seeds
/// from the same function (at `round = 0`) so a client's stream is
/// stable across schedules.
pub fn task_seed(run_seed: u64, round: usize, client: usize) -> u64 {
    let mut z = run_seed
        ^ 0x9E37_79B9_7F4A_7C15
        ^ ((round as u64) << 32)
        ^ (client as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One client's work item for a round: everything an executor needs to
/// run the client hermetically (no shared mutable state).
#[derive(Debug, Clone)]
pub struct ClientTask {
    /// Global client index `c ∈ [0, C)`.
    pub client_id: usize,
    /// Position within the round's roster — the index of this task's
    /// result in [`crate::engine::ExecReport::results`], and the index
    /// coordinators use for per-client round state (e.g. corrections).
    pub ordinal: usize,
    /// Local iterations `s*_c` for this round (straggler model applied).
    pub local_iters: usize,
    /// Normalized aggregation weight over the *surviving* roster.
    pub weight: f64,
    /// Per-(run, round, client) RNG stream seed.
    pub seed: u64,
    /// Fault injected into this client's upload
    /// ([`ClientFault::None`] for honest clients — the default).
    pub fault: ClientFault,
}

impl ClientTask {
    /// The task's private RNG stream. Two executors handing the same
    /// task to different threads observe identical streams.
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed)
    }
}

/// The schedule of one aggregation round: who participates, with what
/// weight, and how much local work each client performs.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub round: usize,
    /// Surviving tasks, sorted by `client_id`, `ordinal` = position.
    pub tasks: Vec<ClientTask>,
}

impl RoundPlan {
    /// Build the plan for round `t`: sample participants, apply dropout
    /// (keeping at least one client so the round stays well-defined),
    /// normalize aggregation weights over the survivors, and assign
    /// per-client iteration counts and RNG streams.
    ///
    /// `client_weight` is the problem's raw (unnormalized) aggregation
    /// weight, e.g. proportional to shard sizes; uniform weights yield
    /// exactly the `1/|active|` averaging of the paper's eq. 10.
    pub fn build(
        cfg: &TrainConfig,
        c_num: usize,
        round: usize,
        client_weight: impl Fn(usize) -> f64,
    ) -> RoundPlan {
        let sampled = sample_active(c_num, cfg.participation, cfg.seed, round);
        // Epoch-correlated churn thins the roster *before* dropout —
        // churned-out devices never saw the broadcast. Inactive
        // scenarios skip the filter entirely (bitwise-legacy plans).
        let present: Vec<usize> = if cfg.scenario.churn > 0.0 {
            let kept: Vec<usize> = sampled
                .iter()
                .copied()
                .filter(|&c| !cfg.scenario.churned_out(cfg.seed, round, c))
                .collect();
            if kept.is_empty() {
                vec![sampled[0]]
            } else {
                kept
            }
        } else {
            sampled
        };
        let survivors: Vec<usize> = if cfg.dropout <= 0.0 {
            present
        } else {
            let kept: Vec<usize> = present
                .iter()
                .copied()
                .filter(|&c| !drops_out(cfg.seed, round, c, cfg.dropout))
                .collect();
            if kept.is_empty() {
                vec![present[0]]
            } else {
                kept
            }
        };
        // Correlated blackout: whole cohorts vanish together.
        let survivors: Vec<usize> = if cfg.scenario.correlated_dropout > 0.0 {
            let kept: Vec<usize> = survivors
                .iter()
                .copied()
                .filter(|&c| !cfg.scenario.cohort_drops(cfg.seed, round, c))
                .collect();
            if kept.is_empty() {
                vec![survivors[0]]
            } else {
                kept
            }
        } else {
            survivors
        };
        let raw: Vec<f64> = survivors.iter().map(|&c| client_weight(c)).collect();
        let total: f64 = raw.iter().sum();
        let tasks = survivors
            .iter()
            .enumerate()
            .map(|(ordinal, &c)| ClientTask {
                client_id: c,
                ordinal,
                local_iters: local_iters_for(cfg, round, c),
                weight: raw[ordinal] / total,
                seed: task_seed(cfg.seed, round, c),
                fault: cfg.scenario.fault_for(cfg.seed, c),
            })
            .collect();
        RoundPlan { round, tasks }
    }

    /// Number of participating (surviving) clients.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// The participating client ids, in task order.
    pub fn client_ids(&self) -> Vec<usize> {
        self.tasks.iter().map(|t| t.client_id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_plan_covers_everyone_uniformly() {
        let cfg = TrainConfig { seed: 3, local_iters: 7, ..TrainConfig::default() };
        let plan = RoundPlan::build(&cfg, 5, 2, |_| 1.0);
        assert_eq!(plan.client_ids(), vec![0, 1, 2, 3, 4]);
        for (i, t) in plan.tasks.iter().enumerate() {
            assert_eq!(t.ordinal, i);
            assert_eq!(t.local_iters, 7);
            assert!((t.weight - 0.2).abs() < 1e-15);
        }
        let total: f64 = plan.tasks.iter().map(|t| t.weight).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn plan_is_deterministic_and_round_varying() {
        let cfg = TrainConfig {
            seed: 11,
            participation: 0.4,
            dropout: 0.2,
            straggler_jitter: 0.5,
            local_iters: 20,
            ..TrainConfig::default()
        };
        let a = RoundPlan::build(&cfg, 10, 4, |_| 1.0);
        let b = RoundPlan::build(&cfg, 10, 4, |_| 1.0);
        assert_eq!(a.client_ids(), b.client_ids());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.local_iters, y.local_iters);
            assert_eq!(x.weight.to_bits(), y.weight.to_bits());
        }
        // Different rounds reshuffle (almost surely, over many rounds).
        let differs = (0..50)
            .any(|t| RoundPlan::build(&cfg, 10, t, |_| 1.0).client_ids() != a.client_ids());
        assert!(differs);
    }

    #[test]
    fn dropout_never_empties_the_round() {
        let cfg = TrainConfig { seed: 5, dropout: 1.0, ..TrainConfig::default() };
        for t in 0..20 {
            let plan = RoundPlan::build(&cfg, 6, t, |_| 1.0);
            assert_eq!(plan.len(), 1, "total dropout must keep one client");
            assert!((plan.tasks[0].weight - 1.0).abs() < 1e-15);
        }
    }

    #[test]
    fn dropout_thins_the_roster_on_average() {
        let cfg = TrainConfig { seed: 9, dropout: 0.5, ..TrainConfig::default() };
        let total: usize = (0..100).map(|t| RoundPlan::build(&cfg, 8, t, |_| 1.0).len()).sum();
        // E ≈ 400 of 800 slots; generous tolerance.
        assert!((250..=550).contains(&total), "survivors {total}");
    }

    #[test]
    fn nonuniform_weights_are_normalized() {
        let cfg = TrainConfig::default();
        let plan = RoundPlan::build(&cfg, 4, 0, |c| (c + 1) as f64);
        let total: f64 = plan.tasks.iter().map(|t| t.weight).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!(plan.tasks[3].weight > plan.tasks[0].weight);
    }

    #[test]
    fn straggler_refactor_preserves_legacy_iters_bitwise() {
        // The historical closed form, recomputed by hand: routing
        // local_iters_for through Dist::StragglerScale must not change
        // a single iteration count under any (seed, round, client).
        for (seed, jitter) in [(3u64, 0.3f64), (17, 0.75), (99, 1.0)] {
            let cfg = TrainConfig {
                seed,
                straggler_jitter: jitter,
                local_iters: 20,
                ..TrainConfig::default()
            };
            for round in 0..6 {
                for client in 0..12 {
                    let mut rng = Rng::new(seed ^ 0x57A6_6000)
                        .split((round as u64) << 20 | client as u64);
                    let u = rng.uniform();
                    let scaled = 20.0 * (1.0 - jitter.clamp(0.0, 1.0) * u);
                    let want = (scaled.round() as usize).max(1);
                    assert_eq!(local_iters_for(&cfg, round, client), want);
                }
            }
        }
        // jitter = 0 keeps the untouched early return (no .max(1)).
        let cfg = TrainConfig { straggler_jitter: 0.0, local_iters: 0, ..TrainConfig::default() };
        assert_eq!(local_iters_for(&cfg, 0, 0), 0);
    }

    #[test]
    fn default_scenario_leaves_plans_bitwise_unchanged() {
        // The calm scenario must be structurally inert: same roster,
        // same weights (bitwise), no fault draws.
        let cfg = TrainConfig {
            seed: 11,
            participation: 0.6,
            dropout: 0.3,
            straggler_jitter: 0.5,
            local_iters: 9,
            ..TrainConfig::default()
        };
        assert_eq!(cfg.scenario, ScenarioConfig::default());
        for round in 0..10 {
            let plan = RoundPlan::build(&cfg, 12, round, |c| (c + 1) as f64);
            // Reproduce the legacy builder by hand: sample + dropout.
            let sampled = sample_active(12, cfg.participation, cfg.seed, round);
            let kept: Vec<usize> = sampled
                .iter()
                .copied()
                .filter(|&c| {
                    let mut rng = Rng::new(cfg.seed ^ 0xD809_0FF1)
                        .split((round as u64) << 20 | c as u64);
                    !(rng.uniform() < cfg.dropout)
                })
                .collect();
            let want = if kept.is_empty() { vec![sampled[0]] } else { kept };
            assert_eq!(plan.client_ids(), want);
            let total: f64 = want.iter().map(|&c| (c + 1) as f64).sum();
            for (i, t) in plan.tasks.iter().enumerate() {
                assert_eq!(t.fault, ClientFault::None);
                assert_eq!(t.weight.to_bits(), ((want[i] + 1) as f64 / total).to_bits());
            }
        }
    }

    #[test]
    fn churn_is_epoch_correlated() {
        let scenario = ScenarioConfig { churn: 0.4, ..ScenarioConfig::default() };
        let cfg = TrainConfig { seed: 7, scenario, ..TrainConfig::default() };
        // Within one epoch a client's presence never flickers.
        for client in 0..16 {
            for epoch in 0..6 {
                let r0 = epoch * CHURN_EPOCH_ROUNDS;
                let first = scenario.churned_out(cfg.seed, r0, client);
                for dr in 1..CHURN_EPOCH_ROUNDS {
                    assert_eq!(first, scenario.churned_out(cfg.seed, r0 + dr, client));
                }
            }
        }
        // Plans exclude churned clients; some epoch actually churns.
        let mut saw_churn = false;
        for round in 0..30 {
            let plan = RoundPlan::build(&cfg, 16, round, |_| 1.0);
            for t in &plan.tasks {
                assert!(!scenario.churned_out(cfg.seed, round, t.client_id));
            }
            if plan.len() < 16 {
                saw_churn = true;
            }
        }
        assert!(saw_churn, "churn 0.4 over 30 rounds must thin some roster");
    }

    #[test]
    fn correlated_dropout_removes_whole_cohorts() {
        let scenario =
            ScenarioConfig { correlated_dropout: 0.5, ..ScenarioConfig::default() };
        let cfg = TrainConfig { seed: 13, scenario, ..TrainConfig::default() };
        let c_num = 4 * NUM_COHORTS;
        let mut saw_blackout = false;
        for round in 0..20 {
            let plan = RoundPlan::build(&cfg, c_num, round, |_| 1.0);
            let ids = plan.client_ids();
            // A cohort is either fully present or fully absent (modulo
            // the keep-one fallback, which only fires on empty rosters).
            if ids.len() > 1 {
                let present: Vec<bool> = (0..NUM_COHORTS)
                    .map(|k| ids.iter().any(|&c| c % NUM_COHORTS == k))
                    .collect();
                for &c in &ids {
                    assert!(present[c % NUM_COHORTS]);
                }
                for k in 0..NUM_COHORTS {
                    let members = (0..c_num).filter(|c| c % NUM_COHORTS == k);
                    let got: Vec<usize> =
                        ids.iter().copied().filter(|c| c % NUM_COHORTS == k).collect();
                    if present[k] {
                        assert_eq!(got, members.collect::<Vec<_>>());
                    } else {
                        assert!(got.is_empty());
                    }
                }
            }
            if ids.len() < c_num {
                saw_blackout = true;
            }
        }
        assert!(saw_blackout);
    }

    #[test]
    fn fault_assignment_is_stable_and_fractional() {
        let scenario = ScenarioConfig {
            fault_fraction: 0.25,
            fault: ClientFault::Byzantine { scale: 1.0 },
            ..ScenarioConfig::default()
        };
        let faulty: Vec<usize> = (0..400)
            .filter(|&c| scenario.fault_for(42, c) != ClientFault::None)
            .collect();
        // Stable across repeated queries (and hence across rounds).
        for &c in &faulty {
            assert_eq!(scenario.fault_for(42, c), ClientFault::Byzantine { scale: 1.0 });
        }
        // Roughly a quarter of the fleet (generous tolerance).
        assert!((60..=140).contains(&faulty.len()), "faulty {}", faulty.len());
        // Different run seeds compromise different devices.
        let other: Vec<usize> = (0..400)
            .filter(|&c| scenario.fault_for(43, c) != ClientFault::None)
            .collect();
        assert_ne!(faulty, other);
    }

    #[test]
    fn scenario_presets_parse_and_roundtrip() {
        for p in ScenarioConfig::presets() {
            assert_eq!(ScenarioConfig::parse(p.name).unwrap(), p);
        }
        assert!(ScenarioConfig::parse("nope").is_err());
        assert!(!ScenarioConfig::default().is_active());
        assert!(ScenarioConfig::parse("hellscape").unwrap().is_active());
    }

    #[test]
    fn task_streams_are_distinct_and_stable() {
        let cfg = TrainConfig { seed: 21, ..TrainConfig::default() };
        let plan = RoundPlan::build(&cfg, 6, 3, |_| 1.0);
        for i in 0..plan.len() {
            for j in (i + 1)..plan.len() {
                assert_ne!(plan.tasks[i].seed, plan.tasks[j].seed);
            }
        }
        let mut a = plan.tasks[2].rng();
        let mut b = plan.tasks[2].rng();
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
