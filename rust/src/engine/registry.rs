//! Sharded, lazily materialized client state for population-scale runs.
//!
//! The async simulator registers C = 10^5–10^6 clients but has only
//! hundreds in flight at once. Allocating per-client state up front
//! would cost O(C) memory before the first dispatch; instead the
//! registry is a vector of *shard slots*, each materialized on first
//! touch. A [`ClientRecord`] is deliberately lightweight — seed, weight,
//! local-step counter, frozen speed, and a codec-residual slot — so a
//! million-client registry touching a few thousand distinct clients
//! costs megabytes, not gigabytes. Full per-client scratch (model
//! snapshot, optimizer state, gradient buffers) is built only while the
//! client is in flight and dropped at upload.
//!
//! Shard allocations are reported to the observability workspace
//! counters ([`crate::obsv::counters::note_workspace_take`]), so the
//! process-wide `ws_bytes_hwm` high-water mark bounds resident client
//! state — the number `benches/async_scale.rs` asserts its RSS budget
//! against in CI.

use crate::obsv::counters::{note_workspace_give, note_workspace_take};

/// One registered client's persistent state between dispatches.
#[derive(Debug, Clone, Default)]
pub struct ClientRecord {
    /// Base RNG stream seed (per-dispatch streams split off this).
    pub seed: u64,
    /// Raw (unnormalized) aggregation weight.
    pub weight: f64,
    /// Local-step counter: the client's mini-batch schedule resumes
    /// where its previous dispatch stopped.
    pub next_step: u64,
    /// Frozen heterogeneity speed multiplier (see
    /// [`crate::engine::TimingModel::client_speed`]).
    pub speed: f64,
    /// Residual slot for error-feedback wire codecs (unused by the
    /// current stateless codecs; reserved so codec state has a home
    /// that survives between a client's dispatches).
    pub residual: Option<Vec<f64>>,
    /// Drift-correction state (FedDyn's `h_c`, SCAFFOLD's `c_c`), boxed
    /// so honest-majority fleets with no correction pay one pointer per
    /// record. Lives here — not in coordinator-local maps — so it
    /// survives lazy materialization at large C and is dropped with the
    /// shard (see `crate::client::drift`).
    pub drift: Option<Box<crate::client::DriftState>>,
}

/// Registry of `population` client records in lazily materialized
/// shards of `shard_size` records each.
#[derive(Debug)]
pub struct ClientRegistry {
    population: usize,
    shard_size: usize,
    shards: Vec<Option<Box<[ClientRecord]>>>,
    materialized: usize,
}

impl ClientRegistry {
    /// Default shard size: small enough that sparse uniform sampling
    /// out of 10^6 clients materializes kilobytes per new shard, large
    /// enough that dense populations stay a handful of allocations.
    pub const DEFAULT_SHARD: usize = 256;

    pub fn new(population: usize, shard_size: usize) -> ClientRegistry {
        assert!(population > 0 && shard_size > 0);
        let num_shards = population.div_ceil(shard_size);
        ClientRegistry {
            population,
            shard_size,
            shards: vec![None; num_shards],
            materialized: 0,
        }
    }

    pub fn population(&self) -> usize {
        self.population
    }

    /// Number of shards currently materialized.
    pub fn materialized_shards(&self) -> usize {
        self.materialized
    }

    /// Approximate bytes of materialized record storage (what the
    /// workspace counters were fed).
    pub fn record_bytes(&self) -> u64 {
        self.materialized as u64 * Self::shard_bytes(self.shard_size)
    }

    fn shard_bytes(shard_size: usize) -> u64 {
        (shard_size * std::mem::size_of::<ClientRecord>()) as u64
    }

    /// Mutable access to client `id`'s record, materializing its shard
    /// on first touch with `init(client_id)` for every record in the
    /// shard (records must be a pure function of the id so lazy
    /// materialization is order-independent).
    pub fn get_or_init(
        &mut self,
        id: usize,
        init: impl Fn(usize) -> ClientRecord,
    ) -> &mut ClientRecord {
        assert!(id < self.population, "client {id} out of population {}", self.population);
        let shard = id / self.shard_size;
        if self.shards[shard].is_none() {
            let lo = shard * self.shard_size;
            let hi = (lo + self.shard_size).min(self.population);
            // The tail shard is padded with defaults to keep shard
            // byte accounting uniform.
            let records: Vec<ClientRecord> = (lo..lo + self.shard_size)
                .map(|c| if c < hi { init(c) } else { ClientRecord::default() })
                .collect();
            note_workspace_take(Self::shard_bytes(self.shard_size));
            self.materialized += 1;
            self.shards[shard] = Some(records.into_boxed_slice());
        }
        &mut self.shards[shard].as_mut().unwrap()[id % self.shard_size]
    }

    /// Read-only view of client `id`'s record, if its shard has been
    /// materialized.
    pub fn get(&self, id: usize) -> Option<&ClientRecord> {
        let shard = id / self.shard_size;
        self.shards
            .get(shard)?
            .as_ref()
            .map(|s| &s[id % self.shard_size])
    }

    /// Visit every materialized record in client-id order (tail padding
    /// excluded). Used by the drift-correction layer to project stored
    /// client state through a server basis change — only clients that
    /// ever materialized can hold state, so this is O(touched), not
    /// O(population).
    pub fn for_each_materialized(&mut self, mut f: impl FnMut(usize, &mut ClientRecord)) {
        for (si, slot) in self.shards.iter_mut().enumerate() {
            if let Some(records) = slot {
                let lo = si * self.shard_size;
                for (i, rec) in records.iter_mut().enumerate() {
                    if lo + i < self.population {
                        f(lo + i, rec);
                    }
                }
            }
        }
    }
}

impl Drop for ClientRegistry {
    fn drop(&mut self) {
        // Return the materialized shard bytes to the workspace
        // accounting so back-to-back runs don't ratchet `ws_bytes_out`.
        note_workspace_give(self.materialized as u64 * Self::shard_bytes(self.shard_size));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init(c: usize) -> ClientRecord {
        ClientRecord {
            seed: c as u64 * 7 + 1,
            weight: 1.0 + c as f64,
            speed: 1.0,
            ..ClientRecord::default()
        }
    }

    #[test]
    fn lazy_materialization_touches_only_needed_shards() {
        let mut reg = ClientRegistry::new(1_000_000, 256);
        assert_eq!(reg.materialized_shards(), 0);
        assert_eq!(reg.record_bytes(), 0);
        reg.get_or_init(3, init);
        reg.get_or_init(5, init); // same shard
        assert_eq!(reg.materialized_shards(), 1);
        reg.get_or_init(999_999, init); // tail shard
        assert_eq!(reg.materialized_shards(), 2);
        // Records are what init produced, and persist across touches.
        assert_eq!(reg.get(5).unwrap().seed, 5 * 7 + 1);
        reg.get_or_init(5, init).next_step = 42;
        assert_eq!(reg.get(5).unwrap().next_step, 42);
        // Untouched shards stay unmaterialized.
        assert!(reg.get(100_000).is_none());
    }

    #[test]
    fn million_client_registry_is_cheap_until_touched() {
        let reg = ClientRegistry::new(1_000_000, 256);
        // The slot vector is the only up-front cost: one Option per
        // shard, no records.
        assert_eq!(reg.population(), 1_000_000);
        assert_eq!(reg.record_bytes(), 0);
        // Touching k scattered clients materializes ≤ k shards.
        let mut reg = reg;
        for i in 0..200 {
            reg.get_or_init((i * 4999) % 1_000_000, init);
        }
        assert!(reg.materialized_shards() <= 200);
        // ~56 B/record × 256 records/shard × ≤200 shards ≈ ≤ 4 MB.
        assert!(reg.record_bytes() < 8 << 20, "bytes {}", reg.record_bytes());
    }

    #[test]
    fn workspace_accounting_take_and_give_balance() {
        let before = crate::obsv::counters_snapshot();
        {
            let mut reg = ClientRegistry::new(4096, 256);
            for c in (0..4096).step_by(256) {
                reg.get_or_init(c, init);
            }
            let mid = crate::obsv::counters_snapshot();
            assert!(mid.ws_bytes_out >= before.ws_bytes_out + reg.record_bytes());
        }
        // Drop gave everything back (other tests may move the counter
        // concurrently; assert we are not ratcheting by our own 16
        // shards' worth).
        let after = crate::obsv::counters_snapshot();
        let shard = 256 * std::mem::size_of::<ClientRecord>() as u64;
        assert!(after.ws_bytes_out < before.ws_bytes_out + 16 * shard);
    }

    #[test]
    #[should_panic]
    fn out_of_population_panics() {
        let mut reg = ClientRegistry::new(100, 16);
        reg.get_or_init(100, init);
    }
}
