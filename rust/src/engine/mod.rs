//! Client execution engine: round scheduling + pluggable executors.
//!
//! The coordinator layer describes *what* every participating client
//! must do in an aggregation round; this subsystem decides *which*
//! clients run ([`RoundPlan`] — participation sampling, dropout, and
//! straggler skew in one place) and *how* their work is executed
//! ([`ClientExecutor`] — serially, or sharded across OS threads).
//!
//! Two invariants make the engine safe to drop under any coordinator:
//!
//! 1. **Determinism.** A [`RoundPlan`] is a pure function of
//!    `(TrainConfig, round)`; every [`ClientTask`] carries its own RNG
//!    stream seed `f(run_seed, round, client_id)`. Executors return
//!    results in task order and never fold across clients themselves —
//!    the coordinator reduces in plan order — so [`SerialExecutor`] and
//!    [`ThreadPoolExecutor`] produce **bitwise-identical**
//!    [`crate::metrics::RunRecord`]s for the same seed (asserted by
//!    `tests/engine_determinism.rs`).
//! 2. **Honest accounting.** [`ExecReport`] reports both the parallel
//!    wall-clock and the serial-equivalent (sum of per-client) time, so
//!    [`crate::metrics::RoundMetrics`] can report simulation speedup
//!    without contaminating the paper's communication metrics.
//!
//! Beyond the lockstep round loop, the engine also hosts the
//! **event-driven async layer**: a virtual-clock discrete-event queue
//! ([`EventQueue`], deterministic `(time, seq)` total order), the
//! client-timing distributions shared by sync stragglers and async
//! arrival/compute/link draws ([`Dist`] / [`TimingModel`]), and the
//! sharded lazily-materialized client registry ([`ClientRegistry`])
//! that scales registration to C = 10^6 while keeping resident state
//! proportional to the number of *in-flight* clients. The async
//! coordinator (`coordinator::async_server`) composes these with the
//! same executors and per-task RNG streams as the sync path.

pub mod dist;
pub mod event;
pub mod executor;
pub mod plan;
pub mod registry;

pub use dist::{Dist, TimingModel};
pub use event::{Event, EventQueue};
pub use executor::{
    ClientExecutor, ExecReport, ExecTiming, Executor, ExecutorKind, SerialExecutor, TaskTiming,
    ThreadPoolExecutor,
};
pub use plan::{
    local_iters_for, sample_active, task_seed, ClientFault, ClientTask, RoundPlan,
    ScenarioConfig,
};
pub use registry::{ClientRecord, ClientRegistry};
