//! Pluggable client executors: run a [`RoundPlan`]'s tasks serially or
//! sharded across OS threads, with identical results either way.
//!
//! The contract that makes parallelism safe to drop under any
//! coordinator:
//!
//! * the work closure is a pure function of its [`ClientTask`] (plus
//!   immutable round state captured by reference),
//! * results come back **in task order**, and
//! * all floating-point *reduction* stays in the coordinator, which
//!   folds the returned per-client results in plan order.
//!
//! Under those rules thread scheduling cannot perturb a single bit of
//! the training trajectory — only the wall-clock, which [`ExecReport`]
//! measures both ways (parallel and serial-equivalent) so benches can
//! report simulation speedup.

use std::time::Instant;

use super::plan::{ClientTask, RoundPlan};

/// Which execution engine a run uses (threaded through
/// [`crate::coordinator::TrainConfig`] and the CLI's `--executor`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Reference semantics: clients run one after another.
    Serial,
    /// Clients sharded across `threads` OS threads (`0` = one per
    /// available core).
    ThreadPool { threads: usize },
}

impl Default for ExecutorKind {
    fn default() -> Self {
        ExecutorKind::Serial
    }
}

impl ExecutorKind {
    /// Stable label for config echoes and JSON output.
    pub fn label(&self) -> String {
        match *self {
            ExecutorKind::Serial => "serial".to_string(),
            ExecutorKind::ThreadPool { threads: 0 } => "threads:auto".to_string(),
            ExecutorKind::ThreadPool { threads } => format!("threads:{threads}"),
        }
    }

    /// Parse a CLI spelling: `serial`, `threads`, `threads:auto`, or
    /// `threads:N`.
    pub fn parse(s: &str) -> Result<ExecutorKind, String> {
        match s {
            "serial" => Ok(ExecutorKind::Serial),
            "threads" | "threads:auto" => Ok(ExecutorKind::ThreadPool { threads: 0 }),
            other => other
                .strip_prefix("threads:")
                .and_then(|n| n.parse::<usize>().ok())
                .map(|threads| ExecutorKind::ThreadPool { threads })
                .ok_or_else(|| {
                    format!("unknown executor '{other}' (expected serial|threads|threads:N)")
                }),
        }
    }
}

/// When one task ran: offsets on the executor call's single monotonic
/// clock ([`ExecTiming::started`]), measured on the worker that ran it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskTiming {
    /// Seconds from [`ExecTiming::started`] to the task starting.
    pub start_s: f64,
    /// Task duration in seconds (`end offset − start offset`, same
    /// clock — so sums of durations and the latency histograms built
    /// from them are directly comparable to `serial_s`).
    pub dur_s: f64,
    /// Index of the worker (chunk) that ran the task; `0` for the
    /// serial executor. Trace export maps this to a per-worker track.
    pub worker: usize,
}

/// The executor call's single monotonic epoch — the one place in the
/// engine that reads the wall clock (fedlint rule D2 allowlists exactly
/// this file for `Instant::now`). Both executors stamp every task
/// through [`ExecClock::timed`], so serial and thread-pool paths share
/// one capture site and one clock by construction.
#[derive(Debug, Clone, Copy)]
struct ExecClock {
    started: Instant,
}

impl ExecClock {
    fn start() -> ExecClock {
        ExecClock { started: Instant::now() }
    }

    /// Seconds elapsed since the call epoch.
    fn offset_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Run `work`, stamping its start/duration offsets on this clock
    /// (same clock as `wall_s`/`serial_s`, so the per-task durations
    /// and the latency histograms built from them are comparable).
    fn timed<R>(&self, worker: usize, work: impl FnOnce() -> R) -> (R, TaskTiming) {
        let t0 = self.offset_s();
        let r = work();
        let t1 = self.offset_s();
        (r, TaskTiming { start_s: t0, dur_s: t1 - t0, worker })
    }
}

/// Per-task timings of one executor call, all offsets from one
/// `Instant` read at call entry.
#[derive(Debug)]
pub struct ExecTiming {
    /// The call's epoch: every [`TaskTiming`] offset is relative to
    /// this instant, and `wall_s` is its total elapsed.
    pub started: Instant,
    /// One entry per [`ClientTask`], in `ordinal` order (same order as
    /// [`ExecReport::results`]).
    pub tasks: Vec<TaskTiming>,
}

/// What an executor hands back: per-task results in task order plus the
/// two wall-clock views of the same work.
#[derive(Debug)]
pub struct ExecReport<R> {
    /// One entry per [`ClientTask`], in `ordinal` order.
    pub results: Vec<R>,
    /// Elapsed wall-clock of the whole execution (parallel time).
    pub wall_s: f64,
    /// Serial-equivalent time: Σ over tasks of per-task wall-clock,
    /// folded in task order. Defined as exactly the sum of
    /// `timing.tasks[i].dur_s` — same monotonic clock, same numbers —
    /// so for the serial executor this equals the per-client latency
    /// histogram's total bitwise (tasks are planned in ascending client
    /// id). `serial_s / wall_s` is the executor's realized speedup.
    pub serial_s: f64,
    /// Per-task start/duration/worker timings (feeds
    /// [`crate::obsv::Recorder::record_exec`]).
    pub timing: ExecTiming,
}

/// A strategy for executing one round's client work items.
pub trait ClientExecutor {
    fn name(&self) -> &'static str;

    /// Run `work` on every task of `plan`; results in task order.
    fn execute<R, F>(&self, plan: &RoundPlan, work: F) -> ExecReport<R>
    where
        R: Send,
        F: Fn(&ClientTask) -> R + Sync;
}

fn run_serial<R, F>(plan: &RoundPlan, work: &F) -> ExecReport<R>
where
    F: Fn(&ClientTask) -> R,
{
    let clock = ExecClock::start();
    let mut results = Vec::with_capacity(plan.tasks.len());
    let mut tasks = Vec::with_capacity(plan.tasks.len());
    for task in &plan.tasks {
        let (r, t) = clock.timed(0, || work(task));
        results.push(r);
        tasks.push(t);
    }
    let serial_s = tasks.iter().map(|t| t.dur_s).sum();
    ExecReport {
        results,
        wall_s: clock.offset_s(),
        serial_s,
        timing: ExecTiming { started: clock.started, tasks },
    }
}

/// The reference executor: clients run one after another on the calling
/// thread (the seed repo's original behavior).
#[derive(Debug, Clone, Copy, Default)]
pub struct SerialExecutor;

impl ClientExecutor for SerialExecutor {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn execute<R, F>(&self, plan: &RoundPlan, work: F) -> ExecReport<R>
    where
        R: Send,
        F: Fn(&ClientTask) -> R + Sync,
    {
        run_serial(plan, &work)
    }
}

/// Shards the plan's tasks into contiguous chunks, one scoped OS thread
/// per chunk. Chunking (rather than work-stealing) keeps the
/// result-assembly order trivially deterministic.
///
/// Workers are **scoped threads spawned per `execute` call**, not a
/// persistent pool: spawn cost (~tens of µs per worker, ≤3 calls per
/// round) is negligible next to a client's local-iteration work, and
/// scoped borrows keep the work closure free of `'static` bounds. If a
/// future workload makes spawn cost measurable, swap in persistent
/// workers behind this same type without touching the coordinators.
/// Requested worker counts are capped at the machine's core count —
/// oversubscription would corrupt the serial-equivalent timing (see
/// `effective_threads`).
#[derive(Debug, Clone, Copy)]
pub struct ThreadPoolExecutor {
    /// Worker count; `0` = one per available core.
    pub threads: usize,
}

impl ThreadPoolExecutor {
    pub fn new(threads: usize) -> ThreadPoolExecutor {
        ThreadPoolExecutor { threads }
    }

    fn effective_threads(&self, num_tasks: usize) -> usize {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        // Cap at the core count even when more workers are requested:
        // oversubscribed workers only add scheduling noise, and worse,
        // they inflate the per-task wall-clock that feeds the
        // serial-equivalent metric (a descheduled task still "runs" on
        // its stopwatch), turning the reported speedup into fiction.
        let configured = if self.threads == 0 { cores } else { self.threads.min(cores) };
        configured.min(num_tasks).max(1)
    }
}

impl ClientExecutor for ThreadPoolExecutor {
    fn name(&self) -> &'static str {
        "thread_pool"
    }

    fn execute<R, F>(&self, plan: &RoundPlan, work: F) -> ExecReport<R>
    where
        R: Send,
        F: Fn(&ClientTask) -> R + Sync,
    {
        let n = plan.tasks.len();
        let workers = self.effective_threads(n);
        if workers <= 1 || n <= 1 {
            return run_serial(plan, &work);
        }
        let clock = ExecClock::start();
        let chunk = (n + workers - 1) / workers;
        let work_ref = &work;
        let per_chunk: Vec<Vec<(R, TaskTiming)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = plan
                .tasks
                .chunks(chunk)
                .enumerate()
                .map(|(worker, tasks)| {
                    scope.spawn(move || {
                        tasks
                            .iter()
                            .map(|task| clock.timed(worker, || work_ref(task)))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("client worker panicked")).collect()
        });
        let mut serial_s = 0.0;
        let mut results = Vec::with_capacity(n);
        let mut tasks = Vec::with_capacity(n);
        for chunk_results in per_chunk {
            for (r, t) in chunk_results {
                serial_s += t.dur_s;
                results.push(r);
                tasks.push(t);
            }
        }
        ExecReport {
            results,
            wall_s: clock.offset_s(),
            serial_s,
            timing: ExecTiming { started: clock.started, tasks },
        }
    }
}

/// Config-driven executor choice, used by the coordinators.
#[derive(Debug, Clone, Copy)]
pub enum Executor {
    Serial(SerialExecutor),
    ThreadPool(ThreadPoolExecutor),
}

impl Executor {
    pub fn from_kind(kind: ExecutorKind) -> Executor {
        match kind {
            ExecutorKind::Serial => Executor::Serial(SerialExecutor),
            ExecutorKind::ThreadPool { threads } => {
                Executor::ThreadPool(ThreadPoolExecutor::new(threads))
            }
        }
    }
}

impl ClientExecutor for Executor {
    fn name(&self) -> &'static str {
        match self {
            Executor::Serial(e) => e.name(),
            Executor::ThreadPool(e) => e.name(),
        }
    }

    fn execute<R, F>(&self, plan: &RoundPlan, work: F) -> ExecReport<R>
    where
        R: Send,
        F: Fn(&ClientTask) -> R + Sync,
    {
        match self {
            Executor::Serial(e) => e.execute(plan, work),
            Executor::ThreadPool(e) => e.execute(plan, work),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;

    fn plan(c_num: usize) -> RoundPlan {
        let cfg = TrainConfig { seed: 7, local_iters: 5, ..TrainConfig::default() };
        RoundPlan::build(&cfg, c_num, 0, |_| 1.0)
    }

    #[test]
    fn serial_and_threaded_agree_in_order_and_value() {
        let p = plan(13);
        let f = |t: &ClientTask| (t.client_id * 10 + t.ordinal) as u64 + t.seed % 7;
        let a = SerialExecutor.execute(&p, f);
        for threads in [2, 3, 4, 8, 32] {
            let b = ThreadPoolExecutor::new(threads).execute(&p, f);
            assert_eq!(a.results, b.results, "threads={threads}");
        }
    }

    #[test]
    fn per_task_rng_streams_match_across_executors() {
        let p = plan(9);
        let f = |t: &ClientTask| {
            let mut rng = t.rng();
            (0..8).map(|_| rng.next_u64()).collect::<Vec<_>>()
        };
        let a = SerialExecutor.execute(&p, f);
        let b = ThreadPoolExecutor::new(4).execute(&p, f);
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn report_times_are_sane() {
        let p = plan(6);
        let rep = ThreadPoolExecutor::new(3).execute(&p, |t| {
            let mut acc = 0u64;
            for i in 0..20_000u64 {
                acc = acc.wrapping_add(i.wrapping_mul(t.seed | 1));
            }
            std::hint::black_box(acc)
        });
        assert_eq!(rep.results.len(), 6);
        assert!(rep.wall_s >= 0.0 && rep.serial_s >= 0.0);
        assert_eq!(rep.timing.tasks.len(), 6);
        for t in &rep.timing.tasks {
            assert!(t.worker < 3);
            assert!(t.start_s >= 0.0 && t.dur_s >= 0.0);
            // Every task ran inside the call's wall-clock window (same
            // monotonic clock, so the comparison is meaningful).
            assert!(t.start_s + t.dur_s <= rep.wall_s + 1e-6);
        }
    }

    #[test]
    fn serial_s_is_exactly_the_timing_sum() {
        // Satellite contract: serial_s is *defined* as the task-order
        // sum of per-task durations on the call's single monotonic
        // clock — the same samples the latency histograms are built
        // from — so the equality is bitwise, for both executors.
        let p = plan(7);
        for rep in [
            SerialExecutor.execute(&p, |t| t.seed),
            ThreadPoolExecutor::new(3).execute(&p, |t| t.seed),
        ] {
            let sum: f64 = rep.timing.tasks.iter().map(|t| t.dur_s).sum();
            assert_eq!(rep.serial_s, sum);
            assert_eq!(rep.timing.tasks.len(), rep.results.len());
        }
        let serial = SerialExecutor.execute(&p, |t| t.seed);
        assert!(serial.timing.tasks.iter().all(|t| t.worker == 0));
    }

    #[test]
    fn singleton_and_empty_plans() {
        let p1 = plan(1);
        let rep = ThreadPoolExecutor::new(8).execute(&p1, |t| t.client_id);
        assert_eq!(rep.results, vec![0]);
        let p0 = RoundPlan { round: 0, tasks: vec![] };
        let rep0 = ThreadPoolExecutor::new(8).execute(&p0, |t| t.client_id);
        assert!(rep0.results.is_empty());
    }

    #[test]
    fn kind_parse_and_label_roundtrip() {
        assert_eq!(ExecutorKind::parse("serial").unwrap(), ExecutorKind::Serial);
        assert_eq!(
            ExecutorKind::parse("threads").unwrap(),
            ExecutorKind::ThreadPool { threads: 0 }
        );
        assert_eq!(
            ExecutorKind::parse("threads:6").unwrap(),
            ExecutorKind::ThreadPool { threads: 6 }
        );
        assert!(ExecutorKind::parse("gpu").is_err());
        assert_eq!(ExecutorKind::Serial.label(), "serial");
        assert_eq!(ExecutorKind::ThreadPool { threads: 0 }.label(), "threads:auto");
        assert_eq!(ExecutorKind::ThreadPool { threads: 4 }.label(), "threads:4");
    }

    #[test]
    fn executor_dispatch_matches_concrete_types() {
        let p = plan(5);
        let f = |t: &ClientTask| t.seed;
        let via_enum = Executor::from_kind(ExecutorKind::ThreadPool { threads: 2 });
        assert_eq!(via_enum.name(), "thread_pool");
        assert_eq!(
            via_enum.execute(&p, f).results,
            SerialExecutor.execute(&p, f).results
        );
    }
}
