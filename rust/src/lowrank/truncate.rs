//! Automatic compression via rank truncation (Algorithm 1 lines 16–18).
//!
//! After aggregation, the server holds `S̃* = mean_c S̃_c^{s*}` on the
//! *shared* augmented bases — so, unlike other federated low-rank schemes
//! (eq. 10 discussion), the SVD needed to re-compress is only `2r×2r`:
//!
//! ```text
//! P_{r₁}, Σ_{r₁}, Q_{r₁} = svd(S̃*)   with  ‖[σ_{r₁+1}…σ_{2r}]‖₂ < ϑ
//! U^{t+1} = Ũ P_{r₁},  V^{t+1} = Ṽ Q_{r₁},  S^{t+1} = Σ_{r₁}
//! ```
//!
//! This keeps `S` full-rank diagonal (required by the robust-splitting
//! consistency, Appendix D) and bounds the compression error by `ϑ`,
//! which is exactly the `+Lϑ` term in Theorems 2–4.

use crate::linalg::svd_ws;
use crate::tensor::{matmul, Matrix, Workspace};

use super::factorization::LowRank;

/// Outcome of a truncation step.
#[derive(Debug, Clone)]
pub struct TruncationResult {
    /// The compressed factorization (rank `r₁`).
    pub fac: LowRank,
    /// Discarded tail energy `‖[σ_{r₁+1}…]‖₂` (≤ ϑ by construction).
    pub discarded: f64,
    /// All singular values of `S̃*` (diagnostics / Fig 4 rank plots).
    pub sigma: Vec<f64>,
}

/// Truncate the aggregated augmented state `(Ũ, S̃*, Ṽ)`.
///
/// * `theta` — absolute singular-value tail threshold `ϑ`. The paper uses
///   the relative rule `ϑ = τ‖S̃*‖₂`; callers compute that (see
///   [`relative_threshold`]).
/// * `min_rank` / `max_rank` — clamp the new rank (max_rank enforces the
///   static-shape cap; min_rank ≥ 1 keeps the factorization non-empty).
pub fn truncate(
    u_tilde: &Matrix,
    s_star: &Matrix,
    v_tilde: &Matrix,
    theta: f64,
    min_rank: usize,
    max_rank: usize,
) -> TruncationResult {
    let mut ws = Workspace::new();
    truncate_ws(u_tilde, s_star, v_tilde, theta, min_rank, max_rank, &mut ws)
}

/// [`truncate`] with caller-owned scratch: the 2r×2r SVD's working
/// matrices come from `ws` and return to it, so the per-round
/// compression step reuses its buffers across rounds. The truncated
/// factors are fresh allocations — they become the next round's state.
#[allow(clippy::too_many_arguments)]
pub fn truncate_ws(
    u_tilde: &Matrix,
    s_star: &Matrix,
    v_tilde: &Matrix,
    theta: f64,
    min_rank: usize,
    max_rank: usize,
    ws: &mut Workspace,
) -> TruncationResult {
    let dec = svd_ws(s_star, ws);
    let r1 = dec.rank_for_tolerance(theta).clamp(min_rank.max(1), max_rank);
    let (p, sig, q) = dec.truncate(r1);
    let discarded = dec.sigma_fro_tail(r1);

    // Project the bases: U' = Ũ P, V' = Ṽ Q — still orthonormal because
    // P, Q have orthonormal columns.
    let u_new = matmul(u_tilde, &p);
    let v_new = matmul(v_tilde, &q);
    let fac = LowRank { u: u_new, s: Matrix::diag(&sig), v: v_new };

    TruncationResult { fac, discarded, sigma: dec.sigma }
}

/// The paper's relative threshold rule `ϑ = τ‖S̃*‖` (Frobenius norm, as
/// used in the numerical section: `ϑ = τ‖S̃*‖` with τ=0.1 / 0.01).
pub fn relative_threshold(s_star: &Matrix, tau: f64) -> f64 {
    tau * s_star.fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::augment::augment_basis;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Build an augmented state whose S̃* has a known spectrum.
    fn augmented_state(
        m: usize,
        r2: usize,
        sigma: &[f64],
        seed: u64,
    ) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let u_tilde = crate::linalg::random_orthonormal(m, r2, &mut rng);
        let v_tilde = crate::linalg::random_orthonormal(m, r2, &mut rng);
        // S* with prescribed singular values via random rotations.
        let p = crate::linalg::random_orthonormal(r2, r2, &mut rng);
        let q = crate::linalg::random_orthonormal(r2, r2, &mut rng);
        let s_star = crate::tensor::matmul_nt(&matmul(&p, &Matrix::diag(sigma)), &q);
        (u_tilde, s_star, v_tilde)
    }

    #[test]
    fn truncation_discards_small_tail_only() {
        let sigma = [5.0, 2.0, 1e-6, 1e-8];
        let (u, s, v) = augmented_state(20, 4, &sigma, 501);
        let res = truncate(&u, &s, &v, 1e-3, 1, 4);
        assert_eq!(res.fac.rank(), 2);
        assert!(res.discarded < 1e-3);
        assert!(res.fac.validate() < 1e-9);
        // Reconstruction error equals the tail.
        let dense_before = crate::tensor::usv(&u, &s, &v);
        let err = res.fac.to_dense().sub(&dense_before).fro_norm();
        assert!((err - res.discarded).abs() < 1e-8);
    }

    #[test]
    fn new_s_is_full_rank_diagonal() {
        let sigma = [3.0, 1.0, 0.5, 1e-9];
        let (u, s, v) = augmented_state(16, 4, &sigma, 503);
        let res = truncate(&u, &s, &v, 1e-4, 1, 4);
        let r1 = res.fac.rank();
        for i in 0..r1 {
            assert!(res.fac.s[(i, i)] > 0.0, "S must stay full rank");
            for j in 0..r1 {
                if i != j {
                    assert_eq!(res.fac.s[(i, j)], 0.0, "S must be diagonal");
                }
            }
        }
    }

    #[test]
    fn min_and_max_rank_clamps() {
        let sigma = [1.0, 1e-12, 1e-13, 1e-14];
        let (u, s, v) = augmented_state(12, 4, &sigma, 507);
        // Even with a huge threshold the rank stays ≥ 2 when asked.
        let res = truncate(&u, &s, &v, 1e9, 2, 4);
        assert_eq!(res.fac.rank(), 2);
        // And a zero threshold keeps everything but respects max_rank.
        let res2 = truncate(&u, &s, &v, 0.0, 1, 3);
        assert_eq!(res2.fac.rank(), 3);
    }

    #[test]
    fn relative_threshold_rule() {
        let s = Matrix::diag(&[3.0, 4.0]); // ‖S‖_F = 5
        assert!((relative_threshold(&s, 0.1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn augment_then_truncate_is_identity_when_nothing_learned() {
        // If clients do nothing (S̃* = S̃), truncation must recover the
        // original factorization's matrix (possibly rotated factors).
        let mut rng = Rng::new(509);
        let fac = LowRank::random_init(18, 18, 3, &mut rng);
        let g_u = Matrix::randn(18, 3, &mut rng);
        let g_v = Matrix::randn(18, 3, &mut rng);
        let aug = augment_basis(&fac, &g_u, &g_v, 6);
        let res = truncate(&aug.u_tilde, &aug.s_tilde, &aug.v_tilde, 1e-10, 1, 6);
        assert!(res.fac.to_dense().sub(&fac.to_dense()).max_abs() < 1e-8);
        assert_eq!(res.fac.rank(), 3);
    }

    #[test]
    fn prop_truncation_error_bounded_by_theta() {
        prop::check(
            "truncate: ‖W_trunc − W‖ ≤ ϑ, orthonormal output",
            8,
            |rng, size| {
                let r2 = 2 * (1 + rng.below(size.min(3) + 1));
                let m = r2 + 4 + rng.below(10);
                let sigma: Vec<f64> =
                    (0..r2).map(|i| 10f64.powi(-(i as i32)) * (1.0 + rng.uniform())).collect();
                let (u, s, v) = augmented_state(m, r2, &sigma, rng.next_u64());
                let theta = rng.uniform_in(1e-6, 1.0);
                (u, s, v, theta)
            },
            |(u, s, v, theta)| {
                let res = truncate(u, s, v, *theta, 1, s.rows());
                let before = crate::tensor::usv(u, s, v);
                let err = res.fac.to_dense().sub(&before).fro_norm();
                // err == discarded tail ≤ ϑ (unless min_rank clamp, r1=1 keeps σ₁)
                if err > *theta + 1e-9 {
                    return Err(format!("truncation error {err} > ϑ {theta}"));
                }
                if res.fac.validate() > 1e-8 {
                    return Err("output bases not orthonormal".into());
                }
                Ok(())
            },
        );
    }
}
