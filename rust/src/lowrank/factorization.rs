//! The low-rank factorization `W_r = U S Vᵀ ∈ M_r`.

use crate::linalg::{orthonormality_error, random_orthonormal, svd};
use crate::tensor::{matmul, usv, Matrix};
use crate::util::rng::Rng;

/// A rank-`r` factorization `W = U S Vᵀ` with orthonormal bases
/// `U ∈ R^{m×r}`, `V ∈ R^{n×r}` and coefficients `S ∈ R^{r×r}`.
///
/// Invariants maintained by FeDLRT across rounds (checked by
/// [`LowRank::validate`]):
/// * `UᵀU = VᵀV = I_r`,
/// * after truncation, `S = diag(σ₁…σ_r)` is full-rank diagonal.
#[derive(Debug, Clone)]
pub struct LowRank {
    pub u: Matrix,
    pub s: Matrix,
    pub v: Matrix,
}

impl LowRank {
    /// Random initial factorization with orthonormal bases and diagonal
    /// full-rank `S` (the paper's initialization: `U¹, V¹` orthonormal,
    /// `S¹` full rank).
    pub fn random_init(m: usize, n: usize, r: usize, rng: &mut Rng) -> LowRank {
        assert!(r >= 1 && r <= m.min(n), "rank {r} out of range for {m}x{n}");
        let u = random_orthonormal(m, r, rng);
        let v = random_orthonormal(n, r, rng);
        // Diagonal, strictly positive, descending — mimics post-truncation
        // state so round 1 behaves like any other round.
        let diag: Vec<f64> = (0..r).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let s = Matrix::diag(&diag);
        LowRank { u, s, v }
    }

    /// Best rank-`r` approximation of a dense matrix (truncated SVD).
    pub fn from_dense(w: &Matrix, r: usize) -> LowRank {
        let dec = svd(w);
        let (u, sig, v) = dec.truncate(r);
        LowRank { u, s: Matrix::diag(&sig), v }
    }

    /// Current rank (number of basis columns).
    pub fn rank(&self) -> usize {
        self.s.rows()
    }

    /// Row dimension of the represented matrix.
    pub fn m(&self) -> usize {
        self.u.rows()
    }

    /// Column dimension of the represented matrix.
    pub fn n(&self) -> usize {
        self.v.rows()
    }

    /// Reconstruct the dense `W = U S Vᵀ` (test/diagnostic use — the
    /// production algorithm never materializes this, per §3.3).
    pub fn to_dense(&self) -> Matrix {
        usv(&self.u, &self.s, &self.v)
    }

    /// Frobenius norm of the represented matrix, computed at `O(r²)`
    /// cost via orthonormality: `‖U S Vᵀ‖_F = ‖S‖_F`.
    pub fn fro_norm(&self) -> f64 {
        self.s.fro_norm()
    }

    /// Number of parameters held by the factors.
    pub fn param_count(&self) -> usize {
        let r = self.rank();
        self.m() * r + r * r + self.n() * r
    }

    /// Compression ratio versus the dense `m×n` matrix.
    pub fn compression_ratio(&self) -> f64 {
        (self.m() * self.n()) as f64 / self.param_count() as f64
    }

    /// Validate the structural invariants; returns the worst violation.
    pub fn validate(&self) -> f64 {
        let eu = orthonormality_error(&self.u);
        let ev = orthonormality_error(&self.v);
        eu.max(ev)
    }

    /// Zero-pad factors to rank `r_max` (static-shape AOT interop; see
    /// DESIGN.md §Static-shape AOT with dynamic rank). Columns ≥ rank are
    /// zero, `S` active block top-left.
    pub fn pad_to(&self, r_max: usize) -> LowRank {
        assert!(r_max >= self.rank());
        LowRank {
            u: self.u.hcat(&Matrix::zeros(self.m(), r_max - self.rank())),
            s: self.s.embed(r_max, r_max),
            v: self.v.hcat(&Matrix::zeros(self.n(), r_max - self.rank())),
        }
    }

    /// Inverse of [`pad_to`]: keep the leading `r` columns/block.
    pub fn unpad(&self, r: usize) -> LowRank {
        assert!(r <= self.rank());
        LowRank {
            u: self.u.first_cols(r),
            s: self.s.block(r, r),
            v: self.v.first_cols(r),
        }
    }

    /// Evaluate the bilinear form `p(x)ᵀ W p(y)` at `O(nr)` cost without
    /// forming `W` — the least-squares model's forward pass.
    pub fn bilinear(&self, px: &[f64], py: &[f64]) -> f64 {
        // a = Uᵀ px ∈ R^r, b = Vᵀ py ∈ R^r, result = aᵀ S b.
        let r = self.rank();
        let mut a = vec![0.0; r];
        let mut b = vec![0.0; r];
        for i in 0..self.m() {
            let pxi = px[i];
            if pxi != 0.0 {
                let row = self.u.row(i);
                for j in 0..r {
                    a[j] += pxi * row[j];
                }
            }
        }
        for i in 0..self.n() {
            let pyi = py[i];
            if pyi != 0.0 {
                let row = self.v.row(i);
                for j in 0..r {
                    b[j] += pyi * row[j];
                }
            }
        }
        let sb = crate::tensor::matvec(&self.s, &b);
        a.iter().zip(&sb).map(|(x, y)| x * y).sum()
    }
}

/// Project a dense gradient onto the coefficient space: `Uᵀ G V`
/// (the Riemannian/Galerkin coefficient gradient, eq. 5 for S).
pub fn project_coeff_grad(u: &Matrix, g: &Matrix, v: &Matrix) -> Matrix {
    let ug = crate::tensor::matmul_tn(u, g); // r×n
    matmul(&ug, v) // r×r (r×n · n×r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_init_invariants() {
        let mut rng = Rng::new(301);
        let f = LowRank::random_init(20, 15, 4, &mut rng);
        assert!(f.validate() < 1e-10);
        assert_eq!(f.rank(), 4);
        // S diagonal full-rank
        for i in 0..4 {
            assert!(f.s[(i, i)] > 0.0);
            for j in 0..4 {
                if i != j {
                    assert_eq!(f.s[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn fro_norm_matches_dense() {
        let mut rng = Rng::new(303);
        let f = LowRank::random_init(12, 12, 3, &mut rng);
        assert!((f.fro_norm() - f.to_dense().fro_norm()).abs() < 1e-10);
    }

    #[test]
    fn from_dense_best_approximation() {
        let mut rng = Rng::new(307);
        // Exactly rank-3 matrix recovered exactly.
        let a = LowRank::random_init(10, 10, 3, &mut rng).to_dense();
        let f = LowRank::from_dense(&a, 3);
        assert!(f.to_dense().sub(&a).max_abs() < 1e-9);
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let mut rng = Rng::new(311);
        let f = LowRank::random_init(8, 9, 2, &mut rng);
        let padded = f.pad_to(5);
        assert_eq!(padded.rank(), 5);
        // Padding is exact: same dense matrix.
        assert!(padded.to_dense().sub(&f.to_dense()).max_abs() < 1e-12);
        let back = padded.unpad(2);
        assert!(back.to_dense().sub(&f.to_dense()).max_abs() < 1e-12);
    }

    #[test]
    fn bilinear_matches_dense() {
        let mut rng = Rng::new(313);
        let f = LowRank::random_init(7, 6, 3, &mut rng);
        let px = rng.normal_vec(7);
        let py = rng.normal_vec(6);
        let dense = f.to_dense();
        let want: f64 = (0..7)
            .map(|i| px[i] * (0..6).map(|j| dense[(i, j)] * py[j]).sum::<f64>())
            .sum();
        assert!((f.bilinear(&px, &py) - want).abs() < 1e-10);
    }

    #[test]
    fn project_coeff_grad_matches_explicit() {
        let mut rng = Rng::new(317);
        let u = random_orthonormal(9, 3, &mut rng);
        let v = random_orthonormal(8, 3, &mut rng);
        let g = Matrix::randn(9, 8, &mut rng);
        let proj = project_coeff_grad(&u, &g, &v);
        let want = matmul(&crate::tensor::matmul_tn(&u, &g), &v);
        assert!(proj.sub(&want).max_abs() < 1e-12);
        assert_eq!(proj.shape(), (3, 3));
    }

    #[test]
    fn compression_ratio() {
        let mut rng = Rng::new(319);
        let f = LowRank::random_init(512, 512, 16, &mut rng);
        let dense = 512.0 * 512.0;
        let fac = (512 * 16 + 16 * 16 + 512 * 16) as f64;
        assert!((f.compression_ratio() - dense / fac).abs() < 1e-12);
    }
}
