//! Server-side basis augmentation (Algorithm 1 lines 4–6, eq. 6).
//!
//! Given the current bases `U, V` and the aggregated basis gradients
//! `G_U = mean_c ∇_U L_c`, `G_V = mean_c ∇_V L_c`, the server computes
//!
//! ```text
//! [U | Ū] R = qr([U | G_U]),    [V | V̄] R = qr([V | G_V])
//! ```
//!
//! and broadcasts only the *new* halves `Ū, V̄` (the clients already hold
//! `U, V`). By Lemma 2 this choice of augmentation directions is
//! consistent with the basis-update step of the augmented BUG splitting
//! scheme (K/L steps integrated with one explicit-Euler step), which is
//! what gives the robust-integrator guarantee of Theorem 5.
//!
//! By Lemma 1, because the QR of `[U | G_U]` leaves the first `r` columns
//! equal to `U`, the projected coefficients need no communication at all:
//! `S̃ = Ũᵀ U S Vᵀ Ṽ = [[S, 0], [0, 0]]`.

use crate::linalg::qr_thin_ws;
use crate::tensor::{matmul_into, matmul_tn_into, Matrix, Workspace};

use super::factorization::LowRank;

/// Result of augmenting one basis pair.
#[derive(Debug, Clone)]
pub struct AugmentedBasis {
    /// Full augmented basis `Ũ = [U | Ū] ∈ R^{m×(r+a)}`.
    pub u_tilde: Matrix,
    /// Full augmented basis `Ṽ = [V | V̄] ∈ R^{n×(r+a)}`.
    pub v_tilde: Matrix,
    /// New directions `Ū` (what actually gets broadcast).
    pub u_bar: Matrix,
    /// New directions `V̄`.
    pub v_bar: Matrix,
    /// Augmented coefficients `S̃ = [[S,0],[0,0]]` (assembled locally on
    /// clients; kept here for the server's own bookkeeping).
    pub s_tilde: Matrix,
    /// Rank before augmentation.
    pub r_old: usize,
}

impl AugmentedBasis {
    /// Augmented rank `r + a` (a = r unless capped).
    pub fn rank(&self) -> usize {
        self.u_tilde.cols()
    }

    /// View as a LowRank factorization (Ũ S̃ Ṽᵀ).
    pub fn as_factorization(&self) -> LowRank {
        LowRank { u: self.u_tilde.clone(), s: self.s_tilde.clone(), v: self.v_tilde.clone() }
    }
}

/// Augment `(U, V)` with aggregated basis gradients `(g_u, g_v)`.
///
/// `max_rank` caps the augmented rank (static-shape AOT interop and
/// memory budget); the augmentation adds `a = min(r, max_rank - r)` new
/// directions. The paper's un-capped scheme is `max_rank = 2r`.
///
/// Implementation detail: we orthonormalize `(I - U Uᵀ) G_U` against `U`
/// rather than re-running QR on `[U | G_U]`. This is algebraically the
/// same subspace (Lemma 1 shows the QR leaves the leading `r` columns
/// equal to `U`) but keeps the existing basis *bit-identical*, which the
/// "broadcast only `Ū`" optimization relies on.
pub fn augment_basis(fac: &LowRank, g_u: &Matrix, g_v: &Matrix, max_rank: usize) -> AugmentedBasis {
    let mut ws = Workspace::new();
    augment_basis_ws(fac, g_u, g_v, max_rank, &mut ws)
}

/// [`augment_basis`] with caller-owned scratch: the projection
/// intermediates and the QR's reflector stack all come from `ws`, so
/// the per-round server augmentation reuses its buffers across rounds
/// (the returned augmented bases are fresh — they become round state).
pub fn augment_basis_ws(
    fac: &LowRank,
    g_u: &Matrix,
    g_v: &Matrix,
    max_rank: usize,
    ws: &mut Workspace,
) -> AugmentedBasis {
    let r = fac.rank();
    let a = r.min(max_rank.saturating_sub(r));
    assert!(a > 0 || max_rank <= r, "augmentation with zero budget");

    let u_bar = new_directions(&fac.u, g_u, a, ws);
    let v_bar = new_directions(&fac.v, g_v, a, ws);

    let u_tilde = fac.u.hcat(&u_bar);
    let v_tilde = fac.v.hcat(&v_bar);
    // Lemma 1: S̃ = [[S, 0], [0, 0]].
    let s_tilde = fac.s.embed(r + a, r + a);

    AugmentedBasis { u_tilde, v_tilde, u_bar, v_bar, s_tilde, r_old: r }
}

/// Orthonormal directions spanning `(I − B Bᵀ) G`, truncated/padded to
/// exactly `a` columns.
fn new_directions(basis: &Matrix, g: &Matrix, a: usize, ws: &mut Workspace) -> Matrix {
    let m = basis.rows();
    if a == 0 {
        return Matrix::zeros(m, 0);
    }
    let r = basis.cols();
    let gc = g.cols();
    // Project out the existing span, G_perp = G − B (Bᵀ G), run twice
    // (re-orthogonalization) for stability when G is nearly inside
    // span(B) — the near-stationary regime. Both intermediates live in
    // workspace scratch; the product is subtracted in place by negating
    // the small BᵀG factor and accumulating with β = 1.
    let mut btg = ws.take_mat(r, gc);
    let mut g_perp = ws.take_mat(m, gc);
    g_perp.copy_from(g);
    for _pass in 0..2 {
        matmul_tn_into(basis, &g_perp, &mut btg, 0.0);
        btg.scale_inplace(-1.0);
        matmul_into(basis, &btg, &mut g_perp, 1.0);
    }

    let (q, r_fac) = qr_thin_ws(&g_perp, ws);
    ws.give_mat(btg);
    ws.give_mat(g_perp);
    // Drop numerically-null directions (zero diagonal in R): replacing
    // them with junk columns would pollute the augmented basis.
    let tol = 1e-12 * (1.0 + g.max_abs()) * (m as f64).sqrt();
    let mut out = Matrix::zeros(m, a);
    let mut dst = 0;
    for j in 0..q.cols().min(a) {
        if r_fac[(j, j)].abs() > tol {
            for i in 0..m {
                out[(i, dst)] = q[(i, j)];
            }
            dst += 1;
        }
    }
    // Remaining columns stay zero — harmless padding: zero basis columns
    // contribute zero gradients and are removed at truncation.
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_error;
    use crate::tensor::{matmul, matmul_tn};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn setup(m: usize, n: usize, r: usize, seed: u64) -> (LowRank, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let fac = LowRank::random_init(m, n, r, &mut rng);
        let g_u = Matrix::randn(m, r, &mut rng);
        let g_v = Matrix::randn(n, r, &mut rng);
        (fac, g_u, g_v)
    }

    #[test]
    fn augmented_basis_is_orthonormal_and_keeps_u() {
        let (fac, g_u, g_v) = setup(20, 18, 4, 401);
        let aug = augment_basis(&fac, &g_u, &g_v, 8);
        assert_eq!(aug.rank(), 8);
        assert!(orthonormality_error(&aug.u_tilde) < 1e-9);
        assert!(orthonormality_error(&aug.v_tilde) < 1e-9);
        // Leading r columns bit-identical to U, V.
        assert_eq!(aug.u_tilde.first_cols(4), fac.u);
        assert_eq!(aug.v_tilde.first_cols(4), fac.v);
    }

    #[test]
    fn augmented_span_contains_gradient() {
        let (fac, g_u, g_v) = setup(16, 16, 3, 403);
        let aug = augment_basis(&fac, &g_u, &g_v, 6);
        // G_U must lie in span(Ũ): ‖(I − Ũ Ũᵀ) G_U‖ ≈ 0.
        let proj = matmul(&aug.u_tilde, &matmul_tn(&aug.u_tilde, &g_u));
        assert!(g_u.sub(&proj).max_abs() < 1e-9);
        let proj_v = matmul(&aug.v_tilde, &matmul_tn(&aug.v_tilde, &g_v));
        assert!(g_v.sub(&proj_v).max_abs() < 1e-9);
    }

    #[test]
    fn lemma1_structured_coefficients() {
        let (fac, g_u, g_v) = setup(12, 12, 3, 407);
        let aug = augment_basis(&fac, &g_u, &g_v, 6);
        // S̃ = Ũᵀ (U S Vᵀ) Ṽ must equal [[S,0],[0,0]] — Lemma 1.
        let w = fac.to_dense();
        let s_tilde_explicit = matmul(&matmul_tn(&aug.u_tilde, &w), &aug.v_tilde);
        assert!(s_tilde_explicit.sub(&aug.s_tilde).max_abs() < 1e-9);
        // And the augmented factorization represents the same matrix.
        assert!(aug.as_factorization().to_dense().sub(&w).max_abs() < 1e-9);
    }

    #[test]
    fn gradient_inside_span_yields_zero_directions() {
        // G_U ∈ span(U): augmentation adds only (numerically) zero columns.
        let mut rng = Rng::new(409);
        let fac = LowRank::random_init(15, 15, 4, &mut rng);
        let coef = Matrix::randn(4, 4, &mut rng);
        let g_u = matmul(&fac.u, &coef);
        let g_v = matmul(&fac.v, &coef);
        let aug = augment_basis(&fac, &g_u, &g_v, 8);
        assert!(aug.u_bar.max_abs() < 1e-8, "u_bar should be ~0");
        assert!(aug.v_bar.max_abs() < 1e-8);
        // Still orthonormal in the nonzero part; dense matrix unchanged.
        assert!(aug.as_factorization().to_dense().sub(&fac.to_dense()).max_abs() < 1e-9);
    }

    #[test]
    fn rank_cap_respected() {
        let (fac, g_u, g_v) = setup(20, 20, 4, 411);
        let aug = augment_basis(&fac, &g_u, &g_v, 6); // cap below 2r
        assert_eq!(aug.rank(), 6);
        assert!(orthonormality_error(&aug.u_tilde) < 1e-9);
    }

    #[test]
    fn prop_augmentation_invariants() {
        prop::check(
            "augment: orthonormal, contains old span, Lemma 1",
            10,
            |rng, size| {
                let r = 1 + rng.below(size.min(4) + 1);
                let m = (2 * r + 2) + rng.below(8);
                let n = (2 * r + 2) + rng.below(8);
                let fac = LowRank::random_init(m, n, r, rng);
                let g_u = Matrix::randn(m, r, rng);
                let g_v = Matrix::randn(n, r, rng);
                (fac, g_u, g_v)
            },
            |(fac, g_u, g_v)| {
                let aug = augment_basis(fac, g_u, g_v, 2 * fac.rank());
                if orthonormality_error(&aug.u_tilde) > 1e-8 {
                    return Err("Ũ not orthonormal".into());
                }
                if orthonormality_error(&aug.v_tilde) > 1e-8 {
                    return Err("Ṽ not orthonormal".into());
                }
                let w = fac.to_dense();
                let diff = aug.as_factorization().to_dense().sub(&w).max_abs();
                if diff > 1e-8 * (1.0 + w.max_abs()) {
                    return Err(format!("augmentation changed W (diff {diff})"));
                }
                Ok(())
            },
        );
    }
}
