//! Dynamical low-rank factorization algebra.
//!
//! Implements the DLRA/BUG-splitting machinery of §3: the factorization
//! type [`LowRank`], server-side basis augmentation (eq. 6, Lemma 2),
//! Lemma-1 structured assembly of the augmented coefficients, and the
//! SVD-based automatic compression (rank truncation).

pub mod augment;
pub mod factorization;
pub mod truncate;

pub use augment::{augment_basis, augment_basis_ws, AugmentedBasis};
pub use factorization::LowRank;
pub use truncate::{truncate, truncate_ws, TruncationResult};
