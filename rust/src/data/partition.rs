//! Client partitioners: how the global dataset is split across clients.
//!
//! * [`uniform_partition`] — the paper's §4.2 setup ("training data is
//!   equally partitioned across clients"), iid shards.
//! * [`dirichlet_partition`] — label-skew heterogeneity à la common FL
//!   benchmarks (smaller α ⇒ more skew); used by the heterogeneity
//!   ablations beyond the paper's main figures.

use crate::util::rng::Rng;

/// The heterogeneity-ablation α grid (extreme / moderate / mild label
/// skew). `--scenario skew` uses the extreme end; the drift-correction
/// bench sweeps the full grid.
pub const DIRICHLET_ALPHA_PRESETS: [f64; 3] = [0.1, 0.3, 1.0];

/// Shuffle indices and split into `c` equal shards (remainder dropped so
/// all clients hold the same count, matching the paper's uniform setup).
pub fn uniform_partition(n: usize, c: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(c >= 1 && n >= c, "need at least one sample per client");
    let mut idx = rng.permutation(n);
    let per = n / c;
    idx.truncate(per * c);
    idx.chunks(per).map(|ch| ch.to_vec()).collect()
}

/// Label-skewed partition: for each class, split its samples across
/// clients with Dirichlet(α) proportions. Guarantees every client ends
/// up with at least `min_per_client` samples by round-robin top-up.
pub fn dirichlet_partition(
    labels: &[i32],
    classes: usize,
    c: usize,
    alpha: f64,
    min_per_client: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(c >= 1 && alpha > 0.0);
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); c];
    for class in 0..classes {
        let mut members: Vec<usize> =
            (0..labels.len()).filter(|&i| labels[i] as usize == class).collect();
        rng.shuffle(&mut members);
        // Dirichlet(α,…,α) via normalized Gamma(α) draws.
        let props: Vec<f64> = {
            let g: Vec<f64> = (0..c).map(|_| gamma_sample(alpha, rng)).collect();
            let total: f64 = g.iter().sum::<f64>().max(1e-300);
            g.iter().map(|x| x / total).collect()
        };
        // Cut points over the member list.
        let mut start = 0usize;
        for (k, p) in props.iter().enumerate() {
            let take = if k + 1 == c {
                members.len() - start
            } else {
                ((p * members.len() as f64).round() as usize).min(members.len() - start)
            };
            shards[k].extend_from_slice(&members[start..start + take]);
            start += take;
        }
    }
    // Top-up starved clients from the fattest shard.
    loop {
        let (min_i, min_len) =
            shards.iter().enumerate().map(|(i, s)| (i, s.len())).min_by_key(|&(_, l)| l).unwrap();
        if min_len >= min_per_client {
            break;
        }
        let (max_i, _) =
            shards.iter().enumerate().map(|(i, s)| (i, s.len())).max_by_key(|&(_, l)| l).unwrap();
        if max_i == min_i || shards[max_i].len() <= min_per_client {
            break;
        }
        let moved = shards[max_i].pop().unwrap();
        shards[min_i].push(moved);
    }
    shards
}

/// Marsaglia–Tsang gamma sampling (with α<1 boost).
fn gamma_sample(alpha: f64, rng: &mut Rng) -> f64 {
    if alpha < 1.0 {
        // Boost: Gamma(α) = Gamma(α+1) · U^{1/α}.
        let u = rng.uniform().max(1e-300);
        return gamma_sample(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.uniform().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_shards_are_disjoint_and_equal() {
        let mut rng = Rng::new(21);
        let shards = uniform_partition(103, 4, &mut rng);
        assert_eq!(shards.len(), 4);
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        assert_eq!(all.len(), 100); // 103 → 25×4
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 100, "shards overlap");
        for s in &shards {
            assert_eq!(s.len(), 25);
        }
    }

    #[test]
    fn dirichlet_low_alpha_skews_labels() {
        let mut rng = Rng::new(23);
        // 4 classes, balanced labels.
        let labels: Vec<i32> = (0..400).map(|i| (i % 4) as i32).collect();
        let skewed = dirichlet_partition(&labels, 4, 4, 0.1, 10, &mut rng);
        let fair = dirichlet_partition(&labels, 4, 4, 100.0, 10, &mut rng);
        // Measure skew: per client, max class share.
        let skew = |shards: &Vec<Vec<usize>>| -> f64 {
            shards
                .iter()
                .map(|s| {
                    let mut h = [0usize; 4];
                    for &i in s {
                        h[labels[i] as usize] += 1;
                    }
                    *h.iter().max().unwrap() as f64 / s.len().max(1) as f64
                })
                .sum::<f64>()
                / shards.len() as f64
        };
        assert!(skew(&skewed) > skew(&fair) + 0.1, "{} vs {}", skew(&skewed), skew(&fair));
        // Everyone keeps the minimum.
        for s in &skewed {
            assert!(s.len() >= 10);
        }
    }

    #[test]
    fn dirichlet_partition_covers_everything_once() {
        let mut rng = Rng::new(29);
        let labels: Vec<i32> = (0..300).map(|i| (i % 3) as i32).collect();
        let shards = dirichlet_partition(&labels, 3, 5, 0.5, 5, &mut rng);
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        all.sort_unstable();
        let n = all.len();
        all.dedup();
        assert_eq!(all.len(), n, "duplicated indices");
        assert_eq!(n, 300);
    }

    #[test]
    fn dirichlet_presets_cover_every_sample_without_dropping_the_tail() {
        // 307 is deliberately not divisible by the client count: the
        // uniform partitioner drops the tail, the Dirichlet one must
        // not — every index appears exactly once at every preset α.
        let labels: Vec<i32> = (0..307).map(|i| (i % 5) as i32).collect();
        for &alpha in &DIRICHLET_ALPHA_PRESETS {
            let mut rng = Rng::new(37);
            let shards = dirichlet_partition(&labels, 5, 4, alpha, 5, &mut rng);
            assert_eq!(shards.len(), 4, "α={alpha}");
            let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
            assert_eq!(all.len(), 307, "α={alpha}: dropped samples");
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), 307, "α={alpha}: duplicated samples");
            for s in &shards {
                assert!(s.len() >= 5, "α={alpha}: starved client ({} samples)", s.len());
            }
        }
    }

    #[test]
    fn gamma_sampler_mean() {
        let mut rng = Rng::new(31);
        for &alpha in &[0.3, 1.0, 4.0] {
            let n = 4000;
            let mean: f64 =
                (0..n).map(|_| gamma_sample(alpha, &mut rng)).sum::<f64>() / n as f64;
            assert!((mean - alpha).abs() < 0.15 * alpha.max(1.0), "α={alpha}: mean {mean}");
        }
    }
}
