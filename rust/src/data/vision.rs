//! Synthetic "vision" classification dataset.
//!
//! A random *teacher network* (two-layer MLP with fixed weights) labels
//! Gaussian-mixture inputs: each sample draws a class-conditioned mean
//! pattern plus noise, and the teacher's argmax provides the label. This
//! gives a dataset that is (a) genuinely learnable, (b) not linearly
//! separable, (c) label-balanced, and (d) deterministic given a seed —
//! the properties the federated benchmarks need from CIFAR10/100
//! (DESIGN.md §Substitutions).
//!
//! The "augmentation" analogue of the paper's random horizontal flips is
//! a sign-flip of a feature subset plus small Gaussian jitter, applied
//! per epoch on the *training* split only.

use crate::tensor::{matvec, Matrix};
use crate::util::rng::Rng;

/// An in-memory dataset split.
#[derive(Debug, Clone)]
pub struct Split {
    /// Features, `N×d` (f32-ready but stored f64 for Rust-side math).
    pub x: Matrix,
    /// Integer labels in `[0, classes)`.
    pub y: Vec<i32>,
}

impl Split {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// The full dataset: train + test splits and metadata.
#[derive(Debug, Clone)]
pub struct VisionDataset {
    pub train: Split,
    pub test: Split,
    pub d_in: usize,
    pub classes: usize,
}

impl VisionDataset {
    /// Generate a dataset with `train_n`/`test_n` samples.
    pub fn synthesize(
        d_in: usize,
        classes: usize,
        train_n: usize,
        test_n: usize,
        seed: u64,
    ) -> VisionDataset {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
        // Class-mean patterns: smooth low-frequency profiles so nearby
        // classes overlap (like natural image classes do).
        let means: Vec<Vec<f64>> = (0..classes)
            .map(|c| {
                let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
                let freq = 1.0 + rng.uniform() * 3.0;
                (0..d_in)
                    .map(|j| {
                        1.2 * (freq * j as f64 / d_in as f64 * std::f64::consts::TAU
                            + phase + c as f64)
                            .sin()
                    })
                    .collect()
            })
            .collect();
        // Teacher MLP: d_in → h → classes, fixed random weights.
        let h = (2 * d_in).min(256);
        let w1 = Matrix::randn(d_in, h, &mut rng).scale((2.0 / d_in as f64).sqrt());
        let w2 = Matrix::randn(h, classes, &mut rng).scale((2.0 / h as f64).sqrt());

        let make_split = |n: usize, rng: &mut Rng| -> Split {
            let mut x = Matrix::zeros(n, d_in);
            let mut y = Vec::with_capacity(n);
            for i in 0..n {
                let c = rng.below(classes);
                let row = x.row_mut(i);
                for (j, v) in row.iter_mut().enumerate() {
                    *v = means[c][j] + 0.6 * rng.normal();
                }
                // Label: the mixture component, tie-broken by the teacher
                // MLP near class boundaries. The +3 bias keeps the label
                // distribution balanced while the teacher's nonlinear
                // decision surface relabels ambiguous samples — so the
                // task is learnable but not linearly trivial.
                let h1: Vec<f64> = matvec(&w1.t(), row).iter().map(|&z| z.max(0.0)).collect();
                let mut logits = matvec(&w2.t(), &h1);
                logits[c] += 3.0;
                let label = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                y.push(label as i32);
            }
            Split { x, y }
        };

        let train = make_split(train_n, &mut rng);
        let test = make_split(test_n, &mut rng);
        VisionDataset { train, test, d_in, classes }
    }

    /// Augmented copy of training row `i` (per-step determinism from
    /// `(epoch, i)`): random feature-block sign flip + Gaussian jitter.
    pub fn augmented_row(&self, i: usize, epoch: u64, out: &mut [f32]) {
        let row = self.train.x.row(i);
        let mut rng = Self::augment_rng(i, epoch);
        let flip = rng.uniform() < 0.5;
        let half = row.len() / 2;
        for (j, o) in out.iter_mut().enumerate() {
            // "Horizontal flip": mirror the first half of the features.
            let src = if flip && j < half { half - 1 - j } else { j };
            *o = (row[src] + 0.05 * rng.normal()) as f32;
        }
    }

    /// [`augmented_row`](Self::augmented_row) at f64 precision for the
    /// native Rust backends: identical RNG stream and flip/jitter
    /// schedule, so the f32 variant is exactly this value cast down.
    /// Allocation-free (the MLP fast path fills batches through it).
    pub fn augmented_row_f64(&self, i: usize, epoch: u64, out: &mut [f64]) {
        let row = self.train.x.row(i);
        let mut rng = Self::augment_rng(i, epoch);
        let flip = rng.uniform() < 0.5;
        let half = row.len() / 2;
        for (j, o) in out.iter_mut().enumerate() {
            let src = if flip && j < half { half - 1 - j } else { j };
            *o = row[src] + 0.05 * rng.normal();
        }
    }

    /// The per-(sample, epoch) augmentation stream both precisions share.
    fn augment_rng(i: usize, epoch: u64) -> Rng {
        Rng::new(0xA06_0000 ^ (epoch << 24) ^ i as u64)
    }

    /// Label histogram of a set of training indices (diagnostics).
    pub fn label_histogram(&self, idx: &[usize]) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &i in idx {
            h[self.train.y[i] as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_and_balancedish() {
        let a = VisionDataset::synthesize(24, 4, 400, 100, 7);
        let b = VisionDataset::synthesize(24, 4, 400, 100, 7);
        assert_eq!(a.train.y, b.train.y);
        assert_eq!(a.train.x.data(), b.train.x.data());
        // No class should be empty or hugely dominant.
        let idx: Vec<usize> = (0..a.train.len()).collect();
        let hist = a.label_histogram(&idx);
        for (c, &count) in hist.iter().enumerate() {
            assert!(count > 20, "class {c} underrepresented: {hist:?}");
        }
    }

    #[test]
    fn teacher_labels_are_learnable_by_linear_probe() {
        // A least-squares linear probe on the raw features should beat
        // chance comfortably — i.e. the labels carry signal.
        let ds = VisionDataset::synthesize(16, 4, 600, 200, 11);
        // One-vs-all ridge via normal equations on train.
        let n = ds.train.len();
        let d = ds.d_in + 1;
        let mut xtx = Matrix::zeros(d, d);
        let mut xty = Matrix::zeros(d, ds.classes);
        for i in 0..n {
            let mut row = ds.train.x.row(i).to_vec();
            row.push(1.0);
            for a in 0..d {
                for b in 0..d {
                    xtx[(a, b)] += row[a] * row[b];
                }
                let c = ds.train.y[i] as usize;
                xty[(a, c)] += row[a];
            }
        }
        for a in 0..d {
            xtx[(a, a)] += 1e-3 * n as f64;
        }
        // Solve via pinv for each class column.
        let mut correct = 0;
        let mut w = Matrix::zeros(d, ds.classes);
        for c in 0..ds.classes {
            let col = xty.col(c);
            let sol = crate::linalg::svd::pinv_solve(&xtx, &col, 1e-12);
            for a in 0..d {
                w[(a, c)] = sol[a];
            }
        }
        for i in 0..ds.test.len() {
            let mut row = ds.test.x.row(i).to_vec();
            row.push(1.0);
            let scores = crate::tensor::matvec(&w.t(), &row);
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == ds.test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test.len() as f64;
        assert!(acc > 0.4, "linear probe accuracy {acc} ≤ chance-ish");
    }

    #[test]
    fn f64_augmentation_matches_f32_stream() {
        // The two precisions must draw the same flips and jitter — the
        // f32 row is the f64 row cast down, element for element.
        let ds = VisionDataset::synthesize(18, 3, 60, 10, 5);
        let mut a32 = vec![0f32; 18];
        let mut a64 = vec![0f64; 18];
        for (i, epoch) in [(0usize, 0u64), (7, 3), (59, 11)] {
            ds.augmented_row(i, epoch, &mut a32);
            ds.augmented_row_f64(i, epoch, &mut a64);
            for (x, y) in a32.iter().zip(&a64) {
                assert_eq!(*x, *y as f32);
            }
        }
    }

    #[test]
    fn augmentation_is_deterministic_and_bounded() {
        let ds = VisionDataset::synthesize(20, 3, 50, 10, 3);
        let mut a = vec![0f32; 20];
        let mut b = vec![0f32; 20];
        ds.augmented_row(5, 2, &mut a);
        ds.augmented_row(5, 2, &mut b);
        assert_eq!(a, b);
        ds.augmented_row(5, 3, &mut b);
        assert_ne!(a, b);
        // Jitter stays small relative to signal.
        let orig: Vec<f64> = ds.train.x.row(5).to_vec();
        let scale = orig.iter().map(|v| v.abs()).fold(0.0f64, f64::max);
        assert!(scale > 0.1);
    }
}
