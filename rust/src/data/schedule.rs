//! Deterministic mini-batch scheduling, shared by every stochastic
//! backend (`nn::NnProblem` and `models::mlp::MlpProblem`).
//!
//! A client's shard is consumed in fixed-size batches indexed by the
//! local step counter: `epoch = step / num_batches`,
//! `bi = step % num_batches`. The batch count **rounds up** —
//! `⌈len/b⌉` — so the shard tail is cycled into the final batch of each
//! epoch (wrapping back to the shard start for filler) instead of being
//! silently dropped. The earlier floor division meant samples beyond
//! `⌊len/b⌋·b` were never visited by any epoch; with the ceil schedule
//! every sample is visited at least once per epoch (see the
//! `every_sample_visited_each_epoch` test).
//!
//! Both backends draw from these functions so their batch schedules are
//! identical given the same `(shard, batch, step)`.

/// Batches per epoch: `⌈shard_len / batch⌉`, at least 1.
pub fn num_batches(shard_len: usize, batch: usize) -> usize {
    assert!(batch > 0, "batch size must be positive");
    ((shard_len + batch - 1) / batch).max(1)
}

/// `(epoch, batch-index)` for local step counter `step`.
pub fn batch_slot(shard_len: usize, batch: usize, step: u64) -> (u64, usize) {
    let nb = num_batches(shard_len, batch) as u64;
    (step / nb, (step % nb) as usize)
}

/// Position within the shard of slot `k` of batch `bi` (the final batch
/// wraps past the tail to the shard start).
pub fn sample_index(shard_len: usize, batch: usize, bi: usize, k: usize) -> usize {
    (bi * batch + k) % shard_len.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_batch_count() {
        assert_eq!(num_batches(100, 32), 4); // 3×32 + tail of 4
        assert_eq!(num_batches(96, 32), 3);
        assert_eq!(num_batches(5, 32), 1);
        assert_eq!(num_batches(0, 32), 1);
    }

    #[test]
    fn every_sample_visited_each_epoch() {
        // The tail (indices 96..100) must be visited — the floor
        // schedule never touched them.
        let (len, b) = (100usize, 32usize);
        let nb = num_batches(len, b);
        let mut seen = vec![false; len];
        for bi in 0..nb {
            for k in 0..b {
                seen[sample_index(len, b, bi, k)] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "schedule drops samples: {seen:?}");
    }

    #[test]
    fn tail_batch_wraps_to_start() {
        // Batch 3 of (len=100, b=32) covers 96..100 then wraps to 0..28.
        let idx: Vec<usize> = (0..32).map(|k| sample_index(100, 32, 3, k)).collect();
        assert_eq!(&idx[..4], &[96, 97, 98, 99]);
        assert_eq!(idx[4], 0);
        assert_eq!(idx[31], 27);
    }

    #[test]
    fn slot_is_deterministic_in_step() {
        let (len, b) = (100usize, 32usize);
        assert_eq!(batch_slot(len, b, 0), (0, 0));
        assert_eq!(batch_slot(len, b, 3), (0, 3));
        assert_eq!(batch_slot(len, b, 4), (1, 0));
        assert_eq!(batch_slot(len, b, 9), (2, 1));
    }

    #[test]
    fn tiny_shard_wraps() {
        // Shard smaller than the batch: one batch per epoch, wrapping.
        let idx: Vec<usize> = (0..8).map(|k| sample_index(5, 8, 0, k)).collect();
        assert_eq!(idx, vec![0, 1, 2, 3, 4, 0, 1, 2]);
        assert_eq!(batch_slot(5, 8, 7), (7, 0));
    }
}
