//! Datasets and client partitioners.
//!
//! The paper's vision benchmarks use CIFAR10/CIFAR100; this environment
//! has no network access and a CPU-only budget, so [`vision`] provides a
//! synthetic teacher-generated classification dataset with the same
//! federated structure (shardable, label-skewable, augmentable). See
//! DESIGN.md §Substitutions for why this preserves the paper's
//! measurements.

pub mod partition;
pub mod schedule;
pub mod vision;

pub use partition::{dirichlet_partition, uniform_partition, DIRICHLET_ALPHA_PRESETS};
pub use vision::VisionDataset;
