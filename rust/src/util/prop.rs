//! Miniature property-based testing harness (no `proptest` offline).
//!
//! [`check`] runs a property over `CASES` randomly generated inputs with
//! deterministic seeding; on failure it retries the failing seed with a
//! shrink loop over the generator's `size` parameter to report the
//! smallest failing size. Generators receive `(rng, size)` and grow their
//! inputs with `size`, mirroring proptest's value-size scaling.

use super::rng::Rng;

/// Number of cases per property (override with FEDLRT_PROP_CASES).
pub fn cases() -> usize {
    std::env::var("FEDLRT_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(48)
}

/// Run `prop` on `cases()` inputs produced by `gen` at growing sizes.
///
/// `gen(rng, size)` should produce inputs whose complexity scales with
/// `size` (1..=max_size). `prop(input)` returns `Err(reason)` on failure.
/// Panics with the seed, size, and reason of the smallest failure found.
pub fn check<T, G, P>(name: &str, max_size: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let n = cases();
    for case in 0..n {
        let seed = 0xF3D1_0000 + case as u64;
        let size = 1 + (case * max_size) / n.max(1);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng, size.max(1));
        if let Err(reason) = prop(&input) {
            // Shrink: retry the same seed at smaller sizes to find the
            // smallest size that still fails.
            let mut smallest = (size, reason.clone(), format!("{input:?}"));
            for s in 1..size {
                let mut rng = Rng::new(seed);
                let small = gen(&mut rng, s);
                if let Err(r) = prop(&small) {
                    smallest = (s, r, format!("{small:?}"));
                    break;
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={}):\n  {}\n  input: {}",
                smallest.0,
                smallest.1,
                truncate(&smallest.2, 600)
            );
        }
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.len() <= max {
        s.to_string()
    } else {
        format!("{}… ({} bytes)", &s[..max], s.len())
    }
}

/// Assert two floats are close in absolute+relative terms.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > {tol}*{scale}", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check("reverse-involution", 32, |rng, size| rng.normal_vec(size), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if w == *v {
                Ok(())
            } else {
                Err("reverse twice != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 8, |rng, size| rng.normal_vec(size), |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1e6, 1e6 + 1.0, 1e-9).is_err());
        assert!(close(1e6, 1e6 + 1.0, 1e-5).is_ok());
    }
}
