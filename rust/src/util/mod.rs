//! Shared infrastructure: PRNG, JSON, CLI parsing, property testing,
//! and small helpers. These substrates replace crates (rand, serde,
//! clap, proptest) that are unavailable in the offline build
//! environment — see DESIGN.md §Offline-environment substrate decisions.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Wall-clock stopwatch for coarse phase timing.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Median of a slice (copies + sorts; fine for metrics-sized data).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "mean of empty slice");
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }
}
