//! Tiny command-line argument parser (the environment has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and generates a usage string. Declarative enough for the `fedlrt` CLI,
//! the examples, and the bench drivers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI parser.
#[derive(Debug, Clone)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positional: Vec<(String, String)>,
}

/// Parsed argument values.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Declare a positional argument (for usage text only).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = write!(s, "\nusage: {}", self.program);
        for (p, _) in &self.positional {
            let _ = write!(s, " <{p}>");
        }
        let _ = writeln!(s, " [options]\n\noptions:");
        for o in &self.opts {
            if o.is_flag {
                let _ = writeln!(s, "  --{:<22} {}", o.name, o.help);
            } else {
                // An empty default marks an optional value (e.g.
                // `--trace <path>`: omitted = feature off).
                let suffix = match o.default.as_deref() {
                    Some("") | None => "(optional)".to_string(),
                    Some(d) => format!("(default: {d})"),
                };
                let _ = writeln!(
                    s,
                    "  --{:<22} {} {}",
                    format!("{} <v>", o.name),
                    o.help,
                    suffix
                );
            }
        }
        s
    }

    /// Parse a raw argument list (exclusive of argv[0]).
    pub fn parse(&self, raw: &[String]) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key, val);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse `std::env::args()`, printing usage and exiting on error/--help.
    pub fn parse_env(&self) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&raw) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self.values.get(name).unwrap_or_else(|| panic!("undeclared option --{name}"));
        raw.parse().unwrap_or_else(|_| panic!("--{name}: cannot parse '{raw}'"))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Parse a comma-separated list of usize, e.g. `--clients 1,2,4,8`.
    pub fn usize_list(&self, name: &str) -> Vec<usize> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad int '{s}'")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("n", "20", "problem size")
            .opt("lr", "0.001", "learning rate")
            .opt("clients", "1,2,4", "client counts")
            .flag("verbose", "verbosity")
    }

    fn to_vec(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults() {
        let a = cli().parse(&[]).unwrap();
        assert_eq!(a.usize("n"), 20);
        assert_eq!(a.f64("lr"), 0.001);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = cli().parse(&to_vec(&["--n", "64", "--verbose", "--lr=0.5"])).unwrap();
        assert_eq!(a.usize("n"), 64);
        assert_eq!(a.f64("lr"), 0.5);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn lists_and_positionals() {
        let a = cli().parse(&to_vec(&["run", "--clients", "1,2,8", "extra"])).unwrap();
        assert_eq!(a.usize_list("clients"), vec![1, 2, 8]);
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&to_vec(&["--nope"])).is_err());
        assert!(cli().parse(&to_vec(&["--n"])).is_err()); // missing value
        assert!(cli().parse(&to_vec(&["--verbose=1"])).is_err()); // flag w/ value
    }

    #[test]
    fn usage_mentions_options() {
        let u = cli().usage();
        assert!(u.contains("--n"));
        assert!(u.contains("--verbose"));
    }

    #[test]
    fn empty_default_reads_as_optional() {
        let u = Cli::new("t", "test").opt("trace", "", "trace path").usage();
        assert!(u.contains("(optional)"), "{u}");
        assert!(!u.contains("(default: )"), "{u}");
    }
}
