//! Minimal JSON value model, parser, and serializer.
//!
//! The offline environment has no `serde`; the library needs JSON for its
//! config files and for machine-readable experiment results, so we carry
//! a small, strict JSON implementation: full RFC 8259 value model,
//! recursive-descent parser with byte-offset error reporting, and a
//! pretty/compact writer. Numbers are stored as `f64` (adequate for the
//! configs and metrics this library emits).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch `key` as f64 or fall back to `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|j| j.as_usize()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|j| j.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|j| j.as_bool()).unwrap_or(default)
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn raw_utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("[1, 2").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn builder_and_accessors() {
        let mut o = Json::obj();
        o.set("n", 512usize).set("tau", 0.01).set("name", "fedlrt").set("vc", true);
        assert_eq!(o.usize_or("n", 0), 512);
        assert_eq!(o.f64_or("tau", 0.0), 0.01);
        assert_eq!(o.str_or("name", ""), "fedlrt");
        assert!(o.bool_or("vc", false));
        assert_eq!(o.usize_or("missing", 7), 7);
        let round = parse(&o.to_string_pretty()).unwrap();
        assert_eq!(round, o);
    }

    #[test]
    fn deterministic_pretty_output() {
        let mut o = Json::obj();
        o.set("z", 1usize).set("a", 2usize);
        // BTreeMap => keys sorted.
        assert_eq!(o.to_string_compact(), r#"{"a":2,"z":1}"#);
    }
}
