//! Deterministic pseudo-random number generation.
//!
//! The offline build environment carries no `rand` crate, so we implement
//! the small amount of randomness the library needs from scratch:
//! [`Rng`] is a SplitMix64 generator (Steele et al., "Fast splittable
//! pseudorandom number generators", OOPSLA 2014) — a 64-bit state,
//! full-period, statistically solid generator that is trivially seedable
//! and reproducible across platforms. On top of it we provide uniform,
//! normal (Box–Muller), and integer-range sampling plus Fisher–Yates
//! shuffling; everything the federated experiments require.

/// SplitMix64 pseudo-random generator. Deterministic and `Send`.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of Box–Muller, if any.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), gauss_spare: None }
    }

    /// Derive an independent child generator (e.g. one per client).
    /// Children with different `stream` ids are decorrelated.
    pub fn split(&self, stream: u64) -> Rng {
        // Mix the stream id through the output function before seeding.
        let mut z = self.state ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(z)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        // Avoid u == 0 so ln() stays finite.
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Uniform integer in `[0, n)`. Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of iid uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_decorrelates() {
        let root = Rng::new(7);
        let mut c0 = root.split(0);
        let mut c1 = root.split(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(11);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
