//! Client-side optimizers and learning-rate schedules (Table 2).
//!
//! The paper's client inner loop is plain SGD (eqs. 2/4/7/8) with
//! momentum and weight decay for the vision benchmarks (Table 2). The
//! optimizer state lives on the *client* and is reset at each
//! aggregation round — matching the paper's setup where local iterations
//! restart from the broadcast global state.
//!
//! Weight decay is **coupled L2 regularization**: the decay term
//! `wd·w` is added to the gradient *before* the momentum buffer (and
//! before Adam's moment estimates), i.e. classic `SGD(weight_decay=…)` /
//! vanilla Adam-with-L2 — *not* AdamW/decoupled decay, which would
//! apply `w ← (1 − λ·wd)·w` outside the momentum path. This matches
//! the reference implementations the paper's Table 2 settings come
//! from; see DESIGN.md §Substitutions.

use crate::tensor::Matrix;

/// SGD hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SgdConfig {
    pub momentum: f64,
    pub weight_decay: f64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { momentum: 0.0, weight_decay: 0.0 }
    }
}

/// SGD with (optional) momentum and coupled L2 weight decay for one
/// parameter tensor (the decay enters the gradient before the momentum
/// buffer — see the module docs).
#[derive(Debug, Clone)]
pub struct Sgd {
    cfg: SgdConfig,
    velocity: Option<Matrix>,
}

impl Sgd {
    pub fn new(cfg: SgdConfig) -> Sgd {
        Sgd { cfg, velocity: None }
    }

    /// One update: effective gradient `g + V_c + wd·w` fed through the
    /// momentum buffer, then `w ← w − λ·v` (coupled L2, not decoupled).
    /// `extra` is an additive gradient correction (the variance
    /// correction term `V_c`), applied before momentum.
    pub fn step(&mut self, w: &mut Matrix, g: &Matrix, lr: f64, extra: Option<&Matrix>) {
        if extra.is_none() && self.cfg.weight_decay == 0.0 && self.cfg.momentum == 0.0 {
            // Plain SGD: no effective-gradient copy needed — keeps the
            // client inner loop allocation-free (bitwise identical to
            // the general path below).
            w.axpy(-lr, g);
            return;
        }
        let mut eff = g.clone();
        if let Some(e) = extra {
            eff.axpy(1.0, e);
        }
        if self.cfg.weight_decay != 0.0 {
            eff.axpy(self.cfg.weight_decay, w);
        }
        if self.cfg.momentum != 0.0 {
            let v = self
                .velocity
                .get_or_insert_with(|| Matrix::zeros(w.rows(), w.cols()));
            v.scale_inplace(self.cfg.momentum);
            v.axpy(1.0, &eff);
            w.axpy(-lr, v);
        } else {
            w.axpy(-lr, &eff);
        }
    }

    pub fn reset(&mut self) {
        self.velocity = None;
    }
}

/// Adam optimizer (Table 2: the ViT benchmark uses Adam with standard
/// parameters). State is per-client and reset each aggregation round,
/// like [`Sgd`].
#[derive(Debug, Clone)]
pub struct Adam {
    beta1: f64,
    beta2: f64,
    eps: f64,
    weight_decay: f64,
    m: Option<Matrix>,
    v: Option<Matrix>,
    t: u64,
}

impl Adam {
    /// Standard PyTorch defaults: β=(0.9, 0.999), ε=1e-8.
    pub fn new(weight_decay: f64) -> Adam {
        Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay, m: None, v: None, t: 0 }
    }

    /// One Adam update; `extra` is the variance-correction term, applied
    /// to the gradient before the moment updates (so the correction is
    /// also adaptively scaled, matching how FedLin-style corrections
    /// compose with adaptive optimizers).
    pub fn step(&mut self, w: &mut Matrix, g: &Matrix, lr: f64, extra: Option<&Matrix>) {
        let mut eff = g.clone();
        if let Some(e) = extra {
            eff.axpy(1.0, e);
        }
        if self.weight_decay != 0.0 {
            eff.axpy(self.weight_decay, w);
        }
        self.t += 1;
        let m = self.m.get_or_insert_with(|| Matrix::zeros(w.rows(), w.cols()));
        m.scale_inplace(self.beta1);
        m.axpy(1.0 - self.beta1, &eff);
        let v = self.v.get_or_insert_with(|| Matrix::zeros(w.rows(), w.cols()));
        v.scale_inplace(self.beta2);
        for (vi, gi) in v.data_mut().iter_mut().zip(eff.data()) {
            *vi += (1.0 - self.beta2) * gi * gi;
        }
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (m, v) = (self.m.as_ref().unwrap(), self.v.as_ref().unwrap());
        for ((wi, mi), vi) in w.data_mut().iter_mut().zip(m.data()).zip(v.data()) {
            let mhat = mi / bc1;
            let vhat = vi / bc2;
            *wi -= lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn reset(&mut self) {
        self.m = None;
        self.v = None;
        self.t = 0;
    }
}

/// Which client optimizer a training run uses (Table 2's Optimizer row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    Sgd(SgdConfig),
    Adam { weight_decay: f64 },
}

impl Default for OptimizerKind {
    fn default() -> Self {
        OptimizerKind::Sgd(SgdConfig::default())
    }
}

/// A client-side optimizer instance for one parameter tensor.
#[derive(Debug, Clone)]
pub enum ClientOptimizer {
    Sgd(Sgd),
    Adam(Adam),
}

impl ClientOptimizer {
    pub fn new(kind: OptimizerKind) -> ClientOptimizer {
        match kind {
            OptimizerKind::Sgd(cfg) => ClientOptimizer::Sgd(Sgd::new(cfg)),
            OptimizerKind::Adam { weight_decay } => {
                ClientOptimizer::Adam(Adam::new(weight_decay))
            }
        }
    }

    pub fn step(&mut self, w: &mut Matrix, g: &Matrix, lr: f64, extra: Option<&Matrix>) {
        match self {
            ClientOptimizer::Sgd(o) => o.step(w, g, lr, extra),
            ClientOptimizer::Adam(o) => o.step(w, g, lr, extra),
        }
    }
}

/// Learning-rate schedule over aggregation rounds.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    Constant(f64),
    /// Cosine annealing from `start` to `end` over `total` rounds
    /// (all four vision benchmarks in Table 2).
    Cosine { start: f64, end: f64, total: usize },
}

impl LrSchedule {
    pub fn at(&self, round: usize) -> f64 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::Cosine { start, end, total } => {
                if total <= 1 {
                    return end;
                }
                let t = (round.min(total - 1)) as f64 / (total - 1) as f64;
                end + 0.5 * (start - end) * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn plain_sgd_descends_quadratic() {
        // min ½‖w‖² — gradient w, fixed point 0.
        let mut w = Matrix::from_vec(1, 2, vec![1.0, -2.0]);
        let mut opt = Sgd::new(SgdConfig::default());
        for _ in 0..100 {
            let g = w.clone();
            opt.step(&mut w, &g, 0.1, None);
        }
        assert!(w.max_abs() < 1e-4);
    }

    #[test]
    fn momentum_accelerates() {
        let run = |momentum: f64| {
            let mut w = Matrix::from_vec(1, 1, vec![1.0]);
            let mut opt = Sgd::new(SgdConfig { momentum, weight_decay: 0.0 });
            for _ in 0..30 {
                let g = w.clone();
                opt.step(&mut w, &g, 0.05, None);
            }
            w[(0, 0)].abs()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut w = Matrix::from_vec(1, 1, vec![1.0]);
        let mut opt = Sgd::new(SgdConfig { momentum: 0.0, weight_decay: 0.5 });
        let zero_g = Matrix::zeros(1, 1);
        opt.step(&mut w, &zero_g, 0.1, None);
        assert!((w[(0, 0)] - (1.0 - 0.1 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn extra_term_is_added() {
        // Variance correction: step with g=0, extra=v must move by −λv.
        let mut w = Matrix::zeros(2, 2);
        let mut rng = Rng::new(5);
        let v = Matrix::randn(2, 2, &mut rng);
        let mut opt = Sgd::new(SgdConfig::default());
        opt.step(&mut w, &Matrix::zeros(2, 2), 0.3, Some(&v));
        assert!(w.sub(&v.scale(-0.3)).max_abs() < 1e-12);
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut w = Matrix::from_vec(1, 2, vec![3.0, -4.0]);
        let mut opt = Adam::new(0.0);
        for _ in 0..300 {
            let g = w.clone();
            opt.step(&mut w, &g, 0.05, None);
        }
        assert!(w.max_abs() < 1e-2, "{w:?}");
    }

    #[test]
    fn adam_reset_clears_state() {
        let mut w = Matrix::zeros(1, 1);
        let mut opt = Adam::new(0.0);
        opt.step(&mut w, &Matrix::from_vec(1, 1, vec![1.0]), 0.1, None);
        opt.reset();
        assert!(opt.m.is_none() && opt.t == 0);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let s = LrSchedule::Cosine { start: 1e-2, end: 1e-5, total: 200 };
        assert!((s.at(0) - 1e-2).abs() < 1e-12);
        assert!((s.at(199) - 1e-5).abs() < 1e-9);
        assert!(s.at(100) < 1e-2 && s.at(100) > 1e-5);
        // Monotone decreasing.
        for t in 1..200 {
            assert!(s.at(t) <= s.at(t - 1) + 1e-15);
        }
    }
}
