//! `fedlrt` — the command-line launcher for federated dynamical
//! low-rank training.
//!
//! Subcommands:
//!
//! * `train` — federated NN training through the PJRT artifacts
//!   (the §4.2 vision benchmarks; requires `make artifacts`).
//! * `lsq`   — the §4.1 convex least-squares experiments (pure Rust).
//! * `costs` — Table 1 / Fig 3 cost model at a chosen operating point.
//! * `info`  — runtime + artifact inventory.
//!
//! Invoking with `--problem <mlp|lsq>` (no subcommand) runs the chosen
//! problem family end to end: `--problem mlp` trains the native
//! multi-layer MLP backend on the Fig-5 preset offline (no artifacts)
//! against its dense baseline and verifies the headline claims
//! (accuracy above chance, communication saving, compression).
//!
//! Examples:
//! ```text
//! fedlrt --problem mlp
//! fedlrt --problem mlp --figure fig6_mlp --clients 8 --vc full
//! fedlrt lsq --mode homogeneous --clients 8
//! fedlrt train --model resnet18_head --clients 4 --rounds 40 --vc full
//! fedlrt costs --n 512 --r 32
//! fedlrt info
//! ```

use anyhow::Result;
use fedlrt::client::Correction;
use fedlrt::comm::{CodecKind, FaultModel, NetPolicy};
use fedlrt::coordinator::{
    run_async_obs, run_dense_obs, run_fedlrt_obs, Aggregator, DenseAlgo, RankConfig, Schedule,
    TrainConfig, VarCorrection,
};
use fedlrt::engine::{Dist, ExecutorKind, ScenarioConfig, TimingModel};
use fedlrt::obsv::Recorder;
use fedlrt::models::least_squares::LeastSquares;
use fedlrt::nn::experiment::{print_rows, run_mlp_sweep};
use fedlrt::nn::{NnOptions, NnProblem};
use fedlrt::opt::{LrSchedule, OptimizerKind, SgdConfig};
use fedlrt::runtime::Runtime;
use fedlrt::util::cli::{Args, Cli};
use fedlrt::util::rng::Rng;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let usage = "usage: fedlrt <train|lsq|costs|info> [options] | fedlrt --problem <mlp|lsq>\n\
                 (--help per subcommand)";
    let (sub, rest) = match raw.split_first() {
        Some((s, rest)) if !s.starts_with("--") => (s.as_str(), rest.to_vec()),
        Some((s, _)) if s == "--help" || s == "-h" => {
            println!("{usage}");
            return Ok(());
        }
        // Bare-option invocation: `fedlrt --problem mlp [...]`. Only
        // `--problem` selects this path — any other bare option is a
        // typo'd command line and gets the usage text, not a training
        // run.
        Some(_) if raw.iter().any(|a| a == "--problem" || a.starts_with("--problem=")) => {
            ("problem", raw.clone())
        }
        Some(_) | None => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    };
    match sub {
        "train" => cmd_train(&rest),
        "lsq" => cmd_lsq(&rest),
        "costs" => cmd_costs(&rest),
        "info" => cmd_info(),
        "problem" => cmd_problem(&rest),
        other => {
            eprintln!("unknown subcommand '{other}' (expected train|lsq|costs|info)");
            std::process::exit(2);
        }
    }
}

/// `fedlrt --problem mlp` — the native multi-layer backend, end to end:
/// trains the chosen Fig-5/Fig-6 MLP preset with FeDLRT and its dense
/// baseline offline and checks the headline claims.
fn cmd_problem(rest: &[String]) -> Result<()> {
    // Split off the family selection BEFORE option parsing: the
    // remaining arguments belong to the selected family's own CLI
    // (`--problem lsq --mode heterogeneous` must reach cmd_lsq's
    // parser, which owns `--mode`; parsing them here would reject
    // them as unknown options).
    let mut fwd: Vec<String> = Vec::new();
    let mut family: Option<String> = None;
    let mut it = rest.iter();
    while let Some(arg) = it.next() {
        if arg == "--problem" {
            family = it.next().cloned();
        } else if let Some(v) = arg.strip_prefix("--problem=") {
            family = Some(v.to_string());
        } else {
            fwd.push(arg.clone());
        }
    }
    match family.as_deref() {
        Some("mlp") | None => {}
        Some("lsq") => return cmd_lsq(&fwd),
        Some(other) => {
            eprintln!("unknown --problem '{other}' (mlp|lsq)");
            std::process::exit(2);
        }
    }
    let cli = Cli::new("fedlrt --problem mlp", "run the native MLP problem end to end")
        .opt("figure", "fig5_mlp", "MLP preset: fig5_mlp|fig6_mlp")
        .opt("clients", "4", "number of clients")
        .opt("vc", "simplified", "variance correction: none|simplified|full")
        .opt("seed", "0", "random seed")
        .flag("full", "paper-scale rounds/data (default: smoke scale)")
        .opt("out", "results/problem_mlp.jsonl", "JSONL output path");
    let a = cli.parse(&fwd).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let figure = a.str("figure").to_string();
    let preset = fedlrt::coordinator::presets::mlp_presets()
        .into_iter()
        .find(|p| p.figure == figure)
        .unwrap_or_else(|| {
            eprintln!("unknown --figure '{figure}' (fig5_mlp|fig6_mlp)");
            std::process::exit(2)
        });
    let clients = a.usize("clients");
    let vc = parse_vc(a.str("vc"));
    let full = a.flag("full");
    let seed = a.u64("seed");
    println!(
        "--problem mlp: {} / {} analogue — {}×{:?}→{} MLP, C={}, vc={}, {} scale",
        preset.paper_net,
        preset.paper_data,
        preset.d_in,
        preset.hidden,
        preset.classes,
        clients,
        vc.label(),
        if full { "paper" } else { "smoke" }
    );
    let rows = run_mlp_sweep(&preset, &[clients], vc, full, seed);
    let dense_label = if vc == VarCorrection::None { "fedavg acc" } else { "fedlin acc" };
    print_rows(&format!("{} (native MLP backend)", preset.figure), dense_label, &rows);
    let row = &rows[0];
    let chance = 1.0 / preset.classes as f64;
    // Acceptance gates: a ≥2-hidden-layer MLP trained offline to well
    // above chance, with large FeDLRT communication savings.
    assert!(preset.hidden.len() >= 2, "preset must have ≥ 2 hidden layers");
    assert!(
        row.fedlrt_acc > 2.0 * chance,
        "FeDLRT accuracy {:.3} ≤ 2× chance {:.3}",
        row.fedlrt_acc,
        2.0 * chance
    );
    assert!(
        row.comm_saving > 0.5,
        "comm saving {:.3} ≤ 50% vs dense baseline",
        row.comm_saving
    );
    println!(
        "\nOK: acc {:.3} > 2×chance {:.3}, comm saving {:.1}% > 50%, compression {:.1}x",
        row.fedlrt_acc,
        2.0 * chance,
        100.0 * row.comm_saving,
        row.compression
    );
    let out = std::path::Path::new(a.str("out"));
    row.fedlrt.append_jsonl(out)?;
    row.dense.append_jsonl(out)?;
    println!("records appended to {}", out.display());
    Ok(())
}

/// Build the telemetry recorder for a `--trace <path>` argument (empty
/// = phases/latency only, no event buffering).
fn recorder_for(trace: &str) -> Recorder {
    if trace.is_empty() {
        Recorder::new()
    } else {
        Recorder::with_trace()
    }
}

/// Flush the buffered Chrome trace when `--trace <path>` was given.
/// Load the file in Perfetto (ui.perfetto.dev) or `chrome://tracing`.
fn finish_trace(obs: &Recorder, trace: &str) -> Result<()> {
    if !trace.is_empty() {
        let path = std::path::Path::new(trace);
        obs.write_trace(path)?;
        println!("trace: {} events written to {}", obs.trace_len(), path.display());
    }
    Ok(())
}

fn parse_executor(s: &str) -> ExecutorKind {
    ExecutorKind::parse(s).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

fn parse_codec(s: &str) -> CodecKind {
    CodecKind::parse(s).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

fn parse_correction(s: &str) -> Correction {
    Correction::parse(s).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

fn parse_scenario(s: &str) -> ScenarioConfig {
    ScenarioConfig::parse(s).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

fn parse_vc(s: &str) -> VarCorrection {
    match s {
        "none" => VarCorrection::None,
        "full" => VarCorrection::Full,
        "simplified" | "simpl" => VarCorrection::Simplified,
        other => {
            eprintln!("unknown --vc '{other}' (none|simplified|full)");
            std::process::exit(2);
        }
    }
}

/// The event-driven federation options shared by `train` and `lsq`
/// (see `coordinator::async_server`; all ignored under `--schedule
/// sync`).
fn async_opts(cli: Cli) -> Cli {
    cli.opt("schedule", "sync", "federation schedule: sync|fedbuff|async")
        .opt("population", "0", "registered async client population (0 = problem clients)")
        .opt("buffer-k", "8", "async: aggregate when K updates are buffered")
        .opt("concurrency", "16", "async: in-flight dispatch slots (concurrent clients)")
        .opt("staleness-p", "1.0", "async: staleness-weight exponent p in 1/(1+σ)^p")
        .opt("max-staleness", "0", "fedbuff: discard arrivals staler than this (0 = unbounded)")
        .flag("hold-stale", "fedbuff: admit over-stale arrivals instead of discarding them")
        .opt("basis-every", "1", "async: refresh the shared basis every N aggregations")
        .opt("server-lr", "1.0", "async: server step size on the aggregated update")
        .opt("arrival", "constant:1", "async arrival-gap distribution (constant:V|uniform:LO,HI|lognormal:MU,SIGMA)")
        .opt("compute", "constant:1", "async client compute-time distribution")
        .opt("link", "constant:0", "async link-latency distribution")
        .opt("het-sigma", "0", "async per-client lognormal speed heterogeneity σ")
}

fn parse_dist(a: &Args, name: &str) -> Dist {
    Dist::parse(a.str(name)).unwrap_or_else(|e| {
        eprintln!("--{name}: {e}");
        std::process::exit(2);
    })
}

/// The unreliable-transport and robust-aggregation options shared by
/// `train` and `lsq` (see `comm::faults` and `coordinator::aggregate`;
/// all defaults are structurally inactive / bitwise-legacy).
fn fault_opts(cli: Cli) -> Cli {
    cli.opt("loss-prob", "0", "per-attempt upload loss probability")
        .opt("corrupt-prob", "0", "per-attempt payload corruption probability (checksum-detected)")
        .opt("dup-prob", "0", "per-attempt duplicate-delivery probability")
        .opt("net-delay", "constant:0", "per-attempt delivery delay-jitter distribution")
        .opt("timeout", "0", "upload deadline in virtual seconds (0 = none)")
        .opt("retries", "0", "retransmissions after the first attempt (exponential backoff)")
        .opt("quorum", "0", "sync: min surviving uploads per round, else the round is skipped")
        .opt("aggregator", "mean", "robust aggregation: mean|trimmed[:frac]|median|clip[:mult]")
}

/// Fold the parsed fault/aggregation options into `cfg`.
fn apply_fault_opts(cfg: &mut TrainConfig, a: &Args) {
    cfg.fault = FaultModel {
        loss_prob: a.f64("loss-prob"),
        corrupt_prob: a.f64("corrupt-prob"),
        dup_prob: a.f64("dup-prob"),
        delay: parse_dist(a, "net-delay"),
    };
    cfg.net_policy = NetPolicy {
        timeout: a.f64("timeout"),
        retries: a.u64("retries") as u32,
        quorum: a.usize("quorum"),
    };
    cfg.aggregator = Aggregator::parse(a.str("aggregator")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
}

/// Fold the parsed async options into `cfg`.
fn apply_async_opts(cfg: &mut TrainConfig, a: &Args) {
    cfg.schedule = Schedule::parse(a.str("schedule")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    cfg.population = a.usize("population");
    cfg.async_cfg.buffer_k = a.usize("buffer-k");
    cfg.async_cfg.concurrency = a.usize("concurrency");
    cfg.async_cfg.staleness_p = a.f64("staleness-p");
    cfg.async_cfg.max_staleness = a.u64("max-staleness");
    cfg.async_cfg.hold_stale = a.flag("hold-stale");
    cfg.async_cfg.basis_every = a.usize("basis-every");
    cfg.async_cfg.server_lr = a.f64("server-lr");
    cfg.timing = TimingModel {
        arrival: parse_dist(a, "arrival"),
        compute: parse_dist(a, "compute"),
        link: parse_dist(a, "link"),
        het_sigma: a.f64("het-sigma"),
    };
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let cli = Cli::new("fedlrt train", "federated NN training via PJRT artifacts")
        .opt("model", "resnet18_head", "artifact config name")
        .opt("algo", "fedlrt", "fedlrt|fedavg|fedlin")
        .opt("vc", "simplified", "variance correction (fedlrt): none|simplified|full")
        .opt("clients", "4", "number of clients")
        .opt("rounds", "40", "aggregation rounds")
        .opt("iters", "8", "local iterations per round")
        .opt("lr", "0.05", "start learning rate (cosine to 1%)")
        .opt("rank", "16", "initial rank")
        .opt("max-rank", "32", "rank cap")
        .opt("tau", "0.01", "truncation tolerance τ")
        .opt("momentum", "0.9", "SGD momentum")
        .opt("train-n", "4096", "training samples")
        .opt("seed", "0", "random seed")
        .opt("alpha", "0", "Dirichlet label-skew α (0 = uniform shards)")
        .opt("participation", "1.0", "fraction of clients sampled per round")
        .opt("dropout", "0.0", "per-round client dropout probability")
        .opt(
            "correction",
            "none",
            "client drift correction: none|fedprox[:mu]|feddyn[:alpha]|scaffold[:strength]",
        )
        .opt(
            "scenario",
            "calm",
            "hostile preset: calm|skew|churn|blackout|byzantine|noisy|hellscape",
        )
        .opt("executor", "serial", "client execution engine: serial|threads|threads:N")
        .opt("codec", "dense", "wire codec: dense|f16|q8")
        .opt(
            "kernel-threads",
            "0",
            "matmul kernel worker threads (0 = env FEDLRT_KERNEL_THREADS or 1)",
        )
        .opt("trace", "", "write a Chrome trace-event JSON (Perfetto-loadable) to this path")
        .opt("out", "results/train.jsonl", "JSONL output path");
    let cli = fault_opts(async_opts(cli));
    let a = cli.parse(rest).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let mut rt = Runtime::new(Runtime::default_dir())?;
    let scenario = parse_scenario(a.str("scenario"));
    // Explicit --alpha overrides the scenario's label-skew preset.
    let alpha = a.f64("alpha");
    let dirichlet_alpha =
        if alpha > 0.0 { Some(alpha) } else { scenario.dirichlet_alpha };
    let problem = NnProblem::new(
        &mut rt,
        NnOptions {
            config: a.str("model").to_string(),
            num_clients: a.usize("clients"),
            train_n: a.usize("train-n"),
            test_n: 1024,
            eval_cap: 1024,
            seed: a.u64("seed"),
            augment: true,
            dirichlet_alpha,
        },
    )?;
    let rounds = a.usize("rounds");
    let mut cfg = TrainConfig {
        rounds,
        local_iters: a.usize("iters"),
        lr: LrSchedule::Cosine { start: a.f64("lr"), end: a.f64("lr") * 0.01, total: rounds },
        opt: OptimizerKind::Sgd(SgdConfig { momentum: a.f64("momentum"), weight_decay: 1e-4 }),
        var_correction: parse_vc(a.str("vc")),
        rank: RankConfig {
            initial_rank: a.usize("rank"),
            max_rank: a.usize("max-rank").min(problem.max_rank()),
            tau: a.f64("tau"),
        },
        seed: a.u64("seed"),
        eval_every: (rounds / 10).max(1),
        participation: a.f64("participation"),
        straggler_jitter: 0.0,
        dropout: a.f64("dropout"),
        executor: parse_executor(a.str("executor")),
        codec: parse_codec(a.str("codec")),
        kernel_threads: a.usize("kernel-threads"),
        correction: parse_correction(a.str("correction")),
        scenario,
        ..TrainConfig::default()
    };
    apply_async_opts(&mut cfg, &a);
    apply_fault_opts(&mut cfg, &a);
    let obs = recorder_for(a.str("trace"));
    let rec = if cfg.schedule != Schedule::Sync {
        if a.str("algo") != "fedlrt" {
            eprintln!("--schedule {} requires --algo fedlrt", cfg.schedule.label());
            std::process::exit(2);
        }
        run_async_obs(&problem, &cfg, "cli_train", &obs)
    } else {
        match a.str("algo") {
            "fedlrt" => run_fedlrt_obs(&problem, &cfg, "cli_train", &obs),
            "fedavg" => run_dense_obs(&problem, &cfg, DenseAlgo::FedAvg, "cli_train", &obs),
            "fedlin" => run_dense_obs(&problem, &cfg, DenseAlgo::FedLin, "cli_train", &obs),
            other => {
                eprintln!("unknown --algo '{other}'");
                std::process::exit(2);
            }
        }
    };
    finish_trace(&obs, a.str("trace"))?;
    for r in &rec.rounds {
        if let Some(acc) = r.eval_metric {
            println!(
                "round {:>4}: loss {:<10.5} rank {:?} acc {:.4}",
                r.round, r.global_loss, r.ranks, acc
            );
        }
    }
    println!(
        "final loss {:.5}, acc {:.4}, comm {:.2} Mfloats ({:.2} MB on wire, codec {})",
        rec.final_loss(),
        rec.final_metric().unwrap_or(f64::NAN),
        rec.total_comm_floats() as f64 / 1e6,
        rec.total_bytes() as f64 / 1e6,
        cfg.codec.label()
    );
    rec.append_jsonl(std::path::Path::new(a.str("out")))?;
    Ok(())
}

fn cmd_lsq(rest: &[String]) -> Result<()> {
    let cli = Cli::new("fedlrt lsq", "convex least-squares experiments (§4.1)")
        .opt("mode", "homogeneous", "homogeneous|heterogeneous")
        .opt("algo", "fedlrt", "fedlrt|fedavg|fedlin")
        .opt("vc", "simplified", "variance correction: none|simplified|full")
        .opt("n", "20", "matrix dimension")
        .opt("target-rank", "4", "target rank (homogeneous)")
        .opt("clients", "4", "number of clients")
        .opt("points", "4000", "total data points")
        .opt("rounds", "100", "aggregation rounds")
        .opt("iters", "20", "local iterations")
        .opt("lr", "0.005", "learning rate")
        .opt("tau", "0.1", "truncation tolerance")
        .opt("seed", "0", "random seed")
        .opt("dropout", "0.0", "per-round client dropout probability")
        .opt(
            "correction",
            "none",
            "client drift correction: none|fedprox[:mu]|feddyn[:alpha]|scaffold[:strength]",
        )
        .opt(
            "scenario",
            "calm",
            "hostile preset: calm|skew|churn|blackout|byzantine|noisy|hellscape",
        )
        .opt("executor", "serial", "client execution engine: serial|threads|threads:N")
        .opt("codec", "dense", "wire codec: dense|f16|q8")
        .opt(
            "kernel-threads",
            "0",
            "matmul kernel worker threads (0 = env FEDLRT_KERNEL_THREADS or 1)",
        )
        .opt("trace", "", "write a Chrome trace-event JSON (Perfetto-loadable) to this path");
    let cli = fault_opts(async_opts(cli));
    let a = cli.parse(rest).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let mut rng = Rng::new(a.u64("seed"));
    let problem = match a.str("mode") {
        "heterogeneous" => LeastSquares::heterogeneous(
            a.usize("n"),
            a.usize("points"),
            a.usize("clients"),
            &mut rng,
        ),
        _ => LeastSquares::homogeneous(
            a.usize("n"),
            a.usize("target-rank"),
            a.usize("points"),
            a.usize("clients"),
            &mut rng,
        ),
    };
    let mut cfg = TrainConfig {
        rounds: a.usize("rounds"),
        local_iters: a.usize("iters"),
        lr: LrSchedule::Constant(a.f64("lr")),
        var_correction: parse_vc(a.str("vc")),
        rank: RankConfig {
            initial_rank: (a.usize("n") / 2).min(8),
            max_rank: a.usize("n") / 2,
            tau: a.f64("tau"),
        },
        seed: a.u64("seed"),
        dropout: a.f64("dropout"),
        executor: parse_executor(a.str("executor")),
        codec: parse_codec(a.str("codec")),
        kernel_threads: a.usize("kernel-threads"),
        correction: parse_correction(a.str("correction")),
        scenario: parse_scenario(a.str("scenario")),
        ..TrainConfig::default()
    };
    apply_async_opts(&mut cfg, &a);
    apply_fault_opts(&mut cfg, &a);
    let obs = recorder_for(a.str("trace"));
    let rec = if cfg.schedule != Schedule::Sync {
        if matches!(a.str("algo"), "fedavg" | "fedlin") {
            eprintln!("--schedule {} requires --algo fedlrt", cfg.schedule.label());
            std::process::exit(2);
        }
        run_async_obs(&problem, &cfg, "cli_lsq", &obs)
    } else {
        match a.str("algo") {
            "fedavg" => run_dense_obs(&problem, &cfg, DenseAlgo::FedAvg, "cli_lsq", &obs),
            "fedlin" => run_dense_obs(&problem, &cfg, DenseAlgo::FedLin, "cli_lsq", &obs),
            _ => run_fedlrt_obs(&problem, &cfg, "cli_lsq", &obs),
        }
    };
    finish_trace(&obs, a.str("trace"))?;
    for r in rec.rounds.iter().step_by((cfg.rounds / 10).max(1)) {
        println!(
            "round {:>4}: loss {:<12.4e} rank {:?} dist {:.4e}",
            r.round,
            r.global_loss,
            r.ranks,
            r.dist_to_opt.unwrap_or(f64::NAN)
        );
    }
    println!(
        "final loss {:.4e} (L* = {:.4e}), rank {}, comm {} floats / {} bytes on wire ({})",
        rec.final_loss(),
        problem.min_loss(),
        rec.final_rank(),
        rec.total_comm_floats(),
        rec.total_bytes(),
        cfg.codec.label()
    );
    Ok(())
}

fn cmd_costs(rest: &[String]) -> Result<()> {
    let cli = Cli::new("fedlrt costs", "Table 1 cost model")
        .opt("n", "512", "layer dimension")
        .opt("r", "32", "rank")
        .opt("iters", "10", "local iterations")
        .opt("batch", "128", "batch size");
    let a = cli.parse(rest).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let p = fedlrt::costmodel::CostParams {
        n: a.usize("n"),
        r: a.usize("r"),
        s_star: a.usize("iters"),
        b: a.usize("batch"),
    };
    println!(
        "{:<24} {:>14} {:>14} {:>12} {:>7}",
        "method", "client flops", "server flops", "comm", "rounds"
    );
    for m in fedlrt::costmodel::ALL_METHODS {
        let c = fedlrt::costmodel::costs(m, p);
        println!(
            "{:<24} {:>14.3e} {:>14.3e} {:>12.3e} {:>7}",
            m.label(),
            c.client_compute,
            c.server_compute,
            c.comm_cost,
            c.comm_rounds
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("fedlrt — Federated Dynamical Low-Rank Training (Schotthöfer & Laiu, 2024)");
    match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts at:  {:?}", Runtime::default_dir());
            println!("model configs:");
            for (name, e) in &rt.manifest.configs {
                println!(
                    "  {:<16} d_in={:<4} core={}x{} ×{}  classes={:<4} r_pad={} batch={}",
                    name, e.d_in, e.n_core, e.n_core, e.num_lr, e.classes, e.r_pad, e.batch
                );
            }
        }
        Err(e) => println!("artifacts not available ({e}); run `make artifacts`"),
    }
    Ok(())
}
