//! Byte-exact wire codecs.
//!
//! The paper's headline claim is an order-of-magnitude cut in
//! communication cost, so the simulation must measure *bytes on the
//! wire*, not abstract float counts. Every transfer through
//! [`crate::comm::Network`] is serialized by a [`Codec`] and the
//! serialized length is what the accounting records; the receive side
//! sees the *decoded* tensor, so lossy codecs visibly trade accuracy
//! for bytes in the training trajectory.
//!
//! Three codecs cover the design space (cf. Konečný et al., *Federated
//! Learning: Strategies for Improving Communication Efficiency*):
//!
//! | Codec | Wire format | Bytes for `n` entries | Receive-side error |
//! |---|---|---|---|
//! | [`DenseF32`] | little-endian `f32` per entry | `4·n` | none (reference) |
//! | [`F16Cast`] | IEEE 754 binary16 per entry | `2·n` | relative ≈ 2⁻¹¹ |
//! | [`QuantizeInt8`] | `f32` scale + `f32` min + `u8` per entry | `8 + n` | absolute ≤ `(max−min)/255` |
//!
//! **Reference-codec convention.** Simulation numerics are `f64`, but
//! deployments ship `f32`; the seed accounting therefore counted
//! `floats × 4` bytes while the coordinator math stayed at `f64`.
//! `DenseF32` preserves exactly that convention: it serializes real
//! `f32` bytes (so measured bytes equal `floats × 4`) and its simulated
//! receive side is the identity at simulation precision
//! ([`Codec::transparent`]), keeping training trajectories bitwise
//! identical to the pre-codec accounting. The lossy codecs round-trip
//! for real: what the coordinator computes with is what survived the
//! wire.
//!
//! **QuantizeInt8 error bound.** Per-tensor affine quantization
//! `q = round((x − min)/s)` with `s = (max − min)/255` stored as `f32`.
//! Decode returns `min + q·s`, so the round-trip error is at most
//! `s/2` from rounding plus the `f32` representation error of `min`
//! and `s` (relative 2⁻²⁴) — bounded by `(max − min)/255` overall,
//! which the unit tests assert on random tensors.

/// Identifier of a wire codec — what configs, presets, and the CLI
/// carry (`--codec dense|f16|q8`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// Reference: 4 bytes/entry, transparent at simulation precision.
    DenseF32,
    /// IEEE 754 half precision: 2 bytes/entry, lossy.
    F16Cast,
    /// Per-tensor affine int8 quantization: 1 byte/entry + 8-byte
    /// header, lossy.
    QuantizeInt8,
}

pub const ALL_CODECS: [CodecKind; 3] =
    [CodecKind::DenseF32, CodecKind::F16Cast, CodecKind::QuantizeInt8];

impl CodecKind {
    pub fn label(&self) -> &'static str {
        match self {
            CodecKind::DenseF32 => "dense",
            CodecKind::F16Cast => "f16",
            CodecKind::QuantizeInt8 => "q8",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> anyhow::Result<CodecKind> {
        match s {
            "dense" | "f32" => Ok(CodecKind::DenseF32),
            "f16" | "half" => Ok(CodecKind::F16Cast),
            "q8" | "int8" => Ok(CodecKind::QuantizeInt8),
            other => Err(anyhow::anyhow!("unknown codec '{other}' (expected dense|f16|q8)")),
        }
    }

    /// The codec implementation (static — `CodecKind` stays `Copy`).
    pub fn codec(&self) -> &'static dyn Codec {
        match self {
            CodecKind::DenseF32 => &DenseF32,
            CodecKind::F16Cast => &F16Cast,
            CodecKind::QuantizeInt8 => &QuantizeInt8,
        }
    }

    /// Exact serialized size of a message of `entries` values — matches
    /// `codec().encode(values).len()` for any values of that length
    /// (asserted in tests). Used for descriptor-only accounting where
    /// no tensor data exists (scalar/metadata payloads).
    pub fn wire_bytes(&self, entries: u64) -> u64 {
        if entries == 0 {
            return 0;
        }
        match self {
            CodecKind::DenseF32 => 4 * entries,
            CodecKind::F16Cast => 2 * entries,
            CodecKind::QuantizeInt8 => 8 + entries,
        }
    }

    /// Asymptotic bytes per tensor entry (header amortized away) — the
    /// factor the closed-form cost model applies to Table 1 / Fig 3
    /// communication entries.
    pub fn bytes_per_entry(&self) -> f64 {
        match self {
            CodecKind::DenseF32 => 4.0,
            CodecKind::F16Cast => 2.0,
            CodecKind::QuantizeInt8 => 1.0,
        }
    }
}

/// A pluggable wire codec: `f64` tensor data → bytes → `f64` tensor
/// data. Implementations must be shape-oblivious (a tensor travels as
/// its flattened entries) and length-preserving through the round trip.
pub trait Codec: Sync {
    fn kind(&self) -> CodecKind;

    /// Serialize `values` to wire bytes.
    fn encode(&self, values: &[f64]) -> Vec<u8>;

    /// Deserialize wire bytes back to values.
    fn decode(&self, bytes: &[u8]) -> Vec<f64>;

    /// True when the simulated receive side is the identity at
    /// simulation (`f64`) precision — see the module docs on the
    /// reference-codec convention. Lossy codecs return `false` and
    /// their decoded values feed the coordinator numerics.
    fn transparent(&self) -> bool {
        false
    }
}

/// Reference codec: little-endian `f32` per entry.
pub struct DenseF32;

impl Codec for DenseF32 {
    fn kind(&self) -> CodecKind {
        CodecKind::DenseF32
    }

    fn encode(&self, values: &[f64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 * values.len());
        for &v in values {
            out.extend_from_slice(&(v as f32).to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8]) -> Vec<f64> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
            .collect()
    }

    fn transparent(&self) -> bool {
        true
    }
}

/// Lossy codec: IEEE 754 binary16 per entry (round-to-nearest-even).
pub struct F16Cast;

impl Codec for F16Cast {
    fn kind(&self) -> CodecKind {
        CodecKind::F16Cast
    }

    fn encode(&self, values: &[f64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 * values.len());
        for &v in values {
            out.extend_from_slice(&f32_to_f16_bits(v as f32).to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8]) -> Vec<f64> {
        bytes.chunks_exact(2).map(|c| f16_bits_to_f64(u16::from_le_bytes([c[0], c[1]]))).collect()
    }
}

/// Lossy codec: per-tensor affine `u8` quantization
/// (`scale: f32`, `min: f32` header, one byte per entry).
pub struct QuantizeInt8;

impl Codec for QuantizeInt8 {
    fn kind(&self) -> CodecKind {
        CodecKind::QuantizeInt8
    }

    fn encode(&self, values: &[f64]) -> Vec<u8> {
        if values.is_empty() {
            return Vec::new();
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        // Degenerate ranges (constant tensor, or a spread that
        // underflows f32) collapse to scale 0: every entry decodes to
        // `min`, with error ≤ (hi − lo)/2 from representing the tensor
        // by its midpoint.
        let mut scale = ((hi - lo) / 255.0) as f32;
        let mut min = lo as f32;
        if !scale.is_finite() || scale <= 0.0 {
            scale = 0.0;
            min = (lo + (hi - lo) / 2.0) as f32;
        }
        let mut out = Vec::with_capacity(8 + values.len());
        out.extend_from_slice(&scale.to_le_bytes());
        out.extend_from_slice(&min.to_le_bytes());
        let (s64, m64) = (scale as f64, min as f64);
        for &v in values {
            let q = if s64 > 0.0 { ((v - m64) / s64).round().clamp(0.0, 255.0) } else { 0.0 };
            out.push(q as u8);
        }
        out
    }

    fn decode(&self, bytes: &[u8]) -> Vec<f64> {
        if bytes.is_empty() {
            return Vec::new();
        }
        let scale = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as f64;
        let min = f32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as f64;
        bytes[8..].iter().map(|&q| min + q as f64 * scale).collect()
    }
}

/// `f32` → IEEE 754 binary16 bit pattern, round-to-nearest-even,
/// overflow to ±inf, underflow through subnormals to ±0.
fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (keep NaN payload nonzero).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7c00; // overflow → inf
    }
    if unbiased >= -14 {
        // Normal half: round the 23-bit mantissa to 10 bits (RNE). A
        // mantissa carry propagates into the exponent field correctly
        // because the encoding is monotone in (exp, mant).
        let mant16 = (mant >> 13) as u16;
        let round = mant & 0x1fff;
        let mut h = sign | (((unbiased + 15) as u16) << 10) | mant16;
        if round > 0x1000 || (round == 0x1000 && (h & 1) == 1) {
            h += 1;
        }
        return h;
    }
    if unbiased >= -25 {
        // Subnormal half: value = m16 · 2⁻²⁴ with m16 = round(m24 · 2^(unbiased+1)).
        let m24 = mant | 0x0080_0000;
        let shift = (-(unbiased + 1)) as u32; // 14..=24
        let m16 = m24 >> shift;
        let rem = m24 & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m16;
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1; // may round up to the smallest normal — encoding stays valid
        }
        return sign | m as u16;
    }
    sign // underflow → ±0
}

/// IEEE 754 binary16 bit pattern → `f64` (exact).
fn f16_bits_to_f64(h: u16) -> f64 {
    let sign = if h & 0x8000 != 0 { -1.0 } else { 1.0 };
    let exp = ((h >> 10) & 0x1f) as i32;
    let mant = (h & 0x3ff) as f64;
    let mag = match exp {
        0 => mant * (2.0f64).powi(-24),
        0x1f => {
            if mant == 0.0 {
                f64::INFINITY
            } else {
                return f64::NAN;
            }
        }
        e => (1.0 + mant / 1024.0) * (2.0f64).powi(e - 15),
    };
    sign * mag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_values(n: usize, scale: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn wire_bytes_matches_encoder_output() {
        for kind in ALL_CODECS {
            let codec = kind.codec();
            for n in [0usize, 1, 7, 64, 255] {
                let vals = random_values(n, 1.0, 11 + n as u64);
                assert_eq!(
                    codec.encode(&vals).len() as u64,
                    kind.wire_bytes(n as u64),
                    "{} / n={n}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn round_trip_preserves_length() {
        for kind in ALL_CODECS {
            let codec = kind.codec();
            for n in [0usize, 1, 5, 100] {
                let vals = random_values(n, 3.0, 5 + n as u64);
                assert_eq!(codec.decode(&codec.encode(&vals)).len(), n, "{}", kind.label());
            }
        }
    }

    #[test]
    fn dense_f32_is_the_reference() {
        let codec = CodecKind::DenseF32.codec();
        assert!(codec.transparent());
        // Values representable in f32 round-trip exactly.
        let vals = [1.0, -2.5, 0.0, 1024.0, -0.015625];
        let back = codec.decode(&codec.encode(&vals));
        assert_eq!(back, vals.to_vec());
        // Arbitrary f64 round-trips at f32 precision.
        let vals = random_values(200, 1.0, 17);
        for (a, b) in vals.iter().zip(codec.decode(&codec.encode(&vals))) {
            assert!((a - b).abs() <= a.abs() * 1e-7 + 1e-30, "{a} vs {b}");
        }
    }

    #[test]
    fn f16_error_within_half_precision() {
        let codec = CodecKind::F16Cast.codec();
        for seed in 0..4 {
            let vals = random_values(300, 10.0f64.powi(seed as i32 - 2), 23 + seed);
            let back = codec.decode(&codec.encode(&vals));
            for (a, b) in vals.iter().zip(&back) {
                // Relative 2⁻¹¹ in the normal range, absolute 2⁻²⁴ near 0.
                let tol = a.abs() * (1.0 / 2048.0) + (2.0f64).powi(-24);
                assert!((a - b).abs() <= tol, "f16: {a} -> {b}");
            }
        }
    }

    #[test]
    fn f16_special_values_and_exactness() {
        let codec = CodecKind::F16Cast.codec();
        // Powers of two and small integers are exact in binary16.
        let vals = [0.0, -0.0, 1.0, -1.0, 0.5, 2.0, 3.0, 1536.0, -0.125];
        assert_eq!(codec.decode(&codec.encode(&vals)), vals.to_vec());
        // Overflow saturates to inf.
        let big = codec.decode(&codec.encode(&[1e9]));
        assert!(big[0].is_infinite() && big[0] > 0.0);
        // Tiny values underflow to zero.
        let tiny = codec.decode(&codec.encode(&[1e-12]));
        assert_eq!(tiny[0], 0.0);
    }

    #[test]
    fn q8_error_bounded_by_documented_bound() {
        let codec = CodecKind::QuantizeInt8.codec();
        for seed in 0..6 {
            let vals = random_values(400, 10.0f64.powi(seed as i32 - 3), 41 + seed);
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let bound = (hi - lo) / 255.0 + (hi.abs() + lo.abs() + 1.0) * 1e-6;
            let back = codec.decode(&codec.encode(&vals));
            for (a, b) in vals.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "q8: {a} -> {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn q8_degenerate_tensors() {
        let codec = CodecKind::QuantizeInt8.codec();
        // Constant tensor decodes to the constant (at f32 precision).
        let back = codec.decode(&codec.encode(&[2.5; 10]));
        assert!(back.iter().all(|&x| (x - 2.5).abs() < 1e-6), "{back:?}");
        // All-zero tensor decodes to exact zeros.
        let back = codec.decode(&codec.encode(&[0.0; 8]));
        assert!(back.iter().all(|&x| x == 0.0));
        // Asymmetric range far from zero must not wrap (affine, not symmetric).
        let vals = [100.0, 100.5, 101.0];
        let back = codec.decode(&codec.encode(&vals));
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() < 0.01, "{a} -> {b}");
        }
    }

    #[test]
    fn codec_kind_parse_and_labels() {
        assert_eq!(CodecKind::parse("dense").unwrap(), CodecKind::DenseF32);
        assert_eq!(CodecKind::parse("f16").unwrap(), CodecKind::F16Cast);
        assert_eq!(CodecKind::parse("q8").unwrap(), CodecKind::QuantizeInt8);
        assert!(CodecKind::parse("zstd").is_err());
        for kind in ALL_CODECS {
            assert_eq!(CodecKind::parse(kind.label()).unwrap(), kind);
            assert_eq!(kind.codec().kind(), kind);
        }
    }

    #[test]
    fn bytes_per_entry_ordering() {
        assert!(CodecKind::QuantizeInt8.bytes_per_entry() < CodecKind::F16Cast.bytes_per_entry());
        assert!(CodecKind::F16Cast.bytes_per_entry() < CodecKind::DenseF32.bytes_per_entry());
    }
}
