//! Unreliable-transport fault injection: message loss, checksum-detected
//! corruption, duplication, delay jitter, and the timeout/retry/quorum
//! policy layered on top.
//!
//! The paper's descent guarantees (Thm. 3.2–3.6) assume a lossless,
//! synchronous transport; this module simulates the regimes where that
//! assumption breaks while keeping the engine's determinism contract:
//! every fault decision is a pure function of
//! `(run seed, round-or-dispatch, client, attempt)` through a salted
//! RNG stream disjoint from every scheduling/timing stream, so the set
//! of lost/corrupt/duplicated messages — and therefore the event
//! timeline and the surviving roster — is identical under any executor
//! or kernel-thread setting.
//!
//! Structure:
//!
//! * [`FaultModel`] — the per-link Bernoulli loss / corruption /
//!   duplication probabilities plus a delay-jitter [`Dist`]. The
//!   default is structurally inactive: no draws, no wire framing, no
//!   plan filtering — bitwise-legacy everything.
//! * [`NetPolicy`] — the server's response: per-round upload deadline
//!   (`timeout`), bounded retransmission with exponential backoff
//!   (`retries`), and the sync-round quorum (`quorum` = min surviving
//!   uploads; below it the round is skipped with state untouched).
//! * CRC-32 wire framing ([`frame`]/[`verify`]) — the checksum header
//!   that detects corrupted payloads. CRC-32 detects **every** burst
//!   error of ≤ 32 bits, in particular any single flipped byte
//!   (property-tested in `tests/coordinator_props.rs`), so a corrupt
//!   draw and a checksum rejection are the same event: the Bernoulli
//!   `corrupt_prob` draw *is* the verify outcome, and the simulation
//!   can decide fates at plan time without materializing the frame.
//!   When the fault model is active every transcoded message pays
//!   [`CHECKSUM_BYTES`] of header on the wire; when inactive the wire
//!   format (and every byte count) is bitwise-legacy.
//! * [`sync_gate`] — the sync coordinators' per-round hook: decides
//!   each participating client's delivery outcome, filters the
//!   [`RoundPlan`] to the delivered roster (weights renormalized,
//!   ordinals reassigned), books the drop/corrupt/retransmission
//!   counters into the [`Network`], and reports whether the round falls
//!   below quorum. Returns `None` when the transport is structurally
//!   inactive — the zero-code-path-change legacy gate.

use crate::engine::dist::Dist;
use crate::engine::plan::{ClientTask, RoundPlan};
use crate::util::rng::Rng;

use super::{Network, RoundComm};

/// Message-fate RNG salt. Disjoint from every other purpose salt in the
/// tree (`0x5E1E_C700` sampling, `0x57A6_6000` stragglers, `0xD809_0FF1`
/// dropout, `0xA11D_A7E5`/`0xC0FF_EE00`/`0x11CC_4A7B`/`0x4E7E_0561`
/// async timing, `0xD15C_A7C4` client pick, `0xC4BB_A9E1` churn,
/// `0xC0C0_D07A` cohorts, `0xFA17_717A` fault assignment, `0xFA01_7557`
/// fault noise) so transport fates never alias a scheduling draw.
const SALT_NET_FAULT: u64 = 0xBAD0_C0DE;

/// Per-link unreliable-transport model. All probabilities are
/// per-*attempt* (each retransmission redraws its fate); the default is
/// structurally inactive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    /// Bernoulli probability an upload attempt is lost in transit.
    pub loss_prob: f64,
    /// Bernoulli probability an attempt arrives with a corrupted
    /// payload (always detected — see the CRC-32 framing above — and
    /// treated like a loss by the retry policy, but counted separately).
    pub corrupt_prob: f64,
    /// Bernoulli probability a delivered attempt arrives twice (the
    /// duplicate is deduplicated server-side but its bytes ride the
    /// wire and are billed as retransmitted traffic).
    pub dup_prob: f64,
    /// Extra per-attempt delivery delay in virtual seconds
    /// (`constant:0` draws nothing).
    pub delay: Dist,
}

impl Default for FaultModel {
    fn default() -> Self {
        FaultModel {
            loss_prob: 0.0,
            corrupt_prob: 0.0,
            dup_prob: 0.0,
            delay: Dist::Constant(0.0),
        }
    }
}

impl FaultModel {
    /// Whether any fault knob is set. `false` = the structurally
    /// inactive legacy path: no fate draws, no checksum framing, no
    /// byte-count change anywhere.
    pub fn is_active(&self) -> bool {
        self.loss_prob > 0.0
            || self.corrupt_prob > 0.0
            || self.dup_prob > 0.0
            || !matches!(self.delay, Dist::Constant(v) if v == 0.0)
    }

    /// Draw one attempt's fate from `rng` in a fixed order (loss,
    /// corruption, duplication, delay) — the stream is message-scoped,
    /// so the draw order is a per-message contract and enabling one
    /// knob never shifts another knob's draws across messages.
    pub fn attempt_fate(&self, rng: &mut Rng) -> AttemptFate {
        let lost = rng.uniform() < self.loss_prob;
        let corrupt = rng.uniform() < self.corrupt_prob;
        let duplicated = rng.uniform() < self.dup_prob;
        let delay_s = self.delay.sample(rng).max(0.0);
        AttemptFate { lost, corrupt, duplicated, delay_s }
    }

    /// One client's delivery outcome for a sync round under `policy`:
    /// attempts are made until one arrives intact, the retry budget is
    /// exhausted, or the round deadline passes. `latency` is the link's
    /// per-message latency (the backoff unit). Pure function of
    /// `(seed, round, client)` — see [`attempt_rng`].
    pub fn deliver(
        &self,
        policy: &NetPolicy,
        seed: u64,
        round: u64,
        client: u64,
        latency: f64,
    ) -> DeliveryOutcome {
        let mut out = DeliveryOutcome {
            delivered: false,
            attempts: 0,
            wire_copies: 0,
            lost: 0,
            corrupt: 0,
            elapsed_s: 0.0,
        };
        for attempt in 0..=policy.retries {
            if attempt > 0 {
                // Failure detection + exponential backoff before each
                // retransmission: latency · 2^(attempt−1).
                out.elapsed_s += latency * (1u64 << (attempt - 1).min(62)) as f64;
            }
            let mut rng = attempt_rng(seed, round, client, attempt);
            let fate = self.attempt_fate(&mut rng);
            out.attempts += 1;
            out.wire_copies += 1 + fate.duplicated as u32;
            out.elapsed_s += latency + fate.delay_s;
            if policy.timeout > 0.0 && out.elapsed_s > policy.timeout {
                // Round deadline passed while this attempt was in
                // flight: the server has stopped listening.
                return out;
            }
            if fate.lost {
                out.lost += 1;
                continue;
            }
            if fate.corrupt {
                out.corrupt += 1;
                continue;
            }
            out.delivered = true;
            return out;
        }
        out
    }
}

/// The fate of one delivery attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttemptFate {
    pub lost: bool,
    pub corrupt: bool,
    pub duplicated: bool,
    /// Extra delivery delay of this attempt (virtual seconds, ≥ 0).
    pub delay_s: f64,
}

/// The message-scoped fate stream for `(seed, round-or-dispatch,
/// client, attempt)`. The async server draws its retransmission link
/// time from the same stream *after* the fate (fixed order), so retry
/// scheduling stays a pure function of metadata.
pub fn attempt_rng(seed: u64, round: u64, client: u64, attempt: u32) -> Rng {
    Rng::new(seed ^ SALT_NET_FAULT).split(round).split(client).split(attempt as u64)
}

/// Everything known about one client's upload delivery in a round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeliveryOutcome {
    /// Did any attempt arrive intact before the deadline?
    pub delivered: bool,
    /// Transmission attempts made (each is charged one link latency in
    /// `estimated_comm_time`).
    pub attempts: u32,
    /// Payload copies that rode the wire (attempts + duplicates) —
    /// the billing multiplier for `bytes_retx`.
    pub wire_copies: u32,
    /// Attempts lost in transit.
    pub lost: u32,
    /// Attempts rejected by the wire checksum.
    pub corrupt: u32,
    /// Virtual seconds from first send to the final attempt's arrival
    /// (backoffs included).
    pub elapsed_s: f64,
}

impl DeliveryOutcome {
    /// Upload messages that never reached the server usefully: lost
    /// attempts, plus — for an undelivered client — the late/abandoned
    /// final attempt that was neither lost nor corrupt.
    pub fn dropped_msgs(&self) -> u64 {
        let base = self.lost as u64;
        if self.delivered {
            base
        } else {
            base + (self.attempts - self.lost - self.corrupt) as u64
        }
    }
}

/// Server-side transport policy: deadline, retry budget, sync quorum.
/// The default is structurally inactive (no deadline, no retries, no
/// quorum).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct NetPolicy {
    /// Per-round upload deadline in virtual seconds (0 = none). A sync
    /// client whose winning attempt lands after the deadline is
    /// dropped; an async upload attempt slower than the deadline is
    /// retransmitted.
    pub timeout: f64,
    /// Retransmissions allowed after the first attempt, with
    /// exponential backoff.
    pub retries: u32,
    /// Minimum surviving uploads for a sync round to aggregate; below
    /// it the round is skipped with basis/state untouched. 0 = no
    /// quorum (but a zero-survivor round is always skipped — averaging
    /// nothing would zero the model).
    pub quorum: usize,
}

impl NetPolicy {
    /// Whether any policy knob is set (config-echo gate).
    pub fn is_active(&self) -> bool {
        self.timeout > 0.0 || self.retries > 0 || self.quorum > 0
    }
}

/// Whether the transport layer does anything at all this run: fault
/// draws happen, frames carry checksums, and sync rounds route through
/// [`sync_gate`]'s filter. Quorum alone activates it (a quorum check
/// needs the delivery bookkeeping even over a lossless link).
pub fn transport_active(fault: &FaultModel, policy: &NetPolicy) -> bool {
    fault.is_active() || policy.timeout > 0.0 || policy.quorum > 0
}

// ---------------------------------------------------------------------
// CRC-32 wire framing (the checksum header).
// ---------------------------------------------------------------------

/// Wire checksum header length prepended to every framed payload when
/// the fault model is active.
pub const CHECKSUM_BYTES: u64 = 4;

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`), bitwise
/// table-free implementation — only fault-path frames pay for it.
/// Detects every burst error of ≤ 32 bits, hence any single flipped
/// byte.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = !0;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Prepend the 4-byte little-endian CRC-32 header.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + CHECKSUM_BYTES as usize);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Check the header; `Some(payload)` iff the frame is intact.
pub fn verify(framed: &[u8]) -> Option<&[u8]> {
    if framed.len() < CHECKSUM_BYTES as usize {
        return None;
    }
    let (hdr, payload) = framed.split_at(CHECKSUM_BYTES as usize);
    let want = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]);
    (crc32(payload) == want).then_some(payload)
}

// ---------------------------------------------------------------------
// Per-round stats surfaced in RoundMetrics.
// ---------------------------------------------------------------------

/// Fault/skip counters of one aggregation round, copied into
/// [`crate::metrics::RoundMetrics`] (all-default when the transport is
/// clean, and then omitted from the JSON row).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultRoundStats {
    /// True when a sync round was skipped below the upload quorum (or
    /// on total blackout); the model/basis/state were left untouched.
    pub skipped: bool,
    /// Upload messages lost in transit or abandoned past the deadline.
    pub msgs_dropped: u64,
    /// Upload arrivals rejected by the wire checksum.
    pub msgs_corrupt: u64,
    /// Retransmitted/duplicate bytes beyond each consumed upload's
    /// first copy.
    pub bytes_retx: u64,
}

impl FaultRoundStats {
    /// Lift the round's comm counters (skip flag stays false).
    pub fn from_comm(c: &RoundComm) -> FaultRoundStats {
        FaultRoundStats {
            skipped: false,
            msgs_dropped: c.msgs_dropped,
            msgs_corrupt: c.msgs_corrupt,
            bytes_retx: c.bytes_retx,
        }
    }

    /// Same, for a round recorded as skipped.
    pub fn skipped_from_comm(c: &RoundComm) -> FaultRoundStats {
        FaultRoundStats { skipped: true, ..FaultRoundStats::from_comm(c) }
    }

    /// Anything worth emitting in the JSON row?
    pub fn any(&self) -> bool {
        self.skipped || self.msgs_dropped > 0 || self.msgs_corrupt > 0 || self.bytes_retx > 0
    }
}

// ---------------------------------------------------------------------
// The sync coordinators' per-round gate.
// ---------------------------------------------------------------------

/// Outcome of [`sync_gate`] for one round.
#[derive(Debug, Clone)]
pub struct SyncGate {
    /// Below quorum (or zero survivors): skip the round, state
    /// untouched.
    pub skip: bool,
    /// Wire copies per surviving task ordinal — the coordinators pass
    /// `copies[task.ordinal]` to [`Network::set_upload_copies`] around
    /// each survivor's uploads so retransmitted bytes are billed.
    pub copies: Vec<u64>,
    pub msgs_dropped: u64,
    pub msgs_corrupt: u64,
    /// Transmission attempts beyond each client's first (latency
    /// charges in `estimated_comm_time`).
    pub retx_attempts: u64,
}

/// Decide every participating client's delivery outcome for a sync
/// round, filter `plan` to the delivered roster (weights renormalized
/// over the survivors, ordinals reassigned), book the counters into
/// `net`, and report the quorum decision.
///
/// Returns `None` when the transport is structurally inactive — the
/// plan, the network, and every downstream byte/float count are then
/// bitwise-identical to the legacy path.
///
/// The delivery unit is the client's whole round: FeDLRT's multiple
/// round trips share one fate sequence per `(round, client)` (a client
/// that cannot reach the server in round `t` contributes to none of the
/// round's aggregations), and a survivor's retransmission multiplier
/// applies to each of its uploaded tensors.
pub fn sync_gate(
    fault: &FaultModel,
    policy: &NetPolicy,
    seed: u64,
    round: u64,
    plan: &mut RoundPlan,
    net: &mut Network,
) -> Option<SyncGate> {
    if !transport_active(fault, policy) {
        return None;
    }
    let latency = net.link.latency;
    let mut dropped = 0u64;
    let mut corrupt = 0u64;
    let mut retx = 0u64;
    let mut survivors: Vec<ClientTask> = Vec::with_capacity(plan.tasks.len());
    let mut copies: Vec<u64> = Vec::with_capacity(plan.tasks.len());
    for task in plan.tasks.drain(..) {
        let out = fault.deliver(policy, seed, round, task.client_id as u64, latency);
        dropped += out.dropped_msgs();
        corrupt += out.corrupt as u64;
        retx += (out.attempts - 1) as u64;
        if out.delivered {
            copies.push(out.wire_copies as u64);
            survivors.push(task);
        }
    }
    net.note_faults(dropped, corrupt, retx);
    let wsum: f64 = survivors.iter().map(|t| t.weight).sum();
    for (i, t) in survivors.iter_mut().enumerate() {
        t.ordinal = i;
        if wsum > 0.0 {
            t.weight /= wsum;
        }
    }
    let n = survivors.len();
    plan.tasks = survivors;
    Some(SyncGate {
        skip: n < policy.quorum.max(1),
        copies,
        msgs_dropped: dropped,
        msgs_corrupt: corrupt,
        retx_attempts: retx,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TrainConfig;

    fn lossy() -> FaultModel {
        FaultModel { loss_prob: 0.3, ..FaultModel::default() }
    }

    #[test]
    fn default_model_is_structurally_inactive() {
        assert!(!FaultModel::default().is_active());
        assert!(!NetPolicy::default().is_active());
        assert!(!transport_active(&FaultModel::default(), &NetPolicy::default()));
        // Any knob activates.
        assert!(lossy().is_active());
        assert!(FaultModel { corrupt_prob: 0.1, ..FaultModel::default() }.is_active());
        assert!(FaultModel { dup_prob: 0.1, ..FaultModel::default() }.is_active());
        assert!(FaultModel {
            delay: Dist::Uniform { lo: 0.0, hi: 1.0 },
            ..FaultModel::default()
        }
        .is_active());
        assert!(transport_active(
            &FaultModel::default(),
            &NetPolicy { quorum: 2, ..NetPolicy::default() }
        ));
    }

    #[test]
    fn crc32_known_vector_and_flip_detection() {
        // IEEE CRC-32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        let payload: Vec<u8> = (0u16..300).map(|i| (i % 251) as u8).collect();
        let framed = frame(&payload);
        assert_eq!(framed.len() as u64, payload.len() as u64 + CHECKSUM_BYTES);
        assert_eq!(verify(&framed), Some(payload.as_slice()));
        // Flip every byte position (header included): always caught.
        for i in 0..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x5A;
            assert!(verify(&bad).is_none(), "flip at {i} undetected");
        }
        assert!(verify(&[1, 2, 3]).is_none(), "short frame rejected");
    }

    #[test]
    fn fates_are_deterministic_and_attempt_varying() {
        let fm = FaultModel {
            loss_prob: 0.4,
            corrupt_prob: 0.2,
            dup_prob: 0.1,
            delay: Dist::Uniform { lo: 0.0, hi: 0.5 },
        };
        let f1 = fm.attempt_fate(&mut attempt_rng(7, 3, 5, 0));
        let f2 = fm.attempt_fate(&mut attempt_rng(7, 3, 5, 0));
        assert_eq!(f1, f2, "same (seed, round, client, attempt) → same fate");
        // Across attempts/clients/rounds the fates vary (almost surely
        // over enough draws).
        let varies = (0..64).any(|a| {
            fm.attempt_fate(&mut attempt_rng(7, 3, 5, a)) != f1
        });
        assert!(varies);
    }

    #[test]
    fn retries_recover_lost_uploads_and_bill_attempts() {
        let fm = lossy();
        let none = NetPolicy::default();
        let many = NetPolicy { retries: 6, ..NetPolicy::default() };
        let mut lost_without = 0;
        let mut lost_with = 0;
        let mut saw_retx = false;
        for c in 0..200u64 {
            let a = fm.deliver(&none, 11, 0, c, 0.02);
            let b = fm.deliver(&many, 11, 0, c, 0.02);
            lost_without += !a.delivered as u32;
            lost_with += !b.delivered as u32;
            if b.delivered && b.attempts > 1 {
                saw_retx = true;
                assert_eq!(b.lost + b.corrupt, b.attempts - 1);
            }
        }
        assert!(lost_without > 20, "p=0.3 should drop many ({lost_without})");
        assert!(lost_with < lost_without / 4, "retries must recover most");
        assert!(saw_retx);
    }

    #[test]
    fn deadline_drops_slow_deliveries() {
        // Loss forces retries; a tight deadline cuts them off.
        let fm = FaultModel { loss_prob: 0.9, ..FaultModel::default() };
        let tight = NetPolicy { timeout: 0.03, retries: 5, ..NetPolicy::default() };
        let loose = NetPolicy { timeout: 1e6, retries: 5, ..NetPolicy::default() };
        let mut fewer = 0;
        for c in 0..100u64 {
            let a = fm.deliver(&tight, 5, 1, c, 0.02);
            let b = fm.deliver(&loose, 5, 1, c, 0.02);
            assert!(a.attempts <= b.attempts);
            if a.attempts < b.attempts {
                fewer += 1;
            }
            if !a.delivered {
                assert!(a.dropped_msgs() + a.corrupt as u64 == a.attempts as u64);
            }
        }
        assert!(fewer > 0, "the deadline must cut some retry sequences short");
    }

    #[test]
    fn duplicates_add_wire_copies() {
        let fm = FaultModel { dup_prob: 0.5, ..FaultModel::default() };
        let pol = NetPolicy::default();
        let copies: u32 =
            (0..100u64).map(|c| fm.deliver(&pol, 3, 0, c, 0.0).wire_copies).sum();
        // 100 attempts, ~50 duplicated.
        assert!(copies > 110 && copies < 190, "copies {copies}");
    }

    #[test]
    fn sync_gate_inactive_returns_none_and_leaves_plan_untouched() {
        let cfg = TrainConfig::default();
        let mut plan = RoundPlan::build(&cfg, 4, 0, |_| 1.0);
        let before: Vec<(usize, u64)> =
            plan.tasks.iter().map(|t| (t.client_id, t.weight.to_bits())).collect();
        let mut net = Network::new(4);
        let gate =
            sync_gate(&FaultModel::default(), &NetPolicy::default(), 0, 0, &mut plan, &mut net);
        assert!(gate.is_none());
        let after: Vec<(usize, u64)> =
            plan.tasks.iter().map(|t| (t.client_id, t.weight.to_bits())).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn sync_gate_filters_renormalizes_and_books_counters() {
        let cfg = TrainConfig { seed: 17, ..TrainConfig::default() };
        let mut net = Network::new(12);
        let fm = FaultModel { loss_prob: 0.4, corrupt_prob: 0.1, ..FaultModel::default() };
        let pol = NetPolicy { retries: 1, ..NetPolicy::default() };
        let mut saw_filter = false;
        for t in 0..10 {
            let mut plan = RoundPlan::build(&cfg, 12, t, |c| (c + 1) as f64);
            let full = plan.len();
            let gate = sync_gate(&fm, &pol, cfg.seed, t as u64, &mut plan, &mut net)
                .expect("active transport");
            assert_eq!(gate.copies.len(), plan.len());
            if plan.len() < full {
                saw_filter = true;
            }
            if !plan.is_empty() {
                let wsum: f64 = plan.tasks.iter().map(|t| t.weight).sum();
                assert!((wsum - 1.0).abs() < 1e-12, "renormalized weights");
            }
            for (i, task) in plan.tasks.iter().enumerate() {
                assert_eq!(task.ordinal, i, "ordinals reassigned");
                assert!(gate.copies[i] >= 1);
            }
            assert_eq!(gate.skip, plan.is_empty(), "no quorum: skip only on blackout");
            net.end_round();
        }
        assert!(saw_filter, "p=0.4 over 10 rounds must drop someone");
        let dropped: u64 = net.rounds.iter().map(|r| r.msgs_dropped).sum();
        assert!(dropped > 0, "drop counters must reach RoundComm");
    }

    #[test]
    fn quorum_miss_flags_skip() {
        let cfg = TrainConfig { seed: 23, ..TrainConfig::default() };
        let mut net = Network::new(6);
        // Heavy loss, no retries, quorum of 5: most rounds must skip.
        let fm = FaultModel { loss_prob: 0.7, ..FaultModel::default() };
        let pol = NetPolicy { quorum: 5, ..NetPolicy::default() };
        let mut skips = 0;
        for t in 0..10 {
            let mut plan = RoundPlan::build(&cfg, 6, t, |_| 1.0);
            let gate =
                sync_gate(&fm, &pol, cfg.seed, t as u64, &mut plan, &mut net).unwrap();
            assert_eq!(gate.skip, plan.len() < 5);
            skips += gate.skip as u32;
            net.end_round();
        }
        assert!(skips > 0, "p=0.7 against quorum 5 of 6 must skip rounds");
        // Quorum over a lossless link with a full roster never skips.
        let mut plan = RoundPlan::build(&cfg, 6, 0, |_| 1.0);
        let gate = sync_gate(
            &FaultModel::default(),
            &NetPolicy { quorum: 6, ..NetPolicy::default() },
            cfg.seed,
            0,
            &mut plan,
            &mut net,
        )
        .unwrap();
        assert!(!gate.skip);
        assert_eq!(plan.len(), 6);
    }
}
