//! Simulated federated network with exact communication accounting.
//!
//! The paper's evaluation reports *communication cost* — floats on the
//! wire per aggregation round (Table 1, Fig 3) and cumulative savings
//! (Figs 5–8). This module is the substrate that measures it: every
//! server↔client transfer in the coordinator goes through [`Network`],
//! which records message sizes per round and per direction and can
//! convert volumes to wall-clock estimates under a bandwidth/latency
//! model (used for the Fig 3 cost curves).

pub mod message;

pub use message::Payload;

/// Bandwidth/latency model of one server↔client link.
///
/// Defaults approximate a WAN edge-client uplink: 100 Mbit/s, 20 ms RTT —
/// the regime the paper's "communication is the bottleneck" motivation
/// assumes. The cost curves only depend on it through a monotone scaling.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Bytes per second.
    pub bandwidth: f64,
    /// Per-message latency in seconds.
    pub latency: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel { bandwidth: 100e6 / 8.0, latency: 20e-3 }
    }
}

impl LinkModel {
    /// Transfer time of `bytes` over this link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }
}

/// Communication record of a single aggregation round.
#[derive(Debug, Clone, Default)]
pub struct RoundComm {
    /// Floats broadcast server→clients (counted once — broadcast).
    pub broadcast_floats: u64,
    /// Floats uplinked clients→server (counted per client).
    pub aggregate_floats: u64,
    /// Number of communication *rounds* (synchronous round trips),
    /// the paper's "Com. Rounds" column of Table 1.
    pub round_trips: u64,
    /// Per-message log (direction, label, floats) for debugging.
    pub log: Vec<(Direction, &'static str, u64)>,
}

/// Message direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server → all clients.
    Broadcast,
    /// Client → server (aggregated).
    Aggregate,
}

impl RoundComm {
    /// Total floats on the wire this round (broadcast counted once,
    /// uplink counted per client — matches Table 1's per-client cost
    /// when divided by C).
    pub fn total_floats(&self) -> u64 {
        self.broadcast_floats + self.aggregate_floats
    }

    /// Per-client download+upload volume in floats: what one edge device
    /// pays (broadcast counted once per client, uplink its own share).
    pub fn per_client_floats(&self, num_clients: usize) -> f64 {
        self.broadcast_floats as f64 + self.aggregate_floats as f64 / num_clients as f64
    }

    /// Floats attributable to messages whose label satisfies `pred` —
    /// used to separate compressed-layer traffic from dense-parameter
    /// traffic (the paper's footnote-6 accounting).
    pub fn floats_matching(&self, mut pred: impl FnMut(&str) -> bool) -> u64 {
        self.log.iter().filter(|(_, label, _)| pred(label)).map(|(_, _, f)| f).sum()
    }
}

/// The simulated network: records all traffic of a training run.
#[derive(Debug, Clone)]
pub struct Network {
    pub num_clients: usize,
    /// Clients participating in the current round (≤ num_clients);
    /// aggregation volume scales with this.
    pub active_clients: usize,
    pub link: LinkModel,
    current: RoundComm,
    /// Completed rounds.
    pub rounds: Vec<RoundComm>,
    /// Bytes per float on the wire (4 = f32, what deployments send).
    pub bytes_per_float: u64,
}

impl Network {
    pub fn new(num_clients: usize) -> Network {
        Network {
            num_clients,
            active_clients: num_clients,
            link: LinkModel::default(),
            current: RoundComm::default(),
            rounds: Vec::new(),
            bytes_per_float: 4,
        }
    }

    /// Record a server→clients broadcast of `payload`.
    pub fn broadcast(&mut self, label: &'static str, payload: &Payload) {
        let f = payload.floats();
        self.current.broadcast_floats += f;
        self.current.log.push((Direction::Broadcast, label, f));
    }

    /// Set the number of participating clients for this round.
    pub fn set_active_clients(&mut self, n: usize) {
        self.active_clients = n.clamp(1, self.num_clients);
    }

    /// Record a clients→server aggregation where *each participating*
    /// client uploads a message of `payload`'s size.
    pub fn aggregate(&mut self, label: &'static str, payload: &Payload) {
        let f = payload.floats() * self.active_clients as u64;
        self.current.aggregate_floats += f;
        self.current.log.push((Direction::Aggregate, label, f));
    }

    /// Mark the end of one synchronous round trip (broadcast+aggregate
    /// pair). Table 1 counts these as "Com. Rounds".
    pub fn end_round_trip(&mut self) {
        self.current.round_trips += 1;
    }

    /// Close the current aggregation round and start a new record.
    pub fn end_round(&mut self) -> &RoundComm {
        let done = std::mem::take(&mut self.current);
        self.rounds.push(done);
        self.rounds.last().unwrap()
    }

    /// Cumulative floats over all completed rounds.
    pub fn total_floats(&self) -> u64 {
        self.rounds.iter().map(|r| r.total_floats()).sum()
    }

    /// Cumulative per-client floats (download + own upload share).
    pub fn per_client_floats(&self) -> f64 {
        self.rounds.iter().map(|r| r.per_client_floats(self.num_clients)).sum()
    }

    /// Wall-clock estimate of all communication under the link model.
    /// Each round trip costs latency; volume is serialized per direction
    /// (server link is the bottleneck for aggregation).
    pub fn estimated_comm_time(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| {
                let bytes_down = r.broadcast_floats * self.bytes_per_float;
                let bytes_up = r.aggregate_floats * self.bytes_per_float;
                self.link.transfer_time(bytes_down)
                    + self.link.transfer_time(bytes_up)
                    + self.link.latency * r.round_trips as f64
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_broadcast_vs_aggregate() {
        let mut net = Network::new(4);
        net.broadcast("factors", &Payload::Matrix { rows: 10, cols: 3 });
        net.aggregate("grads", &Payload::Matrix { rows: 10, cols: 3 });
        net.end_round_trip();
        let round = net.end_round();
        assert_eq!(round.broadcast_floats, 30);
        assert_eq!(round.aggregate_floats, 30 * 4);
        assert_eq!(round.round_trips, 1);
        assert_eq!(round.total_floats(), 30 + 120);
        assert!((round.per_client_floats(4) - (30.0 + 30.0)).abs() < 1e-12);
    }

    #[test]
    fn multi_round_totals() {
        let mut net = Network::new(2);
        for _ in 0..3 {
            net.broadcast("w", &Payload::Floats(100));
            net.aggregate("w", &Payload::Floats(100));
            net.end_round_trip();
            net.end_round();
        }
        assert_eq!(net.rounds.len(), 3);
        assert_eq!(net.total_floats(), 3 * (100 + 200));
    }

    #[test]
    fn link_time_monotone_in_bytes() {
        let link = LinkModel::default();
        assert!(link.transfer_time(1000) < link.transfer_time(1_000_000));
        assert!(link.transfer_time(0) >= link.latency);
    }

    #[test]
    fn comm_time_positive_and_scales() {
        let mut a = Network::new(4);
        a.broadcast("x", &Payload::Floats(1_000_000));
        a.end_round_trip();
        a.end_round();
        let mut b = Network::new(4);
        b.broadcast("x", &Payload::Floats(1_000));
        b.end_round_trip();
        b.end_round();
        assert!(a.estimated_comm_time() > b.estimated_comm_time());
    }
}
