//! Simulated federated network with byte-exact communication accounting.
//!
//! The paper's evaluation reports *communication cost* — volume on the
//! wire per aggregation round (Table 1, Fig 3) and cumulative savings
//! (Figs 5–8). This module is the substrate that measures it: every
//! server↔client transfer in the coordinator goes through [`Network`],
//! which serializes the tensor data with the configured wire
//! [`Codec`](wire::Codec), records *measured serialized bytes* (and
//! logical float counts) per round and per direction, hands the
//! *decoded* tensor back to the receive side, and can convert volumes
//! to wall-clock estimates under a bandwidth/latency model (the Fig 3
//! cost curves).

pub mod faults;
pub mod message;
pub mod wire;

pub use faults::{
    sync_gate, AttemptFate, DeliveryOutcome, FaultModel, FaultRoundStats, NetPolicy, SyncGate,
    CHECKSUM_BYTES,
};
pub use message::Payload;
pub use wire::{Codec, CodecKind, ALL_CODECS};

use crate::tensor::Matrix;

/// Bandwidth/latency model of one server↔client link.
///
/// Defaults approximate a WAN edge-client uplink: 100 Mbit/s, 20 ms RTT —
/// the regime the paper's "communication is the bottleneck" motivation
/// assumes. The cost curves only depend on it through a monotone scaling.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Bytes per second.
    pub bandwidth: f64,
    /// Per-message latency in seconds.
    pub latency: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel { bandwidth: 100e6 / 8.0, latency: 20e-3 }
    }
}

impl LinkModel {
    /// Transfer time of `bytes` over this link (one latency + serialization).
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }
}

/// Communication record of a single aggregation round.
#[derive(Debug, Clone, Default)]
pub struct RoundComm {
    /// Floats broadcast server→clients (counted once — broadcast).
    pub broadcast_floats: u64,
    /// Floats uplinked clients→server (counted per client).
    pub aggregate_floats: u64,
    /// Measured serialized bytes server→clients.
    pub bytes_down: u64,
    /// Measured serialized bytes clients→server (per client, summed).
    pub bytes_up: u64,
    /// Number of communication *rounds* (synchronous round trips),
    /// the paper's "Com. Rounds" column of Table 1.
    pub round_trips: u64,
    /// Clients that participated in this round (recorded at
    /// [`Network::end_round`]) — the divisor for a participating
    /// client's upload share.
    pub participants: usize,
    /// Upload messages lost in transit or abandoned past the round
    /// deadline (fault injection — 0 on a clean transport).
    pub msgs_dropped: u64,
    /// Upload arrivals rejected by the wire checksum (fault injection).
    pub msgs_corrupt: u64,
    /// Bytes beyond each consumed upload's first wire copy
    /// (retransmissions + duplicates). Kept out of `bytes_up` so the
    /// Table-1 per-client volumes stay first-copy-exact; the comm-time
    /// estimate charges them separately.
    pub bytes_retx: u64,
    /// Transmission attempts beyond each upload's first — each one
    /// pays a link latency in [`Network::estimated_comm_time`].
    pub retx_attempts: u64,
    /// Per-message log (direction, label, floats, bytes) for debugging
    /// and the footnote-6 label-based accounting splits.
    pub log: Vec<(Direction, &'static str, u64, u64)>,
}

/// Message direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server → all clients.
    Broadcast,
    /// Client → server (aggregated).
    Aggregate,
}

impl RoundComm {
    /// Total floats on the wire this round (broadcast counted once,
    /// uplink counted per client — matches Table 1's per-client cost
    /// when divided by the participant count).
    pub fn total_floats(&self) -> u64 {
        self.broadcast_floats + self.aggregate_floats
    }

    /// Total measured bytes on the wire this round.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_down + self.bytes_up
    }

    /// Per-client download+upload volume in floats: what one
    /// *participating* edge device pays (broadcast counted once per
    /// client; upload volume divided by the participant count, since
    /// only participants upload — under partial participation/dropout
    /// dividing by the full population would understate it).
    pub fn per_client_floats(&self) -> f64 {
        self.broadcast_floats as f64 + self.aggregate_floats as f64 / self.participants.max(1) as f64
    }

    /// Floats attributable to messages whose label satisfies `pred` —
    /// used to separate compressed-layer traffic from dense-parameter
    /// traffic (the paper's footnote-6 accounting).
    pub fn floats_matching(&self, mut pred: impl FnMut(&str) -> bool) -> u64 {
        self.log.iter().filter(|(_, label, _, _)| pred(label)).map(|(_, _, f, _)| f).sum()
    }

    /// Measured bytes attributable to messages whose label satisfies `pred`.
    pub fn bytes_matching(&self, mut pred: impl FnMut(&str) -> bool) -> u64 {
        self.log.iter().filter(|(_, label, _, _)| pred(label)).map(|(_, _, _, b)| b).sum()
    }
}

/// The simulated network: records all traffic of a training run.
#[derive(Debug, Clone)]
pub struct Network {
    pub num_clients: usize,
    /// Clients participating in the current round (≤ num_clients);
    /// aggregation volume scales with this. Reset to `num_clients` at
    /// `end_round` so a stale participation count cannot leak into the
    /// next round.
    pub active_clients: usize,
    pub link: LinkModel,
    /// Wire codec all payloads are serialized with.
    pub codec: CodecKind,
    /// Per-link fault model. When active, every framed message pays
    /// [`CHECKSUM_BYTES`] of wire header (per payload part — each part
    /// already carries its own codec header); when inactive (the
    /// default) the wire format is bitwise-legacy.
    pub fault: FaultModel,
    /// Wire copies each upload currently bills (1 = clean transport;
    /// coordinators raise it around a retransmitting client's uploads —
    /// copies beyond the first accrue to `bytes_retx`).
    upload_copies: u64,
    current: RoundComm,
    /// Completed rounds.
    pub rounds: Vec<RoundComm>,
}

impl Network {
    pub fn new(num_clients: usize) -> Network {
        Network::with_codec(num_clients, CodecKind::DenseF32)
    }

    /// A network whose transfers are serialized with `codec`.
    pub fn with_codec(num_clients: usize, codec: CodecKind) -> Network {
        Network {
            num_clients,
            active_clients: num_clients,
            link: LinkModel::default(),
            codec,
            fault: FaultModel::default(),
            upload_copies: 1,
            current: RoundComm::default(),
            rounds: Vec::new(),
        }
    }

    /// Serialize through the wire codec: measured byte count plus the
    /// receive-side values (identity for the transparent reference
    /// codec — see `wire` module docs; real decode otherwise). For the
    /// transparent codec the byte count comes from the closed form
    /// (asserted byte-identical to the encoder in the wire tests), so
    /// the hot path skips the per-entry encode.
    fn transcode(&self, values: &[f64]) -> (u64, Vec<f64>) {
        // An active fault model frames every payload with a CRC-32
        // checksum header (see [`faults`]); an inactive one leaves the
        // wire format — and every byte count — bitwise-legacy.
        let hdr = if self.fault.is_active() { CHECKSUM_BYTES } else { 0 };
        let codec = self.codec.codec();
        if codec.transparent() {
            return (self.codec.wire_bytes(values.len() as u64) + hdr, values.to_vec());
        }
        let bytes = codec.encode(values);
        let n = bytes.len() as u64 + hdr;
        let decoded = codec.decode(&bytes);
        debug_assert_eq!(decoded.len(), values.len(), "codec changed message length");
        (n, decoded)
    }

    /// Record a server→clients broadcast of `values` (counted once —
    /// broadcast); returns what the clients receive after decode.
    pub fn broadcast_vec(&mut self, label: &'static str, values: &[f64]) -> Vec<f64> {
        let (bytes, decoded) = self.transcode(values);
        self.current.broadcast_floats += values.len() as u64;
        self.current.bytes_down += bytes;
        self.current.log.push((Direction::Broadcast, label, values.len() as u64, bytes));
        decoded
    }

    /// [`Network::broadcast_vec`] for a matrix (shape-preserving).
    pub fn broadcast_mat(&mut self, label: &'static str, m: &Matrix) -> Matrix {
        let decoded = self.broadcast_vec(label, m.data());
        Matrix::from_vec(m.rows(), m.cols(), decoded)
    }

    /// Record *one participating client's* upload of `values`; returns
    /// what the server receives after decode. Call once per client.
    pub fn aggregate_vec(&mut self, label: &'static str, values: &[f64]) -> Vec<f64> {
        let (bytes, decoded) = self.transcode(values);
        self.current.aggregate_floats += values.len() as u64;
        self.current.bytes_up += bytes;
        self.current.bytes_retx += bytes * (self.upload_copies - 1);
        self.current.log.push((Direction::Aggregate, label, values.len() as u64, bytes));
        decoded
    }

    /// [`Network::aggregate_vec`] for a matrix (shape-preserving).
    pub fn aggregate_mat(&mut self, label: &'static str, m: &Matrix) -> Matrix {
        let decoded = self.aggregate_vec(label, m.data());
        Matrix::from_vec(m.rows(), m.cols(), decoded)
    }

    /// Serialize `values` through the wire codec **without recording any
    /// traffic**: returns `(measured bytes, decoded receive-side values)`.
    ///
    /// For buffered (K-of-N) aggregation the codec must apply when an
    /// update *arrives* (decode-on-receive numerics are a property of
    /// the transfer) while the round's upload accounting must bill only
    /// the K updates actually *consumed* — a held or discarded straggler
    /// is not part of this aggregation's `bytes_up`. Callers pair this
    /// with [`Network::note_upload`] at consumption time.
    pub fn transcode_vec(&self, values: &[f64]) -> (u64, Vec<f64>) {
        self.transcode(values)
    }

    /// Bill one consumed upload (previously transcoded via
    /// [`Network::transcode_vec`]) into the current round's aggregate
    /// accounting.
    pub fn note_upload(&mut self, label: &'static str, floats: u64, bytes: u64) {
        self.current.aggregate_floats += floats;
        self.current.bytes_up += bytes;
        self.current.bytes_retx += bytes * (self.upload_copies - 1);
        self.current.log.push((Direction::Aggregate, label, floats, bytes));
    }

    /// One client's upload of several tensors coalesced into a single
    /// *message* (one log entry, e.g. the naive-FeDLRT factor triple);
    /// returns the decoded parts in input order. Each part is encoded
    /// with its own codec header: tensors of very different dynamic
    /// range (orthonormal bases vs. singular values) must not share one
    /// per-tensor quantization scale, or the large part would crush the
    /// small part's resolution — a few header bytes buy full per-tensor
    /// accuracy.
    pub fn aggregate_batch(&mut self, label: &'static str, parts: &[&[f64]]) -> Vec<Vec<f64>> {
        let mut floats = 0u64;
        let mut bytes = 0u64;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let (b, decoded) = self.transcode(p);
            floats += p.len() as u64;
            bytes += b;
            out.push(decoded);
        }
        self.current.aggregate_floats += floats;
        self.current.bytes_up += bytes;
        self.current.bytes_retx += bytes * (self.upload_copies - 1);
        self.current.log.push((Direction::Aggregate, label, floats, bytes));
        out
    }

    /// Arity-preserving form of [`Network::aggregate_batch`]: N parts
    /// in, exactly N decoded parts out, so decode sites destructure
    /// with `let [u, s, v] = …` instead of `parts.next().unwrap()`
    /// chains that hide which part went missing (fedlint rule D6).
    pub fn aggregate_batch_n<const N: usize>(
        &mut self,
        label: &'static str,
        parts: [&[f64]; N],
    ) -> [Vec<f64>; N] {
        self.aggregate_batch(label, &parts)
            .try_into()
            .expect("aggregate_batch returns exactly one decoded vec per input part")
    }

    /// Descriptor-only broadcast accounting (no tensor data — scalar or
    /// metadata payloads): bytes are the codec's exact wire size for
    /// that entry count.
    pub fn broadcast(&mut self, label: &'static str, payload: &Payload) {
        let hdr = if self.fault.is_active() { CHECKSUM_BYTES } else { 0 };
        let f = payload.floats();
        let bytes = self.codec.wire_bytes(f) + hdr;
        self.current.broadcast_floats += f;
        self.current.bytes_down += bytes;
        self.current.log.push((Direction::Broadcast, label, f, bytes));
    }

    /// Set the number of participating clients for this round. `0` is
    /// legal — a quorum-missed/total-blackout round aggregates nobody
    /// and must stamp `participants = 0` rather than leak a stale or
    /// fabricated participation count (legacy callers always pass ≥ 1,
    /// so the old lower clamp was unreachable).
    pub fn set_active_clients(&mut self, n: usize) {
        self.active_clients = n.min(self.num_clients);
    }

    /// Bill each subsequent upload as `copies` wire copies (first copy
    /// into `bytes_up`, the rest into `bytes_retx`). Coordinators set
    /// this around a retransmitting client's uploads and must reset it
    /// to 1 afterwards; [`Network::end_round`] also resets it so a
    /// stale multiplier cannot leak across rounds.
    pub fn set_upload_copies(&mut self, copies: u64) {
        self.upload_copies = copies.max(1);
    }

    /// Book transport-fault counters into the current round.
    pub fn note_faults(&mut self, dropped: u64, corrupt: u64, retx_attempts: u64) {
        self.current.msgs_dropped += dropped;
        self.current.msgs_corrupt += corrupt;
        self.current.retx_attempts += retx_attempts;
    }

    /// Descriptor-only aggregation accounting: *each participating*
    /// client uploads one message of `payload`'s size.
    pub fn aggregate(&mut self, label: &'static str, payload: &Payload) {
        let hdr = if self.fault.is_active() { CHECKSUM_BYTES } else { 0 };
        let c = self.active_clients as u64;
        let f = payload.floats() * c;
        let bytes = (self.codec.wire_bytes(payload.floats()) + hdr) * c;
        self.current.aggregate_floats += f;
        self.current.bytes_up += bytes;
        self.current.log.push((Direction::Aggregate, label, f, bytes));
    }

    /// Mark the end of one synchronous round trip (broadcast+aggregate
    /// pair). Table 1 counts these as "Com. Rounds".
    pub fn end_round_trip(&mut self) {
        self.current.round_trips += 1;
    }

    /// Close the current aggregation round and start a new record. The
    /// participating-client count is stamped into the record and the
    /// active count resets to full participation for the next round.
    pub fn end_round(&mut self) -> &RoundComm {
        self.current.participants = self.active_clients;
        self.active_clients = self.num_clients;
        self.upload_copies = 1;
        let done = std::mem::take(&mut self.current);
        self.rounds.push(done);
        let idx = self.rounds.len() - 1;
        &self.rounds[idx]
    }

    /// Cumulative floats over all completed rounds.
    pub fn total_floats(&self) -> u64 {
        self.rounds.iter().map(|r| r.total_floats()).sum()
    }

    /// Cumulative measured bytes over all completed rounds.
    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.total_bytes()).sum()
    }

    /// Cumulative per-client floats (download + own upload share).
    pub fn per_client_floats(&self) -> f64 {
        self.rounds.iter().map(|r| r.per_client_floats()).sum()
    }

    /// Wall-clock estimate of all communication under the link model:
    /// serialization time per direction (measured bytes over bandwidth,
    /// retransmitted copies included) plus link latency charged exactly
    /// once per synchronous round trip *and once per retransmission
    /// attempt* — a retried upload is a real extra message on the wire.
    /// (The latency is a property of the round trip, not of each
    /// direction's transfer — charging it per direction *and* per round
    /// trip would triple-count it.) With a clean transport both fault
    /// terms are exactly zero (u64 adds), reproducing the legacy
    /// estimate bitwise.
    pub fn estimated_comm_time(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| {
                (r.bytes_down + r.bytes_up + r.bytes_retx) as f64 / self.link.bandwidth
                    + self.link.latency * (r.round_trips + r.retx_attempts) as f64
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_broadcast_vs_aggregate() {
        let mut net = Network::new(4);
        net.broadcast("factors", &Payload::Matrix { rows: 10, cols: 3 });
        net.aggregate("grads", &Payload::Matrix { rows: 10, cols: 3 });
        net.end_round_trip();
        let round = net.end_round();
        assert_eq!(round.broadcast_floats, 30);
        assert_eq!(round.aggregate_floats, 30 * 4);
        assert_eq!(round.round_trips, 1);
        assert_eq!(round.total_floats(), 30 + 120);
        assert_eq!(round.participants, 4);
        assert!((round.per_client_floats() - (30.0 + 30.0)).abs() < 1e-12);
        // Reference codec: bytes are exactly floats × 4.
        assert_eq!(round.bytes_down, 30 * 4);
        assert_eq!(round.bytes_up, 120 * 4);
    }

    #[test]
    fn multi_round_totals() {
        let mut net = Network::new(2);
        for _ in 0..3 {
            net.broadcast("w", &Payload::Floats(100));
            net.aggregate("w", &Payload::Floats(100));
            net.end_round_trip();
            net.end_round();
        }
        assert_eq!(net.rounds.len(), 3);
        assert_eq!(net.total_floats(), 3 * (100 + 200));
        assert_eq!(net.total_bytes(), 4 * net.total_floats());
    }

    #[test]
    fn dense_codec_is_transparent_and_counts_4_bytes_per_float() {
        let mut net = Network::new(3);
        let vals: Vec<f64> = (0..17).map(|i| (i as f64).sin() * 1e3).collect();
        let down = net.broadcast_vec("w", &vals);
        // Bitwise identity at simulation precision (reference codec).
        for (a, b) in vals.iter().zip(&down) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let up = net.aggregate_vec("g", &vals);
        assert_eq!(up, vals);
        net.end_round_trip();
        let r = net.end_round();
        assert_eq!(r.bytes_down, 17 * 4);
        assert_eq!(r.bytes_up, 17 * 4);
        assert_eq!(r.total_floats(), 34);
    }

    #[test]
    fn lossy_codec_measures_fewer_bytes_and_decodes_on_receive() {
        let mut net = Network::with_codec(2, CodecKind::QuantizeInt8);
        let m = Matrix::from_fn(8, 8, |i, j| (i * 8 + j) as f64 / 10.0);
        let received = net.broadcast_mat("w", &m);
        assert_eq!(received.shape(), m.shape());
        // Lossy: values change, but stay within the documented bound.
        let spread = m.max_abs(); // values span [0, 6.3]
        assert!(received.sub(&m).max_abs() <= spread / 255.0 + 1e-6);
        assert!(received.sub(&m).max_abs() > 0.0);
        net.end_round_trip();
        let r = net.end_round();
        assert_eq!(r.bytes_down, 8 + 64); // header + 1 byte/entry
        assert_eq!(r.broadcast_floats, 64);
    }

    #[test]
    fn aggregate_batch_splits_and_coalesces() {
        let mut net = Network::with_codec(2, CodecKind::F16Cast);
        let a = [1.0, 2.0, 3.0];
        let b = [4.0; 5];
        let parts = net.aggregate_batch("triple", &[&a, &b]);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a.to_vec());
        assert_eq!(parts[1], b.to_vec());
        net.end_round_trip();
        let r = net.end_round().clone();
        assert_eq!(r.aggregate_floats, 8);
        assert_eq!(r.bytes_up, 16); // one log entry, 2 B/entry
        assert_eq!(r.log.len(), 1);

        // q8: one header per part — a large-range part must not crush a
        // small-range part's quantization resolution.
        let mut net = Network::with_codec(2, CodecKind::QuantizeInt8);
        let small = [0.001, 0.002, 0.003, 0.004];
        let large = [0.0, 500.0, 1000.0];
        let parts = net.aggregate_batch("triple", &[&small, &large]);
        net.end_round_trip();
        let r = net.end_round();
        assert_eq!(r.bytes_up, (8 + 4) + (8 + 3));
        for (x, y) in small.iter().zip(&parts[0]) {
            // Shared-scale coalescing would decode these all to ~0 with
            // error ~ 1000/255 ≫ the per-part bound (max−min)/255.
            assert!((x - y).abs() <= (0.003 / 255.0) + 1e-6, "{x} -> {y}");
        }
    }

    #[test]
    fn partial_participation_upload_share_and_reset() {
        // Satellite regression: upload share divides by *participants*,
        // and a stale participation count must not leak into the next
        // round.
        let mut net = Network::new(4);
        net.set_active_clients(2);
        for _ in 0..2 {
            net.aggregate_vec("g", &[1.0; 10]);
        }
        net.broadcast_vec("w", &[1.0; 8]);
        net.end_round_trip();
        {
            let r = net.end_round();
            assert_eq!(r.participants, 2);
            // Each of the 2 participants pays the 8-float download plus
            // its own 10-float upload — NOT 20/4 = 5.
            assert!((r.per_client_floats() - (8.0 + 20.0 / 2.0)).abs() < 1e-12);
        }
        // Next round, no set_active_clients call: back to full
        // participation for both descriptor accounting and the divisor.
        net.aggregate("g", &Payload::Floats(10));
        net.end_round_trip();
        let r2 = net.end_round();
        assert_eq!(r2.participants, 4);
        assert_eq!(r2.aggregate_floats, 40);
        assert!((r2.per_client_floats() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn buffered_aggregation_bills_only_consumed_updates() {
        // Satellite regression: under K-of-N buffering, N in-flight
        // clients transcode their uploads on arrival, but only the
        // K consumed this aggregation may appear in bytes_up /
        // aggregate_floats, and per_client_floats must divide by K —
        // not by all N in flight.
        let (k, n) = (2usize, 5usize);
        let mut net = Network::new(100);
        let update = [1.0; 10];
        // All N arrivals transcode (decode-on-receive) without billing.
        let arrivals: Vec<(u64, Vec<f64>)> =
            (0..n).map(|_| net.transcode_vec(&update)).collect();
        assert_eq!(arrivals[0].1, update.to_vec());
        // Only K are consumed by this aggregation.
        for (bytes, _) in arrivals.iter().take(k) {
            net.note_upload("dS", update.len() as u64, *bytes);
        }
        net.broadcast_vec("w", &[1.0; 8]);
        net.set_active_clients(k);
        net.end_round_trip();
        let r = net.end_round();
        assert_eq!(r.participants, k);
        assert_eq!(r.aggregate_floats, (k * 10) as u64);
        assert_eq!(r.bytes_up, (k * 10 * 4) as u64);
        // Each consumed client pays the download plus its own upload —
        // NOT (k·10)/n.
        assert!((r.per_client_floats() - (8.0 + 10.0)).abs() < 1e-12);
        // The log carries one entry per consumed update only.
        let consumed = r.log.iter().filter(|(d, l, _, _)| {
            *d == Direction::Aggregate && *l == "dS"
        });
        assert_eq!(consumed.count(), k);
    }

    #[test]
    fn link_time_monotone_in_bytes() {
        let link = LinkModel::default();
        assert!(link.transfer_time(1000) < link.transfer_time(1_000_000));
        assert!(link.transfer_time(0) >= link.latency);
    }

    #[test]
    fn latency_charged_once_per_round_trip() {
        // Satellite regression: with a high-latency link, the latency
        // term must appear exactly once per round trip (the old
        // accounting charged it up to 3× — once in each direction's
        // transfer time and once per round trip again).
        let mut net = Network::new(2);
        net.link = LinkModel { bandwidth: 1e6, latency: 5.0 };
        net.broadcast_vec("w", &[0.0; 250]); // 1000 bytes down
        net.aggregate_vec("g", &[0.0; 250]); // 1000 bytes up
        net.end_round_trip();
        net.end_round();
        let want = 2000.0 / 1e6 + 5.0;
        let got = net.estimated_comm_time();
        assert!((got - want).abs() < 1e-9, "latency multi-counted: {got} vs {want}");
        // Two round trips in a round ⇒ exactly two latencies.
        let mut net2 = Network::new(2);
        net2.link = LinkModel { bandwidth: 1e6, latency: 5.0 };
        net2.broadcast_vec("w", &[0.0; 250]);
        net2.end_round_trip();
        net2.aggregate_vec("g", &[0.0; 250]);
        net2.end_round_trip();
        net2.end_round();
        assert!((net2.estimated_comm_time() - (2000.0 / 1e6 + 10.0)).abs() < 1e-9);
    }

    #[test]
    fn zero_participant_round_is_well_defined() {
        // Satellite regression: a quorum-missed / total-blackout round
        // aggregates nobody — participation must stamp as 0 (not a
        // fabricated 1), per-client volume must not divide by zero, and
        // no stale state may leak into the next round.
        let mut net = Network::new(4);
        net.broadcast_vec("w", &[1.0; 8]); // broadcast went out before the blackout
        net.set_active_clients(0);
        net.end_round_trip();
        {
            let r = net.end_round();
            assert_eq!(r.participants, 0);
            assert_eq!(r.bytes_up, 0);
            // Divisor guard: the (hypothetical) participant pays only
            // the download.
            assert!((r.per_client_floats() - 8.0).abs() < 1e-12);
            assert!(r.per_client_floats().is_finite());
        }
        // Next round: participation resets to full.
        net.aggregate("g", &Payload::Floats(10));
        net.end_round_trip();
        let r2 = net.end_round();
        assert_eq!(r2.participants, 4);
        assert_eq!(r2.aggregate_floats, 40);
    }

    #[test]
    fn retransmissions_bill_retx_bytes_not_bytes_up() {
        // A client that needed 3 wire copies (2 retransmissions or
        // duplicates): bytes_up keeps the first copy only, the extra
        // copies accrue to bytes_retx, and end_round resets the
        // multiplier.
        let mut net = Network::new(2);
        net.set_upload_copies(3);
        net.aggregate_vec("dS", &[1.0; 10]); // 40 B first copy
        net.set_upload_copies(1);
        net.aggregate_vec("dS", &[1.0; 10]);
        net.end_round_trip();
        {
            let r = net.end_round();
            assert_eq!(r.bytes_up, 80);
            assert_eq!(r.bytes_retx, 80); // 2 extra copies × 40 B
            assert_eq!(r.aggregate_floats, 20);
        }
        // Buffered path bills copies identically, and end_round cleared
        // the multiplier even without an explicit reset.
        let mut net = Network::new(2);
        net.set_upload_copies(2);
        let (bytes, _) = net.transcode_vec(&[1.0; 10]);
        net.note_upload("dS", 10, bytes);
        net.end_round_trip();
        net.end_round();
        assert_eq!(net.rounds[0].bytes_retx, 40);
        net.aggregate_vec("dS", &[1.0; 10]);
        net.end_round();
        assert_eq!(net.rounds[1].bytes_retx, 0, "multiplier must not leak");
    }

    #[test]
    fn comm_time_charges_latency_per_attempt_and_is_legacy_with_no_retries() {
        // Satellite regression: retransmission attempts each pay one
        // link latency and their bytes ride the bandwidth term; with
        // retries = 0 the estimate reproduces the legacy value bitwise.
        let mut clean = Network::new(2);
        clean.link = LinkModel { bandwidth: 1e6, latency: 5.0 };
        clean.broadcast_vec("w", &[0.0; 250]);
        clean.aggregate_vec("g", &[0.0; 250]);
        clean.end_round_trip();
        clean.end_round();
        let legacy = 2000.0 / 1e6 + 5.0;
        assert_eq!(
            clean.estimated_comm_time().to_bits(),
            legacy.to_bits(),
            "clean transport must be bitwise-legacy"
        );

        let mut faulty = Network::new(2);
        faulty.link = LinkModel { bandwidth: 1e6, latency: 5.0 };
        faulty.broadcast_vec("w", &[0.0; 250]);
        faulty.set_upload_copies(2);
        faulty.aggregate_vec("g", &[0.0; 250]);
        faulty.set_upload_copies(1);
        faulty.note_faults(1, 0, 1); // the lost first attempt, retried once
        faulty.end_round_trip();
        faulty.end_round();
        let want = (2000.0 + 1000.0) / 1e6 + 5.0 * 2.0;
        assert!((faulty.estimated_comm_time() - want).abs() < 1e-12);
        assert_eq!(faulty.rounds[0].msgs_dropped, 1);
        assert_eq!(faulty.rounds[0].retx_attempts, 1);
    }

    #[test]
    fn active_fault_model_adds_checksum_header_bytes() {
        let vals = [1.0; 10];
        let mut clean = Network::new(2);
        let mut faulty = Network::new(2);
        faulty.fault = FaultModel { loss_prob: 0.1, ..FaultModel::default() };
        clean.broadcast_vec("w", &vals);
        clean.aggregate_vec("g", &vals);
        clean.broadcast("hdr", &Payload::Floats(3));
        faulty.broadcast_vec("w", &vals);
        faulty.aggregate_vec("g", &vals);
        faulty.broadcast("hdr", &Payload::Floats(3));
        clean.end_round();
        faulty.end_round();
        let (c, f) = (&clean.rounds[0], &faulty.rounds[0]);
        // +4 B per framed message, floats unchanged.
        assert_eq!(f.bytes_down, c.bytes_down + 2 * CHECKSUM_BYTES);
        assert_eq!(f.bytes_up, c.bytes_up + CHECKSUM_BYTES);
        assert_eq!(f.total_floats(), c.total_floats());
    }

    #[test]
    fn comm_time_positive_and_scales() {
        let mut a = Network::new(4);
        a.broadcast("x", &Payload::Floats(1_000_000));
        a.end_round_trip();
        a.end_round();
        let mut b = Network::new(4);
        b.broadcast("x", &Payload::Floats(1_000));
        b.end_round_trip();
        b.end_round();
        assert!(a.estimated_comm_time() > b.estimated_comm_time());
    }
}
