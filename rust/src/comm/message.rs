//! Protocol payload descriptors.
//!
//! The coordinator describes every transfer with a [`Payload`] so the
//! network layer can count floats-on-the-wire exactly. The variants map
//! one-to-one onto the messages of Algorithms 1/3/4/5:
//!
//! | Algorithm step | Payload |
//! |---|---|
//! | broadcast {Uᵗ,Vᵗ,Sᵗ} | two `Matrix{n,r}` + `CoeffDiag(r)` |
//! | aggregate {G_U, G_V} | two `Matrix{n,r}` |
//! | broadcast {Ū, V̄} | two `Matrix{n,a}` |
//! | aggregate / broadcast G_S̃ | `Matrix{2r,2r}` |
//! | aggregate S̃_c^{s*} | `Matrix{2r,2r}` |
//! | FedAvg/FedLin dense W, G_W | `Matrix{n,n}` |
//! | naive-FeDLRT factor-triple upload (Alg 6) | coalesced per-client message via `Network::aggregate_batch` |
//!
//! Descriptor-only variants (including `Batch`, built with
//! [`Payload::batch`]) remain for scalar/metadata accounting where no
//! tensor data exists; all coordinator tensor traffic travels through
//! the data-carrying `Network` methods below.
//!
//! A payload of `k` entries serializes through the configured wire
//! codec ([`crate::comm::wire`]) to measured bytes:
//!
//! | Codec (`--codec`) | Bytes for `k` entries | Example: `Matrix{512,16}` |
//! |---|---|---|
//! | `dense` (reference) | `4·k` | 32 768 B |
//! | `f16` | `2·k` | 16 384 B |
//! | `q8` | `8 + k` (per-tensor scale/min header) | 8 200 B |
//!
//! Data-carrying transfers (`broadcast_mat`/`aggregate_mat`/…) measure
//! the actual encoder output; descriptor-only transfers use
//! [`crate::comm::wire::CodecKind::wire_bytes`], which is asserted to
//! match the encoder exactly.

/// Size descriptor of one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// A dense matrix of the given shape.
    Matrix { rows: usize, cols: usize },
    /// A diagonal coefficient matrix (only the diagonal is sent —
    /// after truncation `S = Σ` is diagonal, Algorithm 1 line 18).
    CoeffDiag(usize),
    /// A raw float count (scalars, metadata treated as float-equivalent).
    Floats(u64),
    /// Several payloads coalesced into one labelled message (e.g. the
    /// naive-FeDLRT client's {Ũ_c, Ṽ_c, S̃_c} factor-triple upload).
    /// Build with [`Payload::batch`].
    Batch { label: &'static str, floats: u64 },
}

impl Payload {
    /// Number of floats on the wire.
    pub fn floats(&self) -> u64 {
        match *self {
            Payload::Matrix { rows, cols } => (rows * cols) as u64,
            Payload::CoeffDiag(r) => r as u64,
            Payload::Floats(n) => n,
            Payload::Batch { floats, .. } => floats,
        }
    }

    pub fn matrix(rows: usize, cols: usize) -> Payload {
        Payload::Matrix { rows, cols }
    }

    /// Coalesce any number of payloads into one labelled message.
    pub fn batch(label: &'static str, parts: &[Payload]) -> Payload {
        Payload::Batch { label, floats: parts.iter().map(|p| p.floats()).sum() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(Payload::matrix(512, 16).floats(), 8192);
        assert_eq!(Payload::CoeffDiag(16).floats(), 16);
        assert_eq!(Payload::Floats(7).floats(), 7);
        assert_eq!(Payload::Batch { label: "x", floats: 7 }.floats(), 7);
    }

    #[test]
    fn batch_builder_sums_parts() {
        let b = Payload::batch(
            "factor_triple",
            &[Payload::matrix(10, 3), Payload::CoeffDiag(3), Payload::matrix(10, 3)],
        );
        assert_eq!(b.floats(), 30 + 3 + 30);
        assert!(matches!(b, Payload::Batch { label: "factor_triple", .. }));
        // Empty batches are legal and free.
        assert_eq!(Payload::batch("empty", &[]).floats(), 0);
    }
}
