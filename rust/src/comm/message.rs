//! Protocol payload descriptors.
//!
//! The coordinator describes every transfer with a [`Payload`] so the
//! network layer can count floats-on-the-wire exactly. The variants map
//! one-to-one onto the messages of Algorithms 1/3/4/5:
//!
//! | Algorithm step | Payload |
//! |---|---|
//! | broadcast {Uᵗ,Vᵗ,Sᵗ} | two `Matrix{n,r}` + `CoeffDiag(r)` |
//! | aggregate {G_U, G_V} | two `Matrix{n,r}` |
//! | broadcast {Ū, V̄} | two `Matrix{n,a}` |
//! | aggregate / broadcast G_S̃ | `Matrix{2r,2r}` |
//! | aggregate S̃_c^{s*} | `Matrix{2r,2r}` |
//! | FedAvg/FedLin dense W, G_W | `Matrix{n,n}` |
//! | naive-FeDLRT factor-triple upload (Alg 6) | `Batch{label, floats}` via [`Payload::batch`] |

/// Size descriptor of one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// A dense matrix of the given shape.
    Matrix { rows: usize, cols: usize },
    /// A diagonal coefficient matrix (only the diagonal is sent —
    /// after truncation `S = Σ` is diagonal, Algorithm 1 line 18).
    CoeffDiag(usize),
    /// A raw float count (scalars, metadata treated as float-equivalent).
    Floats(u64),
    /// Several payloads coalesced into one labelled message (e.g. the
    /// naive-FeDLRT client's {Ũ_c, Ṽ_c, S̃_c} factor-triple upload).
    /// Build with [`Payload::batch`].
    Batch { label: &'static str, floats: u64 },
}

impl Payload {
    /// Number of floats on the wire.
    pub fn floats(&self) -> u64 {
        match *self {
            Payload::Matrix { rows, cols } => (rows * cols) as u64,
            Payload::CoeffDiag(r) => r as u64,
            Payload::Floats(n) => n,
            Payload::Batch { floats, .. } => floats,
        }
    }

    pub fn matrix(rows: usize, cols: usize) -> Payload {
        Payload::Matrix { rows, cols }
    }

    /// Coalesce any number of payloads into one labelled message.
    pub fn batch(label: &'static str, parts: &[Payload]) -> Payload {
        Payload::Batch { label, floats: parts.iter().map(|p| p.floats()).sum() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(Payload::matrix(512, 16).floats(), 8192);
        assert_eq!(Payload::CoeffDiag(16).floats(), 16);
        assert_eq!(Payload::Floats(7).floats(), 7);
        assert_eq!(Payload::Batch { label: "x", floats: 7 }.floats(), 7);
    }

    #[test]
    fn batch_builder_sums_parts() {
        let b = Payload::batch(
            "factor_triple",
            &[Payload::matrix(10, 3), Payload::CoeffDiag(3), Payload::matrix(10, 3)],
        );
        assert_eq!(b.floats(), 30 + 3 + 30);
        assert!(matches!(b, Payload::Batch { label: "factor_triple", .. }));
        // Empty batches are legal and free.
        assert_eq!(Payload::batch("empty", &[]).floats(), 0);
    }
}
