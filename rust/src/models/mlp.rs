//! Native multi-layer perceptron backend for the §4.2 vision benchmarks.
//!
//! [`MlpProblem`] implements [`FedProblem`] entirely in Rust: an L-layer
//! MLP (configurable hidden widths, ReLU activations, softmax
//! cross-entropy) over the synthetic [`VisionDataset`]. Every hidden
//! weight `W_i` is a low-rank-capable layer; the per-layer biases and
//! the classifier head are dense parameters riding along with FedAvg /
//! FedLin updates, exactly as the paper trains "the fully connected
//! layers" with FeDLRT and the rest conventionally. Unlike
//! `nn::NnProblem`, no PJRT artifacts are required — this is the
//! offline path for Figs 5–8.
//!
//! ## Gradient forms
//!
//! With activations `a_0 = x`, `z_i = a_{i-1} W_i + b_i`,
//! `a_i = relu(z_i)` and backpropagated errors `δ_i = ∂L/∂z_i`, the
//! dense layer gradient is `∇_{W_i} = a_{i-1}ᵀ δ_i`. For a factored
//! layer `W = U S Vᵀ` the three forms follow by the chain rule without
//! ever materializing `∇_W` (the paper's client-cost argument, Table 1):
//!
//! ```text
//! A = a_{i-1} U  (b×r)      D = δ_i V  (b×r)
//! G_S = Aᵀ D                        = Uᵀ (∇_W) V
//! G_U = a_{i-1}ᵀ (D Sᵀ)             = (∇_W) V Sᵀ
//! G_V = δ_iᵀ (A S)                  = (∇_W)ᵀ U S
//! δ_{i-1} = ((D Sᵀ) Uᵀ) ⊙ relu'(z_{i-1})
//! ```
//!
//! all at `O(b·n·r)` skinny products through the packed `_into`
//! kernels.
//!
//! ## Performance structure
//!
//! Each client owns an [`MlpScratch`] behind its own lock: the batch
//! buffer, per-layer activation / projection / delta buffers, and the
//! softmax workspace, all rebuilt in place. The coefficient-gradient
//! fast path ([`FedProblem::grad_coeff_into`]) fills both the `r̃×r̃`
//! coefficient gradients **and** the dense-parameter gradients (biases,
//! head) into caller buffers and performs **zero heap allocations** in
//! steady state — asserted by the counting-allocator check in
//! `benches/micro_hotpath.rs`.
//!
//! Mini-batches are scheduled deterministically from `(client, step)`
//! via [`crate::data::schedule`] (shared with `NnProblem`, tail-cycling
//! included) with the existing feature-flip augmentation.

use std::sync::Mutex;

use crate::data::schedule;
use crate::data::{dirichlet_partition, uniform_partition, VisionDataset};
use crate::tensor::{
    matmul_into_view, matmul_nt_into_view, matmul_tn_into_view, MatMut, MatRef, Matrix,
};
use crate::util::rng::Rng;

use super::{FedProblem, Grads, LrGrad, LrWant, LrWeight, ProblemSpec, Weights};

/// Options for constructing an [`MlpProblem`].
#[derive(Debug, Clone)]
pub struct MlpOptions {
    /// Input feature dimension.
    pub d_in: usize,
    /// Hidden-layer widths; each hidden weight is low-rank-capable.
    /// Must be non-empty (the §4.2 networks have ≥ 2 hidden layers).
    pub hidden: Vec<usize>,
    /// Number of classes (softmax width).
    pub classes: usize,
    pub num_clients: usize,
    pub train_n: usize,
    pub test_n: usize,
    /// Cap on samples used for the per-round global-loss estimate
    /// (full test set is always used for accuracy).
    pub eval_cap: usize,
    /// Mini-batch size.
    pub batch: usize,
    pub seed: u64,
    /// Feature-augmentation on training batches (paper's flips).
    pub augment: bool,
    /// `None` = the paper's uniform shards; `Some(α)` = Dirichlet label
    /// skew. Skewed shards also skew [`FedProblem::client_weight`]
    /// (proportional to shard size).
    pub dirichlet_alpha: Option<f64>,
}

impl Default for MlpOptions {
    fn default() -> Self {
        MlpOptions {
            d_in: 32,
            hidden: vec![64, 64],
            classes: 10,
            num_clients: 4,
            train_n: 2048,
            test_n: 512,
            eval_cap: 1024,
            batch: 64,
            seed: 0,
            augment: true,
            dirichlet_alpha: None,
        }
    }
}

/// Per-client reusable numeric state: the batch buffers plus every
/// forward/backward intermediate. One lock *per client* so thread-pool
/// clients never contend; all buffers are grown once and reused, which
/// is what keeps the steady-state fast path allocation-free.
#[derive(Debug, Default)]
struct MlpScratch {
    /// Batch features, flat `b×d_in`.
    x: Vec<f64>,
    /// Batch labels.
    labels: Vec<usize>,
    /// Post-ReLU activations `a_1 … a_L`, flat `b×n_i` each.
    acts: Vec<Vec<f64>>,
    /// Per factored layer: `A = a_{i-1} U`, flat `b×r`.
    au: Vec<Vec<f64>>,
    /// Per factored layer: `A·S`, flat `b×r`.
    aus: Vec<Vec<f64>>,
    /// Logits, then (in place) softmax deltas, flat `b×classes`.
    logits: Vec<f64>,
    /// Backpropagated error ping-pong buffers, flat `b×n_i`.
    delta_a: Vec<f64>,
    delta_b: Vec<f64>,
    /// `D = δ V` scratch, flat `b×r`.
    dv: Vec<f64>,
    /// `D Sᵀ` scratch, flat `b×r`.
    dst: Vec<f64>,
}

/// Where the backward pass puts the low-rank layer gradients.
enum LrSink<'a> {
    /// Forward only (loss / accuracy evaluation).
    None,
    /// Coefficient gradients `G_S` written into prealloc `r̃×r̃` buffers
    /// (the zero-allocation client-inner-loop path).
    Coeff(&'a mut [Matrix]),
    /// Full factor triples `(G_U, G_V, G_S)`, freshly allocated.
    Factors(&'a mut Vec<LrGrad>),
    /// Dense layer gradients `∇_W`, freshly allocated.
    Dense(&'a mut Vec<LrGrad>),
}

/// The federated MLP problem.
#[derive(Debug)]
pub struct MlpProblem {
    opts: MlpOptions,
    /// Layer widths `[d_in, h_1, …, h_L]`.
    widths: Vec<usize>,
    /// Dense-parameter shapes `[b_1 … b_L, W_head, b_head]`.
    dense_shapes: Vec<(usize, usize)>,
    dataset: VisionDataset,
    shards: Vec<Vec<usize>>,
    scratch: Vec<Mutex<MlpScratch>>,
}

impl MlpProblem {
    /// Build the problem: synthesize + partition the dataset.
    pub fn new(opts: MlpOptions) -> MlpProblem {
        assert!(!opts.hidden.is_empty(), "MLP needs at least one hidden layer");
        assert!(opts.classes >= 2 && opts.batch >= 1 && opts.num_clients >= 1);
        let dataset = VisionDataset::synthesize(
            opts.d_in,
            opts.classes,
            opts.train_n,
            opts.test_n,
            opts.seed,
        );
        let mut rng = Rng::new(opts.seed ^ 0x5A4D);
        let shards = match opts.dirichlet_alpha {
            None => uniform_partition(opts.train_n, opts.num_clients, &mut rng),
            Some(alpha) => dirichlet_partition(
                &dataset.train.y,
                opts.classes,
                opts.num_clients,
                alpha,
                opts.batch.min(opts.train_n / opts.num_clients),
                &mut rng,
            ),
        };
        for s in &shards {
            assert!(!s.is_empty(), "empty client shard");
        }
        let mut widths = Vec::with_capacity(opts.hidden.len() + 1);
        widths.push(opts.d_in);
        widths.extend_from_slice(&opts.hidden);
        let mut dense_shapes: Vec<(usize, usize)> =
            opts.hidden.iter().map(|&h| (1, h)).collect();
        dense_shapes.push((*widths.last().unwrap(), opts.classes));
        dense_shapes.push((1, opts.classes));
        let scratch = (0..opts.num_clients).map(|_| Mutex::new(MlpScratch::default())).collect();
        MlpProblem { opts, widths, dense_shapes, dataset, shards, scratch }
    }

    pub fn options(&self) -> &MlpOptions {
        &self.opts
    }

    pub fn dataset(&self) -> &VisionDataset {
        &self.dataset
    }

    /// Fill the scratch batch buffers for client `c` at local step
    /// `step` — deterministic schedule shared with `NnProblem`
    /// ([`crate::data::schedule`]), allocation-free once warm.
    fn fill_batch(&self, c: usize, step: u64, scr: &mut MlpScratch) {
        let shard = &self.shards[c];
        let b = self.opts.batch;
        let d = self.opts.d_in;
        let (epoch, bi) = schedule::batch_slot(shard.len(), b, step);
        scr.x.resize(b * d, 0.0);
        scr.labels.resize(b, 0);
        for k in 0..b {
            let idx = shard[schedule::sample_index(shard.len(), b, bi, k)];
            let row = &mut scr.x[k * d..(k + 1) * d];
            if self.opts.augment {
                self.dataset.augmented_row_f64(idx, epoch, row);
            } else {
                row.copy_from_slice(self.dataset.train.x.row(idx));
            }
            scr.labels[k] = self.dataset.train.y[idx] as usize;
        }
    }

    /// One forward (and optional backward) pass over the batch staged in
    /// `scr` (`rows` samples). Returns the mean cross-entropy loss;
    /// counts correct argmax predictions into `correct`; writes
    /// dense-parameter gradients into `g_dense` (order: biases, head
    /// weight, head bias) and low-rank layer gradients into `lr_sink`.
    ///
    /// All intermediates live in `scr`; with grown buffers this function
    /// performs zero heap allocations for the `None`/`Coeff` sinks.
    fn batch_run(
        &self,
        w: &Weights,
        scr: &mut MlpScratch,
        rows: usize,
        mut correct: Option<&mut usize>,
        g_dense: Option<&mut [Matrix]>,
        mut lr_sink: LrSink<'_>,
    ) -> f64 {
        let l_num = self.opts.hidden.len();
        let classes = self.opts.classes;
        let b = rows;
        assert_eq!(w.lr.len(), l_num, "weight/layer count mismatch");
        assert_eq!(w.dense.len(), l_num + 2, "dense parameter count mismatch");
        let MlpScratch { x, labels, acts, au, aus, logits, delta_a, delta_b, dv, dst } = scr;
        acts.resize_with(l_num, Vec::new);
        au.resize_with(l_num, Vec::new);
        aus.resize_with(l_num, Vec::new);

        // ---- Forward ----
        for i in 0..l_num {
            let (n_in, n_out) = (self.widths[i], self.widths[i + 1]);
            let (done, rest) = acts.split_at_mut(i);
            let a_prev: &[f64] = if i == 0 { x.as_slice() } else { &done[i - 1] };
            let a_prev = MatRef::new(a_prev, b, n_in, n_in);
            let a_i = &mut rest[0];
            a_i.resize(b * n_out, 0.0);
            match &w.lr[i] {
                LrWeight::Factored(f) => {
                    let r = f.rank();
                    au[i].resize(b * r, 0.0);
                    matmul_into_view(a_prev, f.u.view(), MatMut::new(&mut au[i], b, r, r), 0.0);
                    aus[i].resize(b * r, 0.0);
                    matmul_into_view(
                        MatRef::new(&au[i], b, r, r),
                        f.s.view(),
                        MatMut::new(&mut aus[i], b, r, r),
                        0.0,
                    );
                    matmul_nt_into_view(
                        MatRef::new(&aus[i], b, r, r),
                        f.v.view(),
                        MatMut::new(a_i, b, n_out, n_out),
                        0.0,
                    );
                }
                LrWeight::Dense(m) => {
                    matmul_into_view(a_prev, m.view(), MatMut::new(a_i, b, n_out, n_out), 0.0);
                }
            }
            // Bias + ReLU in place.
            let bias = &w.dense[i];
            for row in 0..b {
                let z = &mut a_i[row * n_out..(row + 1) * n_out];
                for (zv, bv) in z.iter_mut().zip(bias.row(0)) {
                    *zv = (*zv + bv).max(0.0);
                }
            }
        }

        // ---- Head + softmax cross-entropy ----
        let n_last = *self.widths.last().unwrap();
        let a_last = MatRef::new(&acts[l_num - 1], b, n_last, n_last);
        let w_head = &w.dense[l_num];
        let b_head = &w.dense[l_num + 1];
        logits.resize(b * classes, 0.0);
        matmul_into_view(a_last, w_head.view(), MatMut::new(logits, b, classes, classes), 0.0);
        let want_grads = g_dense.is_some();
        let mut loss = 0.0;
        for row in 0..b {
            let lrow = &mut logits[row * classes..(row + 1) * classes];
            for (lv, bv) in lrow.iter_mut().zip(b_head.row(0)) {
                *lv += bv;
            }
            let y = labels[row];
            let m = lrow.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let zy = lrow[y] - m;
            let mut sum = 0.0;
            let mut argmax = 0usize;
            let mut best = f64::NEG_INFINITY;
            for (j, v) in lrow.iter_mut().enumerate() {
                if *v > best {
                    best = *v;
                    argmax = j;
                }
                *v = (*v - m).exp();
                sum += *v;
            }
            // ln Σe^{z−m} − (z_y − m): the log-sum-exp form stays finite
            // even when the true class's softmax mass underflows.
            loss += sum.ln() - zy;
            if let Some(ref mut cnt) = correct {
                if argmax == y {
                    **cnt += 1;
                }
            }
            if want_grads {
                // δ_logits = (softmax − onehot) / b, written in place.
                let inv = 1.0 / (sum * b as f64);
                for v in lrow.iter_mut() {
                    *v *= inv;
                }
                lrow[y] -= 1.0 / b as f64;
            }
        }
        loss /= b as f64;
        let g_dense = match g_dense {
            Some(g) => g,
            None => return loss,
        };
        assert_eq!(g_dense.len(), l_num + 2, "dense gradient buffer count");

        // ---- Backward ----
        let delta = MatRef::new(logits, b, classes, classes);
        matmul_tn_into_view(a_last, delta, g_dense[l_num].view_mut(), 0.0);
        col_sums_into(logits, b, classes, &mut g_dense[l_num + 1]);
        delta_a.resize(b * n_last, 0.0);
        matmul_nt_into_view(delta, w_head.view(), MatMut::new(delta_a, b, n_last, n_last), 0.0);
        relu_mask(delta_a, &acts[l_num - 1]);
        let mut cur_is_a = true;
        for i in (0..l_num).rev() {
            let (n_in, n_out) = (self.widths[i], self.widths[i + 1]);
            let (cur, next) = if cur_is_a {
                (&mut *delta_a, &mut *delta_b)
            } else {
                (&mut *delta_b, &mut *delta_a)
            };
            col_sums_into(cur, b, n_out, &mut g_dense[i]);
            let delta_i = MatRef::new(cur, b, n_out, n_out);
            let a_prev: &[f64] = if i == 0 { x.as_slice() } else { &acts[i - 1] };
            let a_prev = MatRef::new(a_prev, b, n_in, n_in);
            match &w.lr[i] {
                LrWeight::Factored(f) => {
                    let r = f.rank();
                    dv.resize(b * r, 0.0);
                    matmul_into_view(delta_i, f.v.view(), MatMut::new(dv, b, r, r), 0.0);
                    let d_view = MatRef::new(dv, b, r, r);
                    // `dst = D·Sᵀ` is shared between G_U and the delta
                    // propagation; compute it at most once per layer.
                    let mut dst_ready = false;
                    match &mut lr_sink {
                        LrSink::Coeff(out) => {
                            matmul_tn_into_view(
                                MatRef::new(&au[i], b, r, r),
                                d_view,
                                out[i].view_mut(),
                                0.0,
                            );
                        }
                        LrSink::Factors(out) => {
                            let mut g_s = Matrix::zeros(r, r);
                            matmul_tn_into_view(
                                MatRef::new(&au[i], b, r, r),
                                d_view,
                                g_s.view_mut(),
                                0.0,
                            );
                            dst.resize(b * r, 0.0);
                            matmul_nt_into_view(d_view, f.s.view(), MatMut::new(dst, b, r, r), 0.0);
                            dst_ready = true;
                            let mut g_u = Matrix::zeros(n_in, r);
                            matmul_tn_into_view(
                                a_prev,
                                MatRef::new(dst, b, r, r),
                                g_u.view_mut(),
                                0.0,
                            );
                            let mut g_v = Matrix::zeros(n_out, r);
                            matmul_tn_into_view(
                                delta_i,
                                MatRef::new(&aus[i], b, r, r),
                                g_v.view_mut(),
                                0.0,
                            );
                            out.push(LrGrad::Factors { g_u, g_v, g_s });
                        }
                        LrSink::Dense(_) => {
                            panic!("dense gradient requested at factored weights")
                        }
                        LrSink::None => unreachable!("grads wanted without a sink"),
                    }
                    if i > 0 {
                        // δ_{i-1} = ((D Sᵀ) Uᵀ) ⊙ relu'(z_{i-1}).
                        if !dst_ready {
                            dst.resize(b * r, 0.0);
                            matmul_nt_into_view(
                                MatRef::new(dv, b, r, r),
                                f.s.view(),
                                MatMut::new(dst, b, r, r),
                                0.0,
                            );
                        }
                        next.resize(b * n_in, 0.0);
                        matmul_nt_into_view(
                            MatRef::new(dst, b, r, r),
                            f.u.view(),
                            MatMut::new(next, b, n_in, n_in),
                            0.0,
                        );
                        relu_mask(next, &acts[i - 1]);
                    }
                }
                LrWeight::Dense(m) => {
                    match &mut lr_sink {
                        LrSink::Dense(out) => {
                            let mut g_w = Matrix::zeros(n_in, n_out);
                            matmul_tn_into_view(a_prev, delta_i, g_w.view_mut(), 0.0);
                            out.push(LrGrad::Dense(g_w));
                        }
                        LrSink::Coeff(_) | LrSink::Factors(_) => {
                            panic!("factored gradient requested at dense weights")
                        }
                        LrSink::None => unreachable!("grads wanted without a sink"),
                    }
                    if i > 0 {
                        next.resize(b * n_in, 0.0);
                        matmul_nt_into_view(
                            delta_i,
                            m.view(),
                            MatMut::new(next, b, n_in, n_in),
                            0.0,
                        );
                        relu_mask(next, &acts[i - 1]);
                    }
                }
            }
            cur_is_a = !cur_is_a;
        }
        // Backward walked layers in reverse; restore layer order.
        match lr_sink {
            LrSink::Factors(out) | LrSink::Dense(out) => out.reverse(),
            _ => {}
        }
        loss
    }

    /// Evaluate `(mean loss, accuracy)` over a split with fresh scratch
    /// (eval is off the hot path; allocations here are fine). Every
    /// sample in the (capped) range is visited exactly once — the final
    /// batch is simply shorter, so tails are neither dropped nor
    /// double-counted and the full test set really is what accuracy is
    /// measured on.
    fn evaluate(&self, w: &Weights, on_test: bool, cap: usize) -> (f64, f64) {
        let split = if on_test { &self.dataset.test } else { &self.dataset.train };
        let b = self.opts.batch;
        let d = self.opts.d_in;
        let n = split.len().min(cap.max(1)).max(1);
        let mut scr = MlpScratch::default();
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut start = 0usize;
        while start < n {
            let rows = b.min(n - start);
            scr.x.resize(rows * d, 0.0);
            scr.labels.resize(rows, 0);
            for k in 0..rows {
                let idx = start + k;
                scr.x[k * d..(k + 1) * d].copy_from_slice(split.x.row(idx));
                scr.labels[k] = split.y[idx] as usize;
            }
            // batch_run returns the per-batch mean; re-weight by the
            // batch length so the total is the exact mean over n.
            loss_sum +=
                rows as f64 * self.batch_run(w, &mut scr, rows, Some(&mut correct), None, LrSink::None);
            start += rows;
        }
        (loss_sum / n as f64, correct as f64 / n as f64)
    }

    /// Zero matrices shaped like the dense parameters.
    fn dense_grad_buffers(&self) -> Vec<Matrix> {
        self.dense_shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect()
    }
}

/// `out` (1×n) = column sums of the flat `b×n` matrix `src`.
fn col_sums_into(src: &[f64], b: usize, n: usize, out: &mut Matrix) {
    debug_assert_eq!(out.shape(), (1, n), "bias gradient shape");
    let o = out.data_mut();
    o.fill(0.0);
    for row in 0..b {
        for (ov, &sv) in o.iter_mut().zip(&src[row * n..(row + 1) * n]) {
            *ov += sv;
        }
    }
}

/// `δ ⊙ relu'(z)`: zero the error wherever the activation was clamped.
fn relu_mask(delta: &mut [f64], act: &[f64]) {
    debug_assert_eq!(delta.len(), act.len());
    for (d, &a) in delta.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

impl FedProblem for MlpProblem {
    fn spec(&self) -> ProblemSpec {
        ProblemSpec {
            dense_shapes: self.dense_shapes.clone(),
            lr_shapes: self.widths.windows(2).map(|w| (w[0], w[1])).collect(),
        }
    }

    fn num_clients(&self) -> usize {
        self.opts.num_clients
    }

    fn grad(&self, c: usize, w: &Weights, want: LrWant, step: u64) -> Grads {
        let mut scr = self.scratch[c].lock().expect("client scratch poisoned");
        self.fill_batch(c, step, &mut scr);
        let b = self.opts.batch;
        let mut dense = self.dense_grad_buffers();
        let (loss, lr) = match want {
            LrWant::Coeff => {
                let mut out: Vec<Matrix> = w
                    .lr
                    .iter()
                    .map(|lw| {
                        let r = lw.as_factored().rank();
                        Matrix::zeros(r, r)
                    })
                    .collect();
                let loss = self.batch_run(
                    w,
                    &mut scr,
                    b,
                    None,
                    Some(&mut dense),
                    LrSink::Coeff(&mut out),
                );
                (loss, out.into_iter().map(LrGrad::Coeff).collect())
            }
            LrWant::Factors => {
                let mut out = Vec::with_capacity(w.lr.len());
                let loss = self.batch_run(
                    w,
                    &mut scr,
                    b,
                    None,
                    Some(&mut dense),
                    LrSink::Factors(&mut out),
                );
                (loss, out)
            }
            LrWant::Dense => {
                let mut out = Vec::with_capacity(w.lr.len());
                let loss = self.batch_run(
                    w,
                    &mut scr,
                    b,
                    None,
                    Some(&mut dense),
                    LrSink::Dense(&mut out),
                );
                (loss, out)
            }
        };
        Grads { loss, dense, lr }
    }

    fn grad_coeff_into(
        &self,
        c: usize,
        w: &Weights,
        step: u64,
        out: &mut [Matrix],
        out_dense: &mut [Matrix],
    ) -> Option<f64> {
        // Deterministic per-layer validation: any mismatch falls back to
        // the allocating path for the whole call (never a partial fill).
        if w.lr.len() != self.opts.hidden.len() || out.len() != w.lr.len() {
            return None;
        }
        if out_dense.len() != self.dense_shapes.len() {
            return None;
        }
        for (o, lw) in out.iter().zip(&w.lr) {
            let f = match lw {
                LrWeight::Factored(f) => f,
                LrWeight::Dense(_) => return None,
            };
            if o.shape() != (f.rank(), f.rank()) {
                return None;
            }
        }
        for (o, &shape) in out_dense.iter().zip(&self.dense_shapes) {
            if o.shape() != shape {
                return None;
            }
        }
        let mut scr = self.scratch[c].lock().expect("client scratch poisoned");
        self.fill_batch(c, step, &mut scr);
        Some(self.batch_run(
            w,
            &mut scr,
            self.opts.batch,
            None,
            Some(out_dense),
            LrSink::Coeff(out),
        ))
    }

    fn global_loss(&self, w: &Weights) -> f64 {
        self.evaluate(w, false, self.opts.eval_cap).0
    }

    fn eval_metric(&self, w: &Weights) -> Option<f64> {
        Some(self.evaluate(w, true, usize::MAX).1)
    }

    fn client_weight(&self, c: usize) -> f64 {
        // Proportional to shard size (paper §2's weighted-average
        // extension); uniform shards yield uniform weights.
        self.shards[c].len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::LowRank;

    fn tiny_problem() -> MlpProblem {
        MlpProblem::new(MlpOptions {
            d_in: 10,
            hidden: vec![12, 8],
            classes: 4,
            num_clients: 2,
            train_n: 120,
            test_n: 40,
            eval_cap: 120,
            batch: 16,
            seed: 9,
            augment: true,
            dirichlet_alpha: None,
        })
    }

    fn factored_weights(prob: &MlpProblem, rank: usize, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let spec = prob.spec();
        Weights {
            dense: spec
                .dense_shapes
                .iter()
                .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale(0.3))
                .collect(),
            lr: spec
                .lr_shapes
                .iter()
                .map(|&(m, n)| {
                    LrWeight::Factored(LowRank::random_init(m, n, rank.min(m.min(n)), &mut rng))
                })
                .collect(),
        }
    }

    fn dense_weights_from(w: &Weights) -> Weights {
        Weights {
            dense: w.dense.clone(),
            lr: w.lr.iter().map(|lw| LrWeight::Dense(lw.to_dense())).collect(),
        }
    }

    /// Loss at `(c, step)`'s batch — gradient evaluation reused for its
    /// loss output (the FD tests need batch-exact losses).
    fn batch_loss(prob: &MlpProblem, c: usize, w: &Weights, step: u64) -> f64 {
        let want = match w.lr.first() {
            Some(LrWeight::Factored(_)) => LrWant::Coeff,
            _ => LrWant::Dense,
        };
        prob.grad(c, w, want, step).loss
    }

    #[test]
    fn spec_shapes_are_consistent() {
        let prob = tiny_problem();
        let spec = prob.spec();
        assert_eq!(spec.lr_shapes, vec![(10, 12), (12, 8)]);
        assert_eq!(spec.dense_shapes, vec![(1, 12), (1, 8), (8, 4), (1, 4)]);
        assert_eq!(prob.num_clients(), 2);
    }

    #[test]
    fn dense_gradient_matches_finite_difference() {
        let prob = tiny_problem();
        let w0 = dense_weights_from(&factored_weights(&prob, 4, 33));
        let g = prob.grad(0, &w0, LrWant::Dense, 1);
        assert!(g.loss.is_finite());
        let eps = 1e-6;
        // A low-rank-capable layer entry, a bias entry, and a head entry.
        let checks: Vec<(bool, usize, usize, usize, f64)> = vec![
            // (is_lr, idx, i, j, analytic)
            (true, 0, 3, 5, g.lr[0].dense()[(3, 5)]),
            (true, 1, 7, 2, g.lr[1].dense()[(7, 2)]),
            (false, 0, 0, 4, g.dense[0][(0, 4)]),
            (false, 2, 5, 1, g.dense[2][(5, 1)]),
            (false, 3, 0, 2, g.dense[3][(0, 2)]),
        ];
        for (is_lr, idx, i, j, an) in checks {
            let mut wp = dense_weights_from(&w0);
            let mut wm = dense_weights_from(&w0);
            if is_lr {
                wp.lr[idx].as_dense_mut()[(i, j)] += eps;
                wm.lr[idx].as_dense_mut()[(i, j)] -= eps;
            } else {
                wp.dense[idx][(i, j)] += eps;
                wm.dense[idx][(i, j)] -= eps;
            }
            let fd = (batch_loss(&prob, 0, &wp, 1) - batch_loss(&prob, 0, &wm, 1)) / (2.0 * eps);
            assert!(
                (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                "lr={is_lr} idx={idx} ({i},{j}): fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn factor_gradients_match_finite_difference() {
        let prob = tiny_problem();
        let w = factored_weights(&prob, 3, 55);
        let g = prob.grad(1, &w, LrWant::Factors, 2);
        let eps = 1e-6;
        for layer in 0..2 {
            let (g_u, g_v, g_s) = match &g.lr[layer] {
                LrGrad::Factors { g_u, g_v, g_s } => (g_u, g_v, g_s),
                _ => unreachable!(),
            };
            for (which, i, j, an) in [
                ("u", 2usize, 1usize, g_u[(2, 1)]),
                ("v", 4, 2, g_v[(4, 2)]),
                ("s", 1, 2, g_s[(1, 2)]),
            ] {
                let mut wp = factored_weights(&prob, 3, 55);
                let mut wm = factored_weights(&prob, 3, 55);
                for (wt, sign) in [(&mut wp, eps), (&mut wm, -eps)] {
                    let f = wt.lr[layer].as_factored_mut();
                    match which {
                        "u" => f.u[(i, j)] += sign,
                        "v" => f.v[(i, j)] += sign,
                        _ => f.s[(i, j)] += sign,
                    }
                }
                let fd =
                    (batch_loss(&prob, 1, &wp, 2) - batch_loss(&prob, 1, &wm, 2)) / (2.0 * eps);
                assert!(
                    (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                    "layer {layer} {which}({i},{j}): fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn coeff_gradient_matches_factors_and_finite_difference() {
        let prob = tiny_problem();
        let w = factored_weights(&prob, 3, 77);
        let g_f = prob.grad(0, &w, LrWant::Factors, 3);
        let g_c = prob.grad(0, &w, LrWant::Coeff, 3);
        assert_eq!(g_c.loss.to_bits(), g_f.loss.to_bits());
        for layer in 0..2 {
            let g_s = match &g_f.lr[layer] {
                LrGrad::Factors { g_s, .. } => g_s,
                _ => unreachable!(),
            };
            assert!(g_c.lr[layer].coeff().sub(g_s).max_abs() < 1e-12);
        }
        // FD on an S entry through the Coeff path.
        let an = g_c.lr[1].coeff()[(0, 1)];
        let eps = 1e-6;
        let mut wp = factored_weights(&prob, 3, 77);
        let mut wm = factored_weights(&prob, 3, 77);
        wp.lr[1].as_factored_mut().s[(0, 1)] += eps;
        wm.lr[1].as_factored_mut().s[(0, 1)] -= eps;
        let fd = (batch_loss(&prob, 0, &wp, 3) - batch_loss(&prob, 0, &wm, 3)) / (2.0 * eps);
        assert!((fd - an).abs() < 1e-5 * (1.0 + an.abs()), "fd {fd} vs {an}");
    }

    #[test]
    fn factored_loss_matches_dense_loss() {
        // The factored forward pass computes the same network as its
        // dense materialization.
        let prob = tiny_problem();
        let w_f = factored_weights(&prob, 4, 11);
        let w_d = dense_weights_from(&w_f);
        assert!((prob.global_loss(&w_f) - prob.global_loss(&w_d)).abs() < 1e-10);
        let a_f = prob.eval_metric(&w_f).unwrap();
        let a_d = prob.eval_metric(&w_d).unwrap();
        assert_eq!(a_f, a_d);
    }

    #[test]
    fn fast_path_matches_grad_bitwise_and_fills_dense() {
        let prob = tiny_problem();
        let w = factored_weights(&prob, 3, 21);
        let via_grad = prob.grad(1, &w, LrWant::Coeff, 5);
        let mut out: Vec<Matrix> = vec![Matrix::zeros(3, 3), Matrix::zeros(3, 3)];
        let mut out_dense = prob.dense_grad_buffers();
        let loss = prob
            .grad_coeff_into(1, &w, 5, &mut out, &mut out_dense)
            .expect("MLP offers the fast path");
        assert_eq!(loss.to_bits(), via_grad.loss.to_bits());
        for (o, g) in out.iter().zip(&via_grad.lr) {
            assert_eq!(o.data(), g.coeff().data());
        }
        for (o, g) in out_dense.iter().zip(&via_grad.dense) {
            assert_eq!(o.data(), g.data());
        }
        // Dense gradients are genuinely nonzero — biases and head move.
        assert!(out_dense.iter().any(|g| g.max_abs() > 1e-8));
        // Mismatched buffers fall back gracefully.
        let mut bad = vec![Matrix::zeros(2, 2), Matrix::zeros(3, 3)];
        assert!(prob.grad_coeff_into(1, &w, 5, &mut bad, &mut out_dense).is_none());
        let mut short_dense = prob.dense_grad_buffers();
        short_dense.pop();
        assert!(prob.grad_coeff_into(1, &w, 5, &mut out, &mut short_dense).is_none());
    }

    #[test]
    fn fast_path_handles_augmented_ranks() {
        // The client inner loop calls the fast path at augmented rank
        // 2r; buffers sized accordingly must be accepted.
        let prob = tiny_problem();
        let w = factored_weights(&prob, 4, 41); // rank 4 ≈ augmented 2·2
        let mut out = vec![Matrix::zeros(4, 4), Matrix::zeros(4, 4)];
        let mut out_dense = prob.dense_grad_buffers();
        let loss = prob.grad_coeff_into(0, &w, 0, &mut out, &mut out_dense);
        assert!(loss.expect("fast path").is_finite());
    }

    #[test]
    fn batches_are_deterministic_and_step_varying() {
        let prob = tiny_problem();
        let w = factored_weights(&prob, 3, 61);
        let a = prob.grad(0, &w, LrWant::Coeff, 7);
        let b = prob.grad(0, &w, LrWant::Coeff, 7);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        let c = prob.grad(0, &w, LrWant::Coeff, 8);
        assert_ne!(a.loss.to_bits(), c.loss.to_bits());
    }

    #[test]
    fn dirichlet_partition_weights_are_shard_sized() {
        let prob = MlpProblem::new(MlpOptions {
            d_in: 12,
            hidden: vec![10],
            classes: 4,
            num_clients: 3,
            train_n: 300,
            test_n: 40,
            eval_cap: 100,
            batch: 16,
            seed: 5,
            augment: false,
            dirichlet_alpha: Some(0.3),
        });
        let total: f64 = (0..3).map(|c| prob.client_weight(c)).sum();
        assert_eq!(total as usize, 300);
    }
}
