//! Problem abstraction for federated optimization.
//!
//! A [`FedProblem`] is the thing being trained: it knows the weight
//! structure (dense parameters + low-rank-capable layers), the client
//! partition, and how to evaluate losses and gradients. Two families
//! implement it:
//!
//! * [`least_squares`] — the paper's §4.1 convex tests, with analytic
//!   gradients computed natively in Rust;
//! * [`mlp`] — native multi-layer perceptrons over the synthetic vision
//!   data (the §4.2 benchmarks, no artifacts required);
//! * `nn::NnProblem` — the §4.2 vision benchmarks through the
//!   AOT-compiled JAX/Pallas artifacts via PJRT (optional path).

pub mod checkpoint;
pub mod least_squares;
pub mod mlp;
pub mod quadratic;

use crate::lowrank::LowRank;
use crate::tensor::Matrix;

/// Shapes of all trainables.
#[derive(Debug, Clone, Default)]
pub struct ProblemSpec {
    /// Dense (non-factorized) parameter shapes, e.g. biases, head.
    pub dense_shapes: Vec<(usize, usize)>,
    /// Low-rank-capable layer shapes `(m, n)`.
    pub lr_shapes: Vec<(usize, usize)>,
}

/// One low-rank-capable layer's weight in either representation.
#[derive(Debug, Clone)]
pub enum LrWeight {
    /// Factorized `U S Vᵀ` (FeDLRT).
    Factored(LowRank),
    /// Dense matrix (FedAvg / FedLin baselines).
    Dense(Matrix),
}

impl LrWeight {
    pub fn as_factored(&self) -> &LowRank {
        match self {
            LrWeight::Factored(f) => f,
            LrWeight::Dense(_) => panic!("expected factored weight"),
        }
    }

    /// Mutable access to the factorization — the client inner loop
    /// trains `S̃` in place instead of rebuilding `Weights` per step.
    pub fn as_factored_mut(&mut self) -> &mut LowRank {
        match self {
            LrWeight::Factored(f) => f,
            LrWeight::Dense(_) => panic!("expected factored weight"),
        }
    }

    pub fn as_dense(&self) -> &Matrix {
        match self {
            LrWeight::Dense(m) => m,
            LrWeight::Factored(_) => panic!("expected dense weight"),
        }
    }

    /// Mutable access to the dense representation (dense baselines'
    /// in-place client iterations).
    pub fn as_dense_mut(&mut self) -> &mut Matrix {
        match self {
            LrWeight::Dense(m) => m,
            LrWeight::Factored(_) => panic!("expected dense weight"),
        }
    }

    /// Materialize as a dense matrix regardless of representation.
    pub fn to_dense(&self) -> Matrix {
        match self {
            LrWeight::Dense(m) => m.clone(),
            LrWeight::Factored(f) => f.to_dense(),
        }
    }

    /// Trainable parameter count in the current representation.
    pub fn param_count(&self) -> usize {
        match self {
            LrWeight::Dense(m) => m.rows() * m.cols(),
            LrWeight::Factored(f) => f.param_count(),
        }
    }
}

/// A complete set of trainable weights.
#[derive(Debug, Clone)]
pub struct Weights {
    pub dense: Vec<Matrix>,
    pub lr: Vec<LrWeight>,
}

impl Weights {
    pub fn param_count(&self) -> usize {
        self.dense.iter().map(|m| m.rows() * m.cols()).sum::<usize>()
            + self.lr.iter().map(|w| w.param_count()).sum::<usize>()
    }
}

/// Which gradient form the caller wants for the low-rank layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrWant {
    /// Basis + coefficient gradients `(G_U, G_V, G_S)` at `U S Vᵀ`
    /// (Algorithm 1 line 3 / Algorithm 5 lines 3–5).
    Factors,
    /// Coefficient gradient only `∇_S̃ L_c(Ũ S̃ Ṽᵀ)` — the client inner
    /// loop (eq. 7/8); weights carry the *augmented* factors.
    Coeff,
    /// Dense gradient `∇_W L_c(W)` — FedAvg/FedLin baselines.
    Dense,
}

/// Per-layer gradient matching [`LrWant`].
#[derive(Debug, Clone)]
pub enum LrGrad {
    Factors { g_u: Matrix, g_v: Matrix, g_s: Matrix },
    Coeff(Matrix),
    Dense(Matrix),
}

impl LrGrad {
    pub fn coeff(&self) -> &Matrix {
        match self {
            LrGrad::Coeff(m) => m,
            _ => panic!("expected coefficient gradient"),
        }
    }

    pub fn dense(&self) -> &Matrix {
        match self {
            LrGrad::Dense(m) => m,
            _ => panic!("expected dense gradient"),
        }
    }
}

/// Result of a gradient evaluation.
#[derive(Debug, Clone)]
pub struct Grads {
    /// Mini-batch (or full-batch) loss at the evaluation point.
    pub loss: f64,
    pub dense: Vec<Matrix>,
    pub lr: Vec<LrGrad>,
}

/// A federated optimization problem (eq. 1).
pub trait FedProblem {
    /// Weight structure.
    fn spec(&self) -> ProblemSpec;

    /// Number of clients `C`.
    fn num_clients(&self) -> usize;

    /// Evaluate client `c`'s loss and gradient at `w`.
    ///
    /// `step` selects the mini-batch for stochastic problems (clients
    /// use a deterministic schedule so runs are reproducible); convex
    /// full-batch problems ignore it.
    fn grad(&self, c: usize, w: &Weights, want: LrWant, step: u64) -> Grads;

    /// Allocation-free fast path for the client inner loop: write the
    /// coefficient gradients `∇_S̃ L_c` into `out` (one preallocated
    /// `r̃×r̃` matrix per low-rank layer, shapes matching `w`), the
    /// dense-parameter gradients into `out_dense` (one preallocated
    /// matrix per entry of `w.dense`, same order), and return the loss.
    ///
    /// Problems without dense parameters receive an empty `out_dense`
    /// and ignore it. Problems **with** dense parameters must either
    /// fill `out_dense` completely or return `None` — a fast path that
    /// silently skips dense gradients would freeze biases/heads, since
    /// the coordinators step dense parameters from these buffers on the
    /// fast path (regression-tested in `coordinator::fedlrt`).
    ///
    /// Returns `None` when the problem has no such path (the caller
    /// then falls back to [`FedProblem::grad`] with [`LrWant::Coeff`]).
    /// Implementations must produce exactly the gradients `grad` would
    /// — this is the same computation minus the per-call allocations,
    /// which is what makes the steady-state round loop allocation-free
    /// (asserted by the counting-allocator check in `micro_hotpath`).
    fn grad_coeff_into(
        &self,
        _c: usize,
        _w: &Weights,
        _step: u64,
        _out: &mut [Matrix],
        _out_dense: &mut [Matrix],
    ) -> Option<f64> {
        None
    }

    /// Global loss `L(w) = (1/C) Σ_c L_c(w)` on the full data.
    fn global_loss(&self, w: &Weights) -> f64;

    /// Optional task metric (e.g. validation accuracy ∈ [0,1]).
    fn eval_metric(&self, _w: &Weights) -> Option<f64> {
        None
    }

    /// Distance to a known optimum, if the problem has one (Fig 4).
    fn distance_to_optimum(&self, _w: &Weights) -> Option<f64> {
        None
    }

    /// Aggregation weight of client `c` (paper §2: "the extension to
    /// handle a (non-uniform) weighted average case is straightforward"
    /// — e.g. proportional to shard sizes). Uniform by default; engines
    /// normalize over the participating set.
    fn client_weight(&self, _c: usize) -> f64 {
        1.0
    }
}
