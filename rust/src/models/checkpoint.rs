//! Weight checkpointing: save/load [`Weights`] as JSON.
//!
//! A deployment needs to persist the trained factorization (and resume
//! federated training after a server restart). The format is the
//! in-tree JSON with shape-tagged tensors; factored layers store
//! `U, S, V` separately so the low-rank structure survives the
//! round trip bit-for-bit (f64 values serialized exactly via their
//! bit patterns in hex).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::lowrank::LowRank;
use crate::tensor::Matrix;
use crate::util::json::{parse, Json};

use super::{LrWeight, Weights};

fn matrix_to_json(m: &Matrix) -> Json {
    let mut o = Json::obj();
    o.set("rows", m.rows()).set("cols", m.cols());
    // Exact f64 round-trip: hex bit patterns (JSON numbers would lose
    // the guarantee through decimal formatting).
    let hex: String = m.data().iter().map(|x| format!("{:016x}", x.to_bits())).collect();
    o.set("data_hex", hex);
    o
}

fn matrix_from_json(j: &Json) -> Result<Matrix> {
    let rows = j.get("rows").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("rows"))?;
    let cols = j.get("cols").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("cols"))?;
    let hex = j.get("data_hex").and_then(|x| x.as_str()).ok_or_else(|| anyhow!("data_hex"))?;
    if hex.len() != rows * cols * 16 {
        return Err(anyhow!("checkpoint data length mismatch"));
    }
    let data: Result<Vec<f64>> = (0..rows * cols)
        .map(|i| {
            let chunk = &hex[i * 16..(i + 1) * 16];
            u64::from_str_radix(chunk, 16)
                .map(f64::from_bits)
                .map_err(|e| anyhow!("bad hex at {i}: {e}"))
        })
        .collect();
    Ok(Matrix::from_vec(rows, cols, data?))
}

/// Serialize weights to a JSON value.
pub fn weights_to_json(w: &Weights) -> Json {
    let mut o = Json::obj();
    o.set("format", "fedlrt-checkpoint-v1");
    o.set("dense", Json::Arr(w.dense.iter().map(matrix_to_json).collect()));
    let lr: Vec<Json> = w
        .lr
        .iter()
        .map(|lw| {
            let mut e = Json::obj();
            match lw {
                LrWeight::Dense(m) => {
                    e.set("kind", "dense").set("w", matrix_to_json(m));
                }
                LrWeight::Factored(f) => {
                    e.set("kind", "factored")
                        .set("u", matrix_to_json(&f.u))
                        .set("s", matrix_to_json(&f.s))
                        .set("v", matrix_to_json(&f.v));
                }
            }
            e
        })
        .collect();
    o.set("lr", Json::Arr(lr));
    o
}

/// Deserialize weights from a JSON value.
pub fn weights_from_json(j: &Json) -> Result<Weights> {
    if j.str_or("format", "") != "fedlrt-checkpoint-v1" {
        return Err(anyhow!("not a fedlrt checkpoint (missing format tag)"));
    }
    let dense = j
        .get("dense")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("missing dense"))?
        .iter()
        .map(matrix_from_json)
        .collect::<Result<Vec<_>>>()?;
    let lr = j
        .get("lr")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("missing lr"))?
        .iter()
        .map(|e| -> Result<LrWeight> {
            match e.str_or("kind", "") {
                "dense" => Ok(LrWeight::Dense(matrix_from_json(
                    e.get("w").ok_or_else(|| anyhow!("missing w"))?,
                )?)),
                "factored" => {
                    let u = matrix_from_json(e.get("u").ok_or_else(|| anyhow!("missing u"))?)?;
                    let s = matrix_from_json(e.get("s").ok_or_else(|| anyhow!("missing s"))?)?;
                    let v = matrix_from_json(e.get("v").ok_or_else(|| anyhow!("missing v"))?)?;
                    if u.cols() != s.rows() || v.cols() != s.cols() {
                        return Err(anyhow!("inconsistent factor shapes"));
                    }
                    Ok(LrWeight::Factored(LowRank { u, s, v }))
                }
                other => Err(anyhow!("unknown lr weight kind '{other}'")),
            }
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Weights { dense, lr })
}

/// Save to a file (pretty-printed).
pub fn save(w: &Weights, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, weights_to_json(w).to_string_pretty())
        .with_context(|| format!("writing checkpoint {path:?}"))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<Weights> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    let j = parse(&text).map_err(|e| anyhow!("checkpoint parse: {e}"))?;
    weights_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_weights(seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        Weights {
            dense: vec![Matrix::randn(3, 5, &mut rng), Matrix::randn(1, 4, &mut rng)],
            lr: vec![
                LrWeight::Factored(LowRank::random_init(8, 7, 3, &mut rng)),
                LrWeight::Dense(Matrix::randn(6, 6, &mut rng)),
            ],
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let w = sample_weights(1);
        let back = weights_from_json(&weights_to_json(&w)).unwrap();
        for (a, b) in w.dense.iter().zip(&back.dense) {
            assert_eq!(a.data(), b.data());
        }
        match (&w.lr[0], &back.lr[0]) {
            (LrWeight::Factored(x), LrWeight::Factored(y)) => {
                assert_eq!(x.u.data(), y.u.data());
                assert_eq!(x.s.data(), y.s.data());
                assert_eq!(x.v.data(), y.v.data());
            }
            _ => panic!("kind changed"),
        }
        match (&w.lr[1], &back.lr[1]) {
            (LrWeight::Dense(x), LrWeight::Dense(y)) => assert_eq!(x.data(), y.data()),
            _ => panic!("kind changed"),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("fedlrt_ckpt_test");
        let path = dir.join("w.json");
        let w = sample_weights(2);
        save(&w, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(w.param_count(), back.param_count());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn special_values_survive() {
        // Subnormals, negative zero, infinities must round-trip.
        let m = Matrix::from_vec(
            1,
            4,
            vec![f64::MIN_POSITIVE / 2.0, -0.0, f64::INFINITY, 1.0e-300],
        );
        let w = Weights { dense: vec![m], lr: vec![] };
        let back = weights_from_json(&weights_to_json(&w)).unwrap();
        for (a, b) in w.dense[0].data().iter().zip(back.dense[0].data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(weights_from_json(&Json::obj()).is_err());
        assert!(parse("{").is_err());
        let mut bad = weights_to_json(&sample_weights(3));
        bad.set("format", "other");
        assert!(weights_from_json(&bad).is_err());
    }
}
