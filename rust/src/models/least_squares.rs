//! Distributed linear least-squares regression (paper §4.1).
//!
//! The trainable is a single matrix `W ∈ R^{n×n}`; the model predicts
//! `ŷ(x, y) = p(x)ᵀ W p(y)` where `p: [-1,1] → R^n` is the Legendre
//! polynomial basis of degree `n−1`.
//!
//! * **Homogeneous test** — one global target `f(x,y) = p(x)ᵀ W_r p(y)`
//!   with `rank(W_r) = r`; the 10 000 data points are partitioned
//!   uniformly among clients (client losses differ only through their
//!   shards). Paper: n=20, r=4, C ∈ {1,…,32}, s*=20, λ=1e-3.
//! * **Heterogeneous test** — per-client targets `f_c` (rank-1 each),
//!   all clients see *all* data (client drift comes purely from the
//!   conflicting targets). Paper: n=10, C=4, s*=100, λ=1e-3.
//!
//! Gradients are analytic. For the factored evaluation the code never
//! materializes `∇_W L`, mirroring the paper's client-cost argument:
//! with `A = P_x U`, `B = P_y V` (N×r skinny), residual
//! `res_i = a_iᵀ S b_i − f_i`,
//!
//! ```text
//! ∇_S L = Aᵀ diag(res) B / N                   (r×r)
//! ∇_U L = P_xᵀ (diag(res) B Sᵀ) / N            (n×r)
//! ∇_V L = P_yᵀ (diag(res) A S)  / N            (n×r)
//! ```
//!
//! which is `O(N n r)` — the `O(s*b(4nr+4r²))` row of Table 1.

use std::sync::Mutex;

use crate::lowrank::LowRank;
use crate::tensor::{matmul, matmul_nt, matmul_tn, Matrix};
use crate::util::rng::Rng;

use super::{FedProblem, Grads, LrGrad, LrWant, LrWeight, ProblemSpec, Weights};

/// Evaluate the Legendre basis `[P_0(x), …, P_{n−1}(x)]`.
pub fn legendre_basis(x: f64, n: usize) -> Vec<f64> {
    let mut p = vec![0.0; n];
    if n == 0 {
        return p;
    }
    p[0] = 1.0;
    if n > 1 {
        p[1] = x;
    }
    for k in 1..n.saturating_sub(1) {
        // (k+1) P_{k+1} = (2k+1) x P_k − k P_{k−1}
        p[k + 1] = ((2 * k + 1) as f64 * x * p[k] - k as f64 * p[k - 1]) / (k + 1) as f64;
    }
    p
}

/// One client's data shard: basis-evaluated inputs and targets.
#[derive(Debug, Clone)]
struct Shard {
    /// `P_x ∈ R^{N×n}` — rows are `p(x_i)`.
    px: Matrix,
    /// `P_y ∈ R^{N×n}` — rows are `p(y_i)`.
    py: Matrix,
    /// Targets `f_i`.
    f: Vec<f64>,
}

impl Shard {
    fn len(&self) -> usize {
        self.f.len()
    }

    /// Residuals `p(x_i)ᵀ W p(y_i) − f_i` for dense `W`.
    fn residuals_dense(&self, w: &Matrix) -> Vec<f64> {
        // T = P_x W (N×n), res_i = ⟨T_i, P_y_i⟩ − f_i.
        let t = matmul(&self.px, w);
        let n = w.cols();
        (0..self.len())
            .map(|i| {
                let ti = t.row(i);
                let pyi = self.py.row(i);
                let mut acc = 0.0;
                for j in 0..n {
                    acc += ti[j] * pyi[j];
                }
                acc - self.f[i]
            })
            .collect()
    }

    fn loss_dense(&self, w: &Matrix) -> f64 {
        let res = self.residuals_dense(w);
        res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64)
    }

    /// `∇_W = P_xᵀ diag(res) P_y / N`.
    fn grad_dense(&self, w: &Matrix) -> (f64, Matrix) {
        let res = self.residuals_dense(w);
        let n_inv = 1.0 / self.len() as f64;
        // scaled = diag(res) P_y
        let mut scaled = self.py.clone();
        for i in 0..self.len() {
            let r = res[i] * n_inv;
            for v in scaled.row_mut(i) {
                *v *= r;
            }
        }
        let g = matmul_tn(&self.px, &scaled);
        let loss = res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64);
        (loss, g)
    }

    /// Factored-path intermediates `A = P_x U`, `B = P_y V`, residuals.
    fn factored_parts(&self, fac: &LowRank) -> (Matrix, Matrix, Vec<f64>) {
        let a = matmul(&self.px, &fac.u); // N×r
        let b = matmul(&self.py, &fac.v); // N×r
        let asb = matmul(&a, &fac.s); // N×r: rows a_iᵀ S
        let r = fac.rank();
        let res: Vec<f64> = (0..self.len())
            .map(|i| {
                let ai = asb.row(i);
                let bi = b.row(i);
                let mut acc = 0.0;
                for j in 0..r {
                    acc += ai[j] * bi[j];
                }
                acc - self.f[i]
            })
            .collect();
        (a, b, res)
    }

    fn loss_factored(&self, fac: &LowRank) -> f64 {
        let (_, _, res) = self.factored_parts(fac);
        res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64)
    }

    /// `(loss, G_U, G_V, G_S)` — never materializes `∇_W`.
    fn grad_factors(&self, fac: &LowRank) -> (f64, Matrix, Matrix, Matrix) {
        let (a, b, res) = self.factored_parts(fac);
        let n_inv = 1.0 / self.len() as f64;
        // rb = diag(res) B, ra = diag(res) A (scaled by 1/N)
        let mut rb = b.clone();
        let mut ra = a.clone();
        for i in 0..self.len() {
            let r = res[i] * n_inv;
            for v in rb.row_mut(i) {
                *v *= r;
            }
            for v in ra.row_mut(i) {
                *v *= r;
            }
        }
        // G_S = Aᵀ (diag(res) B) — note A already unscaled, rb has 1/N.
        let g_s = matmul_tn(&a, &rb);
        // G_U = P_xᵀ (diag(res) B Sᵀ)
        let g_u = matmul_tn(&self.px, &matmul_nt(&rb, &fac.s));
        // G_V = P_yᵀ (diag(res) A S)
        let g_v = matmul_tn(&self.py, &matmul(&ra, &fac.s));
        let loss = res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64);
        (loss, g_u, g_v, g_s)
    }

    /// Coefficient gradient only: `G_S = Aᵀ diag(res) B / N`.
    /// (Uncached reference path; the production path is
    /// `LeastSquares::grad_coeff_cached`. Kept for tests/documentation.)
    #[allow(dead_code)]
    fn grad_coeff(&self, fac: &LowRank) -> (f64, Matrix) {
        let (a, b, res) = self.factored_parts(fac);
        let n_inv = 1.0 / self.len() as f64;
        let mut rb = b;
        for i in 0..self.len() {
            let r = res[i] * n_inv;
            for v in rb.row_mut(i) {
                *v *= r;
            }
        }
        let g_s = matmul_tn(&a, &rb);
        let loss = res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64);
        (loss, g_s)
    }
}

/// One client's cached basis projections `(A, B) = (P_x U, P_y V)`.
#[derive(Debug)]
struct ProjCache {
    /// Content fingerprint of the bases the projections were built from.
    key: u64,
    a: Matrix,
    b: Matrix,
}

/// The federated least-squares problem.
#[derive(Debug)]
pub struct LeastSquares {
    n: usize,
    shards: Vec<Shard>,
    /// Known global minimizer (homogeneous case), for Fig 4's error plot.
    w_star: Option<Matrix>,
    /// Per-client cache of the projected features `(A, B) = (P_x U, P_y V)`.
    ///
    /// During the client inner loop (eq. 7/8) the bases are frozen and
    /// only `S̃` changes, so the `O(N·n·r)` projections are reusable
    /// across all `s*` iterations — this is precisely what a real FeDLRT
    /// client implementation would precompute after basis broadcast.
    /// Guarded by a cheap content fingerprint of the bases so stale
    /// entries can never be served. One lock *per client* (not one
    /// shared map) so the thread-pool executor's clients never contend:
    /// a client's gradient work only ever touches its own slot.
    proj_cache: Vec<Mutex<Option<ProjCache>>>,
}

impl Clone for LeastSquares {
    fn clone(&self) -> LeastSquares {
        LeastSquares {
            n: self.n,
            shards: self.shards.clone(),
            w_star: self.w_star.clone(),
            proj_cache: fresh_cache(self.shards.len()),
        }
    }
}

fn fresh_cache(num_clients: usize) -> Vec<Mutex<Option<ProjCache>>> {
    (0..num_clients).map(|_| Mutex::new(None)).collect()
}

impl LeastSquares {
    /// Homogeneous test (§4.1): shared rank-`r` target, uniform shards.
    pub fn homogeneous(
        n: usize,
        target_rank: usize,
        num_points: usize,
        num_clients: usize,
        rng: &mut Rng,
    ) -> LeastSquares {
        // Random rank-r target W_r = Û Ŝ V̂ᵀ, entries O(1).
        let w_r = LowRank::random_init(n, n, target_rank, rng).to_dense();
        // Sample points, evaluate basis + target, shard uniformly.
        let per = num_points / num_clients;
        let mut shards = Vec::with_capacity(num_clients);
        for _ in 0..num_clients {
            let (px, py) = sample_basis(n, per, rng);
            let f = targets(&px, &py, &w_r);
            shards.push(Shard { px, py, f });
        }
        let proj_cache = fresh_cache(shards.len());
        LeastSquares { n, shards, w_star: Some(w_r), proj_cache }
    }

    /// Heterogeneous test (§4.1 / Fig 1): per-client rank-1 targets
    /// `f_c` **and** per-client input samples.
    ///
    /// Reproduction note: the paper's text samples one input set shared
    /// by all clients, but with a shared design the local quadratic
    /// losses have *identical Hessians*, in which case FedAvg's
    /// client-drift bias provably cancels (the average of the affine
    /// local GD maps has the global minimizer as its fixed point) and no
    /// plateau appears. The FedLin paper [27], which Fig 1 is "inspired
    /// by", uses per-client data; we do the same so the drift effect the
    /// figure demonstrates actually exists. See DESIGN.md
    /// §Substitutions.
    pub fn heterogeneous(
        n: usize,
        num_points: usize,
        num_clients: usize,
        rng: &mut Rng,
    ) -> LeastSquares {
        let per = num_points / num_clients;
        let mut shards = Vec::with_capacity(num_clients);
        for _ in 0..num_clients {
            let (px, py) = sample_basis(n, per, rng);
            let w_c = LowRank::random_init(n, n, 1, rng).to_dense();
            let f = targets(&px, &py, &w_c);
            shards.push(Shard { px, py, f });
        }
        let w_star = solve_global_minimizer(n, &shards);
        let proj_cache = fresh_cache(shards.len());
        LeastSquares { n, shards, w_star: Some(w_star), proj_cache }
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Content fingerprint of a basis pair (order-sensitive FNV-1a over
    /// the raw bits + dims). Cost O(nr) — negligible next to the O(Nnr)
    /// projection it guards.
    fn basis_fingerprint(u: &Matrix, v: &Matrix) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        mix(u.rows() as u64);
        mix(u.cols() as u64);
        for &x in u.data() {
            mix(x.to_bits());
        }
        for &x in v.data() {
            mix(x.to_bits());
        }
        h
    }

    /// Coefficient gradient with the per-client projection cache: the
    /// `O(N·n·r)` products `A = P_x U`, `B = P_y V` are computed once per
    /// basis broadcast and reused across the s* local iterations.
    fn grad_coeff_cached(&self, c: usize, fac: &LowRank) -> (f64, Matrix) {
        let key = Self::basis_fingerprint(&fac.u, &fac.v);
        let mut slot = self.proj_cache[c].lock().expect("projection cache poisoned");
        let sh = &self.shards[c];
        let stale = match slot.as_ref() {
            Some(entry) => entry.key != key,
            None => true,
        };
        if stale {
            *slot = Some(ProjCache {
                key,
                a: matmul(&sh.px, &fac.u),
                b: matmul(&sh.py, &fac.v),
            });
        }
        let entry = slot.as_ref().expect("cache entry just written");
        let (a, b) = (&entry.a, &entry.b);
        // res_i = a_iᵀ S b_i − f_i
        let asb = matmul(a, &fac.s);
        let r = fac.rank();
        let n_inv = 1.0 / sh.len() as f64;
        let mut loss = 0.0;
        // rb = diag(res)·B/N without cloning B: accumulate G_S directly.
        let mut g_s = Matrix::zeros(r, r);
        for i in 0..sh.len() {
            let ai = asb.row(i);
            let bi = b.row(i);
            let mut pred = 0.0;
            for j in 0..r {
                pred += ai[j] * bi[j];
            }
            let res = pred - sh.f[i];
            loss += res * res;
            let w = res * n_inv;
            let arow = a.row(i);
            for p in 0..r {
                let ap = arow[p] * w;
                if ap != 0.0 {
                    let row = g_s.row_mut(p);
                    for (gq, &bq) in row.iter_mut().zip(bi) {
                        *gq += ap * bq;
                    }
                }
            }
        }
        (loss / (2.0 * sh.len() as f64), g_s)
    }

    /// The known global minimizer, if any.
    pub fn w_star(&self) -> Option<&Matrix> {
        self.w_star.as_ref()
    }

    /// Global loss value at the minimizer (`> 0` for heterogeneous
    /// targets). Suboptimality gaps should be measured against this.
    pub fn min_loss(&self) -> f64 {
        match &self.w_star {
            Some(w) => {
                let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w.clone())] };
                self.global_loss(&wts)
            }
            None => 0.0,
        }
    }

    /// Smoothness constant `L` of the global loss: the largest eigenvalue
    /// of the quadratic form's Hessian, `L = λ_max((1/C)Σ_c H_c)` with
    /// `H = (1/N) Σ_i (p_x p_yᵀ)(p_x p_yᵀ)ᵀ`-style Kronecker structure.
    /// We report the tractable upper bound `max_i ‖p(x_i)‖²‖p(y_i)‖²`
    /// averaged over shards — used to pick safe step sizes in tests.
    pub fn smoothness_bound(&self) -> f64 {
        let mut worst = 0.0f64;
        for sh in &self.shards {
            let mut acc = 0.0f64;
            for i in 0..sh.len() {
                let nx: f64 = sh.px.row(i).iter().map(|v| v * v).sum();
                let ny: f64 = sh.py.row(i).iter().map(|v| v * v).sum();
                acc += nx * ny;
            }
            worst = worst.max(acc / sh.len() as f64);
        }
        worst
    }
}

/// Exact global minimizer of the averaged quadratic loss via the normal
/// equations in `vec(W)` space: `(Σ_c A_cᵀA_c / N_c) w = Σ_c A_cᵀ f_c / N_c`
/// with design rows `a_i = p(y_i) ⊗ p(x_i)` (row-major vec), solved by
/// SVD pseudo-inverse.
fn solve_global_minimizer(n: usize, shards: &[Shard]) -> Matrix {
    let d = n * n;
    let mut m = Matrix::zeros(d, d);
    let mut rhs = vec![0.0; d];
    for sh in shards {
        let scale = 1.0 / sh.len() as f64;
        // Design matrix A ∈ R^{N×n²}: a_{i,(j,k)} = px[i,j]·py[i,k].
        let mut a = Matrix::zeros(sh.len(), d);
        for i in 0..sh.len() {
            let pxi = sh.px.row(i);
            let pyi = sh.py.row(i);
            let row = a.row_mut(i);
            for j in 0..n {
                for k in 0..n {
                    row[j * n + k] = pxi[j] * pyi[k];
                }
            }
        }
        let ata = matmul_tn(&a, &a);
        m.axpy(scale, &ata);
        let atf = {
            let mut v = vec![0.0; d];
            for i in 0..sh.len() {
                let row = a.row(i);
                let f = sh.f[i];
                for (vj, &aj) in v.iter_mut().zip(row) {
                    *vj += aj * f;
                }
            }
            v
        };
        for (r, x) in rhs.iter_mut().zip(&atf) {
            *r += scale * x;
        }
    }
    let w_vec = crate::linalg::svd::pinv_solve(&m, &rhs, 1e-10);
    Matrix::from_vec(n, n, w_vec)
}

/// Orthonormalized Legendre features `p̃_k(x) = √(2k+1)·P_k(x)`, which
/// satisfy `E_{x∼U[-1,1]}[p̃ p̃ᵀ] = I`. The normalization makes the
/// least-squares Hessian ≈ identity — without it the design has
/// condition number `O(n²)` per factor and gradient descent at the
/// paper's step sizes could not reach the reported accuracies.
pub fn legendre_features(x: f64, n: usize) -> Vec<f64> {
    let mut p = legendre_basis(x, n);
    for (k, v) in p.iter_mut().enumerate() {
        *v *= ((2 * k + 1) as f64).sqrt();
    }
    p
}

fn sample_basis(n: usize, num: usize, rng: &mut Rng) -> (Matrix, Matrix) {
    let mut px = Matrix::zeros(num, n);
    let mut py = Matrix::zeros(num, n);
    for i in 0..num {
        let x = rng.uniform_in(-1.0, 1.0);
        let y = rng.uniform_in(-1.0, 1.0);
        px.row_mut(i).copy_from_slice(&legendre_features(x, n));
        py.row_mut(i).copy_from_slice(&legendre_features(y, n));
    }
    (px, py)
}

fn targets(px: &Matrix, py: &Matrix, w: &Matrix) -> Vec<f64> {
    let t = matmul(px, w);
    let n = w.cols();
    (0..px.rows())
        .map(|i| {
            let ti = t.row(i);
            let pyi = py.row(i);
            let mut acc = 0.0;
            for j in 0..n {
                acc += ti[j] * pyi[j];
            }
            acc
        })
        .collect()
}

impl FedProblem for LeastSquares {
    fn spec(&self) -> ProblemSpec {
        ProblemSpec { dense_shapes: vec![], lr_shapes: vec![(self.n, self.n)] }
    }

    fn num_clients(&self) -> usize {
        self.shards.len()
    }

    fn grad(&self, c: usize, w: &Weights, want: LrWant, _step: u64) -> Grads {
        let shard = &self.shards[c];
        let (loss, lr_grad) = match (want, &w.lr[0]) {
            (LrWant::Dense, LrWeight::Dense(wm)) => {
                let (loss, g) = shard.grad_dense(wm);
                (loss, LrGrad::Dense(g))
            }
            (LrWant::Factors, LrWeight::Factored(f)) => {
                let (loss, g_u, g_v, g_s) = shard.grad_factors(f);
                (loss, LrGrad::Factors { g_u, g_v, g_s })
            }
            (LrWant::Coeff, LrWeight::Factored(f)) => {
                let (loss, g_s) = self.grad_coeff_cached(c, f);
                (loss, LrGrad::Coeff(g_s))
            }
            _ => panic!("weight representation does not match requested gradient"),
        };
        Grads { loss, dense: vec![], lr: vec![lr_grad] }
    }

    fn global_loss(&self, w: &Weights) -> f64 {
        let c = self.num_clients() as f64;
        match &w.lr[0] {
            LrWeight::Dense(wm) => self.shards.iter().map(|s| s.loss_dense(wm)).sum::<f64>() / c,
            LrWeight::Factored(f) => {
                self.shards.iter().map(|s| s.loss_factored(f)).sum::<f64>() / c
            }
        }
    }

    fn distance_to_optimum(&self, w: &Weights) -> Option<f64> {
        self.w_star.as_ref().map(|ws| w.lr[0].to_dense().sub(ws).fro_norm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn legendre_known_values() {
        // P0=1, P1=x, P2=(3x²−1)/2, P3=(5x³−3x)/2 at x=0.5
        let p = legendre_basis(0.5, 4);
        assert!((p[0] - 1.0).abs() < 1e-12);
        assert!((p[1] - 0.5).abs() < 1e-12);
        assert!((p[2] - (3.0 * 0.25 - 1.0) / 2.0).abs() < 1e-12);
        assert!((p[3] - (5.0 * 0.125 - 3.0 * 0.5) / 2.0).abs() < 1e-12);
        // Endpoint identity P_k(1) = 1.
        let p1 = legendre_basis(1.0, 8);
        for v in p1 {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_loss_at_target() {
        let mut rng = Rng::new(601);
        let prob = LeastSquares::homogeneous(8, 3, 200, 2, &mut rng);
        let w_star = prob.w_star.clone().unwrap();
        let w = Weights { dense: vec![], lr: vec![LrWeight::Dense(w_star)] };
        assert!(prob.global_loss(&w) < 1e-20);
        assert_eq!(prob.distance_to_optimum(&w).unwrap(), 0.0);
    }

    #[test]
    fn dense_gradient_matches_finite_difference() {
        let mut rng = Rng::new(603);
        let prob = LeastSquares::homogeneous(5, 2, 50, 1, &mut rng);
        let w0 = Matrix::randn(5, 5, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w0.clone())] };
        let g = prob.grad(0, &wts, LrWant::Dense, 0);
        let eps = 1e-6;
        for &(i, j) in &[(0usize, 0usize), (2, 3), (4, 4), (1, 0)] {
            let mut wp = w0.clone();
            wp[(i, j)] += eps;
            let mut wm = w0.clone();
            wm[(i, j)] -= eps;
            let lp = prob
                .global_loss(&Weights { dense: vec![], lr: vec![LrWeight::Dense(wp)] });
            let lm = prob
                .global_loss(&Weights { dense: vec![], lr: vec![LrWeight::Dense(wm)] });
            let fd = (lp - lm) / (2.0 * eps);
            let an = g.lr[0].dense()[(i, j)];
            assert!((fd - an).abs() < 1e-5, "({i},{j}): fd {fd} vs {an}");
        }
    }

    #[test]
    fn factor_gradients_match_dense_projection() {
        // G_U = G V Sᵀ, G_V = Gᵀ U S, G_S = Uᵀ G V where G = ∇_W L.
        let mut rng = Rng::new(607);
        let prob = LeastSquares::homogeneous(7, 2, 80, 1, &mut rng);
        let fac = LowRank::random_init(7, 7, 3, &mut rng);
        let wts_f = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
        let g_fac = prob.grad(0, &wts_f, LrWant::Factors, 0);
        let wts_d = Weights { dense: vec![], lr: vec![LrWeight::Dense(fac.to_dense())] };
        let g_dense = prob.grad(0, &wts_d, LrWant::Dense, 0);
        let g = g_dense.lr[0].dense();
        let (g_u, g_v, g_s) = match &g_fac.lr[0] {
            LrGrad::Factors { g_u, g_v, g_s } => (g_u, g_v, g_s),
            _ => unreachable!(),
        };
        let want_gu = matmul_nt(&matmul(g, &fac.v), &fac.s);
        let want_gv = matmul(&matmul_tn(g, &fac.u), &fac.s);
        let want_gs = matmul(&matmul_tn(&fac.u, g), &fac.v);
        assert!(g_u.sub(&want_gu).max_abs() < 1e-10);
        assert!(g_v.sub(&want_gv).max_abs() < 1e-10);
        assert!(g_s.sub(&want_gs).max_abs() < 1e-10);
        // Coeff-only path agrees with the full factor path.
        let g_c = prob.grad(0, &wts_f, LrWant::Coeff, 0);
        assert!(g_c.lr[0].coeff().sub(g_s).max_abs() < 1e-12);
        assert!((g_c.loss - g_fac.loss).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_clients_disagree() {
        let mut rng = Rng::new(611);
        let prob = LeastSquares::heterogeneous(6, 100, 3, &mut rng);
        let w = Matrix::randn(6, 6, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w)] };
        let g0 = prob.grad(0, &wts, LrWant::Dense, 0);
        let g1 = prob.grad(1, &wts, LrWant::Dense, 0);
        // Different targets ⇒ different gradients.
        assert!(g0.lr[0].dense().sub(g1.lr[0].dense()).max_abs() > 1e-3);
    }

    #[test]
    fn global_loss_is_mean_of_clients() {
        let mut rng = Rng::new(613);
        let prob = LeastSquares::homogeneous(6, 2, 90, 3, &mut rng);
        let w = Matrix::randn(6, 6, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w)] };
        let mean: f64 = (0..3)
            .map(|c| prob.grad(c, &wts, LrWant::Dense, 0).loss)
            .sum::<f64>()
            / 3.0;
        assert!((prob.global_loss(&wts) - mean).abs() < 1e-12);
    }

    #[test]
    fn prop_factored_loss_equals_dense_loss() {
        prop::check(
            "lsq: loss(USVᵀ) == loss(dense)",
            6,
            |rng, size| {
                let n = 3 + size.min(6);
                let prob = LeastSquares::homogeneous(n, 2, 40, 2, rng);
                let fac = LowRank::random_init(n, n, 2, rng);
                (prob, fac)
            },
            |(prob, fac)| {
                let lf = prob.global_loss(&Weights {
                    dense: vec![],
                    lr: vec![LrWeight::Factored(fac.clone())],
                });
                let ld = prob.global_loss(&Weights {
                    dense: vec![],
                    lr: vec![LrWeight::Dense(fac.to_dense())],
                });
                prop::close(lf, ld, 1e-9)
            },
        );
    }
}
