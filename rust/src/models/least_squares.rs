//! Distributed linear least-squares regression (paper §4.1).
//!
//! The trainable is a single matrix `W ∈ R^{n×n}`; the model predicts
//! `ŷ(x, y) = p(x)ᵀ W p(y)` where `p: [-1,1] → R^n` is the Legendre
//! polynomial basis of degree `n−1`.
//!
//! * **Homogeneous test** — one global target `f(x,y) = p(x)ᵀ W_r p(y)`
//!   with `rank(W_r) = r`; the 10 000 data points are partitioned
//!   uniformly among clients (client losses differ only through their
//!   shards). Paper: n=20, r=4, C ∈ {1,…,32}, s*=20, λ=1e-3.
//! * **Heterogeneous test** — per-client targets `f_c` (rank-1 each),
//!   all clients see *all* data (client drift comes purely from the
//!   conflicting targets). Paper: n=10, C=4, s*=100, λ=1e-3.
//!
//! Gradients are analytic. For the factored evaluation the code never
//! materializes `∇_W L`, mirroring the paper's client-cost argument:
//! with `A = P_x U`, `B = P_y V` (N×r skinny), residual
//! `res_i = a_iᵀ S b_i − f_i`,
//!
//! ```text
//! ∇_S L = Aᵀ diag(res) B / N                   (r×r)
//! ∇_U L = P_xᵀ (diag(res) B Sᵀ) / N            (n×r)
//! ∇_V L = P_yᵀ (diag(res) A S)  / N            (n×r)
//! ```
//!
//! which is `O(N n r)` — the `O(s*b(4nr+4r²))` row of Table 1.
//!
//! Performance structure (see DESIGN.md §Kernel layer): every client
//! owns a [`ClientScratch`] behind its own lock — the projection cache
//! `(A, B)`, the `A·S̃` product buffer, and a [`Workspace`] pool. The
//! factored gradients fuse the `diag(res)` scaling into the skinny
//! projection kernels ([`matmul_tn_scaled_into`]); the dense gradient
//! scales `P_y` into a pooled buffer and runs the packed `Aᵀ·B` kernel
//! (no per-call `P_y` clone either way), residuals are computed
//! exactly once per gradient, and
//! the coefficient-gradient path ([`FedProblem::grad_coeff_into`])
//! performs **zero heap allocations** in steady state — asserted by the
//! counting-allocator check in `benches/micro_hotpath.rs`.

use std::sync::Mutex;

use crate::lowrank::LowRank;
use crate::tensor::{
    gram, matmul, matmul_into, matmul_into_view, matmul_nt_into, matmul_tn_into_view,
    matmul_tn_scaled_into, MatMut, MatRef, Matrix, Workspace,
};
use crate::util::rng::Rng;

use super::{FedProblem, Grads, LrGrad, LrWant, LrWeight, ProblemSpec, Weights};

/// Evaluate the Legendre basis `[P_0(x), …, P_{n−1}(x)]`.
pub fn legendre_basis(x: f64, n: usize) -> Vec<f64> {
    let mut p = vec![0.0; n];
    if n == 0 {
        return p;
    }
    p[0] = 1.0;
    if n > 1 {
        p[1] = x;
    }
    for k in 1..n.saturating_sub(1) {
        // (k+1) P_{k+1} = (2k+1) x P_k − k P_{k−1}
        p[k + 1] = ((2 * k + 1) as f64 * x * p[k] - k as f64 * p[k - 1]) / (k + 1) as f64;
    }
    p
}

/// One client's data shard: basis-evaluated inputs and targets.
#[derive(Debug, Clone)]
struct Shard {
    /// `P_x ∈ R^{N×n}` — rows are `p(x_i)`.
    px: Matrix,
    /// `P_y ∈ R^{N×n}` — rows are `p(y_i)`.
    py: Matrix,
    /// Targets `f_i`.
    f: Vec<f64>,
}

impl Shard {
    fn len(&self) -> usize {
        self.f.len()
    }

    /// Residuals `p(x_i)ᵀ W p(y_i) − f_i` for dense `W` (eval-only
    /// path; the gradient path fuses this computation instead).
    fn residuals_dense(&self, w: &Matrix) -> Vec<f64> {
        // T = P_x W (N×n), res_i = ⟨T_i, P_y_i⟩ − f_i.
        let t = matmul(&self.px, w);
        let n = w.cols();
        (0..self.len())
            .map(|i| {
                let ti = t.row(i);
                let pyi = self.py.row(i);
                let mut acc = 0.0;
                for j in 0..n {
                    acc += ti[j] * pyi[j];
                }
                acc - self.f[i]
            })
            .collect()
    }

    fn loss_dense(&self, w: &Matrix) -> f64 {
        let res = self.residuals_dense(w);
        res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64)
    }

    /// Factored-path intermediates `A = P_x U`, `B = P_y V`, residuals
    /// (eval-only path).
    fn factored_parts(&self, fac: &LowRank) -> (Matrix, Matrix, Vec<f64>) {
        let a = matmul(&self.px, &fac.u); // N×r
        let b = matmul(&self.py, &fac.v); // N×r
        let asb = matmul(&a, &fac.s); // N×r: rows a_iᵀ S
        let r = fac.rank();
        let res: Vec<f64> = (0..self.len())
            .map(|i| {
                let ai = asb.row(i);
                let bi = b.row(i);
                let mut acc = 0.0;
                for j in 0..r {
                    acc += ai[j] * bi[j];
                }
                acc - self.f[i]
            })
            .collect();
        (a, b, res)
    }

    fn loss_factored(&self, fac: &LowRank) -> f64 {
        let (_, _, res) = self.factored_parts(fac);
        res.iter().map(|r| r * r).sum::<f64>() / (2.0 * self.len() as f64)
    }
}

/// One client's cached basis projections `(A, B) = (P_x U, P_y V)`.
#[derive(Debug)]
struct ProjCache {
    /// Content fingerprint of the bases the projections were built from.
    key: u64,
    a: Matrix,
    b: Matrix,
}

/// Per-client reusable numeric state: the projection cache plus every
/// scratch buffer the gradient paths need. One lock *per client* (not
/// one shared map) so the thread-pool executor's clients never contend:
/// a client's gradient work only ever touches its own slot.
#[derive(Debug)]
struct ClientScratch {
    /// Cached `(A, B)` keyed by a basis fingerprint; rebuilt in place
    /// (no reallocation) when the bases change at equal rank.
    proj: Option<ProjCache>,
    /// `A·S̃` product, flat `N×r̃` (resized only when the rank changes).
    asb: Vec<f64>,
    /// Buffer pool for the dense/factored gradient paths.
    ws: Workspace,
}

impl ClientScratch {
    fn new() -> ClientScratch {
        ClientScratch { proj: None, asb: Vec::new(), ws: Workspace::new() }
    }
}

fn fresh_scratch(num_clients: usize) -> Vec<Mutex<ClientScratch>> {
    (0..num_clients).map(|_| Mutex::new(ClientScratch::new())).collect()
}

/// The federated least-squares problem.
#[derive(Debug)]
pub struct LeastSquares {
    n: usize,
    shards: Vec<Shard>,
    /// Known global minimizer (homogeneous case), for Fig 4's error plot.
    w_star: Option<Matrix>,
    /// Per-client scratch: projection cache `(A, B) = (P_x U, P_y V)`
    /// and gradient buffers.
    ///
    /// During the client inner loop (eq. 7/8) the bases are frozen and
    /// only `S̃` changes, so the `O(N·n·r)` projections are reusable
    /// across all `s*` iterations — this is precisely what a real FeDLRT
    /// client implementation would precompute after basis broadcast.
    /// Guarded by a cheap content fingerprint of the bases so stale
    /// entries can never be served.
    scratch: Vec<Mutex<ClientScratch>>,
}

impl Clone for LeastSquares {
    fn clone(&self) -> LeastSquares {
        LeastSquares {
            n: self.n,
            shards: self.shards.clone(),
            w_star: self.w_star.clone(),
            scratch: fresh_scratch(self.shards.len()),
        }
    }
}

impl LeastSquares {
    /// Homogeneous test (§4.1): shared rank-`r` target, uniform shards.
    pub fn homogeneous(
        n: usize,
        target_rank: usize,
        num_points: usize,
        num_clients: usize,
        rng: &mut Rng,
    ) -> LeastSquares {
        // Random rank-r target W_r = Û Ŝ V̂ᵀ, entries O(1).
        let w_r = LowRank::random_init(n, n, target_rank, rng).to_dense();
        // Sample points, evaluate basis + target, shard uniformly.
        let per = num_points / num_clients;
        let mut shards = Vec::with_capacity(num_clients);
        for _ in 0..num_clients {
            let (px, py) = sample_basis(n, per, rng);
            let f = targets(&px, &py, &w_r);
            shards.push(Shard { px, py, f });
        }
        let scratch = fresh_scratch(shards.len());
        LeastSquares { n, shards, w_star: Some(w_r), scratch }
    }

    /// Heterogeneous test (§4.1 / Fig 1): per-client rank-1 targets
    /// `f_c` **and** per-client input samples.
    ///
    /// Reproduction note: the paper's text samples one input set shared
    /// by all clients, but with a shared design the local quadratic
    /// losses have *identical Hessians*, in which case FedAvg's
    /// client-drift bias provably cancels (the average of the affine
    /// local GD maps has the global minimizer as its fixed point) and no
    /// plateau appears. The FedLin paper [27], which Fig 1 is "inspired
    /// by", uses per-client data; we do the same so the drift effect the
    /// figure demonstrates actually exists. See DESIGN.md
    /// §Substitutions.
    pub fn heterogeneous(
        n: usize,
        num_points: usize,
        num_clients: usize,
        rng: &mut Rng,
    ) -> LeastSquares {
        let per = num_points / num_clients;
        let mut shards = Vec::with_capacity(num_clients);
        for _ in 0..num_clients {
            let (px, py) = sample_basis(n, per, rng);
            let w_c = LowRank::random_init(n, n, 1, rng).to_dense();
            let f = targets(&px, &py, &w_c);
            shards.push(Shard { px, py, f });
        }
        let w_star = solve_global_minimizer(n, &shards);
        let scratch = fresh_scratch(shards.len());
        LeastSquares { n, shards, w_star: Some(w_star), scratch }
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Content fingerprint of a basis pair (order-sensitive FNV-1a over
    /// the raw bits + dims). Cost O(nr) — negligible next to the O(Nnr)
    /// projection it guards.
    fn basis_fingerprint(u: &Matrix, v: &Matrix) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        mix(u.rows() as u64);
        mix(u.cols() as u64);
        for &x in u.data() {
            mix(x.to_bits());
        }
        for &x in v.data() {
            mix(x.to_bits());
        }
        h
    }

    /// Dense gradient `∇_W = P_xᵀ diag(res) P_y / N` — residuals
    /// computed exactly once (fused with the loss), and the scaled
    /// `diag(res/N)·P_y` lands in a pooled workspace buffer (no `P_y`
    /// clone, allocation-free once warm) so the dominant `n×N×n`
    /// projection runs through the packed `Aᵀ·B` kernel at full speed.
    fn grad_dense(&self, c: usize, w: &Matrix) -> (f64, Matrix) {
        let sh = &self.shards[c];
        let mut slot = self.scratch[c].lock().expect("client scratch poisoned");
        let ws = &mut slot.ws;
        let n_rows = sh.len();
        let n = w.cols();
        let n_inv = 1.0 / n_rows as f64;
        // T = P_x W in workspace scratch; res_i = ⟨T_i, P_y_i⟩ − f_i.
        let mut t = ws.take(n_rows * n);
        matmul_into_view(sh.px.view(), w.view(), MatMut::new(&mut t, n_rows, n, n), 0.0);
        let mut res = ws.take(n_rows);
        let mut loss = 0.0;
        for i in 0..n_rows {
            let ti = &t[i * n..(i + 1) * n];
            let pyi = sh.py.row(i);
            let mut acc = 0.0;
            for j in 0..n {
                acc += ti[j] * pyi[j];
            }
            let rv = acc - sh.f[i];
            res[i] = rv;
            loss += rv * rv;
        }
        // scaled = diag(res/N)·P_y, reusing T's slot-mate in the pool.
        let mut scaled = ws.take(n_rows * n);
        for i in 0..n_rows {
            let w_i = res[i] * n_inv;
            let src = sh.py.row(i);
            let dst = &mut scaled[i * n..(i + 1) * n];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = w_i * s;
            }
        }
        let mut g = Matrix::zeros(n, n);
        matmul_tn_into_view(
            sh.px.view(),
            MatRef::new(&scaled, n_rows, n, n),
            g.view_mut(),
            0.0,
        );
        ws.give(t);
        ws.give(res);
        ws.give(scaled);
        (loss / (2.0 * n_rows as f64), g)
    }

    /// `(loss, G_U, G_V, G_S)` — never materializes `∇_W`; all
    /// intermediates live in the client's workspace.
    fn grad_factors(&self, c: usize, fac: &LowRank) -> (f64, Matrix, Matrix, Matrix) {
        let sh = &self.shards[c];
        let mut slot = self.scratch[c].lock().expect("client scratch poisoned");
        let ws = &mut slot.ws;
        let n_rows = sh.len();
        let r = fac.rank();
        let n = self.n;
        let n_inv = 1.0 / n_rows as f64;
        let mut a = ws.take_mat(n_rows, r);
        matmul_into(&sh.px, &fac.u, &mut a, 0.0);
        let mut b = ws.take_mat(n_rows, r);
        matmul_into(&sh.py, &fac.v, &mut b, 0.0);
        let mut asb = ws.take_mat(n_rows, r);
        matmul_into(&a, &fac.s, &mut asb, 0.0);
        let mut res = ws.take(n_rows);
        let mut loss = 0.0;
        for i in 0..n_rows {
            let ai = asb.row(i);
            let bi = b.row(i);
            let mut pred = 0.0;
            for j in 0..r {
                pred += ai[j] * bi[j];
            }
            let rv = pred - sh.f[i];
            res[i] = rv;
            loss += rv * rv;
        }
        // G_S = Aᵀ diag(res) B / N.
        let mut g_s = Matrix::zeros(r, r);
        matmul_tn_scaled_into(&a, &b, &res, n_inv, &mut g_s, 0.0);
        // G_U = P_xᵀ diag(res) B Sᵀ / N = (P_xᵀ diag(res/N) B) · Sᵀ.
        let mut m_u = ws.take_mat(n, r);
        matmul_tn_scaled_into(&sh.px, &b, &res, n_inv, &mut m_u, 0.0);
        let mut g_u = Matrix::zeros(n, r);
        matmul_nt_into(&m_u, &fac.s, &mut g_u, 0.0);
        // G_V = P_yᵀ diag(res) A S / N = (P_yᵀ diag(res/N) A) · S.
        let mut m_v = ws.take_mat(n, r);
        matmul_tn_scaled_into(&sh.py, &a, &res, n_inv, &mut m_v, 0.0);
        let mut g_v = Matrix::zeros(n, r);
        matmul_into(&m_v, &fac.s, &mut g_v, 0.0);
        ws.give_mat(a);
        ws.give_mat(b);
        ws.give_mat(asb);
        ws.give_mat(m_u);
        ws.give_mat(m_v);
        ws.give(res);
        (loss / (2.0 * n_rows as f64), g_u, g_v, g_s)
    }

    /// Coefficient gradient written into `out` — the zero-allocation
    /// client-inner-loop path. The `O(N·n·r)` projections `A = P_x U`,
    /// `B = P_y V` are computed once per basis broadcast (rebuilt in
    /// place at equal rank) and reused across the s* local iterations;
    /// `A·S̃` lands in the flat per-client scratch; `G_S` accumulates
    /// directly into `out`.
    fn grad_coeff_cached_into(&self, c: usize, fac: &LowRank, out: &mut Matrix) -> f64 {
        let key = Self::basis_fingerprint(&fac.u, &fac.v);
        let sh = &self.shards[c];
        let r = fac.rank();
        let n_rows = sh.len();
        assert_eq!(out.shape(), (r, r), "coefficient-gradient buffer shape");
        let mut slot = self.scratch[c].lock().expect("client scratch poisoned");
        let scr = &mut *slot;
        let stale = match &scr.proj {
            Some(p) => p.key != key,
            None => true,
        };
        if stale {
            let reusable = matches!(
                &scr.proj,
                Some(p) if p.a.shape() == (n_rows, r) && p.b.shape() == (n_rows, r)
            );
            if reusable {
                // Same shapes: rebuild the projections in place — the
                // once-per-round steady-state path stays allocation-free.
                let p = scr.proj.as_mut().expect("reusable cache entry");
                matmul_into(&sh.px, &fac.u, &mut p.a, 0.0);
                matmul_into(&sh.py, &fac.v, &mut p.b, 0.0);
                p.key = key;
            } else {
                scr.proj = Some(ProjCache {
                    key,
                    a: matmul(&sh.px, &fac.u),
                    b: matmul(&sh.py, &fac.v),
                });
            }
        }
        if scr.asb.len() != n_rows * r {
            // fedlint: allow(d4) — cold path: first call / rank change
            scr.asb.resize(n_rows * r, 0.0);
        }
        let proj = scr.proj.as_ref().expect("cache entry just written");
        let (a, b) = (&proj.a, &proj.b);
        // asb = A·S̃ into the flat scratch (small-product path: no
        // packing buffers, no allocation).
        matmul_into_view(a.view(), fac.s.view(), MatMut::new(&mut scr.asb, n_rows, r, r), 0.0);
        // res_i = a_iᵀ S b_i − f_i; G_S accumulates directly into out.
        out.data_mut().fill(0.0);
        let n_inv = 1.0 / n_rows as f64;
        let mut loss = 0.0;
        for i in 0..n_rows {
            let ai = &scr.asb[i * r..(i + 1) * r];
            let bi = b.row(i);
            let mut pred = 0.0;
            for j in 0..r {
                pred += ai[j] * bi[j];
            }
            let res = pred - sh.f[i];
            loss += res * res;
            let w = res * n_inv;
            let arow = a.row(i);
            for p in 0..r {
                let ap = arow[p] * w;
                if ap != 0.0 {
                    let row = out.row_mut(p);
                    for (gq, &bq) in row.iter_mut().zip(bi) {
                        *gq += ap * bq;
                    }
                }
            }
        }
        loss / (2.0 * n_rows as f64)
    }

    /// The known global minimizer, if any.
    pub fn w_star(&self) -> Option<&Matrix> {
        self.w_star.as_ref()
    }

    /// Global loss value at the minimizer (`> 0` for heterogeneous
    /// targets). Suboptimality gaps should be measured against this.
    pub fn min_loss(&self) -> f64 {
        match &self.w_star {
            Some(w) => {
                let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w.clone())] };
                self.global_loss(&wts)
            }
            None => 0.0,
        }
    }

    /// Smoothness constant `L` of the global loss: the largest eigenvalue
    /// of the quadratic form's Hessian, `L = λ_max((1/C)Σ_c H_c)` with
    /// `H = (1/N) Σ_i (p_x p_yᵀ)(p_x p_yᵀ)ᵀ`-style Kronecker structure.
    /// We report the tractable upper bound `max_i ‖p(x_i)‖²‖p(y_i)‖²`
    /// averaged over shards — used to pick safe step sizes in tests.
    pub fn smoothness_bound(&self) -> f64 {
        let mut worst = 0.0f64;
        for sh in &self.shards {
            let mut acc = 0.0f64;
            for i in 0..sh.len() {
                let nx: f64 = sh.px.row(i).iter().map(|v| v * v).sum();
                let ny: f64 = sh.py.row(i).iter().map(|v| v * v).sum();
                acc += nx * ny;
            }
            worst = worst.max(acc / sh.len() as f64);
        }
        worst
    }
}

/// Exact global minimizer of the averaged quadratic loss via the normal
/// equations in `vec(W)` space: `(Σ_c A_cᵀA_c / N_c) w = Σ_c A_cᵀ f_c / N_c`
/// with design rows `a_i = p(y_i) ⊗ p(x_i)` (row-major vec), solved by
/// SVD pseudo-inverse.
fn solve_global_minimizer(n: usize, shards: &[Shard]) -> Matrix {
    let d = n * n;
    let mut m = Matrix::zeros(d, d);
    let mut rhs = vec![0.0; d];
    for sh in shards {
        let scale = 1.0 / sh.len() as f64;
        // Design matrix A ∈ R^{N×n²}: a_{i,(j,k)} = px[i,j]·py[i,k].
        let mut a = Matrix::zeros(sh.len(), d);
        for i in 0..sh.len() {
            let pxi = sh.px.row(i);
            let pyi = sh.py.row(i);
            let row = a.row_mut(i);
            for j in 0..n {
                for k in 0..n {
                    row[j * n + k] = pxi[j] * pyi[k];
                }
            }
        }
        // AᵀA via the symmetry-exploiting gram kernel.
        let ata = gram(&a);
        m.axpy(scale, &ata);
        let atf = {
            let mut v = vec![0.0; d];
            for i in 0..sh.len() {
                let row = a.row(i);
                let f = sh.f[i];
                for (vj, &aj) in v.iter_mut().zip(row) {
                    *vj += aj * f;
                }
            }
            v
        };
        for (r, x) in rhs.iter_mut().zip(&atf) {
            *r += scale * x;
        }
    }
    let w_vec = crate::linalg::svd::pinv_solve(&m, &rhs, 1e-10);
    Matrix::from_vec(n, n, w_vec)
}

/// Orthonormalized Legendre features `p̃_k(x) = √(2k+1)·P_k(x)`, which
/// satisfy `E_{x∼U[-1,1]}[p̃ p̃ᵀ] = I`. The normalization makes the
/// least-squares Hessian ≈ identity — without it the design has
/// condition number `O(n²)` per factor and gradient descent at the
/// paper's step sizes could not reach the reported accuracies.
pub fn legendre_features(x: f64, n: usize) -> Vec<f64> {
    let mut p = legendre_basis(x, n);
    for (k, v) in p.iter_mut().enumerate() {
        *v *= ((2 * k + 1) as f64).sqrt();
    }
    p
}

fn sample_basis(n: usize, num: usize, rng: &mut Rng) -> (Matrix, Matrix) {
    let mut px = Matrix::zeros(num, n);
    let mut py = Matrix::zeros(num, n);
    for i in 0..num {
        let x = rng.uniform_in(-1.0, 1.0);
        let y = rng.uniform_in(-1.0, 1.0);
        px.row_mut(i).copy_from_slice(&legendre_features(x, n));
        py.row_mut(i).copy_from_slice(&legendre_features(y, n));
    }
    (px, py)
}

fn targets(px: &Matrix, py: &Matrix, w: &Matrix) -> Vec<f64> {
    let t = matmul(px, w);
    let n = w.cols();
    (0..px.rows())
        .map(|i| {
            let ti = t.row(i);
            let pyi = py.row(i);
            let mut acc = 0.0;
            for j in 0..n {
                acc += ti[j] * pyi[j];
            }
            acc
        })
        .collect()
}

impl FedProblem for LeastSquares {
    fn spec(&self) -> ProblemSpec {
        ProblemSpec { dense_shapes: vec![], lr_shapes: vec![(self.n, self.n)] }
    }

    fn num_clients(&self) -> usize {
        self.shards.len()
    }

    fn grad(&self, c: usize, w: &Weights, want: LrWant, _step: u64) -> Grads {
        let (loss, lr_grad) = match (want, &w.lr[0]) {
            (LrWant::Dense, LrWeight::Dense(wm)) => {
                let (loss, g) = self.grad_dense(c, wm);
                (loss, LrGrad::Dense(g))
            }
            (LrWant::Factors, LrWeight::Factored(f)) => {
                let (loss, g_u, g_v, g_s) = self.grad_factors(c, f);
                (loss, LrGrad::Factors { g_u, g_v, g_s })
            }
            (LrWant::Coeff, LrWeight::Factored(f)) => {
                let mut g_s = Matrix::zeros(f.rank(), f.rank());
                let loss = self.grad_coeff_cached_into(c, f, &mut g_s);
                (loss, LrGrad::Coeff(g_s))
            }
            _ => panic!("weight representation does not match requested gradient"),
        };
        Grads { loss, dense: vec![], lr: vec![lr_grad] }
    }

    fn grad_coeff_into(
        &self,
        c: usize,
        w: &Weights,
        _step: u64,
        out: &mut [Matrix],
        _out_dense: &mut [Matrix],
    ) -> Option<f64> {
        if !w.dense.is_empty() || w.lr.len() != 1 || out.len() != 1 {
            return None;
        }
        let f = match &w.lr[0] {
            LrWeight::Factored(f) => f,
            LrWeight::Dense(_) => return None,
        };
        if out[0].shape() != (f.rank(), f.rank()) {
            return None;
        }
        Some(self.grad_coeff_cached_into(c, f, &mut out[0]))
    }

    fn global_loss(&self, w: &Weights) -> f64 {
        let c = self.num_clients() as f64;
        match &w.lr[0] {
            LrWeight::Dense(wm) => self.shards.iter().map(|s| s.loss_dense(wm)).sum::<f64>() / c,
            LrWeight::Factored(f) => {
                self.shards.iter().map(|s| s.loss_factored(f)).sum::<f64>() / c
            }
        }
    }

    fn distance_to_optimum(&self, w: &Weights) -> Option<f64> {
        self.w_star.as_ref().map(|ws| w.lr[0].to_dense().sub(ws).fro_norm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_nt, matmul_tn};
    use crate::util::prop;

    #[test]
    fn legendre_known_values() {
        // P0=1, P1=x, P2=(3x²−1)/2, P3=(5x³−3x)/2 at x=0.5
        let p = legendre_basis(0.5, 4);
        assert!((p[0] - 1.0).abs() < 1e-12);
        assert!((p[1] - 0.5).abs() < 1e-12);
        assert!((p[2] - (3.0 * 0.25 - 1.0) / 2.0).abs() < 1e-12);
        assert!((p[3] - (5.0 * 0.125 - 3.0 * 0.5) / 2.0).abs() < 1e-12);
        // Endpoint identity P_k(1) = 1.
        let p1 = legendre_basis(1.0, 8);
        for v in p1 {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_loss_at_target() {
        let mut rng = Rng::new(601);
        let prob = LeastSquares::homogeneous(8, 3, 200, 2, &mut rng);
        let w_star = prob.w_star.clone().unwrap();
        let w = Weights { dense: vec![], lr: vec![LrWeight::Dense(w_star)] };
        assert!(prob.global_loss(&w) < 1e-20);
        assert_eq!(prob.distance_to_optimum(&w).unwrap(), 0.0);
    }

    #[test]
    fn dense_gradient_matches_finite_difference() {
        let mut rng = Rng::new(603);
        let prob = LeastSquares::homogeneous(5, 2, 50, 1, &mut rng);
        let w0 = Matrix::randn(5, 5, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w0.clone())] };
        let g = prob.grad(0, &wts, LrWant::Dense, 0);
        let eps = 1e-6;
        for &(i, j) in &[(0usize, 0usize), (2, 3), (4, 4), (1, 0)] {
            let mut wp = w0.clone();
            wp[(i, j)] += eps;
            let mut wm = w0.clone();
            wm[(i, j)] -= eps;
            let lp = prob
                .global_loss(&Weights { dense: vec![], lr: vec![LrWeight::Dense(wp)] });
            let lm = prob
                .global_loss(&Weights { dense: vec![], lr: vec![LrWeight::Dense(wm)] });
            let fd = (lp - lm) / (2.0 * eps);
            let an = g.lr[0].dense()[(i, j)];
            assert!((fd - an).abs() < 1e-5, "({i},{j}): fd {fd} vs {an}");
        }
    }

    #[test]
    fn factor_gradients_match_dense_projection() {
        // G_U = G V Sᵀ, G_V = Gᵀ U S, G_S = Uᵀ G V where G = ∇_W L.
        let mut rng = Rng::new(607);
        let prob = LeastSquares::homogeneous(7, 2, 80, 1, &mut rng);
        let fac = LowRank::random_init(7, 7, 3, &mut rng);
        let wts_f = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
        let g_fac = prob.grad(0, &wts_f, LrWant::Factors, 0);
        let wts_d = Weights { dense: vec![], lr: vec![LrWeight::Dense(fac.to_dense())] };
        let g_dense = prob.grad(0, &wts_d, LrWant::Dense, 0);
        let g = g_dense.lr[0].dense();
        let (g_u, g_v, g_s) = match &g_fac.lr[0] {
            LrGrad::Factors { g_u, g_v, g_s } => (g_u, g_v, g_s),
            _ => unreachable!(),
        };
        let want_gu = matmul_nt(&matmul(g, &fac.v), &fac.s);
        let want_gv = matmul(&matmul_tn(g, &fac.u), &fac.s);
        let want_gs = matmul(&matmul_tn(&fac.u, g), &fac.v);
        assert!(g_u.sub(&want_gu).max_abs() < 1e-10);
        assert!(g_v.sub(&want_gv).max_abs() < 1e-10);
        assert!(g_s.sub(&want_gs).max_abs() < 1e-10);
        // Coeff-only path agrees with the full factor path.
        let g_c = prob.grad(0, &wts_f, LrWant::Coeff, 0);
        assert!(g_c.lr[0].coeff().sub(g_s).max_abs() < 1e-12);
        assert!((g_c.loss - g_fac.loss).abs() < 1e-12);
    }

    #[test]
    fn grad_coeff_into_matches_grad_and_does_not_allocate_state() {
        // The fast path must write exactly what grad(LrWant::Coeff)
        // returns, and repeated calls with frozen bases must reuse the
        // projection cache (same result bitwise).
        let mut rng = Rng::new(609);
        let prob = LeastSquares::homogeneous(8, 2, 120, 2, &mut rng);
        let fac = LowRank::random_init(8, 8, 3, &mut rng);
        let w = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
        let via_grad = prob.grad(1, &w, LrWant::Coeff, 0);
        let mut out = vec![Matrix::zeros(3, 3)];
        let loss = prob.grad_coeff_into(1, &w, 0, &mut out, &mut []).expect("fast path");
        assert_eq!(loss.to_bits(), via_grad.loss.to_bits());
        assert_eq!(&out[0], via_grad.lr[0].coeff());
        // Second call (warm cache) is bitwise identical.
        let loss2 = prob.grad_coeff_into(1, &w, 0, &mut out, &mut []).expect("fast path");
        assert_eq!(loss2.to_bits(), loss.to_bits());
        // Mismatched buffer shape falls back gracefully.
        let mut bad = vec![Matrix::zeros(2, 2)];
        assert!(prob.grad_coeff_into(1, &w, 0, &mut bad, &mut []).is_none());
    }

    #[test]
    fn heterogeneous_clients_disagree() {
        let mut rng = Rng::new(611);
        let prob = LeastSquares::heterogeneous(6, 100, 3, &mut rng);
        let w = Matrix::randn(6, 6, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w)] };
        let g0 = prob.grad(0, &wts, LrWant::Dense, 0);
        let g1 = prob.grad(1, &wts, LrWant::Dense, 0);
        // Different targets ⇒ different gradients.
        assert!(g0.lr[0].dense().sub(g1.lr[0].dense()).max_abs() > 1e-3);
    }

    #[test]
    fn global_loss_is_mean_of_clients() {
        let mut rng = Rng::new(613);
        let prob = LeastSquares::homogeneous(6, 2, 90, 3, &mut rng);
        let w = Matrix::randn(6, 6, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w)] };
        let mean: f64 = (0..3)
            .map(|c| prob.grad(c, &wts, LrWant::Dense, 0).loss)
            .sum::<f64>()
            / 3.0;
        assert!((prob.global_loss(&wts) - mean).abs() < 1e-12);
    }

    #[test]
    fn prop_factored_loss_equals_dense_loss() {
        prop::check(
            "lsq: loss(USVᵀ) == loss(dense)",
            6,
            |rng, size| {
                let n = 3 + size.min(6);
                let prob = LeastSquares::homogeneous(n, 2, 40, 2, rng);
                let fac = LowRank::random_init(n, n, 2, rng);
                (prob, fac)
            },
            |(prob, fac)| {
                let lf = prob.global_loss(&Weights {
                    dense: vec![],
                    lr: vec![LrWeight::Factored(fac.clone())],
                });
                let ld = prob.global_loss(&Weights {
                    dense: vec![],
                    lr: vec![LrWeight::Dense(fac.to_dense())],
                });
                prop::close(lf, ld, 1e-9)
            },
        );
    }
}
