//! Synthetic quadratic problem with known smoothness constant.
//!
//! `L_c(W) = (α_c/2) ‖W − B_c‖_F²` — the simplest L-smooth federated
//! problem (`L = max_c α_c`, global minimizer `W* = Σ α_c B_c / Σ α_c`).
//! Used by the theorem-validation tests (drift bound Thm 1, descent
//! Thm 2, convergence Thm 3) where the analysis constants must be
//! checkable exactly, and by failure-injection tests that need a problem
//! whose every quantity is analytic.

use crate::tensor::Matrix;
use crate::util::rng::Rng;

use super::{FedProblem, Grads, LrGrad, LrWant, LrWeight, ProblemSpec, Weights};

/// Federated quadratic: client `c` pulls toward `B_c` with weight `α_c`.
#[derive(Debug, Clone)]
pub struct Quadratic {
    pub targets: Vec<Matrix>,
    pub alphas: Vec<f64>,
    pub n: usize,
}

impl Quadratic {
    /// Random targets of the given rank; `alphas` all 1 (L = 1).
    pub fn random(n: usize, target_rank: usize, num_clients: usize, rng: &mut Rng) -> Quadratic {
        let targets = (0..num_clients)
            .map(|_| crate::lowrank::LowRank::random_init(n, n, target_rank, rng).to_dense())
            .collect();
        Quadratic { targets, alphas: vec![1.0; num_clients], n }
    }

    /// Smoothness constant of every `L_c` (and of `L`).
    pub fn smoothness(&self) -> f64 {
        self.alphas.iter().cloned().fold(0.0f64, f64::max)
    }

    /// Global minimizer `W* = Σ α_c B_c / Σ α_c`.
    pub fn minimizer(&self) -> Matrix {
        let mut acc = Matrix::zeros(self.n, self.n);
        let total: f64 = self.alphas.iter().sum();
        for (b, &a) in self.targets.iter().zip(&self.alphas) {
            acc.axpy(a / total, b);
        }
        acc
    }

    fn local_loss(&self, c: usize, w: &Matrix) -> f64 {
        let d = w.sub(&self.targets[c]);
        0.5 * self.alphas[c] * d.fro_norm().powi(2)
    }

    /// `∇_W L_c = α_c (W − B_c)`.
    fn local_grad(&self, c: usize, w: &Matrix) -> Matrix {
        w.sub(&self.targets[c]).scale(self.alphas[c])
    }
}

impl FedProblem for Quadratic {
    fn spec(&self) -> ProblemSpec {
        ProblemSpec { dense_shapes: vec![], lr_shapes: vec![(self.n, self.n)] }
    }

    fn num_clients(&self) -> usize {
        self.targets.len()
    }

    fn grad(&self, c: usize, w: &Weights, want: LrWant, _step: u64) -> Grads {
        let (loss, lr_grad) = match (want, &w.lr[0]) {
            (LrWant::Dense, LrWeight::Dense(wm)) => {
                (self.local_loss(c, wm), LrGrad::Dense(self.local_grad(c, wm)))
            }
            (LrWant::Factors, LrWeight::Factored(f)) => {
                let dense = f.to_dense();
                let g = self.local_grad(c, &dense);
                let g_u = crate::tensor::matmul_nt(&crate::tensor::matmul(&g, &f.v), &f.s);
                let g_v = crate::tensor::matmul(&crate::tensor::matmul_tn(&g, &f.u), &f.s);
                let g_s = crate::lowrank::factorization::project_coeff_grad(&f.u, &g, &f.v);
                (self.local_loss(c, &dense), LrGrad::Factors { g_u, g_v, g_s })
            }
            (LrWant::Coeff, LrWeight::Factored(f)) => {
                let dense = f.to_dense();
                let g = self.local_grad(c, &dense);
                let g_s = crate::lowrank::factorization::project_coeff_grad(&f.u, &g, &f.v);
                (self.local_loss(c, &dense), LrGrad::Coeff(g_s))
            }
            _ => panic!("weight representation does not match requested gradient"),
        };
        Grads { loss, dense: vec![], lr: vec![lr_grad] }
    }

    fn global_loss(&self, w: &Weights) -> f64 {
        let dense = w.lr[0].to_dense();
        (0..self.num_clients()).map(|c| self.local_loss(c, &dense)).sum::<f64>()
            / self.num_clients() as f64
    }

    fn distance_to_optimum(&self, w: &Weights) -> Option<f64> {
        Some(w.lr[0].to_dense().sub(&self.minimizer()).fro_norm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizer_has_zero_gradient() {
        let mut rng = Rng::new(701);
        let prob = Quadratic::random(6, 2, 3, &mut rng);
        let w_star = prob.minimizer();
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w_star)] };
        let mut g_sum = Matrix::zeros(6, 6);
        for c in 0..3 {
            g_sum.axpy(1.0 / 3.0, prob.grad(c, &wts, LrWant::Dense, 0).lr[0].dense());
        }
        assert!(g_sum.max_abs() < 1e-12);
    }

    #[test]
    fn gradient_is_linear() {
        let mut rng = Rng::new(703);
        let prob = Quadratic::random(5, 2, 2, &mut rng);
        let w = Matrix::randn(5, 5, &mut rng);
        let wts = Weights { dense: vec![], lr: vec![LrWeight::Dense(w.clone())] };
        let g = prob.grad(0, &wts, LrWant::Dense, 0);
        let want = w.sub(&prob.targets[0]);
        assert!(g.lr[0].dense().sub(&want).max_abs() < 1e-12);
    }
}
