//! Training metrics: per-round records and JSON-lines export.
//!
//! Every experiment emits a [`RunRecord`] — the raw material for the
//! figure/table reproductions in `benches/` (see DESIGN.md §Experiment
//! index); drivers append them as JSON lines under `results/`.

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// Metrics of one aggregation round.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    pub round: usize,
    /// Global loss after the round (full-data).
    pub global_loss: f64,
    /// Rank(s) of the low-rank layer(s) after truncation.
    pub ranks: Vec<usize>,
    /// Floats on the wire this round (total).
    pub comm_floats: u64,
    /// Floats attributable to the low-rank (compressed) layers only —
    /// the paper's footnote-6 accounting for the comm-saving figures.
    pub comm_floats_lr: u64,
    /// Measured serialized bytes server→clients this round (wire codec).
    pub bytes_down: u64,
    /// Measured serialized bytes clients→server this round (wire codec).
    pub bytes_up: u64,
    /// Per-client floats (download + own upload share among the
    /// round's participants).
    pub comm_floats_per_client: f64,
    /// Distance to the known optimum, if the problem has one.
    pub dist_to_opt: Option<f64>,
    /// Validation metric (accuracy), if the problem has one.
    pub eval_metric: Option<f64>,
    /// Wall-clock seconds of the whole round (scheduling + client work +
    /// server linear algebra + evaluation).
    pub wall_s: f64,
    /// Wall-clock seconds of client-side work under the configured
    /// [`crate::engine::ClientExecutor`] (parallel time).
    pub client_wall_s: f64,
    /// Serial-equivalent client work: Σ over tasks of per-task
    /// wall-clock, folded in task order. Per-task times come from the
    /// executor call's single monotonic clock — the same samples the
    /// per-client latency histogram is built from — so for the serial
    /// executor this equals the histogram's `sum_s` (bitwise for
    /// single-executor-call rounds, whose task order is client-id
    /// order; see `tests/obsv_telemetry.rs`).
    /// `client_serial_s / client_wall_s` is the round's simulation
    /// speedup (1.0 under the serial executor). Under a thread pool
    /// this is an estimate with mild upward bias from scheduling
    /// overlap; the executor caps workers at the core count to keep
    /// that bias small.
    pub client_serial_s: f64,
    /// Seconds attributed to each taxonomy phase by the coordinator's
    /// span recorder (all zeros when telemetry is disabled). Only
    /// top-level spans accumulate, so `phase_s.sum() ≤ wall_s` up to
    /// timer resolution.
    pub phase_s: crate::obsv::PhaseSeconds,
    /// Per-client latency distribution for the round (exact
    /// p50/p95/max + straggler id); `latency.n == 0` when telemetry is
    /// disabled.
    pub latency: crate::obsv::LatencySummary,
    /// Staleness distribution of the updates consumed by this
    /// aggregation (async schedules); `staleness.n == 0` for sync runs.
    pub staleness: crate::obsv::StalenessSummary,
    /// Virtual-clock timestamp of this aggregation (seconds on the
    /// event simulator's clock); `0.0` for sync runs, whose notion of
    /// time is the round index.
    pub virtual_s: f64,
    /// Transport-fault counters of the round (drops, checksum rejects,
    /// retransmitted bytes, quorum skip). All-default — and omitted
    /// from the JSON line — on a clean transport.
    pub fault: crate::comm::FaultRoundStats,
}

/// A full training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Algorithm label, e.g. "fedlrt_full_vc".
    pub algorithm: String,
    /// Experiment label, e.g. "fig4_homogeneous".
    pub experiment: String,
    pub num_clients: usize,
    pub seed: u64,
    pub rounds: Vec<RoundMetrics>,
    /// Free-form config echo.
    pub config: Json,
}

impl RunRecord {
    pub fn new(algorithm: &str, experiment: &str, num_clients: usize, seed: u64) -> RunRecord {
        RunRecord {
            algorithm: algorithm.to_string(),
            experiment: experiment.to_string(),
            num_clients,
            seed,
            rounds: Vec::new(),
            config: Json::obj(),
        }
    }

    pub fn final_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.global_loss).unwrap_or(f64::NAN)
    }

    pub fn final_rank(&self) -> usize {
        self.rounds.last().and_then(|r| r.ranks.first().copied()).unwrap_or(0)
    }

    pub fn final_metric(&self) -> Option<f64> {
        self.rounds.last().and_then(|r| r.eval_metric)
    }

    /// Cumulative communication volume (floats).
    pub fn total_comm_floats(&self) -> u64 {
        self.rounds.iter().map(|r| r.comm_floats).sum()
    }

    /// Cumulative compressed-layer communication volume (floats).
    pub fn total_comm_floats_lr(&self) -> u64 {
        self.rounds.iter().map(|r| r.comm_floats_lr).sum()
    }

    /// Cumulative measured downlink bytes (wire codec).
    pub fn total_bytes_down(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes_down).sum()
    }

    /// Cumulative measured uplink bytes (wire codec).
    pub fn total_bytes_up(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes_up).sum()
    }

    /// Cumulative measured bytes on the wire, both directions.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes_down() + self.total_bytes_up()
    }

    /// Total client-side wall-clock under the configured executor.
    pub fn total_client_wall_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.client_wall_s).sum()
    }

    /// Total serial-equivalent client work across the run.
    pub fn total_client_serial_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.client_serial_s).sum()
    }

    /// Realized client-execution speedup over the run:
    /// `Σ client_serial_s / Σ client_wall_s`. Both sums come from the
    /// same per-executor-call monotonic clock (see
    /// [`crate::engine::ExecTiming`]), so for the serial executor the
    /// ratio is ≤1.0 and approaches it from below (the wall-clock adds
    /// only loop bookkeeping); a thread pool overlapping client work
    /// drives it above 1.
    pub fn client_speedup(&self) -> f64 {
        let wall = self.total_client_wall_s();
        if wall > 0.0 {
            self.total_client_serial_s() / wall
        } else {
            1.0
        }
    }

    /// First round at which the loss drops below `eps` (rounds-to-ε).
    pub fn rounds_to_loss(&self, eps: f64) -> Option<usize> {
        self.rounds.iter().find(|r| r.global_loss <= eps).map(|r| r.round)
    }

    /// Rounds skipped below the upload quorum (0 on a clean transport).
    pub fn skipped_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.fault.skipped).count()
    }

    /// Cumulative upload messages lost or abandoned across the run.
    pub fn total_msgs_dropped(&self) -> u64 {
        self.rounds.iter().map(|r| r.fault.msgs_dropped).sum()
    }

    /// Cumulative retransmitted/duplicate bytes across the run.
    pub fn total_bytes_retx(&self) -> u64 {
        self.rounds.iter().map(|r| r.fault.bytes_retx).sum()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("algorithm", self.algorithm.as_str())
            .set("experiment", self.experiment.as_str())
            .set("num_clients", self.num_clients)
            .set("seed", self.seed)
            .set("config", self.config.clone());
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| {
                let mut ro = Json::obj();
                ro.set("round", r.round)
                    .set("loss", r.global_loss)
                    .set("ranks", Json::Arr(r.ranks.iter().map(|&x| Json::Num(x as f64)).collect()))
                    .set("comm_floats", r.comm_floats)
                    .set("comm_floats_lr", r.comm_floats_lr)
                    .set("bytes_down", r.bytes_down)
                    .set("bytes_up", r.bytes_up)
                    .set("comm_floats_per_client", r.comm_floats_per_client)
                    .set("wall_s", r.wall_s)
                    .set("client_wall_s", r.client_wall_s)
                    .set("client_serial_s", r.client_serial_s)
                    .set("phase_s", r.phase_s.to_json());
                if r.latency.n > 0 {
                    ro.set("lat_p50_s", r.latency.p50_s)
                        .set("lat_p95_s", r.latency.p95_s)
                        .set("lat_max_s", r.latency.max_s)
                        .set("straggler", r.latency.straggler);
                }
                if r.staleness.n > 0 {
                    ro.set("stale_n", r.staleness.n)
                        .set("stale_p50", r.staleness.p50)
                        .set("stale_p95", r.staleness.p95)
                        .set("stale_max", r.staleness.max)
                        .set("stale_mean", r.staleness.mean);
                }
                if r.virtual_s > 0.0 {
                    ro.set("virtual_s", r.virtual_s);
                }
                if r.fault.any() {
                    ro.set("skipped", r.fault.skipped)
                        .set("msgs_dropped", r.fault.msgs_dropped)
                        .set("msgs_corrupt", r.fault.msgs_corrupt)
                        .set("bytes_retx", r.fault.bytes_retx);
                }
                if let Some(d) = r.dist_to_opt {
                    ro.set("dist_to_opt", d);
                }
                if let Some(m) = r.eval_metric {
                    ro.set("eval", m);
                }
                ro
            })
            .collect();
        o.set("rounds", Json::Arr(rounds));
        o
    }

    /// Append as one JSON line to `path` (creates parents).
    ///
    /// The line (newline included) is built in memory and written with
    /// a **single** `write_all`: parallel bench processes share
    /// `results/*.jsonl` files in append mode, and on POSIX an
    /// O_APPEND write of one buffer lands atomically, whereas the old
    /// `writeln!` issued separate payload/newline writes that could
    /// interleave partial lines.
    pub fn append_jsonl(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut line = self.to_json().to_string_compact();
        line.push('\n');
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(line.as_bytes())
    }
}

/// Median trajectory across seeds: per-round medians of loss / rank /
/// distance (the paper reports medians over 20 random initializations).
///
/// Runs may have unequal lengths (early stopping, rounds-to-ε
/// sweeps). **Minimum-quorum rule:** round `t` is reported while at
/// least `⌈N/2⌉` of the `N` runs reach it, and each reported median is
/// taken over exactly the runs that reach `t` — nothing past
/// `min(len)` is silently dropped, but a tail backed by fewer than
/// half the seeds is cut rather than reported as a "median" of a
/// shrinking minority. The distance median is `Some` only when every
/// run reaching `t` carries `dist_to_opt` there.
pub fn median_trajectory(runs: &[RunRecord]) -> Vec<(usize, f64, f64, Option<f64>)> {
    if runs.is_empty() {
        return vec![];
    }
    let quorum = (runs.len() + 1) / 2;
    let max_rounds = runs.iter().map(|r| r.rounds.len()).max().unwrap_or(0);
    (0..max_rounds)
        .map_while(|t| {
            let reached: Vec<&RoundMetrics> =
                runs.iter().filter_map(|r| r.rounds.get(t)).collect();
            if reached.len() < quorum {
                return None;
            }
            let losses: Vec<f64> = reached.iter().map(|r| r.global_loss).collect();
            let ranks: Vec<f64> = reached
                .iter()
                .map(|r| r.ranks.first().copied().unwrap_or(0) as f64)
                .collect();
            let dists: Vec<f64> = reached.iter().filter_map(|r| r.dist_to_opt).collect();
            let d = if dists.len() == reached.len() {
                Some(crate::util::median(&dists))
            } else {
                None
            };
            Some((t, crate::util::median(&losses), crate::util::median(&ranks), d))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(losses: &[f64]) -> RunRecord {
        let mut r = RunRecord::new("a", "e", 2, 0);
        for (i, &l) in losses.iter().enumerate() {
            r.rounds.push(RoundMetrics {
                round: i,
                global_loss: l,
                ranks: vec![4],
                comm_floats: 100,
                comm_floats_lr: 60,
                bytes_down: 160,
                bytes_up: 240,
                comm_floats_per_client: 50.0,
                dist_to_opt: Some(l.sqrt()),
                eval_metric: None,
                wall_s: 0.0,
                client_wall_s: 0.0,
                client_serial_s: 0.0,
                phase_s: crate::obsv::PhaseSeconds::default(),
                latency: crate::obsv::LatencySummary::default(),
                staleness: crate::obsv::StalenessSummary::default(),
                virtual_s: 0.0,
                fault: crate::comm::FaultRoundStats::default(),
            });
        }
        r
    }

    #[test]
    fn accessors() {
        let r = record(&[1.0, 0.1, 0.01]);
        assert_eq!(r.final_loss(), 0.01);
        assert_eq!(r.final_rank(), 4);
        assert_eq!(r.total_comm_floats(), 300);
        assert_eq!(r.total_bytes_down(), 3 * 160);
        assert_eq!(r.total_bytes_up(), 3 * 240);
        assert_eq!(r.total_bytes(), 3 * 400);
        assert_eq!(r.rounds_to_loss(0.5), Some(1));
        assert_eq!(r.rounds_to_loss(1e-9), None);
    }

    #[test]
    fn json_roundtrip() {
        let r = record(&[1.0, 0.5]);
        let j = r.to_json();
        let parsed = crate::util::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("algorithm").unwrap().as_str().unwrap(), "a");
        assert_eq!(parsed.get("rounds").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn median_trajectory_medians() {
        let runs = vec![record(&[1.0, 0.4]), record(&[3.0, 0.6]), record(&[2.0, 0.5])];
        let traj = median_trajectory(&runs);
        assert_eq!(traj.len(), 2);
        assert_eq!(traj[0].1, 2.0);
        assert_eq!(traj[1].1, 0.5);
    }

    #[test]
    fn median_trajectory_unequal_lengths_quorum() {
        // N=3 → quorum 2: rounds backed by ≥2 runs are reported (over
        // exactly the runs that reach them), the 1-run tail is cut.
        let runs = vec![
            record(&[1.0, 0.4]),
            record(&[3.0, 0.6, 0.3, 0.1]),
            record(&[2.0, 0.5, 0.2]),
        ];
        let traj = median_trajectory(&runs);
        assert_eq!(traj.len(), 3, "round 2 reaches quorum, round 3 does not");
        assert_eq!(traj[0].1, 2.0);
        assert_eq!(traj[1].1, 0.5);
        // Round 2: median over the two surviving runs.
        assert_eq!(traj[2].1, 0.25);
        assert_eq!(traj[2].0, 2);
        // dist_to_opt present on every surviving run → still Some.
        assert!(traj[2].3.is_some());
        // A single run reports its full length (quorum 1).
        let solo = vec![record(&[1.0, 0.5, 0.25])];
        assert_eq!(median_trajectory(&solo).len(), 3);
    }

    #[test]
    fn round_json_has_full_phase_schema_and_latency_gating() {
        let mut r = record(&[1.0]);
        r.rounds[0].phase_s.add(crate::obsv::Phase::Eval, 0.125);
        let j = r.to_json();
        let rounds = j.get("rounds").unwrap().as_arr().unwrap();
        let ps = rounds[0].get("phase_s").unwrap();
        for p in crate::obsv::ALL_PHASES {
            assert!(ps.get(p.label()).is_some(), "phase_s missing key {}", p.label());
        }
        assert_eq!(ps.get("eval").unwrap().as_f64().unwrap(), 0.125);
        // latency.n == 0 → no latency keys emitted.
        assert!(rounds[0].get("lat_p50_s").is_none());
        r.rounds[0].latency = crate::obsv::LatencySummary {
            n: 4,
            p50_s: 0.5,
            p95_s: 0.75,
            max_s: 0.75,
            sum_s: 2.0,
            straggler: 3,
        };
        let j = r.to_json();
        let rounds = j.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds[0].get("lat_p95_s").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(rounds[0].get("straggler").unwrap().as_usize().unwrap(), 3);
        // staleness.n == 0 and virtual_s == 0 → async keys stay out of
        // sync-run lines.
        assert!(rounds[0].get("stale_p50").is_none());
        assert!(rounds[0].get("virtual_s").is_none());
        r.rounds[0].staleness =
            crate::obsv::StalenessSummary { n: 5, p50: 1.0, p95: 3.0, max: 4.0, mean: 1.6 };
        r.rounds[0].virtual_s = 12.5;
        let j = r.to_json();
        let rounds = j.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds[0].get("stale_p95").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(rounds[0].get("stale_n").unwrap().as_usize().unwrap(), 5);
        assert_eq!(rounds[0].get("virtual_s").unwrap().as_f64().unwrap(), 12.5);
    }

    #[test]
    fn fault_counters_gated_out_of_clean_rounds() {
        let mut r = record(&[1.0]);
        let j = r.to_json();
        let rounds = j.get("rounds").unwrap().as_arr().unwrap();
        assert!(rounds[0].get("msgs_dropped").is_none(), "clean rounds stay legacy");
        assert!(rounds[0].get("skipped").is_none());
        assert_eq!(r.skipped_rounds(), 0);
        r.rounds[0].fault = crate::comm::FaultRoundStats {
            skipped: true,
            msgs_dropped: 3,
            msgs_corrupt: 1,
            bytes_retx: 160,
        };
        let j = r.to_json();
        let rounds = j.get("rounds").unwrap().as_arr().unwrap();
        assert!(rounds[0].get("skipped").is_some());
        assert_eq!(rounds[0].get("msgs_dropped").unwrap().as_usize().unwrap(), 3);
        assert_eq!(rounds[0].get("msgs_corrupt").unwrap().as_usize().unwrap(), 1);
        assert_eq!(rounds[0].get("bytes_retx").unwrap().as_usize().unwrap(), 160);
        assert_eq!(r.skipped_rounds(), 1);
        assert_eq!(r.total_msgs_dropped(), 3);
        assert_eq!(r.total_bytes_retx(), 160);
    }
}
