//! Borrowed matrix views: `MatRef` / `MatMut`.
//!
//! A view is `(data, rows, cols, row_stride)` over a row-major `f64`
//! buffer — the unit-column-stride subset of BLAS's general stride
//! model, which is all the FeDLRT algebra needs (sub-blocks, row
//! panels, and column ranges of `U/S/V`; transposes are handled by the
//! kernels' `Aᵀ·B` / `A·Bᵀ` entry points without materializing copies).
//! Views are what let the kernel layer slice factors and workspaces
//! without per-call `Matrix` allocations: every `_into` op in
//! [`super::ops`] bottoms out on these types.
//!
//! `MatMut::split_rows` is the primitive behind the deterministic
//! parallel GEMM: it partitions the output into disjoint row panels
//! that scoped threads can write concurrently without aliasing (see
//! DESIGN.md §Kernel layer).

use super::matrix::Matrix;

/// Immutable view of a row-major matrix block.
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a> {
    data: &'a [f64],
    rows: usize,
    cols: usize,
    row_stride: usize,
}

impl<'a> MatRef<'a> {
    /// View over `data` with explicit shape and row stride.
    pub fn new(data: &'a [f64], rows: usize, cols: usize, row_stride: usize) -> MatRef<'a> {
        assert!(cols == 0 || row_stride >= cols, "row_stride {row_stride} < cols {cols}");
        if rows > 0 && cols > 0 {
            assert!(
                (rows - 1) * row_stride + cols <= data.len(),
                "view {rows}x{cols} (stride {row_stride}) exceeds buffer of {}",
                data.len()
            );
        }
        MatRef { data, rows, cols, row_stride }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    /// Row `i` as a slice (length `cols`).
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Entry `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.row_stride + j]
    }

    /// Sub-block view starting at `(r0, c0)` — no copy.
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> MatRef<'a> {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols, "block out of range");
        let off = r0 * self.row_stride + c0;
        let end = if rows == 0 || cols == 0 {
            off
        } else {
            off + (rows - 1) * self.row_stride + cols
        };
        MatRef { data: &self.data[off..end], rows, cols, row_stride: self.row_stride }
    }

    /// Leading `cols` columns — no copy.
    pub fn first_cols(&self, cols: usize) -> MatRef<'a> {
        self.block(0, 0, self.rows, cols)
    }
}

/// Mutable view of a row-major matrix block.
#[derive(Debug)]
pub struct MatMut<'a> {
    data: &'a mut [f64],
    rows: usize,
    cols: usize,
    row_stride: usize,
}

impl<'a> MatMut<'a> {
    /// Mutable view over `data` with explicit shape and row stride.
    pub fn new(data: &'a mut [f64], rows: usize, cols: usize, row_stride: usize) -> MatMut<'a> {
        assert!(cols == 0 || row_stride >= cols, "row_stride {row_stride} < cols {cols}");
        if rows > 0 && cols > 0 {
            assert!(
                (rows - 1) * row_stride + cols <= data.len(),
                "view {rows}x{cols} (stride {row_stride}) exceeds buffer of {}",
                data.len()
            );
        }
        MatMut { data, rows, cols, row_stride }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    /// Rows are contiguous (no inter-row gap) — required for
    /// `split_rows`-based parallel dispatch.
    #[inline]
    pub fn is_contiguous(&self) -> bool {
        self.row_stride == self.cols
    }

    /// Row `i` as a mutable slice (length `cols`).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Row `i` as an immutable slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Entry `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.row_stride + j]
    }

    /// Set entry `(i, j)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.row_stride + j] = v;
    }

    /// Downgrade to an immutable view (reborrow).
    pub fn as_ref(&self) -> MatRef<'_> {
        MatRef { data: &*self.data, rows: self.rows, cols: self.cols, row_stride: self.row_stride }
    }

    /// Fill every entry with `v` (row-aware: skips inter-row gaps).
    pub fn fill(&mut self, v: f64) {
        if self.is_contiguous() {
            let len = self.rows * self.cols;
            self.data[..len].fill(v);
        } else {
            for i in 0..self.rows {
                self.row_mut(i).fill(v);
            }
        }
    }

    /// Scale every entry by `alpha`.
    pub fn scale(&mut self, alpha: f64) {
        for i in 0..self.rows {
            for x in self.row_mut(i) {
                *x *= alpha;
            }
        }
    }

    /// Split into two disjoint row panels `[0, r)` and `[r, rows)`.
    ///
    /// Both halves keep the original row stride; `r` must be interior
    /// (`0 < r < rows`) so neither side is empty. This is the aliasing
    /// boundary the parallel GEMM hands to scoped threads.
    pub fn split_rows(self, r: usize) -> (MatMut<'a>, MatMut<'a>) {
        assert!(r > 0 && r < self.rows, "split_rows: r={r} not interior to {}", self.rows);
        let (head, tail) = self.data.split_at_mut(r * self.row_stride);
        (
            MatMut { data: head, rows: r, cols: self.cols, row_stride: self.row_stride },
            MatMut { data: tail, rows: self.rows - r, cols: self.cols, row_stride: self.row_stride },
        )
    }
}

impl Matrix {
    /// Borrow the whole matrix as an immutable view.
    #[inline]
    pub fn view(&self) -> MatRef<'_> {
        MatRef::new(self.data(), self.rows(), self.cols(), self.cols())
    }

    /// Borrow the whole matrix as a mutable view.
    #[inline]
    pub fn view_mut(&mut self) -> MatMut<'_> {
        let (rows, cols) = self.shape();
        MatMut::new(self.data_mut(), rows, cols, cols)
    }

    /// Borrow a sub-block as a view — the no-copy counterpart of
    /// [`Matrix::sub_block`].
    pub fn sub_view(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> MatRef<'_> {
        self.view().block(r0, c0, rows, cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numbered(rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |i, j| (i * 100 + j) as f64)
    }

    #[test]
    fn whole_matrix_view_roundtrip() {
        let m = numbered(4, 5);
        let v = m.view();
        assert_eq!(v.shape(), (4, 5));
        assert_eq!(v.get(2, 3), 203.0);
        assert_eq!(v.row(1), m.row(1));
    }

    #[test]
    fn block_views_share_storage() {
        let m = numbered(6, 7);
        let b = m.sub_view(2, 3, 3, 2);
        assert_eq!(b.shape(), (3, 2));
        assert_eq!(b.row_stride(), 7);
        assert_eq!(b.get(0, 0), 203.0);
        assert_eq!(b.get(2, 1), 404.0);
        // Nested block of a block.
        let bb = b.block(1, 1, 2, 1);
        assert_eq!(bb.get(0, 0), 304.0);
        assert_eq!(bb.get(1, 0), 404.0);
    }

    #[test]
    fn mut_view_writes_through() {
        let mut m = numbered(3, 3);
        {
            let mut v = m.view_mut();
            v.set(1, 2, -1.0);
            v.row_mut(0)[0] = -2.0;
        }
        assert_eq!(m[(1, 2)], -1.0);
        assert_eq!(m[(0, 0)], -2.0);
    }

    #[test]
    fn split_rows_partitions() {
        let mut m = numbered(5, 4);
        {
            let v = m.view_mut();
            let (mut a, mut b) = v.split_rows(2);
            assert_eq!(a.shape(), (2, 4));
            assert_eq!(b.shape(), (3, 4));
            a.fill(1.0);
            b.fill(2.0);
        }
        assert_eq!(m[(1, 3)], 1.0);
        assert_eq!(m[(2, 0)], 2.0);
        assert_eq!(m[(4, 3)], 2.0);
    }

    #[test]
    fn fill_and_scale_respect_strides() {
        let mut m = numbered(4, 4);
        {
            let mut blk = MatMut::new(m.data_mut(), 2, 2, 4); // top-left 2x2
            blk.fill(9.0);
            blk.scale(2.0);
        }
        assert_eq!(m[(0, 0)], 18.0);
        assert_eq!(m[(1, 1)], 18.0);
        // outside the block untouched
        assert_eq!(m[(0, 2)], 2.0);
        assert_eq!(m[(2, 0)], 200.0);
    }

    #[test]
    #[should_panic(expected = "exceeds buffer")]
    fn oversized_view_panics() {
        let m = numbered(2, 2);
        let _ = MatRef::new(m.data(), 3, 2, 2);
    }
}
