//! Dense row-major matrix over `f64`.
//!
//! This is the numeric workhorse for the coordinator side of FeDLRT:
//! bases `U, V ∈ R^{n×r}`, coefficients `S ∈ R^{r×r}`, gradients, and the
//! dense baselines (FedAvg/FedLin) all live in this type. The environment
//! carries no ndarray/BLAS, so we provide our own blocked matmul
//! (see `ops.rs` for the optimized kernels) and the structural operations
//! the DLRA algebra needs: transpose, slicing, horizontal concatenation
//! (basis augmentation), and block embedding (Lemma 1 assembly).

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::util::rng::Rng;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "from_vec: shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// iid standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    /// Diagonal matrix from entries.
    pub fn diag(d: &[f64]) -> Matrix {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Extract column `j` (copied).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose (copied).
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.t_into(&mut out);
        out
    }

    /// Transpose into a preallocated `cols × rows` matrix (workspace
    /// reuse in the SVD working-matrix setup).
    pub fn t_into(&self, out: &mut Matrix) {
        assert_eq!(out.shape(), (self.cols, self.rows), "t_into: output shape");
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max-abs entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape(), "add: shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape(), "sub: shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self * alpha` (scalar).
    pub fn scale(&self, alpha: f64) -> Matrix {
        let data = self.data.iter().map(|a| a * alpha).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self += alpha * other` (the optimizer hot path).
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "axpy: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// In-place scale.
    pub fn scale_inplace(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Copy of the leading `rows × cols` sub-block.
    pub fn block(&self, rows: usize, cols: usize) -> Matrix {
        self.sub_block(0, 0, rows, cols)
    }

    /// Copy of an arbitrary sub-block starting at (r0, c0).
    pub fn sub_block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Matrix {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols, "sub_block out of range");
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i)
                .copy_from_slice(&self.data[(r0 + i) * self.cols + c0..(r0 + i) * self.cols + c0 + cols]);
        }
        out
    }

    /// Write `block` into `self` at offset (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(
            r0 + block.rows <= self.rows && c0 + block.cols <= self.cols,
            "set_block out of range"
        );
        for i in 0..block.rows {
            let dst = (r0 + i) * self.cols + c0;
            self.data[dst..dst + block.cols].copy_from_slice(block.row(i));
        }
    }

    /// Horizontal concatenation `[self | other]` (basis augmentation, eq 6).
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "hcat: row mismatch");
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        out.set_block(0, 0, self);
        out.set_block(0, self.cols, other);
        out
    }

    /// Embed into a larger zero matrix at the top-left (Lemma 1: S̃ = [[S,0],[0,0]]).
    pub fn embed(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "embed must grow");
        let mut out = Matrix::zeros(rows, cols);
        out.set_block(0, 0, self);
        out
    }

    /// Keep the first `cols` columns.
    pub fn first_cols(&self, cols: usize) -> Matrix {
        self.sub_block(0, 0, self.rows, cols)
    }

    /// Dot product treating both matrices as flat vectors (⟨A,B⟩_F).
    pub fn dot(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape(), "dot: shape mismatch");
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Convert to f32 (PJRT boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from f32 data (PJRT boundary).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    /// True if every entry is finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Consume the matrix and recover its backing buffer (workspace
    /// recycling — see [`super::workspace::Workspace`]).
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Copy every entry from `other` (shapes must match). Unlike
    /// `clone`, reuses this matrix's allocation.
    pub fn copy_from(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "copy_from: shape mismatch");
        self.data.copy_from_slice(&other.data);
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for i in 0..show_rows {
            write!(f, "  [")?;
            let show_cols = self.cols.min(8);
            for j in 0..show_cols {
                write!(f, "{:>10.4}", self[(i, j)])?;
                if j + 1 < show_cols {
                    write!(f, ", ")?;
                }
            }
            if self.cols > 8 {
                write!(f, ", …")?;
            }
            writeln!(f, "]")?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let m = Matrix::randn(37, 53, &mut rng);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t()[(10, 20)], m[(20, 10)]);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_fn(2, 2, |i, j| (i + j) as f64);
        let b = Matrix::eye(2);
        let c = a.add(&b).sub(&a);
        assert_eq!(c, b);
        assert_eq!(a.scale(2.0)[(1, 1)], 4.0);
        let mut d = a.clone();
        d.axpy(-1.0, &a);
        assert_eq!(d.fro_norm(), 0.0);
    }

    #[test]
    fn blocks_and_concat() {
        let a = Matrix::from_fn(4, 4, |i, j| (10 * i + j) as f64);
        let blk = a.sub_block(1, 2, 2, 2);
        assert_eq!(blk[(0, 0)], 12.0);
        assert_eq!(blk[(1, 1)], 23.0);
        let h = a.first_cols(2).hcat(&a.sub_block(0, 2, 4, 2));
        assert_eq!(h, a);
        let e = Matrix::eye(2).embed(4, 4);
        assert_eq!(e[(1, 1)], 1.0);
        assert_eq!(e[(3, 3)], 0.0);
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(8);
        let m = Matrix::randn(5, 7, &mut rng);
        let back = Matrix::from_f32(5, 7, &m.to_f32());
        assert!(m.sub(&back).max_abs() < 1e-6);
    }

    #[test]
    fn diag_and_eye() {
        let d = Matrix::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(d[(1, 1)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
        assert_eq!(Matrix::eye(3).fro_norm(), 3.0f64.sqrt());
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn add_shape_mismatch_panics() {
        let _ = Matrix::zeros(2, 2).add(&Matrix::zeros(2, 3));
    }
}
