//! Dense matrix substrate: the `Matrix` type and multiplication kernels.
//!
//! See DESIGN.md §System inventory (1). Everything the coordinator
//! computes — bases, coefficients, gradients, dense baselines — uses
//! these types; `linalg` builds QR/SVD on top.

pub mod matrix;
pub mod ops;

pub use matrix::Matrix;
pub use ops::{matmul, matmul_into, matmul_nt, matmul_tn, matvec, usv};
