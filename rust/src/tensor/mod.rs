//! Dense matrix substrate: the `Matrix` type, borrowed views, the
//! packed deterministic-parallel multiplication kernels, and the
//! reusable scratch arena.
//!
//! See DESIGN.md §System inventory (1) and §Kernel layer. Everything
//! the coordinator computes — bases, coefficients, gradients, dense
//! baselines — uses these types; `linalg` builds QR/SVD on top.

pub mod matrix;
pub mod ops;
pub mod view;
pub mod workspace;

pub use matrix::Matrix;
pub use ops::{
    gemm_into, gram, gram_into, kernel_threads, matmul, matmul_into, matmul_into_view,
    matmul_nt, matmul_nt_into, matmul_nt_into_view, matmul_reference, matmul_tn, matmul_tn_into,
    matmul_tn_into_view, matmul_tn_scaled_into, matvec, set_kernel_threads, usv, Op,
};
pub use view::{MatMut, MatRef};
pub use workspace::Workspace;
