//! Reusable scratch-buffer arena for the round hot path.
//!
//! FeDLRT's steady state repeats the same shapes every local iteration
//! and every round: projections `P_x U`, products `A·S̃`, Householder
//! reflector stacks, Jacobi working matrices, mean-gradient
//! accumulators. A [`Workspace`] keeps the backing `Vec<f64>` buffers
//! alive between uses so that, once warm, `take`/`give` cycles perform
//! **zero heap allocations** (asserted by the counting-allocator check
//! in `benches/micro_hotpath.rs`).
//!
//! Ownership rules (see DESIGN.md §Kernel layer):
//! * whoever calls `take`/`take_mat` must `give`/`give_mat` the buffer
//!   back on every exit path — a dropped buffer is not an error, just a
//!   re-allocation next round;
//! * round *state* (factors, records, returned gradients) is never
//!   workspace-backed — only transient scratch is;
//! * a workspace is single-owner: clients each own one (behind their
//!   per-client lock), the coordinator owns one for the server steps.
//!   Workspaces are never shared across threads.

use super::matrix::Matrix;

/// A pool of reusable `f64` buffers.
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f64>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { pool: Vec::new() }
    }

    /// Number of pooled (idle) buffers — diagnostics/tests.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Borrow a zero-filled buffer of exactly `len` elements.
    ///
    /// Prefers a pooled buffer whose capacity already covers `len`
    /// (steady state: no allocation); otherwise grows the largest
    /// pooled buffer or allocates fresh.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        let slot = self.pool.iter().position(|b| b.capacity() >= len);
        let mut buf = match slot {
            Some(i) => self.pool.swap_remove(i),
            None => match self.pool.pop() {
                Some(b) => b,
                None => Vec::new(),
            },
        };
        buf.clear();
        buf.resize(len, 0.0);
        // Observe-only: track outstanding workspace bytes across all
        // workspaces for the process high-water mark (obsv::counters).
        crate::obsv::counters::note_workspace_take(8 * len as u64);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, buf: Vec<f64>) {
        crate::obsv::counters::note_workspace_give(8 * buf.len() as u64);
        if buf.capacity() > 0 {
            self.pool.push(buf);
        }
    }

    /// Borrow a zero-filled `rows × cols` matrix backed by pooled
    /// storage.
    pub fn take_mat(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Return a [`take_mat`](Workspace::take_mat) matrix to the pool.
    pub fn give_mat(&mut self, m: Matrix) {
        self.give(m.into_vec());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_and_sized() {
        let mut ws = Workspace::new();
        let mut b = ws.take(8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&x| x == 0.0));
        b[3] = 5.0;
        ws.give(b);
        // Reused buffer is re-zeroed.
        let b2 = ws.take(8);
        assert!(b2.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn steady_state_reuses_capacity() {
        let mut ws = Workspace::new();
        let b = ws.take(100);
        let cap = b.capacity();
        let ptr = b.as_ptr() as usize;
        ws.give(b);
        // Same-size take must reuse the very same backing allocation.
        let b2 = ws.take(100);
        assert_eq!(b2.capacity(), cap);
        assert_eq!(b2.as_ptr() as usize, ptr);
        ws.give(b2);
        // A smaller take also fits in the pooled buffer.
        let b3 = ws.take(10);
        assert_eq!(b3.as_ptr() as usize, ptr);
    }

    #[test]
    fn take_mat_roundtrip() {
        let mut ws = Workspace::new();
        let mut m = ws.take_mat(3, 4);
        assert_eq!(m.shape(), (3, 4));
        m[(2, 3)] = 7.0;
        ws.give_mat(m);
        assert_eq!(ws.pooled(), 1);
        let m2 = ws.take_mat(4, 3);
        assert_eq!(m2.max_abs(), 0.0);
        assert_eq!(ws.pooled(), 0);
    }

    #[test]
    fn distinct_outstanding_buffers() {
        let mut ws = Workspace::new();
        let a = ws.take(16);
        let b = ws.take(16);
        assert_ne!(a.as_ptr(), b.as_ptr());
        ws.give(a);
        ws.give(b);
        assert_eq!(ws.pooled(), 2);
    }
}
