//! Matrix-multiplication kernels.
//!
//! All FeDLRT linear algebra funnels through these routines, so they are
//! the L3 hot path. We implement a cache-blocked, register-tiled matmul
//! (i-k-j loop order over a packed panel of B, which vectorizes well with
//! rustc's auto-vectorizer on a single core) plus the transposed variants
//! the low-rank algebra needs — `AᵀB` and `ABᵀ` are computed without
//! materializing the transpose.

use super::matrix::Matrix;

/// Loop blocking for the k dimension — fits comfortably in L1 with the
/// 4-wide j unrolling below.
const KC: usize = 256;
/// Row blocking for the i dimension.
const MC: usize = 64;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {} vs {}", a.cols(), b.rows());
    let (m, _k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    matmul_into(a, b, &mut c, 0.0);
    c
}

/// `C = beta·C + A·B`, writing into preallocated `c`.
///
/// The kernel iterates row-panels of A (MC) by depth-panels (KC); within
/// a panel, each A row broadcasts `a_ik` against B's row `k`, giving a
/// saxpy over contiguous memory in both B and C — the auto-vectorizable
/// inner loop.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
    let (m, kdim) = a.shape();
    let n = b.cols();
    assert_eq!(kdim, b.rows(), "matmul_into: inner dims");
    assert_eq!(c.shape(), (m, n), "matmul_into: output shape");

    if beta == 0.0 {
        c.data_mut().fill(0.0);
    } else if beta != 1.0 {
        c.scale_inplace(beta);
    }

    let a_data = a.data();
    let b_data = b.data();
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..kdim).step_by(KC) {
            let k1 = (k0 + KC).min(kdim);
            for i in i0..i1 {
                let a_row = &a_data[i * kdim..(i + 1) * kdim];
                let c_row = &mut c.data_mut()[i * n..(i + 1) * n];
                // Process four k per pass over c_row: quarters the number
                // of traversals of the store-bound C stream (B's rows are
                // L1/L2-resident inside a KC panel).
                let mut k = k0;
                while k + 4 <= k1 {
                    let a0 = a_row[k];
                    let a1 = a_row[k + 1];
                    let a2 = a_row[k + 2];
                    let a3 = a_row[k + 3];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        // Zero-padded rank columns (static-shape AOT
                        // padding) are skipped for free.
                        k += 4;
                        continue;
                    }
                    let b0 = &b_data[k * n..k * n + n];
                    let b1 = &b_data[(k + 1) * n..(k + 1) * n + n];
                    let b2 = &b_data[(k + 2) * n..(k + 2) * n + n];
                    let b3 = &b_data[(k + 3) * n..(k + 3) * n + n];
                    for j in 0..n {
                        c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    k += 4;
                }
                while k < k1 {
                    let aik = a_row[k];
                    if aik != 0.0 {
                        let b_row = &b_data[k * n..k * n + n];
                        for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                            *c_v += aik * b_v;
                        }
                    }
                    k += 1;
                }
            }
        }
    }
}

/// `C = Aᵀ · B` without materializing `Aᵀ`.
///
/// Used for the Galerkin projections `G_S = Ũᵀ G Ṽ` and `UᵀW`: A is tall
/// (n×r), so `AᵀB` iterates A rows (contiguous) and scatters into C rows
/// indexed by A's columns — still a contiguous saxpy over B's row.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_tn: inner dims {} vs {}", a.rows(), b.rows());
    let (kdim, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let a_data = a.data();
    let b_data = b.data();
    for k in 0..kdim {
        let a_row = &a_data[k * m..(k + 1) * m];
        let b_row = &b_data[k * n..(k + 1) * n];
        for (i, &aki) in a_row.iter().enumerate() {
            if aki == 0.0 {
                continue;
            }
            let c_row = &mut c.data_mut()[i * n..(i + 1) * n];
            for j in 0..n {
                c_row[j] += aki * b_row[j];
            }
        }
    }
    c
}

/// `C = A · Bᵀ` without materializing `Bᵀ`.
///
/// Inner product of row i of A with row j of B — both contiguous.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_nt: inner dims {} vs {}", a.cols(), b.cols());
    let (m, kdim) = a.shape();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    let a_data = a.data();
    let b_data = b.data();
    for i in 0..m {
        let a_row = &a_data[i * kdim..(i + 1) * kdim];
        let c_row = &mut c.data_mut()[i * n..(i + 1) * n];
        // Two B rows per pass: A's row is streamed once for both dot
        // products, and four accumulators hide FMA latency.
        let mut j = 0;
        while j + 2 <= n {
            let b0 = &b_data[j * kdim..(j + 1) * kdim];
            let b1 = &b_data[(j + 1) * kdim..(j + 2) * kdim];
            let (mut s00, mut s01, mut s10, mut s11) = (0.0, 0.0, 0.0, 0.0);
            let mut k = 0;
            while k + 2 <= kdim {
                s00 += a_row[k] * b0[k];
                s10 += a_row[k] * b1[k];
                s01 += a_row[k + 1] * b0[k + 1];
                s11 += a_row[k + 1] * b1[k + 1];
                k += 2;
            }
            if k < kdim {
                s00 += a_row[k] * b0[k];
                s10 += a_row[k] * b1[k];
            }
            c_row[j] = s00 + s01;
            c_row[j + 1] = s10 + s11;
            j += 2;
        }
        if j < n {
            let b_row = &b_data[j * kdim..(j + 1) * kdim];
            let mut acc = 0.0;
            for k in 0..kdim {
                acc += a_row[k] * b_row[k];
            }
            c_row[j] = acc;
        }
    }
    c
}

/// Reconstruct the full weight `W = U · S · Vᵀ` (ordering chosen so the
/// intermediate is the skinny `U·S ∈ R^{n×r}`).
pub fn usv(u: &Matrix, s: &Matrix, v: &Matrix) -> Matrix {
    let us = matmul(u, s);
    matmul_nt(&us, v)
}

/// `y = A·x` for a vector `x` (len = A.cols()).
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "matvec: dims");
    let (m, n) = a.shape();
    let mut y = vec![0.0; m];
    for i in 0..m {
        let row = a.row(i);
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * x[j];
        }
        y[i] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(17);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 70, 65), (130, 257, 31)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(c.sub(&want).max_abs() < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut rng = Rng::new(23);
        let a = Matrix::randn(40, 13, &mut rng);
        let b = Matrix::randn(40, 21, &mut rng);
        let tn = matmul_tn(&a, &b);
        assert!(tn.sub(&naive(&a.t(), &b)).max_abs() < 1e-10);

        let c = Matrix::randn(12, 40, &mut rng);
        let d = Matrix::randn(29, 40, &mut rng);
        let nt = matmul_nt(&c, &d);
        assert!(nt.sub(&naive(&c, &d.t())).max_abs() < 1e-10);
    }

    #[test]
    fn matmul_into_beta() {
        let mut rng = Rng::new(29);
        let a = Matrix::randn(8, 9, &mut rng);
        let b = Matrix::randn(9, 7, &mut rng);
        let mut c = Matrix::randn(8, 7, &mut rng);
        let c0 = c.clone();
        matmul_into(&a, &b, &mut c, 1.0);
        let want = c0.add(&naive(&a, &b));
        assert!(c.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn usv_reconstruction() {
        let mut rng = Rng::new(31);
        let u = Matrix::randn(20, 4, &mut rng);
        let s = Matrix::randn(4, 4, &mut rng);
        let v = Matrix::randn(20, 4, &mut rng);
        let w = usv(&u, &s, &v);
        let want = naive(&naive(&u, &s), &v.t());
        assert!(w.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(37);
        let a = Matrix::randn(11, 6, &mut rng);
        let x = rng.normal_vec(6);
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(6, 1, x);
        let want = matmul(&a, &xm);
        for i in 0..11 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_padding_skipped_correctly() {
        // Padded columns (zeros) must not change results.
        let mut rng = Rng::new(41);
        let a = Matrix::randn(10, 4, &mut rng);
        let a_pad = a.hcat(&Matrix::zeros(10, 4));
        let b = Matrix::randn(4, 6, &mut rng);
        let b_pad = {
            let mut bp = Matrix::zeros(8, 6);
            bp.set_block(0, 0, &b);
            bp
        };
        assert!(matmul(&a_pad, &b_pad).sub(&matmul(&a, &b)).max_abs() < 1e-12);
    }
}
