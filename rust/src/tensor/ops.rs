//! Matrix-multiplication kernels: packed, register-tiled, and
//! deterministically parallel.
//!
//! All FeDLRT linear algebra funnels through these routines, so they
//! are the L3 hot path. The design (see DESIGN.md §Kernel layer):
//!
//! * **Packed GEMM** — `C = βC + op(A)·op(B)` over [`MatRef`]/[`MatMut`]
//!   views. A panels are repacked into column-major `MR`-row
//!   micro-panels, B panels into row-major `NR`-column micro-panels,
//!   and a 4×8 register-tiled micro-kernel accumulates 32 unrolled
//!   products per depth step. Transposed operands (`AᵀB`, `ABᵀ`) are
//!   handled during packing — no transpose is ever materialized.
//! * **Deterministic parallelism** — large products split `C` into
//!   `MR`-aligned row panels across scoped threads. Each output element
//!   is reduced by exactly one thread in the same serial k-order
//!   (KC panels ascending, k ascending within a panel), so results are
//!   **bitwise identical** for every thread count — the same contract
//!   `engine_determinism.rs` enforces for client executors. Thread
//!   count comes from [`set_kernel_threads`] (config/CLI
//!   `--kernel-threads`) or the `FEDLRT_KERNEL_THREADS` env var.
//! * **Zero-padded-rank fast path** — a depth step whose `MR` packed
//!   A-values are all zero is skipped: zero-padded rank columns
//!   (static-shape AOT padding) cost nothing, and the B rows aligned
//!   with an all-zero A column are never read (so padding garbage —
//!   even NaN — cannot pollute the product). This is strictly stronger
//!   than the seed kernel's quad-aligned skip.
//! * **Small-product path** — below [`PACK_MIN_FLOPS`] the packing
//!   overhead outweighs the tiling win, so the seed-style direct loops
//!   run instead; they allocate nothing, which is what keeps the
//!   steady-state client gradient path allocation-free.
//!
//! The seed kernel is preserved as [`matmul_reference`]: it is the
//! correctness oracle for `rust/tests/kernel_equivalence.rs` and the
//! perf baseline `benches/micro_hotpath.rs` reports speedups against.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::matrix::Matrix;
use super::view::{MatMut, MatRef};

/// Micro-tile rows (A register footprint).
pub const MR: usize = 4;
/// Micro-tile columns (B register footprint); MR×NR = 32 f64
/// accumulators, within the 16 SIMD registers of x86-64 at 2–4 lanes.
pub const NR: usize = 8;
/// Row blocking: an MC×KC A panel (128 KiB) lives in L2.
const MC: usize = 64;
/// Depth blocking: a KC×NR B micro-panel (16 KiB) streams through L1.
const KC: usize = 256;
/// Column blocking: a KC×NC B panel (512 KiB) stays L2/L3-resident.
const NC: usize = 256;
/// Below this many flops (2mnk) the direct small-product loops win.
const PACK_MIN_FLOPS: f64 = 1.0e6;
/// Below this many flops threading overhead (spawn + duplicate B packs)
/// outweighs the speedup; stay serial.
const PAR_MIN_FLOPS: f64 = 8.0e6;
/// Safety cap on kernel worker threads.
const MAX_KERNEL_THREADS: usize = 64;

// ---------------------------------------------------------------------
// Kernel thread-count knob
// ---------------------------------------------------------------------

/// 0 = unresolved (first reader initializes from the environment).
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the worker-thread count for large matmuls (1 = serial). Wired to
/// `TrainConfig::kernel_threads` / CLI `--kernel-threads`. Results are
/// bitwise independent of this value; only wall-clock changes.
pub fn set_kernel_threads(n: usize) {
    KERNEL_THREADS.store(n.max(1).min(MAX_KERNEL_THREADS), Ordering::Relaxed);
}

/// Current kernel thread count. Defaults to `FEDLRT_KERNEL_THREADS`
/// (env) or 1 when unset.
pub fn kernel_threads() -> usize {
    match KERNEL_THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = std::env::var("FEDLRT_KERNEL_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or(1)
                .min(MAX_KERNEL_THREADS);
            KERNEL_THREADS.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

// ---------------------------------------------------------------------
// Operand forms
// ---------------------------------------------------------------------

/// A GEMM operand: a view used as-is (`N`) or logically transposed
/// (`T`). Transposition happens during packing — never materialized.
#[derive(Clone, Copy, Debug)]
pub enum Op<'a> {
    N(MatRef<'a>),
    T(MatRef<'a>),
}

impl<'a> Op<'a> {
    /// Rows of `op(X)`.
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Op::N(m) => m.rows(),
            Op::T(m) => m.cols(),
        }
    }

    /// Columns of `op(X)`.
    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Op::N(m) => m.cols(),
            Op::T(m) => m.rows(),
        }
    }

    /// Restrict to rows `[r0, r0+len)` of `op(X)` — a view, no copy.
    fn row_block(self, r0: usize, len: usize) -> Op<'a> {
        match self {
            Op::N(m) => Op::N(m.block(r0, 0, len, m.cols())),
            Op::T(m) => Op::T(m.block(0, r0, m.rows(), len)),
        }
    }
}

// ---------------------------------------------------------------------
// Public Matrix-level API
// ---------------------------------------------------------------------

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {} vs {}", a.cols(), b.rows());
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c, 0.0);
    c
}

/// `C = β·C + A·B`, writing into preallocated `c`.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
    gemm_into(Op::N(a.view()), Op::N(b.view()), c.view_mut(), beta, kernel_threads());
}

/// `C = Aᵀ · B` without materializing `Aᵀ` (Galerkin projections
/// `ŨᵀGṼ`, `UᵀW`: A is tall n×r).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_tn: inner dims {} vs {}", a.rows(), b.rows());
    let mut c = Matrix::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c, 0.0);
    c
}

/// `C = β·C + Aᵀ·B` into preallocated `c`.
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
    gemm_into(Op::T(a.view()), Op::N(b.view()), c.view_mut(), beta, kernel_threads());
}

/// `C = A · Bᵀ` without materializing `Bᵀ`.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_nt: inner dims {} vs {}", a.cols(), b.cols());
    let mut c = Matrix::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut c, 0.0);
    c
}

/// `C = β·C + A·Bᵀ` into preallocated `c`.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
    gemm_into(Op::N(a.view()), Op::T(b.view()), c.view_mut(), beta, kernel_threads());
}

/// View-level `C = β·C + A·B` (the workspace-buffer entry point).
pub fn matmul_into_view(a: MatRef<'_>, b: MatRef<'_>, c: MatMut<'_>, beta: f64) {
    gemm_into(Op::N(a), Op::N(b), c, beta, kernel_threads());
}

/// View-level `C = β·C + Aᵀ·B`.
pub fn matmul_tn_into_view(a: MatRef<'_>, b: MatRef<'_>, c: MatMut<'_>, beta: f64) {
    gemm_into(Op::T(a), Op::N(b), c, beta, kernel_threads());
}

/// View-level `C = β·C + A·Bᵀ`.
pub fn matmul_nt_into_view(a: MatRef<'_>, b: MatRef<'_>, c: MatMut<'_>, beta: f64) {
    gemm_into(Op::N(a), Op::T(b), c, beta, kernel_threads());
}

/// `C = β·C + α · Aᵀ · diag(s) · B` — the fused residual-weighted
/// projection of the least-squares gradients (`∇_W = Pxᵀ diag(res) Py / N`,
/// `G_S = Aᵀ diag(res) B / N`), computed without materializing the
/// scaled copy `diag(s)·B` that the seed code cloned per gradient call.
/// Runs serially (its consumers are per-client and already sharded by
/// the executor); zero-weight rows are skipped.
pub fn matmul_tn_scaled_into(
    a: &Matrix,
    b: &Matrix,
    row_scale: &[f64],
    alpha: f64,
    c: &mut Matrix,
    beta: f64,
) {
    let kdim = a.rows();
    assert_eq!(kdim, b.rows(), "matmul_tn_scaled_into: inner dims");
    assert_eq!(row_scale.len(), kdim, "matmul_tn_scaled_into: scale length");
    assert_eq!(c.shape(), (a.cols(), b.cols()), "matmul_tn_scaled_into: output shape");
    if beta == 0.0 {
        c.data_mut().fill(0.0);
    } else if beta != 1.0 {
        c.scale_inplace(beta);
    }
    for k in 0..kdim {
        let w = alpha * row_scale[k];
        if w == 0.0 {
            continue;
        }
        let a_row = a.row(k);
        let b_row = b.row(k);
        for (i, &aki) in a_row.iter().enumerate() {
            let f = aki * w;
            if f == 0.0 {
                continue;
            }
            let c_row = c.row_mut(i);
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += f * bv;
            }
        }
    }
}

/// `C = AᵀA` exploiting symmetry (half the multiplies of
/// `matmul_tn(a, a)`): upper triangle accumulated, then mirrored.
pub fn gram(a: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), a.cols());
    gram_into(a, &mut c);
    c
}

/// `C = AᵀA` into preallocated `c` (overwrites; the mirrored write
/// makes β-accumulation ill-defined, so none is offered).
pub fn gram_into(a: &Matrix, c: &mut Matrix) {
    let (m, n) = a.shape();
    assert_eq!(c.shape(), (n, n), "gram_into: output shape");
    c.data_mut().fill(0.0);
    for k in 0..m {
        let row = a.row(k);
        for p in 0..n {
            let ap = row[p];
            if ap == 0.0 {
                continue;
            }
            let c_row = &mut c.row_mut(p)[p..];
            for (cv, &av) in c_row.iter_mut().zip(&row[p..]) {
                *cv += ap * av;
            }
        }
    }
    for p in 0..n {
        for q in (p + 1)..n {
            c[(q, p)] = c[(p, q)];
        }
    }
}

/// Reconstruct the full weight `W = U · S · Vᵀ` (ordering chosen so the
/// intermediate is the skinny `U·S ∈ R^{n×r}`).
pub fn usv(u: &Matrix, s: &Matrix, v: &Matrix) -> Matrix {
    let us = matmul(u, s);
    matmul_nt(&us, v)
}

/// `y = A·x` for a vector `x` (len = A.cols()).
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "matvec: dims");
    let (m, n) = a.shape();
    let mut y = vec![0.0; m];
    for i in 0..m {
        let row = a.row(i);
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * x[j];
        }
        y[i] = acc;
    }
    y
}

// ---------------------------------------------------------------------
// GEMM core
// ---------------------------------------------------------------------

/// `C = β·C + op(A)·op(B)` with an explicit worker-thread count.
///
/// This is the root kernel entry point; the Matrix-level wrappers pass
/// [`kernel_threads`]. Results are bitwise identical for every
/// `threads` value (row-panel split, per-element serial k-order) —
/// property-tested in `rust/tests/kernel_equivalence.rs`.
pub fn gemm_into(a: Op<'_>, b: Op<'_>, mut c: MatMut<'_>, beta: f64, threads: usize) {
    let (m, kdim) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(kdim, b.rows(), "gemm: inner dims {} vs {}", kdim, b.rows());
    assert_eq!(c.shape(), (m, n), "gemm: output shape");
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    // Observe-only cost accounting (one relaxed atomic add per call;
    // see `obsv::counters`).
    crate::obsv::counters::note_gemm(m, kdim, n);
    let flops = 2.0 * m as f64 * kdim as f64 * n as f64;
    if m < MR || n < NR || flops < PACK_MIN_FLOPS {
        small_gemm(a, b, &mut c);
        return;
    }
    let t = threads.max(1).min(m / MR).min(MAX_KERNEL_THREADS);
    if t > 1 && c.is_contiguous() && flops >= PAR_MIN_FLOPS {
        gemm_threaded(a, b, c, t);
    } else {
        gemm_serial(a, b, c);
    }
}

/// Split C into MR-aligned row panels, one scoped thread per panel.
///
/// Determinism argument: panel starts are multiples of MR, so every
/// micro-panel covers the same global row group `[4j, 4j+4)` as in the
/// serial kernel — identical zero-skip decisions — and each output
/// element is accumulated by exactly one thread in the serial k-order.
fn gemm_threaded(a: Op<'_>, b: Op<'_>, c: MatMut<'_>, threads: usize) {
    let m = c.rows();
    let mut chunk = (m + threads - 1) / threads;
    chunk = ((chunk + MR - 1) / MR) * MR;
    std::thread::scope(|scope| {
        let mut rest = c;
        let mut i0 = 0usize;
        loop {
            let remaining = rest.rows();
            if remaining <= chunk {
                let a_blk = a.row_block(i0, remaining);
                scope.spawn(move || gemm_serial(a_blk, b, rest));
                break;
            }
            let (head, tail) = rest.split_rows(chunk);
            let a_blk = a.row_block(i0, chunk);
            scope.spawn(move || gemm_serial(a_blk, b, head));
            rest = tail;
            i0 += chunk;
        }
    });
}

/// Process-wide pool of packing-buffer pairs. A thread-local would die
/// with the scoped worker threads [`gemm_threaded`] spawns per call, so
/// workers check pairs in and out of this pool instead — steady state
/// performs zero pack-buffer allocations on both the serial and the
/// threaded path. Pool reuse cannot affect results: every packed slot
/// is rewritten (padding included) before the micro-kernel reads it.
/// The uncontended lock is two ~20 ns operations per ≥0.1 ms GEMM.
static PACK_POOL: Mutex<Vec<(Vec<f64>, Vec<f64>)>> = Mutex::new(Vec::new());

fn take_pack_bufs() -> (Vec<f64>, Vec<f64>) {
    PACK_POOL.lock().ok().and_then(|mut p| p.pop()).unwrap_or_default()
}

fn give_pack_bufs(bufs: (Vec<f64>, Vec<f64>)) {
    if let Ok(mut p) = PACK_POOL.lock() {
        p.push(bufs);
    }
}

/// The BLIS-style loop nest over one (possibly row-restricted) C block.
fn gemm_serial(a: Op<'_>, b: Op<'_>, mut c: MatMut<'_>) {
    let m = a.rows();
    let kdim = a.cols();
    let n = b.cols();
    debug_assert_eq!(c.shape(), (m, n));
    let (mut abuf, mut bbuf) = take_pack_bufs();
    let mut panels: u64 = 0;
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..kdim).step_by(KC) {
            let kc = KC.min(kdim - pc);
            let bneed = ((nc + NR - 1) / NR) * NR * kc;
            if bbuf.len() < bneed {
                bbuf.resize(bneed, 0.0);
            }
            pack_b(b, pc, kc, jc, nc, &mut bbuf[..bneed]);
            panels += ((nc + NR - 1) / NR) as u64;
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                let aneed = ((mc + MR - 1) / MR) * MR * kc;
                if abuf.len() < aneed {
                    abuf.resize(aneed, 0.0);
                }
                pack_a(a, ic, mc, pc, kc, &mut abuf[..aneed]);
                panels += ((mc + MR - 1) / MR) as u64;
                macro_kernel(&abuf[..aneed], &bbuf[..bneed], mc, nc, kc, &mut c, ic, jc);
            }
        }
    }
    give_pack_bufs((abuf, bbuf));
    // One atomic add per gemm_serial call, tallied locally above.
    crate::obsv::counters::note_panels_packed(panels);
}

/// Pack the `mc × kc` block of `op(A)` at `(ic, pc)` into MR-row
/// micro-panels: panel `pi` occupies `buf[pi·MR·kc ..]`, laid out
/// `k`-major (`buf[base + k·MR + mi]`), edge rows zero-padded.
fn pack_a(a: Op<'_>, ic: usize, mc: usize, pc: usize, kc: usize, buf: &mut [f64]) {
    let panels = (mc + MR - 1) / MR;
    match a {
        Op::N(m) => {
            for pi in 0..panels {
                let base = pi * MR * kc;
                for mi in 0..MR {
                    let i = pi * MR + mi;
                    if i < mc {
                        let row = &m.row(ic + i)[pc..pc + kc];
                        for (k, &v) in row.iter().enumerate() {
                            buf[base + k * MR + mi] = v;
                        }
                    } else {
                        for k in 0..kc {
                            buf[base + k * MR + mi] = 0.0;
                        }
                    }
                }
            }
        }
        Op::T(src) => {
            // op(A)[i][k] = src[k][i]: walk source rows (contiguous)
            // and scatter into the panels.
            for pi in 0..panels {
                let base = pi * MR * kc;
                for k in 0..kc {
                    let row = src.row(pc + k);
                    for mi in 0..MR {
                        let i = pi * MR + mi;
                        buf[base + k * MR + mi] = if i < mc { row[ic + i] } else { 0.0 };
                    }
                }
            }
        }
    }
}

/// Pack the `kc × nc` block of `op(B)` at `(pc, jc)` into NR-column
/// micro-panels: panel `pj` occupies `buf[pj·NR·kc ..]`, laid out
/// `k`-major (`buf[base + k·NR + ni]`), edge columns zero-padded.
fn pack_b(b: Op<'_>, pc: usize, kc: usize, jc: usize, nc: usize, buf: &mut [f64]) {
    let panels = (nc + NR - 1) / NR;
    match b {
        Op::N(m) => {
            for k in 0..kc {
                let row = m.row(pc + k);
                for pj in 0..panels {
                    let base = pj * NR * kc + k * NR;
                    for ni in 0..NR {
                        let j = pj * NR + ni;
                        buf[base + ni] = if j < nc { row[jc + j] } else { 0.0 };
                    }
                }
            }
        }
        Op::T(src) => {
            // op(B)[k][j] = src[j][k]: walk source rows (contiguous in k).
            for pj in 0..panels {
                let base = pj * NR * kc;
                for ni in 0..NR {
                    let j = pj * NR + ni;
                    if j < nc {
                        let row = &src.row(jc + j)[pc..pc + kc];
                        for (k, &v) in row.iter().enumerate() {
                            buf[base + k * NR + ni] = v;
                        }
                    } else {
                        for k in 0..kc {
                            buf[base + k * NR + ni] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// Drive the micro-kernel over every (MR, NR) tile of the packed block
/// and accumulate into C.
fn macro_kernel(
    ap: &[f64],
    bp: &[f64],
    mc: usize,
    nc: usize,
    kc: usize,
    c: &mut MatMut<'_>,
    ic: usize,
    jc: usize,
) {
    let mpanels = (mc + MR - 1) / MR;
    let npanels = (nc + NR - 1) / NR;
    for pi in 0..mpanels {
        let a_panel = &ap[pi * MR * kc..(pi + 1) * MR * kc];
        let mr = MR.min(mc - pi * MR);
        for pj in 0..npanels {
            let b_panel = &bp[pj * NR * kc..(pj + 1) * NR * kc];
            let nr = NR.min(nc - pj * NR);
            let acc = micro_kernel(kc, a_panel, b_panel);
            for (mi, acc_row) in acc.iter().enumerate().take(mr) {
                let row = c.row_mut(ic + pi * MR + mi);
                let dst = &mut row[jc + pj * NR..jc + pj * NR + nr];
                for (d, &v) in dst.iter_mut().zip(&acc_row[..nr]) {
                    *d += v;
                }
            }
        }
    }
}

/// The 4×8 register tile: 32 independent accumulators, 12 loads per
/// depth step, fully unrolled by the compiler. A depth step whose four
/// packed A values are all zero is skipped (zero-padded rank columns;
/// the matching B values are never read).
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f64], bp: &[f64]) -> [[f64; NR]; MR] {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut acc = [[0.0f64; NR]; MR];
    for k in 0..kc {
        let a = &ap[k * MR..k * MR + MR];
        if a[0] == 0.0 && a[1] == 0.0 && a[2] == 0.0 && a[3] == 0.0 {
            continue;
        }
        let b = &bp[k * NR..k * NR + NR];
        for mi in 0..MR {
            let av = a[mi];
            for (ni, acc_v) in acc[mi].iter_mut().enumerate() {
                *acc_v += av * b[ni];
            }
        }
    }
    acc
}

// ---------------------------------------------------------------------
// Small-product direct paths (seed-style loops; no packing, no
// allocation — required by the zero-allocation gradient contract)
// ---------------------------------------------------------------------

fn small_gemm(a: Op<'_>, b: Op<'_>, c: &mut MatMut<'_>) {
    match (a, b) {
        (Op::N(a), Op::N(b)) => small_nn(a, b, c),
        (Op::T(a), Op::N(b)) => small_tn(a, b, c),
        (Op::N(a), Op::T(b)) => small_nt(a, b, c),
        (Op::T(a), Op::T(b)) => small_tt(a, b, c),
    }
}

/// `C += A·B`, broadcast-saxpy with 4-wide k quads and zero-quad skip.
fn small_nn(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>) {
    let (m, kdim) = a.shape();
    for i in 0..m {
        let a_row = a.row(i);
        let c_row = c.row_mut(i);
        let mut k = 0;
        while k + 4 <= kdim {
            let (a0, a1, a2, a3) = (a_row[k], a_row[k + 1], a_row[k + 2], a_row[k + 3]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                k += 4;
                continue;
            }
            let b0 = b.row(k);
            let b1 = b.row(k + 1);
            let b2 = b.row(k + 2);
            let b3 = b.row(k + 3);
            for (j, cv) in c_row.iter_mut().enumerate() {
                *cv += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            k += 4;
        }
        while k < kdim {
            let aik = a_row[k];
            if aik != 0.0 {
                let b_row = b.row(k);
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += aik * bv;
                }
            }
            k += 1;
        }
    }
}

/// `C += Aᵀ·B`: iterate A rows (the contraction dim) and scatter saxpys
/// into C rows indexed by A's columns.
fn small_tn(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>) {
    let kdim = a.rows();
    for k in 0..kdim {
        let a_row = a.row(k);
        let b_row = b.row(k);
        for (i, &aki) in a_row.iter().enumerate() {
            if aki == 0.0 {
                continue;
            }
            let c_row = c.row_mut(i);
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += aki * bv;
            }
        }
    }
}

/// `C += A·Bᵀ`: row-pair dot products with four accumulators.
fn small_nt(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>) {
    let (m, kdim) = a.shape();
    let n = b.rows();
    for i in 0..m {
        let a_row = a.row(i);
        let c_row = c.row_mut(i);
        let mut j = 0;
        while j + 2 <= n {
            let b0 = b.row(j);
            let b1 = b.row(j + 1);
            let (mut s00, mut s01, mut s10, mut s11) = (0.0, 0.0, 0.0, 0.0);
            let mut k = 0;
            while k + 2 <= kdim {
                s00 += a_row[k] * b0[k];
                s10 += a_row[k] * b1[k];
                s01 += a_row[k + 1] * b0[k + 1];
                s11 += a_row[k + 1] * b1[k + 1];
                k += 2;
            }
            if k < kdim {
                s00 += a_row[k] * b0[k];
                s10 += a_row[k] * b1[k];
            }
            c_row[j] += s00 + s01;
            c_row[j + 1] += s10 + s11;
            j += 2;
        }
        if j < n {
            let b_row = b.row(j);
            let mut acc = 0.0;
            for k in 0..kdim {
                acc += a_row[k] * b_row[k];
            }
            c_row[j] += acc;
        }
    }
}

/// `C += Aᵀ·Bᵀ` — completeness fallback (no FeDLRT hot path uses it).
fn small_tt(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>) {
    let kdim = a.rows();
    let m = a.cols();
    let n = b.rows();
    for i in 0..m {
        let c_row = c.row_mut(i);
        for j in 0..n {
            let b_row = b.row(j);
            let mut acc = 0.0;
            for k in 0..kdim {
                acc += a.get(k, i) * b_row[k];
            }
            c_row[j] += acc;
        }
    }
}

// ---------------------------------------------------------------------
// Seed kernel, preserved as the correctness/perf reference
// ---------------------------------------------------------------------

/// The seed repo's blocked broadcast-saxpy matmul, kept verbatim as the
/// correctness oracle for `kernel_equivalence.rs` and the baseline the
/// packed kernel's speedup is measured against in `micro_hotpath`.
pub fn matmul_reference(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul_reference: inner dims");
    let (m, kdim) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let a_data = a.data();
    let b_data = b.data();
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..kdim).step_by(KC) {
            let k1 = (k0 + KC).min(kdim);
            for i in i0..i1 {
                let a_row = &a_data[i * kdim..(i + 1) * kdim];
                let c_row = &mut c.data_mut()[i * n..(i + 1) * n];
                let mut k = k0;
                while k + 4 <= k1 {
                    let a0 = a_row[k];
                    let a1 = a_row[k + 1];
                    let a2 = a_row[k + 2];
                    let a3 = a_row[k + 3];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        k += 4;
                        continue;
                    }
                    let b0 = &b_data[k * n..k * n + n];
                    let b1 = &b_data[(k + 1) * n..(k + 1) * n + n];
                    let b2 = &b_data[(k + 2) * n..(k + 2) * n + n];
                    let b3 = &b_data[(k + 3) * n..(k + 3) * n + n];
                    for j in 0..n {
                        c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    k += 4;
                }
                while k < k1 {
                    let aik = a_row[k];
                    if aik != 0.0 {
                        let b_row = &b_data[k * n..k * n + n];
                        for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                            *c_v += aik * b_v;
                        }
                    }
                    k += 1;
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(17);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 70, 65), (130, 257, 31)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(c.sub(&want).max_abs() < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_path_matches_naive() {
        // Sizes above PACK_MIN_FLOPS exercise the packed kernel,
        // including edge tiles (dims not multiples of MR/NR).
        let mut rng = Rng::new(19);
        for &(m, k, n) in &[(96, 96, 96), (101, 83, 97), (128, 300, 65)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            let want = naive(&a, &b);
            let tol = 1e-12 * (k as f64) * (1.0 + want.max_abs());
            assert!(c.sub(&want).max_abs() < tol, "({m},{k},{n})");
            // And the preserved seed kernel agrees too.
            let seed = matmul_reference(&a, &b);
            assert!(c.sub(&seed).max_abs() < tol, "seed ({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut rng = Rng::new(23);
        let a = Matrix::randn(40, 13, &mut rng);
        let b = Matrix::randn(40, 21, &mut rng);
        let tn = matmul_tn(&a, &b);
        assert!(tn.sub(&naive(&a.t(), &b)).max_abs() < 1e-10);

        let c = Matrix::randn(12, 40, &mut rng);
        let d = Matrix::randn(29, 40, &mut rng);
        let nt = matmul_nt(&c, &d);
        assert!(nt.sub(&naive(&c, &d.t())).max_abs() < 1e-10);
    }

    #[test]
    fn transposed_variants_match_packed() {
        let mut rng = Rng::new(27);
        let a = Matrix::randn(200, 90, &mut rng);
        let b = Matrix::randn(200, 110, &mut rng);
        let tn = matmul_tn(&a, &b);
        let want = naive(&a.t(), &b);
        assert!(tn.sub(&want).max_abs() < 1e-10 * (1.0 + want.max_abs()));

        let c = Matrix::randn(150, 170, &mut rng);
        let d = Matrix::randn(140, 170, &mut rng);
        let nt = matmul_nt(&c, &d);
        let want = naive(&c, &d.t());
        assert!(nt.sub(&want).max_abs() < 1e-10 * (1.0 + want.max_abs()));
    }

    #[test]
    fn matmul_into_beta() {
        let mut rng = Rng::new(29);
        let a = Matrix::randn(8, 9, &mut rng);
        let b = Matrix::randn(9, 7, &mut rng);
        let mut c = Matrix::randn(8, 7, &mut rng);
        let c0 = c.clone();
        matmul_into(&a, &b, &mut c, 1.0);
        let want = c0.add(&naive(&a, &b));
        assert!(c.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn tn_nt_into_beta() {
        let mut rng = Rng::new(33);
        let a = Matrix::randn(11, 5, &mut rng);
        let b = Matrix::randn(11, 6, &mut rng);
        let mut c = Matrix::randn(5, 6, &mut rng);
        let c0 = c.clone();
        matmul_tn_into(&a, &b, &mut c, 2.0);
        let want = c0.scale(2.0).add(&naive(&a.t(), &b));
        assert!(c.sub(&want).max_abs() < 1e-10);

        let mut d = Matrix::randn(11, 11, &mut rng);
        let d0 = d.clone();
        matmul_nt_into(&a, &b, &mut d, 1.0);
        let want = d0.add(&naive(&a, &b.t()));
        assert!(d.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn scaled_tn_matches_explicit_diag() {
        let mut rng = Rng::new(37);
        let a = Matrix::randn(30, 7, &mut rng);
        let b = Matrix::randn(30, 9, &mut rng);
        let s = rng.normal_vec(30);
        let alpha = 0.25;
        let mut c = Matrix::zeros(7, 9);
        matmul_tn_scaled_into(&a, &b, &s, alpha, &mut c, 0.0);
        // Reference: Aᵀ · diag(α·s) · B built explicitly.
        let mut sb = b.clone();
        for i in 0..30 {
            let w = alpha * s[i];
            for v in sb.row_mut(i) {
                *v *= w;
            }
        }
        let want = matmul_tn(&a, &sb);
        assert!(c.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn gram_matches_tn_self() {
        let mut rng = Rng::new(41);
        for &(m, n) in &[(5, 3), (30, 8), (12, 12), (3, 17)] {
            let a = Matrix::randn(m, n, &mut rng);
            let g = gram(&a);
            let want = matmul_tn(&a, &a);
            assert!(g.sub(&want).max_abs() < 1e-10, "({m},{n})");
            // exact symmetry by construction
            for p in 0..n {
                for q in 0..n {
                    assert_eq!(g[(p, q)].to_bits(), g[(q, p)].to_bits());
                }
            }
        }
    }

    #[test]
    fn threaded_matches_serial_bitwise() {
        let mut rng = Rng::new(43);
        let a = Matrix::randn(180, 170, &mut rng);
        let b = Matrix::randn(170, 190, &mut rng);
        let mut c1 = Matrix::zeros(180, 190);
        gemm_into(Op::N(a.view()), Op::N(b.view()), c1.view_mut(), 0.0, 1);
        for threads in [2usize, 3, 7] {
            let mut ct = Matrix::zeros(180, 190);
            gemm_into(Op::N(a.view()), Op::N(b.view()), ct.view_mut(), 0.0, threads);
            for (x, y) in c1.data().iter().zip(ct.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn usv_reconstruction() {
        let mut rng = Rng::new(31);
        let u = Matrix::randn(20, 4, &mut rng);
        let s = Matrix::randn(4, 4, &mut rng);
        let v = Matrix::randn(20, 4, &mut rng);
        let w = usv(&u, &s, &v);
        let want = naive(&naive(&u, &s), &v.t());
        assert!(w.sub(&want).max_abs() < 1e-10);
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(37);
        let a = Matrix::randn(11, 6, &mut rng);
        let x = rng.normal_vec(6);
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(6, 1, x);
        let want = matmul(&a, &xm);
        for i in 0..11 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_padding_skipped_correctly() {
        // Padded columns (zeros) must not change results.
        let mut rng = Rng::new(41);
        let a = Matrix::randn(10, 4, &mut rng);
        let a_pad = a.hcat(&Matrix::zeros(10, 4));
        let b = Matrix::randn(4, 6, &mut rng);
        let b_pad = {
            let mut bp = Matrix::zeros(8, 6);
            bp.set_block(0, 0, &b);
            bp
        };
        assert!(matmul(&a_pad, &b_pad).sub(&matmul(&a, &b)).max_abs() < 1e-12);
    }

    #[test]
    fn zero_padded_columns_never_read_b() {
        // The packed kernel must never touch B rows aligned with an
        // all-zero A column — NaN garbage in the padding region cannot
        // pollute the product.
        let mut rng = Rng::new(47);
        let (m, k, n, pad) = (96, 64, 96, 32);
        let a = Matrix::randn(m, k, &mut rng);
        let a_pad = a.hcat(&Matrix::zeros(m, pad));
        let b = Matrix::randn(k, n, &mut rng);
        let mut b_pad = Matrix::zeros(k + pad, n);
        b_pad.set_block(0, 0, &b);
        for i in k..k + pad {
            for v in b_pad.row_mut(i) {
                *v = f64::NAN;
            }
        }
        let c_pad = matmul(&a_pad, &b_pad);
        let c = matmul(&a, &b);
        assert!(c_pad.is_finite(), "NaN leaked from padded B rows");
        for (x, y) in c_pad.data().iter().zip(c.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn kernel_thread_knob_roundtrip() {
        // Results are thread-count invariant, so mutating the global
        // knob is safe even with concurrently running tests.
        set_kernel_threads(3);
        assert_eq!(kernel_threads(), 3);
        set_kernel_threads(0); // clamps to 1
        assert_eq!(kernel_threads(), 1);
    }
}
