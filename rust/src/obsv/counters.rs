//! Always-on atomic counters fed from the tensor layer.
//!
//! These are process-global `Relaxed` atomics — the same pattern as the
//! kernel's `KERNEL_THREADS` knob — so the hot path pays a few
//! nanoseconds per GEMM call and **zero allocations** (the
//! `micro_hotpath` zero-allocation gate runs with these compiled in).
//! Consumers take a [`counters_snapshot`] before a region of interest
//! and diff with [`counters_delta`] after; `benches/table1_costs.rs`
//! uses this to put measured FLOPs next to the paper's cost model.
//!
//! Counters are cumulative per process and shared across threads, so
//! deltas around a multi-threaded region attribute *all* threads' work
//! to the region — which is what a cost table wants. They are
//! observe-only and never feed back into training state.

use std::sync::atomic::{AtomicU64, Ordering};

static GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_FLOPS: AtomicU64 = AtomicU64::new(0);
static PANELS_PACKED: AtomicU64 = AtomicU64::new(0);
static WS_BYTES_OUT: AtomicU64 = AtomicU64::new(0);
static WS_BYTES_HWM: AtomicU64 = AtomicU64::new(0);

/// Note one GEMM dispatch of shape `m×k · k×n` (2mnk flops).
#[inline]
pub fn note_gemm(m: usize, k: usize, n: usize) {
    GEMM_CALLS.fetch_add(1, Ordering::Relaxed);
    GEMM_FLOPS.fetch_add(2 * (m as u64) * (k as u64) * (n as u64), Ordering::Relaxed);
}

/// Note `count` A/B panels packed by the blocked kernel.
#[inline]
pub fn note_panels_packed(count: u64) {
    if count > 0 {
        PANELS_PACKED.fetch_add(count, Ordering::Relaxed);
    }
}

/// Note `bytes` of workspace storage going outstanding (a `take`).
/// Updates the process-wide high-water mark.
#[inline]
pub fn note_workspace_take(bytes: u64) {
    let now = WS_BYTES_OUT.fetch_add(bytes, Ordering::Relaxed) + bytes;
    WS_BYTES_HWM.fetch_max(now, Ordering::Relaxed);
}

/// Note `bytes` of workspace storage returning to a pool (a `give`).
#[inline]
pub fn note_workspace_give(bytes: u64) {
    // Saturating: a buffer dropped instead of given back (legal per the
    // workspace ownership rules) leaves the outstanding estimate high
    // rather than wrapping.
    let _ = WS_BYTES_OUT.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(bytes))
    });
}

/// Point-in-time view of the process counters. Diff two snapshots with
/// [`counters_delta`] to attribute work to a region.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    /// GEMM dispatches (packed kernel and small-matrix fallback alike).
    pub gemm_calls: u64,
    /// Multiply-add flops (2mnk per GEMM).
    pub gemm_flops: u64,
    /// A/B panels packed by the blocked kernel.
    pub panels_packed: u64,
    /// Workspace bytes outstanding right now (approximate: buffers
    /// dropped instead of given back stay counted).
    pub ws_bytes_out: u64,
    /// High-water mark of outstanding workspace bytes.
    pub ws_bytes_hwm: u64,
    /// Heap allocations observed by [`super::alloc::CountingAlloc`]
    /// (zero unless the binary installed it as `#[global_allocator]`).
    pub alloc_calls: u64,
    /// Heap bytes requested, same caveat as `alloc_calls`.
    pub alloc_bytes: u64,
}

/// Read all counters. `Relaxed` loads: values are exact once the
/// threads that did the work have been joined.
pub fn counters_snapshot() -> CounterSnapshot {
    let (alloc_calls, alloc_bytes) = super::alloc::counts();
    CounterSnapshot {
        gemm_calls: GEMM_CALLS.load(Ordering::Relaxed),
        gemm_flops: GEMM_FLOPS.load(Ordering::Relaxed),
        panels_packed: PANELS_PACKED.load(Ordering::Relaxed),
        ws_bytes_out: WS_BYTES_OUT.load(Ordering::Relaxed),
        ws_bytes_hwm: WS_BYTES_HWM.load(Ordering::Relaxed),
        alloc_calls,
        alloc_bytes,
    }
}

/// Work done since `since` (high-water marks report the current mark,
/// not a difference — a mark has no meaningful delta).
pub fn counters_delta(since: &CounterSnapshot) -> CounterSnapshot {
    let now = counters_snapshot();
    CounterSnapshot {
        gemm_calls: now.gemm_calls - since.gemm_calls,
        gemm_flops: now.gemm_flops - since.gemm_flops,
        panels_packed: now.panels_packed - since.panels_packed,
        ws_bytes_out: now.ws_bytes_out,
        ws_bytes_hwm: now.ws_bytes_hwm,
        alloc_calls: now.alloc_calls - since.alloc_calls,
        alloc_bytes: now.alloc_bytes - since.alloc_bytes,
    }
}

impl CounterSnapshot {
    /// JSON export for bench rows.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("gemm_calls", self.gemm_calls)
            .set("gemm_flops", self.gemm_flops)
            .set("panels_packed", self.panels_packed)
            .set("ws_bytes_hwm", self.ws_bytes_hwm)
            .set("alloc_calls", self.alloc_calls)
            .set("alloc_bytes", self.alloc_bytes);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_counter_accumulates_flops() {
        let before = counters_snapshot();
        note_gemm(4, 8, 2);
        note_gemm(1, 1, 1);
        let d = counters_delta(&before);
        // Other tests may run concurrently, so assert lower bounds.
        assert!(d.gemm_calls >= 2);
        assert!(d.gemm_flops >= 2 * 4 * 8 * 2 + 2);
    }

    #[test]
    fn workspace_hwm_tracks_peak() {
        note_workspace_take(1 << 20);
        let snap = counters_snapshot();
        assert!(snap.ws_bytes_hwm >= 1 << 20);
        note_workspace_give(1 << 20);
        // give never wraps below zero even if unbalanced.
        note_workspace_give(u64::MAX / 2);
        assert!(counters_snapshot().ws_bytes_hwm >= snap.ws_bytes_hwm);
    }
}
