//! Observability layer: phase spans, per-client latency distributions,
//! kernel counters, and trace export.
//!
//! The paper's headline claim is a *cost* claim — client compute and
//! communication cut by up to an order of magnitude — so the repo needs
//! to attribute time to the algorithm's named phases from real runs,
//! not only from cost-model formulas. This subsystem provides four
//! instruments (see DESIGN.md §Observability):
//!
//! * [`Recorder`]/[`Span`] — hierarchical span timers over a **static
//!   phase taxonomy** (`round > {broadcast, client_train, aggregate,
//!   augment_qr, variance_correction, truncate_svd, eval, io}`) that
//!   every coordinator wraps its stages in;
//! * [`LatencyHist`] / [`StalenessHist`] — per-client latency and
//!   per-dispatch staleness distributions (exact p50/p95/max +
//!   straggler id) over one shared order-independent accumulation core
//!   ([`KeyedHist`]), built from the engine executors' per-task timings
//!   and the async server's consumed-update staleness, exposed per
//!   round;
//! * [`counters`] — lightweight always-on atomic counters fed from the
//!   tensor layer (GEMM calls, FLOPs, panels packed, workspace bytes
//!   high-water mark) plus the reusable counting allocator in
//!   [`alloc`];
//! * exporters — per-phase seconds folded into
//!   [`crate::metrics::RoundMetrics`] as a `phase_s` map, and an
//!   optional Chrome trace-event JSON file (`--trace <path>`, loadable
//!   in Perfetto / `chrome://tracing`) with one track per worker
//!   thread.
//!
//! **Invariants.** Telemetry is observe-only: it never touches round
//! state, so the bitwise serial≡threaded determinism contract is
//! unaffected (asserted by `tests/engine_determinism.rs`). The
//! per-client histogram is keyed by client id, so merging thread-pool
//! timings is order-independent. A [`Recorder::disabled`] recorder is a
//! no-op behind the same API: spans read no clock and allocate nothing
//! (the `micro_hotpath` zero-allocation gate runs with this layer
//! compiled in).

// The crate is #![deny(unsafe_code)]; the counting global allocator is
// the one sanctioned exception (fedlint D5 allowlists the same file).
#[allow(unsafe_code)]
pub mod alloc;
pub mod counters;
pub mod hist;
pub mod span;
pub mod trace;

pub use counters::{counters_delta, counters_snapshot, CounterSnapshot};
pub use hist::{KeyedHist, LatencyHist, LatencySummary, StalenessHist, StalenessSummary};
pub use span::{Recorder, RoundObs, Span};
pub use trace::{write_chrome_trace, TraceEvent};

/// The static phase taxonomy every coordinator reports against.
///
/// `Io` is the catch-all for scheduling, record bookkeeping, and
/// exporter I/O — everything in a round that is neither algorithm math
/// nor communication.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Server→client transfers: encode + wire accounting + decode.
    Broadcast,
    /// Client-side work submitted to the executor (basis-gradient
    /// rounds and local coefficient iterations).
    ClientTrain,
    /// Client→server transfers and the coordinator's fold of uploads.
    Aggregate,
    /// Basis augmentation `qr([U | proj])` (FeDLRT Alg 1 line 5).
    AugmentQr,
    /// Variance-correction assembly (simplified or full; includes the
    /// full mode's extra gradient round trip).
    VarianceCorrection,
    /// Rank truncation via the small `2r×2r` SVD.
    TruncateSvd,
    /// Global loss / validation-metric evaluation.
    Eval,
    /// Scheduling, bookkeeping, and exporter I/O.
    Io,
}

/// Number of phases in the taxonomy (array size for accumulators).
pub const PHASE_COUNT: usize = 8;

/// All phases, in stable display/export order.
pub const ALL_PHASES: [Phase; PHASE_COUNT] = [
    Phase::Broadcast,
    Phase::ClientTrain,
    Phase::Aggregate,
    Phase::AugmentQr,
    Phase::VarianceCorrection,
    Phase::TruncateSvd,
    Phase::Eval,
    Phase::Io,
];

impl Phase {
    /// Stable snake_case label used for JSON keys and trace events.
    pub fn label(self) -> &'static str {
        match self {
            Phase::Broadcast => "broadcast",
            Phase::ClientTrain => "client_train",
            Phase::Aggregate => "aggregate",
            Phase::AugmentQr => "augment_qr",
            Phase::VarianceCorrection => "variance_correction",
            Phase::TruncateSvd => "truncate_svd",
            Phase::Eval => "eval",
            Phase::Io => "io",
        }
    }

    /// Index into a `[_; PHASE_COUNT]` accumulator.
    pub fn index(self) -> usize {
        match self {
            Phase::Broadcast => 0,
            Phase::ClientTrain => 1,
            Phase::Aggregate => 2,
            Phase::AugmentQr => 3,
            Phase::VarianceCorrection => 4,
            Phase::TruncateSvd => 5,
            Phase::Eval => 6,
            Phase::Io => 7,
        }
    }
}

/// Per-round seconds attributed to each taxonomy phase.
///
/// Only **top-level** spans accumulate here (nested spans show up in
/// the trace but are already covered by their parent), so for every
/// round `sum() ≤ wall_s` up to timer resolution.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseSeconds(pub [f64; PHASE_COUNT]);

impl PhaseSeconds {
    pub fn get(&self, p: Phase) -> f64 {
        self.0[p.index()]
    }

    pub fn add(&mut self, p: Phase, secs: f64) {
        self.0[p.index()] += secs;
    }

    /// Total attributed seconds across all phases.
    pub fn sum(&self) -> f64 {
        self.0.iter().sum()
    }

    /// JSON object `{label: seconds}` with every taxonomy key present
    /// (zeros included, so downstream consumers see a fixed schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        for p in ALL_PHASES {
            o.set(p.label(), self.get(p));
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_index_label_stable() {
        for (i, p) in ALL_PHASES.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        assert_eq!(Phase::VarianceCorrection.label(), "variance_correction");
    }

    #[test]
    fn phase_seconds_accumulate_and_export() {
        let mut ps = PhaseSeconds::default();
        ps.add(Phase::Broadcast, 0.25);
        ps.add(Phase::Broadcast, 0.25);
        ps.add(Phase::Eval, 0.5);
        assert_eq!(ps.get(Phase::Broadcast), 0.5);
        assert_eq!(ps.sum(), 1.0);
        let j = ps.to_json();
        for p in ALL_PHASES {
            assert!(j.get(p.label()).is_some(), "missing key {}", p.label());
        }
        assert_eq!(j.get("eval").unwrap().as_f64().unwrap(), 0.5);
    }
}
