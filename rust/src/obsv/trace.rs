//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`).
//!
//! Schema (see DESIGN.md §Observability): the file is a JSON object
//! `{"traceEvents": [...], "displayTimeUnit": "ms"}`. Every span is a
//! **complete event** (`"ph": "X"`) with microsecond `ts`/`dur`
//! measured from the recorder's epoch, `pid` fixed at 1, and `tid`
//! selecting the track:
//!
//! * `tid 0` — the coordinator thread: one enclosing `round N` event
//!   per round with the taxonomy phase spans nested inside it;
//! * `tid k+1` — executor worker `k`: one `<label> cN` event per
//!   client task it ran (`label` names the executor call, e.g. `grad`,
//!   `local`, `vc_grad`; `cN` is the client id).
//!
//! Thread-name metadata events (`"ph": "M"`) label the tracks. Events
//! are emitted in recording order; trace viewers sort by `ts`.

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// One complete ("X") span on some track.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Display name (phase label, `round N`, or `<label> cN`).
    pub name: String,
    /// Microseconds from the recorder's epoch.
    pub ts_us: f64,
    /// Duration in microseconds.
    pub dur_us: f64,
    /// Track: 0 = coordinator, k+1 = executor worker k.
    pub tid: u32,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("cat", "fedlrt")
            .set("ph", "X")
            .set("ts", self.ts_us)
            .set("dur", self.dur_us)
            .set("pid", 1usize)
            .set("tid", self.tid as usize);
        o
    }
}

fn thread_name_meta(tid: u32, name: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", name);
    let mut o = Json::obj();
    o.set("name", "thread_name")
        .set("ph", "M")
        .set("pid", 1usize)
        .set("tid", tid as usize)
        .set("args", args);
    o
}

/// Serialize `events` as a Chrome trace and write it to `path` with a
/// single `write_all` (creates parent directories).
pub fn write_chrome_trace(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    let mut arr: Vec<Json> = Vec::with_capacity(events.len() + 8);
    // Track labels first: the coordinator plus every worker track any
    // event references.
    let mut args = Json::obj();
    args.set("name", "fedlrt");
    let mut proc_meta = Json::obj();
    proc_meta
        .set("name", "process_name")
        .set("ph", "M")
        .set("pid", 1usize)
        .set("tid", 0usize)
        .set("args", args);
    arr.push(proc_meta);
    let max_tid = events.iter().map(|e| e.tid).max().unwrap_or(0);
    arr.push(thread_name_meta(0, "coordinator"));
    for w in 1..=max_tid {
        arr.push(thread_name_meta(w, &format!("client-worker-{}", w - 1)));
    }
    arr.extend(events.iter().map(TraceEvent::to_json));
    let mut root = Json::obj();
    root.set("traceEvents", Json::Arr(arr)).set("displayTimeUnit", "ms");
    let body = root.to_string_compact();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(body.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_file_is_well_formed() {
        let events = vec![
            TraceEvent { name: "round 0".into(), ts_us: 0.0, dur_us: 100.0, tid: 0 },
            TraceEvent { name: "broadcast".into(), ts_us: 1.0, dur_us: 10.0, tid: 0 },
            TraceEvent { name: "grad c3".into(), ts_us: 12.0, dur_us: 30.0, tid: 2 },
        ];
        let dir = std::env::temp_dir().join("fedlrt_obsv_trace_test");
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &events).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process meta + 3 thread metas (coordinator + 2 workers) + 3 events.
        assert_eq!(evs.len(), 7);
        let phases: Vec<&str> =
            evs.iter().map(|e| e.get("ph").unwrap().as_str().unwrap()).collect();
        assert_eq!(phases.iter().filter(|&&p| p == "M").count(), 4);
        assert_eq!(phases.iter().filter(|&&p| p == "X").count(), 3);
        let last = evs.last().unwrap();
        assert_eq!(last.get("name").unwrap().as_str().unwrap(), "grad c3");
        assert_eq!(last.get("tid").unwrap().as_usize().unwrap(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
