//! Reusable counting-allocator instrument (promoted from
//! `benches/micro_hotpath.rs` so every bench and test can assert
//! allocation contracts with the same tool).
//!
//! Rust allows exactly one `#[global_allocator]`, chosen by the final
//! binary — a library cannot install one. So this module ships the
//! *instrument* and each binary opts in:
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: fedlrt::obsv::alloc::CountingAlloc = CountingAlloc;
//! ```
//!
//! When no binary installs it, [`counts`] stays at zero and the
//! telemetry layer simply reports no allocation data — there is no
//! penalty for the instrument existing. When installed, every
//! alloc/realloc is two `Relaxed` atomic adds on top of the system
//! allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// A `GlobalAlloc` that tallies every allocation before delegating to
/// [`System`]. Deallocations are not counted — the contracts under test
/// are "how much did this path *ask for*".
pub struct CountingAlloc;

// SAFETY: every method delegates verbatim to `System`, which upholds
// the `GlobalAlloc` contract; the only additions are `Relaxed` atomic
// counter bumps, which never allocate, panic, or unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract (non-zero
    // layout); we forward it unchanged to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: same contract forwarding as `alloc`, via
    // `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with
    // `layout`, per `GlobalAlloc::realloc`; forwarded unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller guarantees `ptr`/`layout` match the allocation,
    // per `GlobalAlloc::dealloc`; forwarded unchanged to `System`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Cumulative `(calls, bytes)` observed since process start — zeros
/// unless the running binary installed [`CountingAlloc`].
pub fn counts() -> (u64, u64) {
    (ALLOC_CALLS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

/// Allocation delta `(calls, bytes)` across `f()`.
pub fn measure_allocs<F: FnMut()>(mut f: F) -> (u64, u64) {
    let (c0, b0) = counts();
    f();
    let (c1, b1) = counts();
    (c1 - c0, b1 - b0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The test binary does not install CountingAlloc, so the counters
    // stay flat and measure_allocs sees a zero delta even for real
    // allocations — exactly the "not installed" contract.
    #[test]
    fn uninstalled_counts_are_flat() {
        let (dc, db) = measure_allocs(|| {
            std::hint::black_box(vec![0u8; 4096]);
        });
        assert_eq!((dc, db), (0, 0));
    }
}
