//! Keyed sample distributions with exact quantiles: one merge core,
//! two views (per-client latency, per-dispatch staleness).
//!
//! The engine executors time every [`crate::engine::ClientTask`] on the
//! worker that ran it; the coordinator feeds those timings here keyed
//! by **client id**, so a client that runs in several executor calls
//! within one round (e.g. basis-gradient round + local iterations)
//! accumulates its total seconds. Keying by a stable id makes the merge
//! order-independent: serial and thread-pool executors produce the same
//! histogram contents for the same per-task values regardless of
//! completion order. The async server reuses the identical core keyed
//! by **dispatch sequence number** for staleness — one accumulation and
//! merge implementation, not two copies ([`KeyedHist`]).
//!
//! Quantiles are **exact** (nearest-rank over the sorted samples), not
//! bucketed estimates — client counts are metrics-sized, so sorting a
//! copy is cheap and the tests can assert exact values.

/// The shared accumulation core: `key → accumulated value`, kept sorted
/// by key. Adds are binary-search accumulations, so any interleaving of
/// the same `(key, value)` multiset yields identical contents — the
/// order-independence both wrapping histograms rely on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KeyedHist {
    samples: Vec<(usize, f64)>,
}

impl KeyedHist {
    pub fn new() -> KeyedHist {
        KeyedHist::default()
    }

    /// Add `value` to `key`'s accumulated total.
    pub fn add(&mut self, key: usize, value: f64) {
        match self.samples.binary_search_by_key(&key, |&(k, _)| k) {
            Ok(i) => self.samples[i].1 += value,
            Err(i) => self.samples.insert(i, (key, value)),
        }
    }

    /// Fold another histogram's contents in, key by key. Because adds
    /// accumulate per key, `a.merge(&b)` equals `b.merge(&a)` up to
    /// per-key addition order — and is exactly order-independent when
    /// key sets are disjoint (the async case: dispatch seqs are unique).
    pub fn merge(&mut self, other: &KeyedHist) {
        for &(k, v) in &other.samples {
            self.add(k, v);
        }
    }

    /// Number of distinct keys observed.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Sum of all accumulated values, folded in key order.
    pub fn total(&self) -> f64 {
        self.samples.iter().map(|&(_, v)| v).sum()
    }

    /// Exact nearest-rank quantile over the per-key values: the
    /// smallest value `x` such that at least `q·n` values are ≤ `x`.
    /// `quantile(1.0)` is the max.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.samples.iter().map(|&(_, s)| s).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        v[rank - 1]
    }

    /// The `(key, value)` pair with the largest value.
    pub fn max_entry(&self) -> Option<(usize, f64)> {
        self.samples
            .iter()
            .copied()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Reset, keeping capacity.
    pub fn clear(&mut self) {
        self.samples.clear();
    }
}

/// Accumulated per-client latencies for one round (a [`KeyedHist`]
/// keyed by client id).
#[derive(Debug, Clone, Default)]
pub struct LatencyHist {
    hist: KeyedHist,
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist::default()
    }

    /// Add `secs` to `client`'s accumulated latency.
    pub fn add(&mut self, client: usize, secs: f64) {
        self.hist.add(client, secs);
    }

    /// Fold another round fragment's latencies in (order-independent).
    pub fn merge(&mut self, other: &LatencyHist) {
        self.hist.merge(&other.hist);
    }

    /// Number of distinct clients observed.
    pub fn len(&self) -> usize {
        self.hist.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hist.is_empty()
    }

    /// Sum of all per-client latencies, folded in client-id order.
    ///
    /// For a single serial executor call this equals the executor's
    /// `serial_s` bitwise: tasks are planned in ascending client id, so
    /// both sums fold the same numbers in the same order on the same
    /// monotonic clock.
    pub fn total_s(&self) -> f64 {
        self.hist.total()
    }

    /// Exact nearest-rank quantile (see [`KeyedHist::quantile`]).
    pub fn quantile(&self, q: f64) -> f64 {
        self.hist.quantile(q)
    }

    /// The slowest client this round: `(client id, seconds)`.
    pub fn straggler(&self) -> Option<(usize, f64)> {
        self.hist.max_entry()
    }

    /// Collapse into the per-round summary exported with the metrics.
    pub fn summary(&self) -> LatencySummary {
        if self.hist.is_empty() {
            return LatencySummary::default();
        }
        let (straggler, max_s) = self.straggler().unwrap();
        LatencySummary {
            n: self.len(),
            p50_s: self.quantile(0.50),
            p95_s: self.quantile(0.95),
            max_s,
            sum_s: self.total_s(),
            straggler,
        }
    }

    /// Reset for the next round, keeping capacity.
    pub fn clear(&mut self) {
        self.hist.clear();
    }
}

/// Per-round latency-distribution summary (exported in round JSON as
/// `lat_p50_s` / `lat_p95_s` / `lat_max_s` / `straggler` when `n > 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    /// Distinct clients observed; `0` means "no latency data".
    pub n: usize,
    /// Median per-client latency (exact nearest-rank).
    pub p50_s: f64,
    /// 95th-percentile per-client latency (exact nearest-rank).
    pub p95_s: f64,
    /// Slowest client's latency.
    pub max_s: f64,
    /// Sum of per-client latencies (client-id fold order; equals the
    /// serial executor's `serial_s` for single-call rounds).
    pub sum_s: f64,
    /// Client id of the slowest client (the round's straggler).
    pub straggler: usize,
}

/// Staleness distribution of the updates consumed by one async
/// aggregation: a [`KeyedHist`] keyed by **dispatch sequence number**
/// (unique per update, so adds never collide and the merge is exactly
/// order-independent), valued in model-version staleness σ.
#[derive(Debug, Clone, Default)]
pub struct StalenessHist {
    hist: KeyedHist,
}

impl StalenessHist {
    pub fn new() -> StalenessHist {
        StalenessHist::default()
    }

    /// Record that the update from dispatch `dispatch` was consumed at
    /// staleness `sigma` (server versions elapsed since its dispatch).
    pub fn add(&mut self, dispatch: u64, sigma: u64) {
        self.hist.add(dispatch as usize, sigma as f64);
    }

    /// Fold another fragment in (order-independent; shared core).
    pub fn merge(&mut self, other: &StalenessHist) {
        self.hist.merge(&other.hist);
    }

    pub fn len(&self) -> usize {
        self.hist.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hist.is_empty()
    }

    /// Exact nearest-rank staleness quantile.
    pub fn quantile(&self, q: f64) -> f64 {
        self.hist.quantile(q)
    }

    /// Collapse into the per-aggregation summary exported with metrics.
    pub fn summary(&self) -> StalenessSummary {
        if self.hist.is_empty() {
            return StalenessSummary::default();
        }
        StalenessSummary {
            n: self.len(),
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            max: self.quantile(1.0),
            mean: self.hist.total() / self.len() as f64,
        }
    }

    pub fn clear(&mut self) {
        self.hist.clear();
    }
}

/// Per-aggregation staleness summary (exported in round JSON as
/// `stale_p50` / `stale_p95` / `stale_max` / `stale_mean` when `n > 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StalenessSummary {
    /// Updates consumed; `0` means "no staleness data" (sync runs).
    pub n: usize,
    /// Median staleness (server versions).
    pub p50: f64,
    /// 95th-percentile staleness.
    pub p95: f64,
    /// Largest staleness consumed.
    pub max: f64,
    /// Mean staleness.
    pub mean: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_exact_on_known_inputs() {
        // 1..=100 seconds: nearest-rank p50 = 50, p95 = 95, max = 100.
        let mut h = LatencyHist::new();
        for c in 0..100 {
            h.add(c, (c + 1) as f64);
        }
        assert_eq!(h.quantile(0.50), 50.0);
        assert_eq!(h.quantile(0.95), 95.0);
        assert_eq!(h.quantile(1.0), 100.0);
        assert_eq!(h.straggler(), Some((99, 100.0)));
        let s = h.summary();
        assert_eq!((s.n, s.p50_s, s.p95_s, s.max_s), (100, 50.0, 95.0, 100.0));
    }

    #[test]
    fn identical_work_collapses_quantiles() {
        let mut h = LatencyHist::new();
        for c in 0..7 {
            h.add(c, 0.25);
        }
        let s = h.summary();
        assert_eq!(s.p50_s, s.p95_s);
        assert_eq!(s.p95_s, s.max_s);
        assert_eq!(s.sum_s, 7.0 * 0.25);
    }

    #[test]
    fn merge_is_order_independent() {
        let timings = [(3usize, 0.5), (1, 0.25), (2, 0.125), (1, 0.0625)];
        let mut fwd = LatencyHist::new();
        for &(c, s) in &timings {
            fwd.add(c, s);
        }
        let mut rev = LatencyHist::new();
        for &(c, s) in timings.iter().rev() {
            rev.add(c, s);
        }
        assert_eq!(fwd.hist, rev.hist);
        assert_eq!(fwd.summary(), rev.summary());
    }

    #[test]
    fn keyed_merge_equals_elementwise_adds() {
        // Building from fragments via merge == building in one pass —
        // the reuse contract the staleness histogram depends on.
        let parts = [[(10usize, 1.0), (11, 2.0)], [(12, 4.0), (10, 8.0)]];
        let mut merged = KeyedHist::new();
        for part in &parts {
            let mut frag = KeyedHist::new();
            for &(k, v) in part {
                frag.add(k, v);
            }
            merged.merge(&frag);
        }
        let mut flat = KeyedHist::new();
        for &(k, v) in parts.iter().flatten() {
            flat.add(k, v);
        }
        assert_eq!(merged, flat);
        assert_eq!(merged.total(), 15.0);
        assert_eq!(merged.max_entry(), Some((10, 9.0)));
    }

    #[test]
    fn staleness_summary_exact() {
        let mut h = StalenessHist::new();
        // Dispatch seqs are unique — values never accumulate.
        for (d, s) in [(7u64, 0u64), (3, 1), (11, 1), (20, 4)] {
            h.add(d, s);
        }
        let s = h.summary();
        assert_eq!(s.n, 4);
        assert_eq!(s.p50, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.mean, 1.5);
        // Merge of disjoint fragments in either order is identical.
        let mut a = StalenessHist::new();
        a.add(1, 2);
        let mut b = StalenessHist::new();
        b.add(2, 3);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.hist, ba.hist);
        h.clear();
        assert_eq!(h.summary(), StalenessSummary::default());
    }

    #[test]
    fn small_and_empty_hists() {
        let h = LatencyHist::new();
        assert_eq!(h.summary(), LatencySummary::default());
        assert_eq!(h.quantile(0.5), 0.0);
        let mut one = LatencyHist::new();
        one.add(4, 2.0);
        let s = one.summary();
        assert_eq!((s.p50_s, s.p95_s, s.max_s, s.straggler), (2.0, 2.0, 2.0, 4));
    }
}
