//! Per-client latency distributions with exact quantiles.
//!
//! The engine executors time every [`crate::engine::ClientTask`] on the
//! worker that ran it; the coordinator feeds those timings here keyed
//! by **client id**, so a client that runs in several executor calls
//! within one round (e.g. basis-gradient round + local iterations)
//! accumulates its total seconds. Keying by client id makes the merge
//! order-independent: serial and thread-pool executors produce the same
//! histogram contents for the same per-task durations regardless of
//! completion order.
//!
//! Quantiles are **exact** (nearest-rank over the sorted samples), not
//! bucketed estimates — client counts are metrics-sized, so sorting a
//! copy is cheap and the tests can assert exact values.

/// Accumulated per-client latencies for one round.
#[derive(Debug, Clone, Default)]
pub struct LatencyHist {
    /// `client id → accumulated seconds`, kept sorted by client id.
    samples: Vec<(usize, f64)>,
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist::default()
    }

    /// Add `secs` to `client`'s accumulated latency.
    pub fn add(&mut self, client: usize, secs: f64) {
        match self.samples.binary_search_by_key(&client, |&(c, _)| c) {
            Ok(i) => self.samples[i].1 += secs,
            Err(i) => self.samples.insert(i, (client, secs)),
        }
    }

    /// Number of distinct clients observed.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Sum of all per-client latencies, folded in client-id order.
    ///
    /// For a single serial executor call this equals the executor's
    /// `serial_s` bitwise: tasks are planned in ascending client id, so
    /// both sums fold the same numbers in the same order on the same
    /// monotonic clock.
    pub fn total_s(&self) -> f64 {
        self.samples.iter().map(|&(_, s)| s).sum()
    }

    /// Exact nearest-rank quantile: the smallest sample `x` such that
    /// at least `q·n` samples are ≤ `x`. `quantile(1.0)` is the max.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.samples.iter().map(|&(_, s)| s).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        v[rank - 1]
    }

    /// The slowest client this round: `(client id, seconds)`.
    pub fn straggler(&self) -> Option<(usize, f64)> {
        self.samples
            .iter()
            .copied()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Collapse into the per-round summary exported with the metrics.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let (straggler, max_s) = self.straggler().unwrap();
        LatencySummary {
            n: self.len(),
            p50_s: self.quantile(0.50),
            p95_s: self.quantile(0.95),
            max_s,
            sum_s: self.total_s(),
            straggler,
        }
    }

    /// Reset for the next round, keeping capacity.
    pub fn clear(&mut self) {
        self.samples.clear();
    }
}

/// Per-round latency-distribution summary (exported in round JSON as
/// `lat_p50_s` / `lat_p95_s` / `lat_max_s` / `straggler` when `n > 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    /// Distinct clients observed; `0` means "no latency data".
    pub n: usize,
    /// Median per-client latency (exact nearest-rank).
    pub p50_s: f64,
    /// 95th-percentile per-client latency (exact nearest-rank).
    pub p95_s: f64,
    /// Slowest client's latency.
    pub max_s: f64,
    /// Sum of per-client latencies (client-id fold order; equals the
    /// serial executor's `serial_s` for single-call rounds).
    pub sum_s: f64,
    /// Client id of the slowest client (the round's straggler).
    pub straggler: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_exact_on_known_inputs() {
        // 1..=100 seconds: nearest-rank p50 = 50, p95 = 95, max = 100.
        let mut h = LatencyHist::new();
        for c in 0..100 {
            h.add(c, (c + 1) as f64);
        }
        assert_eq!(h.quantile(0.50), 50.0);
        assert_eq!(h.quantile(0.95), 95.0);
        assert_eq!(h.quantile(1.0), 100.0);
        assert_eq!(h.straggler(), Some((99, 100.0)));
        let s = h.summary();
        assert_eq!((s.n, s.p50_s, s.p95_s, s.max_s), (100, 50.0, 95.0, 100.0));
    }

    #[test]
    fn identical_work_collapses_quantiles() {
        let mut h = LatencyHist::new();
        for c in 0..7 {
            h.add(c, 0.25);
        }
        let s = h.summary();
        assert_eq!(s.p50_s, s.p95_s);
        assert_eq!(s.p95_s, s.max_s);
        assert_eq!(s.sum_s, 7.0 * 0.25);
    }

    #[test]
    fn merge_is_order_independent() {
        let timings = [(3usize, 0.5), (1, 0.25), (2, 0.125), (1, 0.0625)];
        let mut fwd = LatencyHist::new();
        for &(c, s) in &timings {
            fwd.add(c, s);
        }
        let mut rev = LatencyHist::new();
        for &(c, s) in timings.iter().rev() {
            rev.add(c, s);
        }
        assert_eq!(fwd.samples, rev.samples);
        assert_eq!(fwd.summary(), rev.summary());
    }

    #[test]
    fn small_and_empty_hists() {
        let h = LatencyHist::new();
        assert_eq!(h.summary(), LatencySummary::default());
        assert_eq!(h.quantile(0.5), 0.0);
        let mut one = LatencyHist::new();
        one.add(4, 2.0);
        let s = one.summary();
        assert_eq!((s.p50_s, s.p95_s, s.max_s, s.straggler), (2.0, 2.0, 2.0, 4));
    }
}
