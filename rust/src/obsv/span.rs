//! Hierarchical span timers over the static phase taxonomy.
//!
//! One [`Recorder`] per run, owned by the coordinator's driver and
//! passed down by shared reference (interior mutability; spans only
//! ever open and close on the coordinator thread — executor workers
//! never touch the recorder, their timings arrive post-join via
//! [`Recorder::record_exec`], which keeps the merge order-independent
//! and the determinism contract trivially intact).
//!
//! Spans nest: only the **top-level** span open at any instant
//! accumulates into the round's [`PhaseSeconds`], so per-round
//! `sum(phase_s) ≤ wall_s` holds by construction; nested spans still
//! appear in the trace for drill-down. RAII closes spans on every exit
//! path.
//!
//! Three operating points, same API (zero-overhead argument in
//! DESIGN.md §Observability):
//!
//! * [`Recorder::disabled`] — spans carry no recorder reference, read
//!   no clock, and allocate nothing;
//! * [`Recorder::new`] — phase seconds + latency histograms (a clock
//!   read per span edge, a fixed-size accumulator, no per-span
//!   allocation) — the default for every run;
//! * [`Recorder::with_trace`] — additionally buffers one
//!   [`TraceEvent`] per span/task for `--trace` export.

use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

use crate::engine::executor::ExecTiming;
use crate::engine::plan::RoundPlan;

use super::hist::{LatencyHist, LatencySummary, StalenessHist, StalenessSummary};
use super::trace::{write_chrome_trace, TraceEvent};
use super::{Phase, PhaseSeconds};

/// What one round's telemetry collapses to (folded into
/// [`crate::metrics::RoundMetrics`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoundObs {
    pub phase_s: PhaseSeconds,
    pub latency: LatencySummary,
    /// Staleness of the updates consumed this aggregation (async
    /// schedules only; `n = 0` for sync rounds).
    pub staleness: StalenessSummary,
}

#[derive(Debug, Default)]
struct Inner {
    /// Currently open spans; accumulation happens only when the
    /// closing span returns the depth to zero.
    depth: u32,
    round: usize,
    round_start: Option<Instant>,
    phase_acc: PhaseSeconds,
    hist: LatencyHist,
    staleness: StalenessHist,
    trace: Vec<TraceEvent>,
}

/// Run-scoped telemetry recorder. See the module docs for ownership
/// and overhead; construction picks the operating point.
#[derive(Debug)]
pub struct Recorder {
    collect: bool,
    tracing: bool,
    epoch: Instant,
    inner: RefCell<Inner>,
}

impl Recorder {
    /// The no-op recorder: same API, no clock reads, no allocations.
    pub fn disabled() -> Recorder {
        Recorder {
            collect: false,
            tracing: false,
            epoch: Instant::now(),
            inner: RefCell::new(Inner::default()),
        }
    }

    /// Phase seconds + per-client latency histograms (the default for
    /// every run; overhead is a clock read per span edge).
    pub fn new() -> Recorder {
        Recorder { collect: true, ..Recorder::disabled() }
    }

    /// Everything in [`Recorder::new`] plus Chrome trace-event capture
    /// for [`Recorder::write_trace`].
    pub fn with_trace() -> Recorder {
        Recorder { collect: true, tracing: true, ..Recorder::disabled() }
    }

    /// Whether phase/latency collection is on.
    pub fn is_enabled(&self) -> bool {
        self.collect
    }

    /// Whether trace events are being buffered.
    pub fn is_tracing(&self) -> bool {
        self.tracing
    }

    /// Open a phase span; closing is RAII (drop the guard).
    pub fn span(&self, phase: Phase) -> Span<'_> {
        if !self.collect {
            // `epoch` is a copy, not a clock read: disabled spans are
            // inert values.
            return Span { rec: None, phase, start: self.epoch };
        }
        self.inner.borrow_mut().depth += 1;
        Span { rec: Some(self), phase, start: Instant::now() }
    }

    fn finish(&self, phase: Phase, start: Instant) {
        let dur_s = start.elapsed().as_secs_f64();
        let mut inner = self.inner.borrow_mut();
        inner.depth -= 1;
        if inner.depth == 0 {
            inner.phase_acc.add(phase, dur_s);
        }
        if self.tracing {
            let ts_us = start.duration_since(self.epoch).as_secs_f64() * 1e6;
            inner.trace.push(TraceEvent {
                name: phase.label().to_string(),
                ts_us,
                dur_us: dur_s * 1e6,
                tid: 0,
            });
        }
    }

    /// Mark the start of round `round` (resets the per-round
    /// accumulators; the matching [`Recorder::end_round`] collapses
    /// them).
    pub fn begin_round(&self, round: usize) {
        if !self.collect {
            return;
        }
        let mut inner = self.inner.borrow_mut();
        inner.round = round;
        inner.round_start = Some(Instant::now());
        inner.phase_acc = PhaseSeconds::default();
        inner.hist.clear();
        inner.staleness.clear();
    }

    /// Record that the update from dispatch `dispatch` was consumed at
    /// model-version staleness `sigma` (async aggregation; keyed by
    /// dispatch sequence so the fold is order-independent — the same
    /// [`super::hist::KeyedHist`] core as the latency histogram).
    pub fn record_staleness(&self, dispatch: u64, sigma: u64) {
        if !self.collect {
            return;
        }
        self.inner.borrow_mut().staleness.add(dispatch, sigma);
    }

    /// Fold an executor call's per-task timings into the round's
    /// per-client latency histogram (and, when tracing, one worker-track
    /// event per task). `label` names the call in the trace (`grad`,
    /// `local`, `vc_grad`).
    pub fn record_exec(&self, label: &str, plan: &RoundPlan, timing: &ExecTiming) {
        if !self.collect {
            return;
        }
        let mut inner = self.inner.borrow_mut();
        for (task, t) in plan.tasks.iter().zip(&timing.tasks) {
            inner.hist.add(task.client_id, t.dur_s);
        }
        if self.tracing {
            let base_us = timing.started.duration_since(self.epoch).as_secs_f64() * 1e6;
            for (task, t) in plan.tasks.iter().zip(&timing.tasks) {
                inner.trace.push(TraceEvent {
                    name: format!("{label} c{}", task.client_id),
                    ts_us: base_us + t.start_s * 1e6,
                    dur_us: t.dur_s * 1e6,
                    tid: t.worker as u32 + 1,
                });
            }
        }
    }

    /// Close the round: returns its phase seconds + latency summary and
    /// resets the accumulators. When tracing, also emits the enclosing
    /// `round N` event on the coordinator track.
    pub fn end_round(&self) -> RoundObs {
        if !self.collect {
            return RoundObs::default();
        }
        let mut inner = self.inner.borrow_mut();
        let obs = RoundObs {
            phase_s: inner.phase_acc,
            latency: inner.hist.summary(),
            staleness: inner.staleness.summary(),
        };
        if self.tracing {
            if let Some(start) = inner.round_start.take() {
                let name = format!("round {}", inner.round);
                inner.trace.push(TraceEvent {
                    name,
                    ts_us: start.duration_since(self.epoch).as_secs_f64() * 1e6,
                    dur_us: start.elapsed().as_secs_f64() * 1e6,
                    tid: 0,
                });
            }
        }
        inner.phase_acc = PhaseSeconds::default();
        inner.hist.clear();
        inner.staleness.clear();
        obs
    }

    /// Number of trace events buffered so far.
    pub fn trace_len(&self) -> usize {
        self.inner.borrow().trace.len()
    }

    /// Write the buffered events as a Chrome trace (no-op buffer when
    /// tracing was off — the file is still valid, just empty of spans).
    pub fn write_trace(&self, path: &Path) -> std::io::Result<()> {
        write_chrome_trace(path, &self.inner.borrow().trace)
    }
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

/// RAII guard for one phase span (see [`Recorder::span`]).
#[must_use = "a span measures the scope it is bound to — bind it to a variable"]
pub struct Span<'a> {
    rec: Option<&'a Recorder>,
    phase: Phase,
    start: Instant,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some(rec) = self.rec {
            rec.finish(self.phase, self.start);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;
    use crate::engine::executor::TaskTiming;
    use crate::util::Stopwatch;

    fn spin(us: u64) {
        let t0 = Instant::now();
        while t0.elapsed().as_micros() < us as u128 {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn top_level_spans_accumulate_nested_do_not() {
        let rec = Recorder::new();
        rec.begin_round(0);
        let outer = Stopwatch::start();
        {
            let _s = rec.span(Phase::Broadcast);
            spin(200);
            {
                let _inner = rec.span(Phase::Eval); // nested: trace-only
                spin(200);
            }
        }
        {
            let _s = rec.span(Phase::TruncateSvd);
            spin(100);
        }
        let wall = outer.elapsed_s();
        let obs = rec.end_round();
        assert!(obs.phase_s.get(Phase::Broadcast) > 0.0);
        // The nested Eval span must not double-count.
        assert_eq!(obs.phase_s.get(Phase::Eval), 0.0);
        assert!(obs.phase_s.get(Phase::TruncateSvd) > 0.0);
        assert!(
            obs.phase_s.sum() <= wall + 1e-6,
            "phase sum {} exceeds wall {}",
            obs.phase_s.sum(),
            wall
        );
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = Recorder::disabled();
        rec.begin_round(3);
        {
            let _s = rec.span(Phase::ClientTrain);
        }
        let obs = rec.end_round();
        assert_eq!(obs, RoundObs::default());
        assert_eq!(rec.trace_len(), 0);
        assert!(!rec.is_enabled() && !rec.is_tracing());
    }

    #[test]
    fn exec_timings_feed_histogram_and_trace() {
        let cfg = TrainConfig { seed: 5, ..TrainConfig::default() };
        let plan = RoundPlan::build(&cfg, 3, 0, |_| 1.0);
        let timing = ExecTiming {
            started: Instant::now(),
            tasks: vec![
                TaskTiming { start_s: 0.0, dur_s: 0.5, worker: 0 },
                TaskTiming { start_s: 0.0, dur_s: 0.25, worker: 1 },
                TaskTiming { start_s: 0.5, dur_s: 1.0, worker: 0 },
            ],
        };
        let rec = Recorder::with_trace();
        rec.begin_round(0);
        rec.record_exec("grad", &plan, &timing);
        let obs = rec.end_round();
        assert_eq!(obs.latency.n, 3);
        assert_eq!(obs.latency.max_s, 1.0);
        assert_eq!(obs.latency.straggler, 2);
        assert_eq!(obs.latency.sum_s, 1.75);
        // 3 task events + 1 round event.
        assert_eq!(rec.trace_len(), 4);
    }

    #[test]
    fn staleness_records_fold_into_round_obs() {
        let rec = Recorder::new();
        rec.begin_round(0);
        rec.record_staleness(10, 0);
        rec.record_staleness(11, 2);
        rec.record_staleness(12, 4);
        let obs = rec.end_round();
        assert_eq!(obs.staleness.n, 3);
        assert_eq!(obs.staleness.p50, 2.0);
        assert_eq!(obs.staleness.max, 4.0);
        assert_eq!(obs.staleness.mean, 2.0);
        // Cleared for the next round.
        rec.begin_round(1);
        assert_eq!(rec.end_round().staleness.n, 0);
        // Disabled recorder stays inert.
        let off = Recorder::disabled();
        off.begin_round(0);
        off.record_staleness(1, 7);
        assert_eq!(off.end_round().staleness.n, 0);
    }

    #[test]
    fn round_reset_between_rounds() {
        let rec = Recorder::new();
        rec.begin_round(0);
        {
            let _s = rec.span(Phase::Eval);
            spin(50);
        }
        let first = rec.end_round();
        assert!(first.phase_s.get(Phase::Eval) > 0.0);
        rec.begin_round(1);
        let second = rec.end_round();
        assert_eq!(second.phase_s.sum(), 0.0);
        assert_eq!(second.latency.n, 0);
    }
}
