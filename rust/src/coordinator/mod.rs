//! The federated coordinator — the paper's system contribution (L3).
//!
//! Round engines for every algorithm in the paper:
//!
//! | Module | Algorithm | Paper |
//! |---|---|---|
//! | [`fedlrt`] | FeDLRT, all three variance-correction modes | Alg 1 / Alg 5 / eq. 7 |
//! | [`dense_baselines`] | FedAvg, FedLin | Alg 3 / Alg 4 |
//! | [`fedlrt_naive`] | per-client-basis low-rank FL | Alg 6 |
//! | [`async_server`] | event-driven async FeDLRT (FedBuff-style buffered K-of-N and staleness-weighted) | §async extension |
//!
//! All engines are generic over [`crate::models::FedProblem`], route
//! every transfer through [`crate::comm::Network`] for exact
//! communication accounting, and emit [`crate::metrics::RunRecord`]s.
//! Per-round client work is scheduled by [`crate::engine::RoundPlan`]
//! (participation sampling, dropout, stragglers) and submitted to the
//! configured [`crate::engine::ClientExecutor`] as hermetic work items;
//! serial and thread-pool execution are bitwise-identical.
//!
//! Every engine has a `run_*_obs` variant taking an explicit
//! [`crate::obsv::Recorder`]; the plain `run_*` entry points use the
//! default (phases + latency, no trace). Telemetry is observe-only —
//! see DESIGN.md §Observability for the determinism argument.

pub mod aggregate;
pub mod async_server;
pub mod config;
pub mod dense_baselines;
pub mod fedlr;
pub mod fedlrt;
pub mod fedlrt_naive;
pub mod presets;
pub mod sampling;

pub use aggregate::{Aggregator, RobustAccum};
pub use async_server::{run_async, run_async_obs, run_async_traced, EventKind, EventTraceRow};
pub use config::{AsyncConfig, RankConfig, Schedule, TrainConfig, VarCorrection};
pub use dense_baselines::{run_dense, run_dense_obs, DenseAlgo};
pub use fedlr::{run_fedlr, run_fedlr_obs};
pub use fedlrt::{run_fedlrt, run_fedlrt_obs};
pub use fedlrt_naive::{run_fedlrt_naive, run_fedlrt_naive_obs};
