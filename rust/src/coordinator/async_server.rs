//! Event-driven asynchronous FeDLRT server: virtual-clock simulation,
//! buffered (FedBuff-style K-of-N) and staleness-weighted aggregation
//! into the shared low-rank basis, over a sharded lazily-materialized
//! client registry that scales registration to C = 10^6.
//!
//! ## Simulation model
//!
//! The server keeps `concurrency` dispatch slots. Each slot draws a
//! client uniformly from the registered population, bills a unicast
//! downlink of the current model (decode-on-receive through the wire
//! codec), and schedules the client's upload at
//! `now + compute_time + link_time` on the virtual clock (draws from
//! [`crate::engine::TimingModel`]). When an upload is processed the
//! slot immediately redisperses after an arrival gap. Arrived updates
//! enter a FIFO buffer; every K arrivals the server aggregates.
//!
//! ## Determinism at any thread count
//!
//! The event timeline — dispatch times, client picks, upload times,
//! buffer membership, staleness — is a pure function of the config and
//! seed, **independent of any numeric training result**: timing draws
//! are keyed by `(seed, salt, dispatch)` and the queue's `(time, seq)`
//! total order breaks ties by insertion. Only the *model contents*
//! depend on client math. That separation lets the server defer all
//! client computation to aggregation time and batch the K consumed
//! runs through one [`crate::engine::ClientExecutor`] call over a
//! synthetic [`RoundPlan`] in buffer order — the executor returns
//! results in task order and the reduction folds them in buffer order,
//! so serial and thread-pool executors produce bitwise-identical event
//! traces AND trajectories (`tests/engine_determinism.rs`).
//!
//! ## Aggregation policies
//!
//! Both policies consume the K oldest buffered updates in arrival
//! order and fold client coefficient deltas `ΔS_c` into the shared
//! basis:
//!
//! * **FedBuff** ([`Schedule::FedBuff`]): weights are the clients' raw
//!   aggregation weights normalized over the buffer (uniform weights →
//!   exactly `1/K`). An arrival whose staleness exceeds
//!   `max_staleness` is discarded on arrival — or admitted anyway when
//!   `hold_stale` is set (never lose data, accept the staleness).
//! * **Staleness-weighted async** ([`Schedule::AsyncStale`]): nothing
//!   is ever discarded; weights are `client_weight · 1/(1+σ)^p`
//!   normalized over the buffer, applied **before** the variance
//!   correction is refreshed from the same weighted fold.
//!
//! A stale update lives in the basis its dispatch saw. When the basis
//! has been refreshed since (`basis_version` differs), its ΔS is
//! carried across by the orthogonal-projection change of coordinates
//! `ΔS ← (U_curᵀ U_disp) · ΔS · (V_dispᵀ V_cur)` — the paper's frozen
//! shared basis is exactly what makes this cheap (r×r matmuls).
//!
//! ## Variance correction, async analog
//!
//! The server maintains `ḡ`, the weighted buffer mean of the clients'
//! first-iteration coefficient gradients (None until the first
//! aggregation). A dispatch snapshot carries the current ḡ; the client
//! applies the FedLin-style correction `ḡ − g_c` from its own first
//! gradient to every local step — so the staleness weights (applied at
//! the fold that *produces* ḡ) act before the correction, as the
//! tentpole specifies. `var_correction = None` disables all of it.

use std::sync::Arc;

use crate::client::{
    change_coords, Correction, CorrectionEngine, DriftState, GradMode, LocalUpdate,
};
use crate::comm::{faults, FaultRoundStats, Network};
use crate::engine::{
    task_seed, ClientExecutor, ClientFault, ClientRecord, ClientRegistry, ClientTask, EventQueue,
    Executor, RoundPlan, TimingModel,
};
use crate::lowrank::{truncate_ws, LowRank};
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::tensor::{Matrix, Workspace};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::aggregate::{plan_order_sum, RobustAccum};
use super::config::{Schedule, TrainConfig, VarCorrection};

/// Salt for the client-pick stream (disjoint from the sync sampling /
/// straggler / dropout salts and the timing-model salts).
const SALT_PICK: u64 = 0xD15C_A7C4;

/// One row of the deterministic event trace (the async determinism
/// contract's witness: fixed seed ⇒ identical rows at any executor or
/// `kernel_threads` setting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventTraceRow {
    /// Virtual timestamp, as raw bits so comparisons are exact.
    pub time_bits: u64,
    /// Queue sequence number of the triggering event.
    pub seq: u64,
    pub kind: EventKind,
    /// Client id (for [`EventKind::Aggregate`]: number of consumed
    /// updates).
    pub client: usize,
    /// Server model version when the row was written.
    pub version: u64,
    /// Staleness (upload/discard rows; for [`EventKind::Retry`] the
    /// retransmission's attempt number; 0 elsewhere).
    pub staleness: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A slot dispatched the model to a client.
    Dispatch,
    /// A client upload arrived and entered the buffer.
    Upload,
    /// A FedBuff upload exceeded `max_staleness` and was dropped — or,
    /// under an active fault model, an upload exhausted its retry
    /// budget / upload deadline and was abandoned.
    Discard,
    /// The buffer reached K and an aggregation ran.
    Aggregate,
    /// An upload attempt was lost/corrupted and a retransmission was
    /// scheduled with exponential backoff (fault model active).
    Retry,
}

/// The frozen model a dispatch hands its client: the decoded
/// (post-codec) factors, dense params, and variance-correction mean.
struct Snapshot {
    factors: Vec<LowRank>,
    dense: Vec<Matrix>,
    /// `(per-layer ḡ_S, per-dense ḡ)` — present only when variance
    /// correction is on AND at least one aggregation has run.
    g_bar: Option<(Vec<Matrix>, Vec<Matrix>)>,
    /// Decoded SCAFFOLD server control variate at dispatch time, in the
    /// dispatch basis (`None` unless the run uses SCAFFOLD).
    ctrl: Option<DriftState>,
}

/// One in-flight dispatch.
struct Flight {
    client: usize,
    dispatch: u64,
    /// Server version at dispatch (staleness = current − this).
    version: u64,
    basis_version: u64,
    iters: usize,
    step0: u64,
    /// Raw (unnormalized) client aggregation weight.
    weight: f64,
    /// Per-dispatch RNG stream seed (same SplitMix derivation as sync
    /// tasks, keyed by dispatch number instead of round).
    seed: u64,
    /// The client's stored drift state at dispatch time (FedDyn h_c /
    /// SCAFFOLD c_c), in the dispatch basis — device semantics: a
    /// concurrent re-dispatch of the same client sees the same state.
    drift: Option<DriftState>,
    /// Current upload attempt number (0 = first transmission); bumped
    /// by each fault-path retransmission.
    attempt: u32,
    /// Payload copies that rode the wire so far (attempts +
    /// duplicates) — billed as `bytes_retx` beyond the first copy when
    /// the update is consumed.
    wire_copies: u64,
    /// Virtual time the upload transmission started (post-compute);
    /// the [`crate::comm::NetPolicy::timeout`] upload deadline counts
    /// from here, mirroring the sync path's network-time-only clock.
    sent_at: f64,
    snapshot: Arc<Snapshot>,
}

/// What one client run returns to the server.
struct ClientUpdate {
    d_s: Vec<Matrix>,
    d_dense: Vec<Matrix>,
    g_first: Vec<Matrix>,
    g_first_dense: Vec<Matrix>,
    first_loss: f64,
    /// Updated drift state / SCAFFOLD delta, in the *snapshot* basis —
    /// the server projects them into the current basis when stale.
    drift_out: Option<DriftState>,
    ctrl_delta: Option<DriftState>,
}

enum Ev {
    Dispatch,
    Upload { flight: usize },
}

/// Run the async server on `problem` under `cfg` (schedule `fedbuff`
/// or `async`); `cfg.rounds` counts **aggregations**.
pub fn run_async<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
) -> RunRecord {
    run_async_obs(problem, cfg, experiment, &Recorder::new())
}

/// [`run_async`] with an explicit telemetry [`Recorder`].
pub fn run_async_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    run_async_core(problem, cfg, experiment, obs, None)
}

/// [`run_async_obs`] that additionally returns the full event trace —
/// the determinism tests' bitwise witness. Trace memory is O(events),
/// so benches at C = 10^6 use the untraced entry points.
pub fn run_async_traced<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
) -> (RunRecord, Vec<EventTraceRow>) {
    let mut trace = Vec::new();
    let record = run_async_core(problem, cfg, experiment, obs, Some(&mut trace));
    (record, trace)
}

/// Change of coordinates for a tensor expressed in the dispatch-time
/// basis: `(U_curᵀ U_disp) · X · (V_dispᵀ V_cur)`. Delegates to the
/// shared [`change_coords`] map (the drift-correction layer uses the
/// same projection to carry client state across basis refreshes).
fn project_between_bases(cur: &LowRank, disp: &LowRank, x: &Matrix) -> Matrix {
    change_coords(&cur.u, &cur.v, &disp.u, &disp.v, x)
}

/// One client's local run against a frozen snapshot: `iters`
/// coefficient steps on S (and dense params) driven by the shared
/// [`LocalUpdate`] loop, with the FedLin-style correction `ḡ − g_c`
/// when the snapshot carries ḡ. Returns deltas relative to the
/// snapshot plus the first-iteration gradients.
#[allow(clippy::too_many_arguments)]
fn client_run<P: FedProblem>(
    problem: &P,
    cfg: &TrainConfig,
    snap: &Snapshot,
    c: usize,
    step0: u64,
    iters: usize,
    lr_t: f64,
    correction: Correction,
    drift_in: Option<&DriftState>,
    fault: ClientFault,
    fault_seed: u64,
) -> ClientUpdate {
    let vc_on = cfg.var_correction != VarCorrection::None;
    let mut w_c = Weights {
        dense: snap.dense.clone(),
        lr: snap.factors.iter().cloned().map(LrWeight::Factored).collect(),
    };
    let g_bar_ref = if vc_on {
        snap.g_bar.as_ref().map(|(gl, gd)| (gl.as_slice(), gd.as_slice()))
    } else {
        None
    };
    let driver = LocalUpdate {
        opt: cfg.opt,
        lr_t,
        iters,
        step0,
        mode: GradMode::Coeff,
        vc_lr: &[],
        vc_dense: &[],
        g_bar: g_bar_ref,
        capture_first_grad: true,
        correction,
        drift_in,
        ctrl: snap.ctrl.as_ref(),
        fault,
        fault_seed,
    };
    let out = driver.run(problem, c, &mut w_c);
    let (g_first, g_first_dense) = out.g_first.unwrap_or_default();
    let d_s: Vec<Matrix> = w_c
        .lr
        .iter()
        .zip(&snap.factors)
        .map(|(lw, f0)| lw.as_factored().s.sub(&f0.s))
        .collect();
    let d_dense: Vec<Matrix> =
        w_c.dense.iter().zip(&snap.dense).map(|(d, d0)| d.sub(d0)).collect();
    ClientUpdate {
        d_s,
        d_dense,
        g_first,
        g_first_dense,
        first_loss: out.first_loss,
        drift_out: out.drift_out,
        ctrl_delta: out.ctrl_delta,
    }
}

fn run_async_core<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
    mut trace: Option<&mut Vec<EventTraceRow>>,
) -> RunRecord {
    let spec = problem.spec();
    let c_num = problem.num_clients();
    let population = if cfg.population == 0 { c_num } else { cfg.population };
    let mut rng = Rng::new(cfg.seed);

    // Same initialization as the sync coordinator: orthonormal bases,
    // scaled full-rank S (identical seed ⇒ identical starting model).
    let mut factors: Vec<LowRank> = spec
        .lr_shapes
        .iter()
        .map(|&(m, n)| {
            let r0 = cfg.rank.initial_rank.min(m.min(n) / 2).max(1);
            let mut f = LowRank::random_init(m, n, r0, &mut rng);
            f.s.scale_inplace((1.0 / m as f64).sqrt());
            f
        })
        .collect();
    let mut dense: Vec<Matrix> = spec
        .dense_shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale((1.0 / m.max(1) as f64).sqrt()))
        .collect();
    let num_lr = factors.len();

    let mut net = Network::with_codec(population, cfg.codec);
    net.fault = cfg.fault;
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    let mut ws = Workspace::new();
    let algo = format!("fedlrt_{}_{}", cfg.schedule.label(), cfg.var_correction.label());
    let mut record = RunRecord::new(&algo, experiment, population, cfg.seed);
    record.config = cfg.to_json();

    let timing: &TimingModel = &cfg.timing;
    let acfg = &cfg.async_cfg;
    let k = acfg.buffer_k.max(1);
    let concurrency = acfg.concurrency.max(1);
    let basis_every = acfg.basis_every.max(1) as u64;
    let vc_on = cfg.var_correction != VarCorrection::None;

    // Drift-correction engine (see `run_fedlrt`); per-client state lives
    // in the sharded registry records, in the current server coefficient
    // basis at all times (projected at every basis refresh below).
    let mut engine = CorrectionEngine::new(cfg.correction);
    let correction = engine.kind();
    let init_rec = |c: usize| ClientRecord {
        seed: task_seed(cfg.seed, 0, c),
        weight: problem.client_weight(c % c_num),
        next_step: 0,
        speed: timing.client_speed(cfg.seed, c),
        residual: None,
        drift: None,
    };

    let mut registry = ClientRegistry::new(population, ClientRegistry::DEFAULT_SHARD);
    let mut queue: EventQueue<Ev> = EventQueue::new();
    let mut flights: Vec<Option<Flight>> = Vec::new();
    let mut free_flights: Vec<usize> = Vec::new();
    let mut buffer: Vec<usize> = Vec::new();

    let mut version: u64 = 0;
    let mut basis_version: u64 = 0;
    let mut g_bar: Option<(Vec<Matrix>, Vec<Matrix>)> = None;
    let mut dispatch_count: u64 = 0;
    let mut gap_count: u64 = 0;

    // Seed the initial dispatch wave: every slot arrives after its own
    // gap draw, so constant-arrival fleets still have a total order.
    for _ in 0..concurrency {
        let gap = timing.arrival_gap(cfg.seed, gap_count);
        gap_count += 1;
        queue.push(gap, Ev::Dispatch);
    }

    let mut agg: usize = 0;
    let mut watch = Stopwatch::start();
    let mut client_wall_s = 0.0;
    let mut client_serial_s = 0.0;
    obs.begin_round(0);

    while agg < cfg.rounds {
        let Some(ev) = queue.pop() else {
            break; // unreachable while slots redispatch; defensive
        };
        match ev.payload {
            Ev::Dispatch => {
                let sp = obs.span(Phase::Broadcast);
                let d = dispatch_count;
                dispatch_count += 1;
                let client = Rng::new(cfg.seed ^ SALT_PICK).split(d).below(population);
                let rec_c = registry.get_or_init(client, &init_rec);
                let iters = cfg.local_iters.max(1);
                let step0 = rec_c.next_step;
                rec_c.next_step += iters as u64;
                let weight = rec_c.weight;
                // Device semantics: the flight carries the drift state
                // as of dispatch time (in the dispatch basis).
                let drift_c: Option<DriftState> = rec_c.drift.as_deref().cloned();
                // Unicast downlink, billed per dispatch; the client
                // computes on the decoded copies (decode-on-receive).
                let bc_factors: Vec<LowRank> = factors
                    .iter()
                    .map(|f| LowRank {
                        u: net.broadcast_mat("U", &f.u),
                        s: net.broadcast_mat("S", &f.s),
                        v: net.broadcast_mat("V", &f.v),
                    })
                    .collect();
                let bc_dense: Vec<Matrix> =
                    dense.iter().map(|m| net.broadcast_mat("dense_w", m)).collect();
                let bc_g_bar = g_bar.as_ref().map(|(gl, gd)| {
                    (
                        gl.iter().map(|g| net.broadcast_mat("g_bar", g)).collect(),
                        gd.iter().map(|g| net.broadcast_mat("g_bar_dense", g)).collect(),
                    )
                });
                // SCAFFOLD's server variate rides every unicast
                // dispatch through the codec (billed per dispatch).
                let bc_ctrl = engine.broadcast_ctrl(
                    &mut net,
                    &factors.iter().map(|f| (f.rank(), f.rank())).collect::<Vec<_>>(),
                    &dense.iter().map(|m| m.shape()).collect::<Vec<_>>(),
                );
                let snapshot = Arc::new(Snapshot {
                    factors: bc_factors,
                    dense: bc_dense,
                    g_bar: bc_g_bar,
                    ctrl: bc_ctrl,
                });
                let compute_t = timing.compute_time(cfg.seed, client, d);
                let flight = Flight {
                    client,
                    dispatch: d,
                    version,
                    basis_version,
                    iters,
                    step0,
                    weight,
                    seed: task_seed(cfg.seed, d as usize, client),
                    drift: drift_c,
                    attempt: 0,
                    wire_copies: 1,
                    sent_at: queue.now() + compute_t,
                    snapshot,
                };
                let mut done_t =
                    queue.now() + compute_t + timing.link_time(cfg.seed, client, d);
                if cfg.fault.is_active() {
                    // First-attempt delay jitter from the message-scoped
                    // fate stream (the pop re-derives the same fate).
                    let mut arng = faults::attempt_rng(cfg.seed, d, client as u64, 0);
                    done_t += cfg.fault.attempt_fate(&mut arng).delay_s;
                }
                let idx = free_flights.pop().unwrap_or_else(|| {
                    flights.push(None);
                    flights.len() - 1
                });
                flights[idx] = Some(flight);
                queue.push(done_t, Ev::Upload { flight: idx });
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(EventTraceRow {
                        time_bits: ev.time.to_bits(),
                        seq: ev.seq,
                        kind: EventKind::Dispatch,
                        client,
                        version,
                        staleness: 0,
                    });
                }
                drop(sp);
            }
            Ev::Upload { flight: idx } => {
                // Unreliable transport: each arrival is an *attempt*
                // whose fate is a pure function of
                // (seed, dispatch, client, attempt) — nothing here
                // reads training results, so the event timeline stays
                // executor-independent. Same activation rule as the
                // sync gate: an active fault model OR a policy-only
                // config (e.g. a bare --timeout) enters; fully
                // inactive transport skips the block (bitwise-legacy).
                if faults::transport_active(&cfg.fault, &cfg.net_policy) {
                    let (fl_client, fl_dispatch, fl_attempt, fl_sent, fl_version) = {
                        let fl = flights[idx].as_ref().expect("attempt for freed flight");
                        (fl.client, fl.dispatch, fl.attempt, fl.sent_at, fl.version)
                    };
                    let mut arng =
                        faults::attempt_rng(cfg.seed, fl_dispatch, fl_client as u64, fl_attempt);
                    let fate = cfg.fault.attempt_fate(&mut arng);
                    if fate.duplicated {
                        // Deduplicated server-side; the copy's bytes
                        // still ride the wire and bill as retx below.
                        flights[idx].as_mut().expect("attempt for freed flight").wire_copies += 1;
                    }
                    let late = cfg.net_policy.timeout > 0.0
                        && ev.time - fl_sent > cfg.net_policy.timeout;
                    if fate.lost || fate.corrupt || late {
                        // Book the failure the way the sync gate does:
                        // checksum rejections count as corrupt; lost and
                        // deadline-abandoned attempts count as dropped.
                        if !fate.lost && fate.corrupt {
                            net.note_faults(0, 1, 0);
                        } else {
                            net.note_faults(1, 0, 0);
                        }
                        if !late && fl_attempt < cfg.net_policy.retries {
                            // Retransmit: derive the next attempt's fate
                            // stream now for arrival shaping — delay
                            // jitter plus a fresh link-time draw AFTER
                            // the fate (fixed order) — with exponential
                            // backoff on the redrawn link time, mirroring
                            // `FaultModel::deliver`.
                            let next_attempt = {
                                let fl = flights[idx].as_mut().expect("attempt for freed flight");
                                fl.attempt += 1;
                                fl.wire_copies += 1;
                                fl.attempt
                            };
                            let mut nrng = faults::attempt_rng(
                                cfg.seed,
                                fl_dispatch,
                                fl_client as u64,
                                next_attempt,
                            );
                            let nfate = cfg.fault.attempt_fate(&mut nrng);
                            let retx_link = timing.link.sample(&mut nrng).max(0.0);
                            let backoff =
                                retx_link * (1u64 << (next_attempt - 1).min(62)) as f64;
                            net.note_faults(0, 0, 1);
                            queue.push(
                                queue.now() + backoff + retx_link + nfate.delay_s,
                                Ev::Upload { flight: idx },
                            );
                            if let Some(tr) = trace.as_deref_mut() {
                                tr.push(EventTraceRow {
                                    time_bits: ev.time.to_bits(),
                                    seq: ev.seq,
                                    kind: EventKind::Retry,
                                    client: fl_client,
                                    version,
                                    staleness: next_attempt as u64,
                                });
                            }
                            continue; // slot stays occupied until the retry lands
                        }
                        // Retry budget exhausted or past the deadline:
                        // the update is lost for good — free the slot
                        // and redispatch.
                        flights[idx] = None;
                        free_flights.push(idx);
                        let gap = timing.arrival_gap(cfg.seed, gap_count);
                        gap_count += 1;
                        queue.push(queue.now() + gap, Ev::Dispatch);
                        if let Some(tr) = trace.as_deref_mut() {
                            tr.push(EventTraceRow {
                                time_bits: ev.time.to_bits(),
                                seq: ev.seq,
                                kind: EventKind::Discard,
                                client: fl_client,
                                version,
                                staleness: version - fl_version,
                            });
                        }
                        continue;
                    }
                }

                // Free the slot: its next client arrives after a gap.
                let gap = timing.arrival_gap(cfg.seed, gap_count);
                gap_count += 1;
                queue.push(queue.now() + gap, Ev::Dispatch);

                let (fl_client, fl_version) = {
                    let fl = flights[idx].as_ref().expect("upload for freed flight");
                    (fl.client, fl.version)
                };
                let sigma = version - fl_version;
                let discard = cfg.schedule == Schedule::FedBuff
                    && acfg.max_staleness > 0
                    && sigma > acfg.max_staleness
                    && !acfg.hold_stale;
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(EventTraceRow {
                        time_bits: ev.time.to_bits(),
                        seq: ev.seq,
                        kind: if discard { EventKind::Discard } else { EventKind::Upload },
                        client: fl_client,
                        version,
                        staleness: sigma,
                    });
                }
                if discard {
                    flights[idx] = None;
                    free_flights.push(idx);
                    continue;
                }
                buffer.push(idx);
                if buffer.len() < k {
                    continue;
                }

                // ---- Aggregation: consume the K oldest arrivals. ----
                let consumed: Vec<usize> = buffer.drain(..k).collect();
                let lr_t = cfg.lr.at(agg);

                // Batch-execute the K client runs in buffer order.
                // Dispatch metadata is result-independent, so running
                // the math here (not at dispatch) changes nothing
                // except enabling deterministic parallelism.
                let sp_train = obs.span(Phase::ClientTrain);
                let tasks: Vec<ClientTask> = consumed
                    .iter()
                    .enumerate()
                    .map(|(ordinal, &fi)| {
                        let fl = flights[fi].as_ref().expect("consumed flight is occupied");
                        ClientTask {
                            client_id: fl.client,
                            ordinal,
                            local_iters: fl.iters,
                            weight: fl.weight,
                            seed: fl.seed,
                            fault: cfg.scenario.fault_for(cfg.seed, fl.client),
                        }
                    })
                    .collect();
                let plan = RoundPlan { round: agg, tasks };
                // The flights' drift states move into the work items
                // (they were cloned out of the registry at dispatch).
                let drift_pre: Vec<Option<DriftState>> = consumed
                    .iter()
                    .map(|&fi| flights[fi].as_mut().expect("consumed flight is occupied").drift.take())
                    .collect();
                let snaps: Vec<Arc<Snapshot>> = consumed
                    .iter()
                    .map(|&fi| flights[fi].as_ref().expect("consumed flight is occupied").snapshot.clone())
                    .collect();
                let steps0: Vec<u64> =
                    consumed.iter().map(|&fi| flights[fi].as_ref().expect("consumed flight is occupied").step0).collect();
                let report = executor.execute(&plan, |task| {
                    client_run(
                        problem,
                        cfg,
                        &snaps[task.ordinal],
                        task.client_id % c_num,
                        steps0[task.ordinal],
                        task.local_iters,
                        lr_t,
                        correction,
                        drift_pre[task.ordinal].as_ref(),
                        task.fault,
                        task.seed,
                    )
                });
                obs.record_exec("async_local", &plan, &report.timing);
                drop(sp_train);
                client_wall_s += report.wall_s;
                client_serial_s += report.serial_s;

                // Reduce in buffer order: staleness weights, uplink
                // billing of exactly the consumed updates, projection
                // of stale updates into the current basis.
                let sp_agg = obs.span(Phase::Aggregate);
                let sigmas: Vec<u64> = consumed
                    .iter()
                    .map(|&fi| version - flights[fi].as_ref().expect("consumed flight is occupied").version)
                    .collect();
                let raw_w: Vec<f64> = consumed
                    .iter()
                    .zip(&sigmas)
                    .map(|(&fi, &s)| {
                        let w = flights[fi].as_ref().expect("consumed flight is occupied").weight;
                        match cfg.schedule {
                            Schedule::AsyncStale => {
                                w / (1.0 + s as f64).powf(acfg.staleness_p)
                            }
                            _ => w,
                        }
                    })
                    .collect();
                let total_w = plan_order_sum(&raw_w);
                let mut ds_mean: Vec<Matrix> =
                    factors.iter().map(|f| ws.take_mat(f.rank(), f.rank())).collect();
                let mut dd_mean: Vec<Matrix> =
                    dense.iter().map(|m| Matrix::zeros(m.rows(), m.cols())).collect();
                // Robust aggregation applies to the model deltas only;
                // the ḡ variance-correction folds below stay weighted
                // means (they are control signals, not the update).
                // Mean keeps the legacy axpy fold, bitwise.
                let mut robust_s = RobustAccum::new(cfg.aggregator, num_lr);
                let mut robust_d = RobustAccum::new(cfg.aggregator, dense.len());
                let mut gb_lr_new: Vec<Matrix> =
                    factors.iter().map(|f| Matrix::zeros(f.rank(), f.rank())).collect();
                let mut gb_dense_new: Vec<Matrix> =
                    dense.iter().map(|m| Matrix::zeros(m.rows(), m.cols())).collect();
                let mut local_loss_w = 0.0;
                let mut drift_staged: Vec<(usize, DriftState)> = Vec::new();
                let mut ctrl_delta_sum: Option<DriftState> = None;
                for (i, &fi) in consumed.iter().enumerate() {
                    let fl = flights[fi].as_ref().expect("consumed flight is occupied");
                    let upd = &report.results[i];
                    let wt = raw_w[i] / total_w;
                    local_loss_w += wt * upd.first_loss;
                    obs.record_staleness(fl.dispatch, sigmas[i]);
                    if cfg.fault.is_active() {
                        // Bill this update's retransmitted/duplicate
                        // wire copies beyond the first.
                        net.set_upload_copies(fl.wire_copies);
                    }
                    let stale_basis = fl.basis_version != basis_version;
                    for l in 0..num_lr {
                        let (bytes, decoded) = net.transcode_vec(upd.d_s[l].data());
                        net.note_upload("dS", upd.d_s[l].data().len() as u64, bytes);
                        let mut ds = Matrix::from_vec(
                            upd.d_s[l].rows(),
                            upd.d_s[l].cols(),
                            decoded,
                        );
                        if stale_basis {
                            ds = project_between_bases(
                                &factors[l],
                                &fl.snapshot.factors[l],
                                &ds,
                            );
                        }
                        robust_s.push(l, &mut ds_mean[l], wt, &ds);
                        if vc_on {
                            let gf_raw = &upd.g_first[l];
                            let (bytes, decoded) = net.transcode_vec(gf_raw.data());
                            net.note_upload("g_first", gf_raw.data().len() as u64, bytes);
                            let mut gf =
                                Matrix::from_vec(gf_raw.rows(), gf_raw.cols(), decoded);
                            if stale_basis {
                                gf = project_between_bases(
                                    &factors[l],
                                    &fl.snapshot.factors[l],
                                    &gf,
                                );
                            }
                            gb_lr_new[l].axpy(wt, &gf);
                        }
                    }
                    for dl in 0..dense.len() {
                        let (bytes, decoded) = net.transcode_vec(upd.d_dense[dl].data());
                        net.note_upload("d_dense", upd.d_dense[dl].data().len() as u64, bytes);
                        let dd = Matrix::from_vec(
                            upd.d_dense[dl].rows(),
                            upd.d_dense[dl].cols(),
                            decoded,
                        );
                        robust_d.push(dl, &mut dd_mean[dl], wt, &dd);
                        if vc_on {
                            let gd_raw = &upd.g_first_dense[dl];
                            let (bytes, decoded) = net.transcode_vec(gd_raw.data());
                            net.note_upload(
                                "g_first_dense",
                                gd_raw.data().len() as u64,
                                bytes,
                            );
                            gb_dense_new[dl].axpy(
                                wt,
                                &Matrix::from_vec(gd_raw.rows(), gd_raw.cols(), decoded),
                            );
                        }
                    }
                    // Drift state comes back in the snapshot basis;
                    // carry it into the current one when stale, then
                    // stage it for the registry (written post-loop).
                    if let Some(st) = &upd.drift_out {
                        let mut st = st.clone();
                        if stale_basis {
                            for l in 0..num_lr {
                                st.lr[l] = project_between_bases(
                                    &factors[l],
                                    &fl.snapshot.factors[l],
                                    &st.lr[l],
                                );
                            }
                        }
                        drift_staged.push((fl.client, st));
                    }
                    // SCAFFOLD deltas bill real uplink bytes, project
                    // like any stale coefficient tensor, and fold below.
                    if let Some(delta) = &upd.ctrl_delta {
                        let mut dec_lr: Vec<Matrix> = Vec::with_capacity(num_lr);
                        for m in &delta.lr {
                            let (bytes, decoded) = net.transcode_vec(m.data());
                            net.note_upload("ctrl", m.data().len() as u64, bytes);
                            let mut d = Matrix::from_vec(m.rows(), m.cols(), decoded);
                            if stale_basis {
                                let l = dec_lr.len();
                                d = project_between_bases(
                                    &factors[l],
                                    &fl.snapshot.factors[l],
                                    &d,
                                );
                            }
                            dec_lr.push(d);
                        }
                        let mut dec_dense: Vec<Matrix> = Vec::with_capacity(delta.dense.len());
                        for m in &delta.dense {
                            let (bytes, decoded) = net.transcode_vec(m.data());
                            net.note_upload("ctrl_dense", m.data().len() as u64, bytes);
                            dec_dense.push(Matrix::from_vec(m.rows(), m.cols(), decoded));
                        }
                        let dec = DriftState { lr: dec_lr, dense: dec_dense };
                        match ctrl_delta_sum.as_mut() {
                            Some(sum) => {
                                for (a, b) in sum.lr.iter_mut().zip(&dec.lr) {
                                    a.axpy(1.0, b);
                                }
                                for (a, b) in sum.dense.iter_mut().zip(&dec.dense) {
                                    a.axpy(1.0, b);
                                }
                            }
                            None => ctrl_delta_sum = Some(dec),
                        }
                    }
                    flights[fi] = None;
                    free_flights.push(fi);
                }
                if cfg.fault.is_active() {
                    net.set_upload_copies(1);
                }
                robust_s.finish(&mut ds_mean);
                robust_d.finish(&mut dd_mean);
                for (client, st) in drift_staged {
                    registry.get_or_init(client, &init_rec).drift = Some(Box::new(st));
                }
                // SCAFFOLD server fold: c ← c + (1/N) Σ δ over the full
                // registered population.
                if let Some(sum) = ctrl_delta_sum {
                    let inv = 1.0 / population as f64;
                    let mut ctrl = engine.ctrl().expect("dispatch initialized ctrl").clone();
                    for (a, b) in ctrl.lr.iter_mut().zip(&sum.lr) {
                        a.axpy(inv, b);
                    }
                    for (a, b) in ctrl.dense.iter_mut().zip(&sum.dense) {
                        a.axpy(inv, b);
                    }
                    engine.set_ctrl(ctrl);
                }
                // Apply the aggregated step to the server model.
                for (l, buf) in ds_mean.into_iter().enumerate() {
                    factors[l].s.axpy(acfg.server_lr, &buf);
                    ws.give_mat(buf);
                }
                for (dl, buf) in dd_mean.into_iter().enumerate() {
                    dense[dl].axpy(acfg.server_lr, &buf);
                }
                g_bar = if vc_on { Some((gb_lr_new, gb_dense_new)) } else { None };
                version += 1;
                net.set_active_clients(consumed.len());
                net.end_round_trip();
                drop(sp_agg);
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(EventTraceRow {
                        time_bits: ev.time.to_bits(),
                        seq: ev.seq,
                        kind: EventKind::Aggregate,
                        client: consumed.len(),
                        version,
                        staleness: 0,
                    });
                }

                // Periodic basis refresh: re-orthogonalize + truncate
                // the (now non-diagonal) S via the small SVD, and carry
                // ḡ across to the new coordinates.
                let sp_svd = obs.span(Phase::TruncateSvd);
                if version % basis_every == 0 {
                    let mut olds: Vec<LowRank> = Vec::with_capacity(num_lr);
                    for l in 0..num_lr {
                        let theta = cfg.rank.tau * factors[l].s.fro_norm();
                        let res = truncate_ws(
                            &factors[l].u,
                            &factors[l].s,
                            &factors[l].v,
                            theta,
                            1,
                            cfg.rank.max_rank,
                            &mut ws,
                        );
                        let old = std::mem::replace(&mut factors[l], res.fac);
                        if let Some((gb_lr, _)) = g_bar.as_mut() {
                            gb_lr[l] = project_between_bases(&factors[l], &old, &gb_lr[l]);
                        }
                        olds.push(old);
                    }
                    basis_version += 1;
                    // Carry every stored drift state — and the server
                    // control variate — into the refreshed basis, so
                    // registry state is always in the current space.
                    if engine.is_stateful() {
                        registry.for_each_materialized(|_, rec| {
                            if let Some(st) = rec.drift.as_deref_mut() {
                                for l in 0..num_lr {
                                    st.lr[l] = project_between_bases(
                                        &factors[l],
                                        &olds[l],
                                        &st.lr[l],
                                    );
                                }
                            }
                        });
                        if engine.is_scaffold() {
                            if let Some(ctrl) = engine.ctrl() {
                                let new_ctrl = DriftState {
                                    lr: (0..num_lr)
                                        .map(|l| {
                                            project_between_bases(
                                                &factors[l],
                                                &olds[l],
                                                &ctrl.lr[l],
                                            )
                                        })
                                        .collect(),
                                    dense: ctrl.dense.clone(),
                                };
                                engine.set_ctrl(new_ctrl);
                            }
                        }
                    }
                }
                drop(sp_svd);

                // ---- Metrics for this aggregation. ----
                let sp_io = obs.span(Phase::Io);
                let comm = net.end_round();
                let (comm_floats, comm_per_client) =
                    (comm.total_floats(), comm.per_client_floats());
                let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
                let comm_floats_lr = comm.floats_matching(|l| {
                    !matches!(l, "dense_w" | "d_dense" | "g_first_dense" | "g_bar_dense" | "ctrl_dense")
                });
                let fault = FaultRoundStats::from_comm(comm);
                drop(sp_io);
                let sp_eval = obs.span(Phase::Eval);
                let should_eval = agg % cfg.eval_every == 0 || agg + 1 == cfg.rounds;
                let w_eval = Weights {
                    dense: dense.clone(),
                    lr: factors.iter().cloned().map(LrWeight::Factored).collect(),
                };
                let global_loss =
                    if should_eval { problem.global_loss(&w_eval) } else { local_loss_w };
                let dist_to_opt =
                    if should_eval { problem.distance_to_optimum(&w_eval) } else { None };
                let eval_metric =
                    if should_eval { problem.eval_metric(&w_eval) } else { None };
                drop(sp_eval);
                let round_obs = obs.end_round();
                record.rounds.push(RoundMetrics {
                    round: agg,
                    global_loss,
                    ranks: factors.iter().map(|f| f.rank()).collect(),
                    comm_floats,
                    comm_floats_lr,
                    bytes_down,
                    bytes_up,
                    comm_floats_per_client: comm_per_client,
                    dist_to_opt,
                    eval_metric,
                    wall_s: watch.elapsed_s(),
                    client_wall_s,
                    client_serial_s,
                    phase_s: round_obs.phase_s,
                    latency: round_obs.latency,
                    staleness: round_obs.staleness,
                    virtual_s: queue.now(),
                    fault,
                });
                agg += 1;
                if agg < cfg.rounds {
                    obs.begin_round(agg);
                    watch = Stopwatch::start();
                    client_wall_s = 0.0;
                    client_serial_s = 0.0;
                }
            }
        }
    }

    record
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{AsyncConfig, RankConfig};
    use crate::engine::Dist;
    use crate::models::quadratic::Quadratic;
    use crate::opt::LrSchedule;

    fn async_cfg(schedule: Schedule, seed: u64) -> TrainConfig {
        TrainConfig {
            rounds: 12,
            local_iters: 4,
            lr: LrSchedule::Constant(5e-2),
            var_correction: VarCorrection::Simplified,
            rank: RankConfig { initial_rank: 2, max_rank: 6, tau: 0.05 },
            seed,
            schedule,
            async_cfg: AsyncConfig {
                buffer_k: 3,
                concurrency: 6,
                staleness_p: 1.0,
                max_staleness: 0,
                hold_stale: false,
                basis_every: 2,
                server_lr: 1.0,
            },
            timing: crate::engine::TimingModel {
                arrival: Dist::Uniform { lo: 0.05, hi: 0.2 },
                compute: Dist::LogNormal { mu: 0.0, sigma: 0.4 },
                link: Dist::Constant(0.05),
                het_sigma: 0.3,
            },
            ..TrainConfig::default()
        }
    }

    fn quad(seed: u64) -> Quadratic {
        let mut rng = Rng::new(seed);
        let base = Quadratic::random(12, 2, 1, &mut rng);
        Quadratic { targets: vec![base.targets[0].clone(); 4], alphas: vec![1.0; 4], n: 12 }
    }

    #[test]
    fn fedbuff_descends_on_quadratic() {
        let prob = quad(900);
        let mut cfg = async_cfg(Schedule::FedBuff, 42);
        cfg.rounds = 30;
        let rec = run_async(&prob, &cfg, "test");
        assert_eq!(rec.rounds.len(), 30);
        let first = rec.rounds.first().unwrap().global_loss;
        let last = rec.final_loss();
        assert!(last.is_finite());
        assert!(last < first * 0.5, "fedbuff failed to descend: {first} -> {last}");
        // Virtual time advances monotonically across aggregations.
        for w in rec.rounds.windows(2) {
            assert!(w[1].virtual_s >= w[0].virtual_s);
        }
    }

    #[test]
    fn async_stale_descends_and_records_staleness() {
        let prob = quad(901);
        let mut cfg = async_cfg(Schedule::AsyncStale, 7);
        cfg.rounds = 30;
        let rec = run_async(&prob, &cfg, "test");
        let first = rec.rounds.first().unwrap().global_loss;
        let last = rec.final_loss();
        assert!(last.is_finite() && last < first, "{first} -> {last}");
        // Every aggregation consumed exactly K updates, and the
        // staleness summary is populated.
        for r in &rec.rounds {
            assert_eq!(r.staleness.n, 3, "round {}", r.round);
            assert!(r.staleness.max >= r.staleness.p50);
        }
        // With 6 in flight and K = 3, some consumed update is stale.
        assert!(rec.rounds.iter().any(|r| r.staleness.max > 0.0));
    }

    #[test]
    fn event_trace_is_identical_across_executors() {
        let prob = quad(902);
        for schedule in [Schedule::FedBuff, Schedule::AsyncStale] {
            let cfg_serial = async_cfg(schedule, 11);
            let mut cfg_pool = cfg_serial.clone();
            cfg_pool.executor = crate::engine::ExecutorKind::ThreadPool { threads: 3 };
            let (ra, ta) =
                run_async_traced(&prob, &cfg_serial, "t", &Recorder::disabled());
            let (rb, tb) = run_async_traced(&prob, &cfg_pool, "t", &Recorder::disabled());
            assert_eq!(ta, tb, "{:?}: event traces diverged", schedule);
            for (x, y) in ra.rounds.iter().zip(&rb.rounds) {
                assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
                assert_eq!(x.ranks, y.ranks);
                assert_eq!(x.bytes_up, y.bytes_up);
            }
        }
    }

    #[test]
    fn buffered_round_bills_only_k_participants() {
        let prob = quad(903);
        let cfg = async_cfg(Schedule::FedBuff, 3);
        let rec = run_async(&prob, &cfg, "test");
        // Uplink per aggregation: K clients × (dS r×r + G_S r×r)
        // through the 4-byte reference codec — strictly fewer than the
        // 6 in-flight clients would bill.
        let r0 = &rec.rounds[0];
        let rank = r0.ranks[0] as u64;
        // rank recorded post-truncation; uploads were at the dispatch
        // rank (initial 2). K=3, two tensors each 2×2.
        assert_eq!(r0.bytes_up, 3 * 2 * (2 * 2) * 4, "rank {rank}");
    }

    #[test]
    fn max_staleness_discard_drops_updates() {
        let prob = quad(904);
        let mut cfg = async_cfg(Schedule::FedBuff, 5);
        cfg.async_cfg.max_staleness = 1;
        cfg.async_cfg.hold_stale = false;
        let (_, trace) = run_async_traced(&prob, &cfg, "t", &Recorder::disabled());
        let discards = trace.iter().filter(|r| r.kind == EventKind::Discard).count();
        let uploads = trace.iter().filter(|r| r.kind == EventKind::Upload).count();
        // Every admitted upload respects the bound; with hold_stale the
        // same seed admits them all.
        for r in trace.iter().filter(|r| r.kind == EventKind::Upload) {
            assert!(r.staleness <= 1);
        }
        cfg.async_cfg.hold_stale = true;
        let (_, trace_hold) = run_async_traced(&prob, &cfg, "t", &Recorder::disabled());
        let discards_hold =
            trace_hold.iter().filter(|r| r.kind == EventKind::Discard).count();
        assert_eq!(discards_hold, 0, "hold_stale must never discard");
        assert!(uploads > 0);
        let _ = discards;
    }

    #[test]
    fn million_client_registry_run_completes() {
        // C = 10^6 registered clients, 8 in flight: the registry stays
        // sparse (≤ dispatches shards materialized) and the run
        // finishes promptly because state is lazily materialized.
        let prob = quad(905);
        let mut cfg = async_cfg(Schedule::FedBuff, 13);
        cfg.population = 1_000_000;
        cfg.async_cfg.concurrency = 8;
        cfg.rounds = 5;
        let rec = run_async(&prob, &cfg, "test");
        assert_eq!(rec.rounds.len(), 5);
        assert_eq!(rec.num_clients, 1_000_000);
        assert!(rec.final_loss().is_finite());
    }

    #[test]
    fn lossy_async_transport_retries_and_stays_deterministic() {
        // Loss/corruption/duplication with a retry budget: the event
        // timeline (retries included) and the trajectory must be
        // bitwise-identical across executors, and the fault counters
        // must actually book traffic.
        let prob = quad(908);
        let mut cfg_serial = async_cfg(Schedule::FedBuff, 19);
        cfg_serial.rounds = 10;
        cfg_serial.fault = crate::comm::FaultModel {
            loss_prob: 0.25,
            corrupt_prob: 0.1,
            dup_prob: 0.1,
            ..crate::comm::FaultModel::default()
        };
        cfg_serial.net_policy =
            crate::comm::NetPolicy { retries: 2, ..crate::comm::NetPolicy::default() };
        let mut cfg_pool = cfg_serial.clone();
        cfg_pool.executor = crate::engine::ExecutorKind::ThreadPool { threads: 3 };
        let (ra, ta) = run_async_traced(&prob, &cfg_serial, "t", &Recorder::disabled());
        let (rb, tb) = run_async_traced(&prob, &cfg_pool, "t", &Recorder::disabled());
        assert_eq!(ta, tb, "fault-path event traces diverged");
        assert!(ta.iter().any(|r| r.kind == EventKind::Retry), "p=0.25 must retry");
        for (x, y) in ra.rounds.iter().zip(&rb.rounds) {
            assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
            assert_eq!(x.fault, y.fault);
            assert_eq!(x.bytes_up, y.bytes_up);
        }
        let dropped: u64 = ra.rounds.iter().map(|r| r.fault.msgs_dropped).sum();
        let retx: u64 = ra.rounds.iter().map(|r| r.fault.bytes_retx).sum();
        assert!(dropped + retx > 0, "faults must surface in the counters");
        assert!(ra.final_loss().is_finite());
    }

    #[test]
    fn variance_correction_none_skips_gradient_uplink() {
        let prob = quad(906);
        let mut cfg = async_cfg(Schedule::FedBuff, 17);
        cfg.var_correction = VarCorrection::None;
        let rec_none = run_async(&prob, &cfg, "t");
        cfg.var_correction = VarCorrection::Simplified;
        let rec_vc = run_async(&prob, &cfg, "t");
        assert!(
            rec_none.total_bytes_up() < rec_vc.total_bytes_up(),
            "vc-off must uplink less: {} vs {}",
            rec_none.total_bytes_up(),
            rec_vc.total_bytes_up()
        );
    }
}
