//! FeDLRT — the paper's Algorithm 1 (full variance correction),
//! Algorithm 5 (simplified variance correction), and the uncorrected
//! variant (eq. 7), in one round engine.
//!
//! Per aggregation round `t` (annotated with Algorithm 1 line numbers):
//!
//! ```text
//!  (2)  broadcast {Uᵗ, Vᵗ, Sᵗ}                                [server→clients]
//!  (3)  G_{U,c} = ∇_U L_c,  G_{V,c} = ∇_V L_c   (+ G_{S,c} for simplified vc)
//!  (4)  G_U, G_V ← aggregate                                  [clients→server]
//!  (5)  Ū ← qr([Uᵗ|G_U]) − Uᵗ,  V̄ ← qr([Vᵗ|G_V]) − Vᵗ          [server]
//!  (6)  broadcast {Ū, V̄}                                      [server→clients]
//!  (7,8) clients assemble Ũ, Ṽ, S̃ = [[Sᵗ,0],[0,0]]            (Lemma 1 — free)
//!  (9-12) full vc only: G_S̃,c ← ∇_S̃ L_c, aggregate, broadcast  [3rd round trip]
//!  (13-15) s* local steps on S̃_c (and dense params), optional V_c
//!  (16) S̃* ← aggregate {S̃_c}                                  [clients→server]
//!  (17) P,Σ,Q ← svd(S̃*), truncate at ϑ = τ‖S̃*‖                [server, 2r×2r]
//!  (18) U^{t+1} = ŨP, V^{t+1} = ṼQ, S^{t+1} = Σ
//! ```
//!
//! Dense (non-factorized) parameters of the same model — e.g. the
//! backbone of the §4.2 networks — ride along with FedAvg updates, or
//! FedLin-corrected updates when variance correction is on, exactly as
//! the paper trains "the fully connected head" with FeDLRT and the rest
//! conventionally.

use crate::client::{
    change_coords, ClientStates, CorrectionEngine, DriftState, GradMode, LocalUpdate,
};
use crate::comm::{sync_gate, FaultRoundStats, Network};
use crate::engine::{ClientExecutor, Executor, RoundPlan};
use crate::lowrank::{augment_basis_ws, truncate_ws, AugmentedBasis, LowRank};
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrGrad, LrWant, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::tensor::{Matrix, Workspace};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::aggregate::RobustAccum;
use super::config::{TrainConfig, VarCorrection};

/// Run FeDLRT on `problem` under `cfg`; returns the full run record
/// (with default telemetry: per-round `phase_s` + latency summaries).
pub fn run_fedlrt<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
) -> RunRecord {
    run_fedlrt_obs(problem, cfg, experiment, &Recorder::new())
}

/// [`run_fedlrt`] with an explicit telemetry [`Recorder`]: the CLI's
/// `--trace` passes [`Recorder::with_trace`], tests pass
/// [`Recorder::disabled`] to prove telemetry is a no-op.
pub fn run_fedlrt_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    let spec = problem.spec();
    let c_num = problem.num_clients();
    let mut rng = Rng::new(cfg.seed);

    // ---- Initialization: orthonormal U¹,V¹, full-rank diagonal S¹. ----
    let mut factors: Vec<LowRank> = spec
        .lr_shapes
        .iter()
        .map(|&(m, n)| {
            let r0 = cfg.rank.initial_rank.min(m.min(n) / 2).max(1);
            let mut f = LowRank::random_init(m, n, r0, &mut rng);
            let scale = (1.0 / m as f64).sqrt();
            f.s.scale_inplace(scale);
            f
        })
        .collect();
    let mut dense: Vec<Matrix> = spec
        .dense_shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale((1.0 / m.max(1) as f64).sqrt()))
        .collect();

    let mut net = Network::with_codec(c_num, cfg.codec);
    net.fault = cfg.fault;
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    // Server-side scratch, reused across all rounds: mean-gradient
    // accumulators, the augmentation QR, and the truncation SVD draw
    // from this pool, so the steady-state server step stops allocating.
    let mut ws = Workspace::new();
    let algo = format!("fedlrt_{}", cfg.var_correction.label());
    let mut record = RunRecord::new(&algo, experiment, c_num, cfg.seed);
    record.config = cfg.to_json();
    // Cross-round client state: batch-schedule cursors (each client's
    // mini-batch stream resumes where *it* left off, so straggler-
    // shortened rounds and partial participation never skip batches)
    // plus FedDyn/SCAFFOLD drift variates, both behind the shared
    // client-state layer.
    let mut states = ClientStates::new(c_num);
    // Drift-correction engine: normalized strategy kind + the SCAFFOLD
    // server control variate. `Correction::None` keeps every hook
    // structurally disabled (bitwise-legacy rounds).
    let mut engine = CorrectionEngine::new(cfg.correction);

    for t in 0..cfg.rounds {
        let watch = Stopwatch::start();
        obs.begin_round(t);
        let lr_t = cfg.lr.at(t);
        // Round schedule: participation sampling, dropout, straggler
        // iteration counts, and normalized aggregation weights, all in
        // one deterministic plan.
        let sp_plan = obs.span(Phase::Io);
        let mut plan = RoundPlan::build(cfg, c_num, t, |c| problem.client_weight(c));
        // Unreliable transport: decide each participant's delivery fate
        // up front (loss/corruption/retries against the round deadline),
        // filter the roster to the delivered clients, and skip the
        // round entirely — state untouched — below the upload quorum.
        // `None` (clean transport) leaves the plan bitwise-untouched.
        let gate =
            sync_gate(&cfg.fault, &cfg.net_policy, cfg.seed, t as u64, &mut plan, &mut net);
        if gate.as_ref().is_some_and(|g| g.skip) {
            drop(sp_plan);
            net.set_active_clients(0);
            let fault = {
                let comm = net.end_round();
                FaultRoundStats::skipped_from_comm(comm)
            };
            let sp_eval = obs.span(Phase::Eval);
            let w_eval = Weights {
                dense: dense.clone(),
                lr: factors.iter().cloned().map(LrWeight::Factored).collect(),
            };
            let global_loss = problem.global_loss(&w_eval);
            let dist_to_opt = problem.distance_to_optimum(&w_eval);
            let eval_metric = problem.eval_metric(&w_eval);
            drop(sp_eval);
            let round_obs = obs.end_round();
            record.rounds.push(RoundMetrics {
                round: t,
                global_loss,
                ranks: factors.iter().map(|f| f.rank()).collect(),
                comm_floats: 0,
                comm_floats_lr: 0,
                bytes_down: 0,
                bytes_up: 0,
                comm_floats_per_client: 0.0,
                dist_to_opt,
                eval_metric,
                wall_s: watch.elapsed_s(),
                client_wall_s: 0.0,
                client_serial_s: 0.0,
                phase_s: round_obs.phase_s,
                latency: round_obs.latency,
                staleness: round_obs.staleness,
                virtual_s: 0.0,
                fault,
            });
            continue;
        }
        let a_num = plan.len();
        net.set_active_clients(a_num);
        let weights: Vec<f64> = plan.tasks.iter().map(|task| task.weight).collect();
        // Batch cursors fetched once per round (they only advance at
        // round end), indexed by task ordinal — executor closures take
        // immutable borrows only.
        let steps0: Vec<u64> =
            plan.tasks.iter().map(|task| states.step0(task.client_id)).collect();
        drop(sp_plan);
        let mut client_wall_s = 0.0;
        let mut client_serial_s = 0.0;

        // (2) Broadcast current factorization + dense params, through
        // the wire codec: clients compute on the *decoded* copies
        // (decode-on-receive). S is diagonal after truncation, so only
        // its diagonal travels.
        let sp_bc = obs.span(Phase::Broadcast);
        let bc: Vec<LowRank> = factors
            .iter()
            .map(|f| {
                let u = net.broadcast_mat("U", &f.u);
                let v = net.broadcast_mat("V", &f.v);
                let s_diag: Vec<f64> = (0..f.rank()).map(|i| f.s[(i, i)]).collect();
                let s = Matrix::diag(&net.broadcast_vec("S_diag", &s_diag));
                LowRank { u, s, v }
            })
            .collect();
        let dense_bc: Vec<Matrix> =
            dense.iter().map(|d| net.broadcast_mat("dense_w", d)).collect();
        drop(sp_bc);

        // (3)-(4) Clients evaluate basis gradients at the broadcast
        // point; each participating client's upload goes through the
        // codec and the server averages the *decoded* tensors in plan
        // order. The simplified-vc variant also needs the non-augmented
        // coefficient gradient G_S — Algorithm 5 folds it into this
        // same round trip.
        let sp_train = obs.span(Phase::ClientTrain);
        let w_t = Weights {
            dense: dense_bc.clone(),
            lr: bc.iter().cloned().map(LrWeight::Factored).collect(),
        };
        let report = executor.execute(&plan, |task| {
            problem.grad(task.client_id, &w_t, LrWant::Factors, steps0[task.ordinal])
        });
        obs.record_exec("grad", &plan, &report.timing);
        drop(sp_train);
        client_wall_s += report.wall_s;
        client_serial_s += report.serial_s;
        let per_client = report.results;
        let num_lr = factors.len();
        // Mean basis/coeff gradients per layer (decoded where uplinked)
        // — accumulators drawn from the cross-round workspace pool.
        let sp_agg = obs.span(Phase::Aggregate);
        let mut g_u_mean: Vec<Matrix> =
            factors.iter().map(|f| ws.take_mat(f.m(), f.rank())).collect();
        let mut g_v_mean: Vec<Matrix> =
            factors.iter().map(|f| ws.take_mat(f.n(), f.rank())).collect();
        let mut g_s_mean: Vec<Matrix> =
            factors.iter().map(|f| ws.take_mat(f.rank(), f.rank())).collect();
        let mut g_dense_mean: Vec<Matrix> =
            dense.iter().map(|d| ws.take_mat(d.rows(), d.cols())).collect();
        for (ordinal, (g, &wt)) in per_client.iter().zip(&weights).enumerate() {
            // Retransmitting clients bill every wire copy of each upload.
            if let Some(gt) = &gate {
                net.set_upload_copies(gt.copies[ordinal]);
            }
            for l in 0..num_lr {
                match &g.lr[l] {
                    LrGrad::Factors { g_u, g_v, g_s } => {
                        g_u_mean[l].axpy(wt, &net.aggregate_mat("G_U", g_u));
                        g_v_mean[l].axpy(wt, &net.aggregate_mat("G_V", g_v));
                        if cfg.var_correction == VarCorrection::Simplified {
                            g_s_mean[l].axpy(wt, &net.aggregate_mat("G_S", g_s));
                        } else {
                            // Not uplinked in this mode (server-side
                            // bookkeeping only).
                            g_s_mean[l].axpy(wt, g_s);
                        }
                    }
                    _ => unreachable!("requested factor gradients"),
                }
            }
            if cfg.var_correction != VarCorrection::None {
                for (acc, gd) in g_dense_mean.iter_mut().zip(&g.dense) {
                    acc.axpy(wt, &net.aggregate_mat("G_dense", gd));
                }
            } else {
                for (acc, gd) in g_dense_mean.iter_mut().zip(&g.dense) {
                    acc.axpy(wt, gd);
                }
            }
        }
        if gate.is_some() {
            net.set_upload_copies(1);
        }
        net.end_round_trip();
        drop(sp_agg);

        // (5) Server-side basis augmentation (QR), (6) broadcast Ū, V̄.
        // Clients assemble their augmented factorization from decoded
        // pieces: Ũ_c = [U_c | Ū_c], S̃ = [[S,0],[0,0]] needs no wire
        // (Lemma 1). The server keeps its own exact `augs` for the
        // final reconstruction/truncation step.
        let sp_qr = obs.span(Phase::AugmentQr);
        let augs: Vec<AugmentedBasis> = (0..num_lr)
            .map(|l| {
                augment_basis_ws(
                    &factors[l],
                    &g_u_mean[l],
                    &g_v_mean[l],
                    2 * factors[l].rank(),
                    &mut ws,
                )
            })
            .collect();
        for buf in g_u_mean {
            ws.give_mat(buf);
        }
        for buf in g_v_mean {
            ws.give_mat(buf);
        }
        drop(sp_qr);
        let sp_bc2 = obs.span(Phase::Broadcast);
        let mut augs_c: Vec<AugmentedBasis> = Vec::with_capacity(num_lr);
        let mut g_s_mean_bc: Vec<Matrix> = Vec::new();
        for (l, aug) in augs.iter().enumerate() {
            let u_bar = net.broadcast_mat("U_bar", &aug.u_bar);
            let v_bar = net.broadcast_mat("V_bar", &aug.v_bar);
            let r2 = aug.rank();
            augs_c.push(AugmentedBasis {
                u_tilde: bc[l].u.hcat(&u_bar),
                v_tilde: bc[l].v.hcat(&v_bar),
                u_bar,
                v_bar,
                s_tilde: bc[l].s.embed(r2, r2),
                r_old: bc[l].rank(),
            });
            if cfg.var_correction == VarCorrection::Simplified {
                // Algorithm 5 line 8: G_S rides with the Ū,V̄ broadcast.
                g_s_mean_bc.push(net.broadcast_mat("G_S", &g_s_mean[l]));
            }
        }
        let g_dense_bc: Vec<Matrix> = if cfg.var_correction != VarCorrection::None {
            g_dense_mean.iter().map(|g| net.broadcast_mat("G_dense", g)).collect()
        } else {
            Vec::new()
        };
        // SCAFFOLD only: the server control variate rides with the Ū,V̄
        // broadcast, billed through the codec in the *non-augmented*
        // r-space (r² floats per layer); the coordinator embeds the
        // decoded copy into the augmented space clients train in.
        let ctrl_bc: Option<DriftState> = engine.broadcast_ctrl(
            &mut net,
            &factors.iter().map(|f| (f.rank(), f.rank())).collect::<Vec<_>>(),
            &dense.iter().map(|d| (d.rows(), d.cols())).collect::<Vec<_>>(),
        );
        net.end_round_trip();
        for buf in g_s_mean {
            ws.give_mat(buf);
        }
        for buf in g_dense_mean {
            ws.give_mat(buf);
        }
        drop(sp_bc2);

        // (9)-(12) Variance-correction terms V_c per client per layer.
        // Full: V_c = G_S̃ − G_S̃,c at the augmented point (extra round).
        // Simplified: V̌_c = [[G_S − G_S,c, 0],[0,0]] (already available).
        // The mean term is what the server *broadcast* (decoded); each
        // client subtracts its own exact local gradient.
        // The whole block — including the full mode's extra gradient
        // round trip — is one `variance_correction` phase span.
        let sp_vc = obs.span(Phase::VarianceCorrection);
        let corrections: Vec<Vec<Option<Matrix>>> = match cfg.var_correction {
            VarCorrection::None => vec![vec![None; num_lr]; a_num],
            VarCorrection::Simplified => (0..a_num)
                .map(|c| {
                    (0..num_lr)
                        .map(|l| {
                            let g_s_c = match &per_client[c].lr[l] {
                                LrGrad::Factors { g_s, .. } => g_s,
                                _ => unreachable!(),
                            };
                            let r2 = augs_c[l].rank();
                            Some(g_s_mean_bc[l].sub(g_s_c).embed(r2, r2))
                        })
                        .collect()
                })
                .collect(),
            VarCorrection::Full => {
                // Clients evaluate ∇_S̃ L_c at the decoded (Ũ, S̃, Ṽ);
                // the server aggregates the decoded uploads and
                // broadcasts the mean back — the third communication
                // round of Algorithm 1.
                let w_aug = Weights {
                    dense: dense_bc.clone(),
                    lr: augs_c.iter().map(|a| LrWeight::Factored(a.as_factorization())).collect(),
                };
                let report = executor.execute(&plan, |task| {
                    problem.grad(task.client_id, &w_aug, LrWant::Coeff, steps0[task.ordinal])
                });
                obs.record_exec("vc_grad", &plan, &report.timing);
                client_wall_s += report.wall_s;
                client_serial_s += report.serial_s;
                let grads_aug = report.results;
                let mut mean: Vec<Matrix> =
                    augs.iter().map(|a| Matrix::zeros(a.rank(), a.rank())).collect();
                for (ordinal, (g, &wt)) in grads_aug.iter().zip(&weights).enumerate() {
                    if let Some(gt) = &gate {
                        net.set_upload_copies(gt.copies[ordinal]);
                    }
                    for (l, m) in mean.iter_mut().enumerate() {
                        m.axpy(wt, &net.aggregate_mat("G_S_tilde", g.lr[l].coeff()));
                    }
                }
                if gate.is_some() {
                    net.set_upload_copies(1);
                }
                let mean_bc: Vec<Matrix> =
                    mean.iter().map(|m| net.broadcast_mat("G_S_tilde", m)).collect();
                net.end_round_trip();
                (0..a_num)
                    .map(|c| {
                        (0..num_lr)
                            .map(|l| Some(mean_bc[l].sub(grads_aug[c].lr[l].coeff())))
                            .collect()
                    })
                    .collect()
            }
        };
        let dense_corrections: Vec<Vec<Option<Matrix>>> = if cfg.var_correction
            == VarCorrection::None
        {
            vec![vec![None; dense.len()]; a_num]
        } else {
            (0..a_num)
                .map(|c| {
                    g_dense_bc
                        .iter()
                        .zip(&per_client[c].dense)
                        .map(|(gm, gc)| Some(gm.sub(gc)))
                        .collect()
                })
                .collect()
        };
        drop(sp_vc);

        // (13)-(15) Local client iterations on the coefficients (and
        // dense params), expressed as hermetic work items: each task
        // reads only broadcast round state and returns its local
        // optimum, so the executor may shard clients across threads.
        //
        // Client state is assembled ONCE per client per round: the
        // augmented factorization is trained *in place* (only S̃ changes
        // between iterations — the seed re-cloned Ũ/Ṽ and the dense
        // params every step), and the coefficient AND dense gradients
        // land in per-layer buffers reused across all s* iterations
        // through the problem's allocation-free `grad_coeff_into` fast
        // path (LeastSquares and MlpProblem implement it; PJRT problems
        // fall back to `grad`). The fast path fills the dense-gradient
        // buffers too, so dense params (biases, heads) take exactly the
        // same optimizer steps on either path — regression-tested by
        // `fast_path_trains_dense_params` below.
        let sp_local = obs.span(Phase::ClientTrain);
        // Per-ordinal drift inputs, mapped into the augmented coefficient
        // space before the executor takes its immutable borrows: stored
        // states live in the current non-augmented r-space (see the
        // truncation step below), so entering the round is a zero-padding
        // embed — Lemma 1's free augmentation applies to the variates too.
        let correction = engine.kind();
        let embed_aug = |st: &DriftState| DriftState {
            lr: st
                .lr
                .iter()
                .enumerate()
                .map(|(l, m)| m.embed(augs_c[l].rank(), augs_c[l].rank()))
                .collect(),
            dense: st.dense.clone(),
        };
        let drift_pre: Vec<Option<DriftState>> = if engine.is_stateful() {
            plan.tasks
                .iter()
                .map(|task| states.drift_cloned(task.client_id).map(|st| embed_aug(&st)))
                .collect()
        } else {
            vec![None; a_num]
        };
        let ctrl_aug: Option<DriftState> = ctrl_bc.as_ref().map(|c| embed_aug(c));
        let report = executor.execute(&plan, |task| {
            let mut w_c = Weights {
                dense: dense_bc.clone(),
                lr: augs_c
                    .iter()
                    .map(|a| {
                        LrWeight::Factored(LowRank {
                            u: a.u_tilde.clone(),
                            s: a.s_tilde.clone(),
                            v: a.v_tilde.clone(),
                        })
                    })
                    .collect(),
            };
            let driver = LocalUpdate {
                opt: cfg.opt,
                lr_t,
                iters: task.local_iters,
                step0: steps0[task.ordinal],
                mode: GradMode::Coeff,
                vc_lr: &corrections[task.ordinal],
                vc_dense: &dense_corrections[task.ordinal],
                g_bar: None,
                capture_first_grad: false,
                correction,
                drift_in: drift_pre[task.ordinal].as_ref(),
                ctrl: ctrl_aug.as_ref(),
                fault: task.fault,
                fault_seed: task.seed,
            };
            let out = driver.run(problem, task.client_id, &mut w_c);
            let s_c: Vec<Matrix> =
                w_c.lr.iter().map(|lw| lw.as_factored().s.clone()).collect();
            (s_c, w_c.dense, out.first_loss, out.drift_out, out.ctrl_delta)
        });
        obs.record_exec("local", &plan, &report.timing);
        drop(sp_local);
        client_wall_s += report.wall_s;
        client_serial_s += report.serial_s;
        // (16) Each client uploads its S̃_c^{s*} (+ dense params) through
        // the codec; the server combines the *decoded* tensors under the
        // configured aggregator — the weighted mean (eq. 10 with
        // non-uniform weights, the bitwise-legacy axpy fold) or a robust
        // rule in coefficient space, applied *before* the truncation
        // refresh — reduced in plan order so the trajectory is bitwise
        // independent of the executor.
        let sp_agg2 = obs.span(Phase::Aggregate);
        let mut s_accum: Vec<Matrix> =
            augs.iter().map(|a| ws.take_mat(a.rank(), a.rank())).collect();
        let mut dense_accum: Vec<Matrix> =
            dense.iter().map(|d| Matrix::zeros(d.rows(), d.cols())).collect();
        let mut robust_s = RobustAccum::new(cfg.aggregator, num_lr);
        let mut robust_d = RobustAccum::new(cfg.aggregator, dense.len());
        // Between-eval loss estimate: the *weighted* mean of the
        // first-iteration client losses, using the plan's normalized
        // weights — an unweighted mean would bias the recorded
        // trajectory whenever `client_weight` is non-uniform (e.g.
        // Dirichlet-sized MLP shards).
        let mut local_loss_w = 0.0;
        // Stateful corrections: participants' post-round variates (in
        // the augmented space — applied to the store only after the
        // basis-change projection below), and the codec-decoded sum of
        // SCAFFOLD control deltas.
        let mut drift_staged: Vec<(usize, DriftState)> = Vec::new();
        let mut ctrl_delta_sum: Option<DriftState> = None;
        for (task, (s_c, dense_c, first_loss, drift_out, ctrl_delta)) in
            plan.tasks.iter().zip(&report.results)
        {
            local_loss_w += task.weight * *first_loss;
            if let Some(gt) = &gate {
                net.set_upload_copies(gt.copies[task.ordinal]);
            }
            for l in 0..num_lr {
                let dec = net.aggregate_mat("S_tilde_c", &s_c[l]);
                robust_s.push(l, &mut s_accum[l], task.weight, &dec);
            }
            for (dl, d) in dense_c.iter().enumerate() {
                let dec = net.aggregate_mat("dense_w", d);
                robust_d.push(dl, &mut dense_accum[dl], task.weight, &dec);
            }
            if let Some(st) = drift_out {
                drift_staged.push((task.client_id, st.clone()));
            }
            if let Some(delta) = ctrl_delta {
                // SCAFFOLD uplink: the delta travels through the codec
                // like every other client→server tensor, so its byte
                // cost lands in `bytes_up`.
                let dec = DriftState {
                    lr: delta.lr.iter().map(|m| net.aggregate_mat("ctrl", m)).collect(),
                    dense: delta
                        .dense
                        .iter()
                        .map(|m| net.aggregate_mat("ctrl_dense", m))
                        .collect(),
                };
                match &mut ctrl_delta_sum {
                    Some(acc) => {
                        for (a, d) in acc.lr.iter_mut().zip(&dec.lr) {
                            a.axpy(1.0, d);
                        }
                        for (a, d) in acc.dense.iter_mut().zip(&dec.dense) {
                            a.axpy(1.0, d);
                        }
                    }
                    None => ctrl_delta_sum = Some(dec),
                }
            }
        }
        if gate.is_some() {
            net.set_upload_copies(1);
        }
        robust_s.finish(&mut s_accum);
        robust_d.finish(&mut dense_accum);
        net.end_round_trip();
        // Advance each participating client's batch schedule by the
        // iterations it actually ran (stragglers advance less; absentees
        // not at all) — the next round resumes where this one stopped.
        states.advance(&plan);
        drop(sp_agg2);

        // (17)-(18) Automatic compression: 2r×2r SVD + truncation
        // (SVD scratch drawn from the cross-round workspace).
        let sp_svd = obs.span(Phase::TruncateSvd);
        let mut discarded_total = 0.0;
        // Old r-space bases, kept only while stored drift state must be
        // carried across this basis refresh.
        let old_bases: Vec<(Matrix, Matrix)> = if engine.is_stateful() {
            factors.iter().map(|f| (f.u.clone(), f.v.clone())).collect()
        } else {
            Vec::new()
        };
        for l in 0..num_lr {
            let theta = cfg.rank.tau * s_accum[l].fro_norm();
            let res = truncate_ws(
                &augs[l].u_tilde,
                &s_accum[l],
                &augs[l].v_tilde,
                theta,
                1,
                cfg.rank.max_rank,
                &mut ws,
            );
            discarded_total += res.discarded;
            factors[l] = res.fac;
        }
        for buf in s_accum {
            ws.give_mat(buf);
        }
        dense = dense_accum;
        // State-across-basis-refresh rule (DESIGN.md §Client update
        // layer): stored drift variates always live in the *current*
        // non-augmented server coefficient space. Project every stored
        // state old → new, then overwrite participants with their
        // post-round augmented-space outputs projected aug → new; the
        // SCAFFOLD server variate absorbs the round's deltas in aug
        // space and projects the same way.
        if engine.is_stateful() {
            states.for_each_drift(|_, st| {
                for l in 0..num_lr {
                    st.lr[l] = change_coords(
                        &factors[l].u,
                        &factors[l].v,
                        &old_bases[l].0,
                        &old_bases[l].1,
                        &st.lr[l],
                    );
                }
            });
            for (id, st) in drift_staged {
                let proj = DriftState {
                    lr: st
                        .lr
                        .iter()
                        .enumerate()
                        .map(|(l, m)| {
                            // Participants trained in the *decoded*
                            // augmented basis — project out of it.
                            change_coords(
                                &factors[l].u,
                                &factors[l].v,
                                &augs_c[l].u_tilde,
                                &augs_c[l].v_tilde,
                                m,
                            )
                        })
                        .collect(),
                    dense: st.dense,
                };
                states.set_drift(id, proj);
            }
            if engine.is_scaffold() {
                let old_ctrl =
                    engine.ctrl().expect("ctrl is ensured by the round broadcast").clone();
                let mut aug_ctrl = DriftState {
                    lr: old_ctrl
                        .lr
                        .iter()
                        .enumerate()
                        .map(|(l, m)| m.embed(augs[l].rank(), augs[l].rank()))
                        .collect(),
                    dense: old_ctrl.dense,
                };
                if let Some(ds) = &ctrl_delta_sum {
                    // c ← c + (1/N) Σ_{participants} δ_c, N the full
                    // population (the textbook server update).
                    let inv = 1.0 / c_num as f64;
                    for (a, d) in aug_ctrl.lr.iter_mut().zip(&ds.lr) {
                        a.axpy(inv, d);
                    }
                    for (a, d) in aug_ctrl.dense.iter_mut().zip(&ds.dense) {
                        a.axpy(inv, d);
                    }
                }
                let new_ctrl = DriftState {
                    lr: aug_ctrl
                        .lr
                        .iter()
                        .enumerate()
                        .map(|(l, m)| {
                            // The server variate is exact server state —
                            // project through the server's exact bases.
                            change_coords(
                                &factors[l].u,
                                &factors[l].v,
                                &augs[l].u_tilde,
                                &augs[l].v_tilde,
                                m,
                            )
                        })
                        .collect(),
                    dense: aug_ctrl.dense,
                };
                engine.set_ctrl(new_ctrl);
            }
        }
        drop(sp_svd);

        // ---- Metrics ----
        let sp_io = obs.span(Phase::Io);
        let comm = net.end_round();
        let (comm_floats, comm_per_client) = (comm.total_floats(), comm.per_client_floats());
        let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
        let comm_floats_lr =
            comm.floats_matching(|l| !matches!(l, "dense_w" | "G_dense" | "ctrl_dense"));
        let fault = FaultRoundStats::from_comm(comm);
        drop(sp_io);
        let sp_eval = obs.span(Phase::Eval);
        let should_eval = t % cfg.eval_every == 0 || t + 1 == cfg.rounds;
        let w_eval = Weights {
            dense: dense.clone(),
            lr: factors.iter().cloned().map(LrWeight::Factored).collect(),
        };
        let global_loss = if should_eval {
            problem.global_loss(&w_eval)
        } else {
            local_loss_w
        };
        let dist_to_opt =
            if should_eval { problem.distance_to_optimum(&w_eval) } else { None };
        let eval_metric = if should_eval { problem.eval_metric(&w_eval) } else { None };
        drop(sp_eval);
        let round_obs = obs.end_round();
        record.rounds.push(RoundMetrics {
            round: t,
            global_loss,
            ranks: factors.iter().map(|f| f.rank()).collect(),
            comm_floats,
            comm_floats_lr,
            bytes_down,
            bytes_up,
            comm_floats_per_client: comm_per_client,
            dist_to_opt,
            eval_metric,
            wall_s: watch.elapsed_s(),
            client_wall_s,
            client_serial_s,
            phase_s: round_obs.phase_s,
            latency: round_obs.latency,
            staleness: round_obs.staleness,
            virtual_s: 0.0,
            fault,
        });
        let _ = discarded_total;
    }

    record
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::least_squares::LeastSquares;
    use crate::models::quadratic::Quadratic;
    use crate::opt::LrSchedule;

    fn quick_cfg(rounds: usize, iters: usize, vc: VarCorrection) -> TrainConfig {
        TrainConfig {
            rounds,
            local_iters: iters,
            lr: LrSchedule::Constant(5e-2),
            var_correction: vc,
            rank: crate::coordinator::config::RankConfig {
                initial_rank: 2,
                max_rank: 6,
                tau: 0.05,
            },
            seed: 42,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn fedlrt_descends_on_quadratic() {
        // Homogeneous targets (same B for all clients, rank 2 ≤ cap):
        // the global minimum value is 0 and is attainable on M_r, so
        // every variance-correction mode must drive the loss down hard.
        let mut rng = Rng::new(800);
        let base = Quadratic::random(12, 2, 1, &mut rng);
        let prob = Quadratic {
            targets: vec![base.targets[0].clone(); 4],
            alphas: vec![1.0; 4],
            n: 12,
        };
        for vc in [VarCorrection::None, VarCorrection::Simplified, VarCorrection::Full] {
            let rec = run_fedlrt(&prob, &quick_cfg(40, 5, vc), "test");
            let first = rec.rounds.first().unwrap().global_loss;
            let last = rec.final_loss();
            assert!(last < first * 0.05, "{}: {first} -> {last}", vc.label());
            assert!(last.is_finite());
        }
    }

    #[test]
    fn fedlrt_identifies_low_rank_on_lsq() {
        let mut rng = Rng::new(801);
        let prob = LeastSquares::homogeneous(12, 3, 600, 2, &mut rng);
        let mut cfg = quick_cfg(80, 10, VarCorrection::Full);
        cfg.lr = LrSchedule::Constant(5e-3);
        cfg.rank.tau = 0.1;
        let rec = run_fedlrt(&prob, &cfg, "test");
        // Rank never drops below the target rank 3 (paper: "never
        // underestimates") once identified, and the loss falls.
        assert!(
            rec.final_loss() < rec.rounds[0].global_loss * 0.35,
            "loss {} -> {}",
            rec.rounds[0].global_loss,
            rec.final_loss()
        );
        assert!(rec.final_rank() >= 3, "final rank {}", rec.final_rank());
    }

    #[test]
    fn variance_correction_beats_none_on_heterogeneous() {
        // Per-client data + targets ⇒ client drift; the suboptimality
        // gap (loss − L(W*)) of the corrected run must be smaller.
        let mut rng = Rng::new(803);
        let prob = LeastSquares::heterogeneous(8, 400, 4, &mut rng);
        let l_star = prob.min_loss();
        let mut cfg_nvc = quick_cfg(30, 40, VarCorrection::None);
        cfg_nvc.lr = LrSchedule::Constant(5e-3);
        cfg_nvc.rank = crate::coordinator::config::RankConfig {
            initial_rank: 4,
            max_rank: 8,
            tau: 1e-6,
        };
        let mut cfg_vc = cfg_nvc.clone();
        cfg_vc.var_correction = VarCorrection::Full;
        let gap_nvc = run_fedlrt(&prob, &cfg_nvc, "test").final_loss() - l_star;
        let gap_vc = run_fedlrt(&prob, &cfg_vc, "test").final_loss() - l_star;
        assert!(
            gap_vc < gap_nvc,
            "vc gap {gap_vc} should beat no-vc gap {gap_nvc} (L* = {l_star})"
        );
    }

    #[test]
    fn comm_cost_ordering_matches_table1() {
        // Table 1: com cost no_vc < simplified (+2r² for G_S) <
        // full (+2·(2r)² for G_S̃ up+down).
        let mut rng = Rng::new(805);
        let prob = Quadratic::random(8, 2, 3, &mut rng);
        let floats = |vc| run_fedlrt(&prob, &quick_cfg(3, 2, vc), "t").total_comm_floats();
        let none = floats(VarCorrection::None);
        let simpl = floats(VarCorrection::Simplified);
        let full = floats(VarCorrection::Full);
        assert!(none < simpl, "no_vc {none} < simpl {simpl}");
        assert!(simpl < full, "simpl {simpl} < full {full}");
    }

    #[test]
    fn ranks_respect_cap() {
        let mut rng = Rng::new(807);
        let prob = Quadratic::random(16, 8, 2, &mut rng);
        let mut cfg = quick_cfg(10, 3, VarCorrection::Simplified);
        cfg.rank.initial_rank = 3;
        cfg.rank.max_rank = 4;
        cfg.rank.tau = 0.0; // no truncation pressure
        let rec = run_fedlrt(&prob, &cfg, "test");
        for r in &rec.rounds {
            assert!(r.ranks[0] <= 4, "rank exceeded cap: {:?}", r.ranks);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(809);
        let prob = Quadratic::random(10, 2, 3, &mut rng);
        let a = run_fedlrt(&prob, &quick_cfg(5, 3, VarCorrection::Full), "t");
        let b = run_fedlrt(&prob, &quick_cfg(5, 3, VarCorrection::Full), "t");
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
            assert_eq!(x.ranks, y.ranks);
        }
    }

    #[test]
    fn codecs_trade_bytes_for_accuracy() {
        let mut rng = Rng::new(813);
        let prob = Quadratic::random(12, 2, 3, &mut rng);
        let run = |codec| {
            let mut cfg = quick_cfg(6, 3, VarCorrection::Simplified);
            cfg.codec = codec;
            run_fedlrt(&prob, &cfg, "t")
        };
        let dense = run(crate::comm::CodecKind::DenseF32);
        let f16 = run(crate::comm::CodecKind::F16Cast);
        let q8 = run(crate::comm::CodecKind::QuantizeInt8);
        // Reference codec: measured bytes are exactly floats × 4.
        assert_eq!(dense.total_bytes(), 4 * dense.total_comm_floats());
        // f16 halves every message; q8 beats 2 bytes/entry overall
        // (1 byte/entry + small per-message headers).
        assert_eq!(f16.total_bytes(), 2 * f16.total_comm_floats());
        assert!(q8.total_bytes() < 2 * q8.total_comm_floats());
        // Lossy codecs feed decoded tensors into the coordinator, so
        // the trajectory visibly differs from the reference while
        // staying numerically alive.
        assert!(f16.final_loss().is_finite() && q8.final_loss().is_finite());
        assert_ne!(dense.final_loss().to_bits(), q8.final_loss().to_bits());
    }

    /// A problem with one low-rank layer AND a dense parameter that
    /// offers the `grad_coeff_into` fast path. `grad(LrWant::Coeff)`
    /// panics, so the test can only pass if the coordinator actually
    /// uses the fast path — and only if it steps the dense parameter
    /// from the fast path's dense-gradient buffer does the loss fall.
    ///
    /// `L_c(W, D) = ½‖D − T_c‖² + ½‖W‖²_F` with `W = U S Vᵀ`.
    struct DenseRider {
        targets: Vec<Matrix>,
    }

    impl crate::models::FedProblem for DenseRider {
        fn spec(&self) -> crate::models::ProblemSpec {
            crate::models::ProblemSpec {
                dense_shapes: vec![(2, 2)],
                lr_shapes: vec![(6, 6)],
            }
        }

        fn num_clients(&self) -> usize {
            self.targets.len()
        }

        fn grad(
            &self,
            c: usize,
            w: &Weights,
            want: LrWant,
            _step: u64,
        ) -> crate::models::Grads {
            let f = match want {
                LrWant::Factors => w.lr[0].as_factored(),
                LrWant::Coeff => panic!(
                    "inner loop fell back to grad(Coeff) — fast path with dense params broken"
                ),
                LrWant::Dense => unreachable!("dense baselines not under test"),
            };
            // ∇_W = W ⇒ G_U = U S Sᵀ, G_V = V Sᵀ S, G_S = S (orthonormal bases).
            let us = crate::tensor::matmul(&f.u, &f.s);
            let g_u = crate::tensor::matmul_nt(&us, &f.s);
            let g_v = crate::tensor::matmul(&f.v, &crate::tensor::matmul_tn(&f.s, &f.s));
            let g_s = f.s.clone();
            let d_res = w.dense[0].sub(&self.targets[c]);
            let loss = 0.5 * (d_res.fro_norm().powi(2) + f.s.fro_norm().powi(2));
            crate::models::Grads {
                loss,
                dense: vec![d_res],
                lr: vec![LrGrad::Factors { g_u, g_v, g_s }],
            }
        }

        fn grad_coeff_into(
            &self,
            c: usize,
            w: &Weights,
            _step: u64,
            out: &mut [Matrix],
            out_dense: &mut [Matrix],
        ) -> Option<f64> {
            let f = w.lr[0].as_factored();
            if out[0].shape() != f.s.shape() || out_dense.len() != 1 {
                return None;
            }
            out[0].copy_from(&f.s);
            out_dense[0].copy_from(&w.dense[0]);
            out_dense[0].axpy(-1.0, &self.targets[c]);
            Some(0.5 * (out_dense[0].fro_norm().powi(2) + f.s.fro_norm().powi(2)))
        }

        fn global_loss(&self, w: &Weights) -> f64 {
            let w_norm2 = match &w.lr[0] {
                LrWeight::Factored(f) => f.s.fro_norm().powi(2),
                LrWeight::Dense(m) => m.fro_norm().powi(2),
            };
            let c = self.targets.len() as f64;
            self.targets
                .iter()
                .map(|t| 0.5 * (w.dense[0].sub(t).fro_norm().powi(2) + w_norm2))
                .sum::<f64>()
                / c
        }
    }

    #[test]
    fn fast_path_trains_dense_params() {
        // Regression for the `dense.is_empty()` fast-path gate: dense
        // parameters must move under FeDLRT when `grad_coeff_into` is
        // implemented. If the fast path skipped dense steps, `D` would
        // stay at its random init and the loss could not fall below the
        // frozen-dense floor; if the coordinator fell back to
        // grad(Coeff), DenseRider panics.
        let mut rng = Rng::new(881);
        // One shared target: the dense optimum is exactly T, so the loss
        // floor is ~0 — any residual means D never moved.
        let t0 = Matrix::randn(2, 2, &mut rng).scale(2.0);
        let prob = DenseRider { targets: vec![t0; 3] };
        let mut cfg = quick_cfg(30, 5, VarCorrection::None);
        cfg.lr = LrSchedule::Constant(0.1);
        let rec = run_fedlrt(&prob, &cfg, "dense_rider");
        let first = rec.rounds.first().unwrap().global_loss;
        let last = rec.final_loss();
        // The lr-layer term decays regardless; only a trained D drives
        // the loss to ~0 (the target term dominates the initial loss).
        assert!(last < 0.1 * first, "dense params frozen? {first} -> {last}");
        assert!(last.is_finite());
    }

    #[test]
    fn lossy_transport_with_retries_is_deterministic_and_counted() {
        let mut rng = Rng::new(815);
        let prob = Quadratic::random(10, 2, 4, &mut rng);
        let mut cfg = quick_cfg(8, 3, VarCorrection::Simplified);
        cfg.fault = crate::comm::FaultModel {
            loss_prob: 0.25,
            corrupt_prob: 0.1,
            ..crate::comm::FaultModel::default()
        };
        cfg.net_policy = crate::comm::NetPolicy { retries: 2, ..crate::comm::NetPolicy::default() };
        let a = run_fedlrt(&prob, &cfg, "t");
        let mut cfg_pool = cfg.clone();
        cfg_pool.executor = crate::engine::ExecutorKind::ThreadPool { threads: 3 };
        let b = run_fedlrt(&prob, &cfg_pool, "t");
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
            assert_eq!(x.fault, y.fault, "fault counters must be executor-independent");
            assert_eq!(x.comm_floats, y.comm_floats);
        }
        // p=0.25 over 8 rounds × 4 clients: some attempt must fail.
        let failed: u64 = a
            .rounds
            .iter()
            .map(|r| r.fault.msgs_dropped + r.fault.msgs_corrupt)
            .sum();
        assert!(failed > 0, "lossy transport produced no failures");
        assert!(a.final_loss().is_finite());
    }

    #[test]
    fn quorum_miss_skips_rounds_without_touching_state() {
        // Total blackout (p = 1, no retries): every round skips with the
        // model untouched — the recorded loss stays bitwise at init.
        let mut rng = Rng::new(816);
        let prob = Quadratic::random(10, 2, 4, &mut rng);
        let mut cfg = quick_cfg(5, 3, VarCorrection::Full);
        cfg.fault = crate::comm::FaultModel {
            loss_prob: 1.0,
            ..crate::comm::FaultModel::default()
        };
        let rec = run_fedlrt(&prob, &cfg, "t");
        assert_eq!(rec.skipped_rounds(), 5);
        let l0 = rec.rounds[0].global_loss;
        for r in &rec.rounds {
            assert!(r.fault.skipped);
            assert!(r.fault.msgs_dropped > 0);
            assert_eq!(r.global_loss.to_bits(), l0.to_bits(), "state must stay untouched");
            assert_eq!(r.comm_floats, 0, "a skipped round moves no traffic");
        }
    }

    #[test]
    fn robust_aggregators_preserve_descent_on_homogeneous_clients() {
        // Identical clients ⇒ identical uploads ⇒ every robust rule
        // reduces to the mean, so descent must match the mean run's.
        let mut rng = Rng::new(817);
        let base = Quadratic::random(12, 2, 1, &mut rng);
        let prob = Quadratic {
            targets: vec![base.targets[0].clone(); 4],
            alphas: vec![1.0; 4],
            n: 12,
        };
        for agg in [
            crate::coordinator::Aggregator::TrimmedMean { trim: 0.25 },
            crate::coordinator::Aggregator::Median,
            crate::coordinator::Aggregator::NormClip { mult: 2.0 },
        ] {
            let mut cfg = quick_cfg(40, 5, VarCorrection::None);
            cfg.aggregator = agg;
            let rec = run_fedlrt(&prob, &cfg, "t");
            let first = rec.rounds.first().unwrap().global_loss;
            let last = rec.final_loss();
            assert!(last < first * 0.05, "{}: {first} -> {last}", agg.label());
        }
    }

    #[test]
    fn thread_pool_executor_matches_serial_bitwise() {
        let mut rng = Rng::new(811);
        let prob = Quadratic::random(10, 2, 4, &mut rng);
        let mut cfg_serial = quick_cfg(6, 3, VarCorrection::Simplified);
        cfg_serial.straggler_jitter = 0.4;
        let mut cfg_pool = cfg_serial.clone();
        cfg_pool.executor = crate::engine::ExecutorKind::ThreadPool { threads: 3 };
        let a = run_fedlrt(&prob, &cfg_serial, "t");
        let b = run_fedlrt(&prob, &cfg_pool, "t");
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
            assert_eq!(x.ranks, y.ranks);
            assert_eq!(x.comm_floats, y.comm_floats);
        }
    }
}
