//! Client sampling and straggler modelling — stable import path.
//!
//! The implementations moved to [`crate::engine::plan`], where
//! [`crate::engine::RoundPlan`] folds sampling, dropout, straggler
//! iteration counts, aggregation-weight normalization, and per-client
//! RNG streams into one schedule object. These re-exports keep the
//! original `coordinator::sampling` paths working; the tests below pin
//! the sampling semantics the paper's reproducibility relies on.

pub use crate::engine::plan::{local_iters_for, sample_active};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;

    #[test]
    fn full_participation_returns_everyone() {
        assert_eq!(sample_active(5, 1.0, 1, 3), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_active(5, 2.0, 1, 3), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn partial_participation_sizes_and_determinism() {
        let a = sample_active(10, 0.3, 7, 2);
        let b = sample_active(10, 0.3, 7, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        // Different rounds sample different subsets (almost surely).
        let c = sample_active(10, 0.3, 7, 3);
        assert_ne!(a, c);
        // Never empty.
        assert_eq!(sample_active(10, 0.0, 7, 0).len(), 1);
    }

    #[test]
    fn all_clients_eventually_selected() {
        let mut seen = vec![false; 8];
        for t in 0..200 {
            for c in sample_active(8, 0.25, 9, t) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn straggler_iters_bounded_and_deterministic() {
        let cfg = TrainConfig {
            local_iters: 20,
            straggler_jitter: 0.5,
            seed: 3,
            ..TrainConfig::default()
        };
        for t in 0..10 {
            for c in 0..6 {
                let a = local_iters_for(&cfg, t, c);
                assert_eq!(a, local_iters_for(&cfg, t, c));
                assert!((10..=20).contains(&a), "iters {a}");
            }
        }
        // jitter 0 → exact s*.
        let none = TrainConfig { local_iters: 20, ..TrainConfig::default() };
        assert_eq!(local_iters_for(&none, 0, 0), 20);
    }
}
