//! Client sampling and straggler modelling.
//!
//! The paper analyses full participation with a uniform `s*` and notes
//! (footnote 3) that the analysis extends to client-dependent local
//! iteration counts; partial participation is the standard production
//! relaxation [26, 6, 29]. Both are deterministic functions of
//! `(seed, round)` so runs stay reproducible.

use crate::util::rng::Rng;

use super::config::TrainConfig;

/// The clients participating in round `t`: a uniformly random subset of
/// size `max(1, ⌈fraction·C⌉)`, sorted for deterministic iteration.
pub fn sample_active(c_num: usize, fraction: f64, seed: u64, round: usize) -> Vec<usize> {
    let take = ((fraction * c_num as f64).ceil() as usize).clamp(1, c_num);
    if take == c_num {
        return (0..c_num).collect();
    }
    let mut rng = Rng::new(seed ^ 0x5E1E_C700).split(round as u64);
    let mut perm = rng.permutation(c_num);
    perm.truncate(take);
    perm.sort_unstable();
    perm
}

/// Local iterations for client `c` in round `t` under the straggler
/// model: `s*·(1 − jitter·u)` with `u ~ U[0,1)` per (round, client).
pub fn local_iters_for(cfg: &TrainConfig, round: usize, client: usize) -> usize {
    if cfg.straggler_jitter <= 0.0 {
        return cfg.local_iters;
    }
    let mut rng =
        Rng::new(cfg.seed ^ 0x57A6_6000).split((round as u64) << 20 | client as u64);
    let u = rng.uniform();
    let scaled = cfg.local_iters as f64 * (1.0 - cfg.straggler_jitter.clamp(0.0, 1.0) * u);
    (scaled.round() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_returns_everyone() {
        assert_eq!(sample_active(5, 1.0, 1, 3), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_active(5, 2.0, 1, 3), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn partial_participation_sizes_and_determinism() {
        let a = sample_active(10, 0.3, 7, 2);
        let b = sample_active(10, 0.3, 7, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        // Different rounds sample different subsets (almost surely).
        let c = sample_active(10, 0.3, 7, 3);
        assert_ne!(a, c);
        // Never empty.
        assert_eq!(sample_active(10, 0.0, 7, 0).len(), 1);
    }

    #[test]
    fn all_clients_eventually_selected() {
        let mut seen = vec![false; 8];
        for t in 0..200 {
            for c in sample_active(8, 0.25, 9, t) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn straggler_iters_bounded_and_deterministic() {
        let cfg = TrainConfig {
            local_iters: 20,
            straggler_jitter: 0.5,
            seed: 3,
            ..TrainConfig::default()
        };
        for t in 0..10 {
            for c in 0..6 {
                let a = local_iters_for(&cfg, t, c);
                assert_eq!(a, local_iters_for(&cfg, t, c));
                assert!((10..=20).contains(&a), "iters {a}");
            }
        }
        // jitter 0 → exact s*.
        let none = TrainConfig { local_iters: 20, ..TrainConfig::default() };
        assert_eq!(local_iters_for(&none, 0, 0), 20);
    }
}
