//! Experiment presets: the paper's hyperparameter tables as code.
//!
//! Table 2 (and §4.1's text) pinned down every run configuration; this
//! module reproduces them, with a `scaled` flag that shrinks round/
//! iteration counts for the CPU-only default bench runs
//! (`FEDLRT_BENCH_FULL=1` restores paper scale).

use crate::comm::CodecKind;
use crate::engine::ExecutorKind;
use crate::opt::{LrSchedule, OptimizerKind, SgdConfig};

use super::config::{RankConfig, TrainConfig, VarCorrection};

/// §4.1 homogeneous least-squares (Fig 4): n=20, r*=4, s*=20, λ=1e-3,
/// τ=0.1, C ∈ {1,2,4,8,16,32}, medians over 20 seeds.
pub fn fig4_config(full: bool) -> TrainConfig {
    TrainConfig {
        rounds: if full { 400 } else { 120 },
        local_iters: 20,
        lr: LrSchedule::Constant(1e-3),
        opt: OptimizerKind::Sgd(SgdConfig::default()),
        var_correction: VarCorrection::Simplified,
        rank: RankConfig { initial_rank: 8, max_rank: 10, tau: 0.1 },
        seed: 0,
        eval_every: 1,
        participation: 1.0,
        straggler_jitter: 0.0,
        dropout: 0.0,
        executor: ExecutorKind::Serial,
        codec: CodecKind::DenseF32,
        kernel_threads: 0,
        ..TrainConfig::default()
    }
}

/// §4.1 heterogeneous least-squares (Fig 1): n=10, C=4, s*=100, λ=1e-3.
///
/// The rank cap is `n` — the paper does not restrict the rank here, and
/// the global minimizer (the average of C rank-1 client targets) has
/// rank up to C, with optimization transients exciting more directions;
/// capping below `n` stalls convergence on exactly those transients.
pub fn fig1_config(full: bool) -> TrainConfig {
    TrainConfig {
        rounds: if full { 300 } else { 100 },
        local_iters: 100,
        lr: LrSchedule::Constant(1e-3),
        opt: OptimizerKind::Sgd(SgdConfig::default()),
        var_correction: VarCorrection::Full,
        rank: RankConfig { initial_rank: 4, max_rank: 10, tau: 1e-6 },
        seed: 0,
        eval_every: 1,
        participation: 1.0,
        straggler_jitter: 0.0,
        dropout: 0.0,
        executor: ExecutorKind::Serial,
        codec: CodecKind::DenseF32,
        kernel_threads: 0,
        ..TrainConfig::default()
    }
}

/// One Table 2 row: the federated vision benchmark setups.
#[derive(Debug, Clone)]
pub struct VisionPreset {
    /// Model config name in the artifact manifest.
    pub model: &'static str,
    /// Paper figure this reproduces.
    pub figure: &'static str,
    /// Paper's network / dataset labels (for the printed tables).
    pub paper_net: &'static str,
    pub paper_data: &'static str,
    pub batch: usize,
    pub lr_start: f64,
    pub lr_end: f64,
    pub rounds_full: usize,
    pub rounds_scaled: usize,
    /// s* rule: `Some(k)` ⇒ s* = k/C (fig 5/7/8); `None` ⇒ fixed 100 (fig 6).
    pub iters_over_c: Option<usize>,
    pub tau: f64,
    pub optimizer: OptimizerKind,
}

/// Table 2, one entry per vision figure.
pub fn vision_presets() -> Vec<VisionPreset> {
    vec![
        VisionPreset {
            model: "resnet18_head",
            figure: "fig5",
            paper_net: "ResNet18",
            paper_data: "CIFAR10",
            batch: 128,
            lr_start: 1e-3,
            lr_end: 5e-4,
            rounds_full: 200,
            rounds_scaled: 12,
            iters_over_c: Some(240),
            tau: 0.01,
            optimizer: OptimizerKind::Sgd(SgdConfig { momentum: 0.9, weight_decay: 1e-3 }),
        },
        VisionPreset {
            model: "alexnet_head",
            figure: "fig6",
            paper_net: "AlexNet",
            paper_data: "CIFAR10",
            batch: 128,
            lr_start: 1e-2,
            lr_end: 1e-5,
            rounds_full: 200,
            rounds_scaled: 10,
            iters_over_c: None, // fixed s* = 100
            tau: 0.01,
            optimizer: OptimizerKind::Sgd(SgdConfig { momentum: 0.0, weight_decay: 1e-4 }),
        },
        VisionPreset {
            model: "vgg16_head",
            figure: "fig7",
            paper_net: "VGG16",
            paper_data: "CIFAR10",
            batch: 128,
            lr_start: 1e-2,
            lr_end: 5e-4,
            rounds_full: 200,
            rounds_scaled: 8,
            iters_over_c: Some(240),
            tau: 0.01,
            optimizer: OptimizerKind::Sgd(SgdConfig { momentum: 0.1, weight_decay: 1e-4 }),
        },
        VisionPreset {
            model: "vit_head",
            figure: "fig8",
            paper_net: "ViT",
            paper_data: "CIFAR100",
            batch: 256,
            lr_start: 3e-4,
            lr_end: 1e-5,
            rounds_full: 200,
            rounds_scaled: 8,
            iters_over_c: Some(240),
            tau: 0.01,
            optimizer: OptimizerKind::Adam { weight_decay: 1e-2 },
        },
    ]
}

impl VisionPreset {
    /// Build the TrainConfig for `c` clients.
    ///
    /// NOTE on `s*`: the paper's local-iteration counts (240/C mini-batch
    /// steps) assume GPU-speed gradient evaluations; the scaled CPU run
    /// keeps the *ratio structure* (s* ∝ 1/C) at a smaller constant.
    pub fn config(&self, c: usize, vc: VarCorrection, full: bool, seed: u64) -> TrainConfig {
        let rounds = if full { self.rounds_full } else { self.rounds_scaled };
        let budget = if full { 240 } else { 24 };
        let local_iters = match self.iters_over_c {
            Some(_) => (budget / c).max(1),
            None => {
                if full {
                    100
                } else {
                    16
                }
            }
        };
        // The scaled runs shorten the cosine horizon accordingly.
        TrainConfig {
            rounds,
            local_iters,
            lr: LrSchedule::Cosine { start: self.lr_start, end: self.lr_end, total: rounds },
            opt: self.optimizer,
            var_correction: vc,
            rank: RankConfig { initial_rank: 16, max_rank: 32, tau: self.tau },
            seed,
            eval_every: (rounds / 4).max(1),
            participation: 1.0,
            straggler_jitter: 0.0,
            dropout: 0.0,
            executor: ExecutorKind::Serial,
            codec: CodecKind::DenseF32,
            kernel_threads: 0,
            ..TrainConfig::default()
        }
    }
}

/// One native-MLP vision benchmark setup: mirrors a [`VisionPreset`]
/// row's *structure* (s* rule, optimizer family, cosine schedule, τ) on
/// the pure-Rust [`crate::models::mlp::MlpProblem`] backend, with
/// network widths and learning rates sized for the synthetic dataset
/// and the CPU budget (DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct MlpPreset {
    /// Figure analogue this reproduces (`fig5_mlp`, `fig6_mlp`).
    pub figure: &'static str,
    /// Paper row this mirrors (for the printed tables).
    pub paper_net: &'static str,
    pub paper_data: &'static str,
    pub d_in: usize,
    /// Hidden widths — each a low-rank-capable layer (≥ 2 of them).
    pub hidden: &'static [usize],
    pub classes: usize,
    pub batch: usize,
    pub lr_start: f64,
    pub lr_end: f64,
    pub rounds_full: usize,
    pub rounds_scaled: usize,
    /// s* rule: `Some(k)` ⇒ s* = k/C (fig 5); `None` ⇒ fixed (fig 6).
    pub iters_over_c: Option<usize>,
    pub tau: f64,
    pub optimizer: OptimizerKind,
    pub initial_rank: usize,
    pub max_rank: usize,
}

/// The native-MLP analogues of the Fig 5 / Fig 6 rows.
pub fn mlp_presets() -> Vec<MlpPreset> {
    vec![
        // Widths ≫ rank cap keep the n²-vs-nr separation the paper's
        // communication savings rely on (n=512, r≤32 there; 128 vs 8
        // here). A cap near the layer width would erase the saving —
        // see the comm arithmetic in `fig5_mlp_comm_saving_headroom`.
        MlpPreset {
            figure: "fig5_mlp",
            paper_net: "ResNet18 (MLP analogue)",
            paper_data: "CIFAR10 (synthetic)",
            d_in: 64,
            hidden: &[128, 128],
            classes: 10,
            batch: 64,
            lr_start: 0.05,
            lr_end: 5e-3,
            rounds_full: 120,
            rounds_scaled: 16,
            iters_over_c: Some(240),
            tau: 0.01,
            optimizer: OptimizerKind::Sgd(SgdConfig { momentum: 0.9, weight_decay: 1e-3 }),
            initial_rank: 8,
            max_rank: 8,
        },
        MlpPreset {
            figure: "fig6_mlp",
            paper_net: "AlexNet (MLP analogue)",
            paper_data: "CIFAR10 (synthetic)",
            d_in: 32,
            hidden: &[96, 64, 48],
            classes: 10,
            batch: 64,
            lr_start: 0.1,
            lr_end: 1e-3,
            rounds_full: 120,
            rounds_scaled: 12,
            iters_over_c: None, // fixed s*, like Fig 6
            tau: 0.01,
            optimizer: OptimizerKind::Sgd(SgdConfig { momentum: 0.0, weight_decay: 1e-4 }),
            initial_rank: 8,
            max_rank: 8,
        },
    ]
}

impl MlpPreset {
    /// Problem options for `c` clients at the chosen scale.
    pub fn options(&self, c: usize, full: bool, seed: u64) -> crate::models::mlp::MlpOptions {
        crate::models::mlp::MlpOptions {
            d_in: self.d_in,
            hidden: self.hidden.to_vec(),
            classes: self.classes,
            num_clients: c,
            train_n: if full { 12_800 } else { 2_048 },
            test_n: if full { 2_560 } else { 512 },
            eval_cap: if full { 2_048 } else { 512 },
            batch: self.batch,
            seed,
            augment: true,
            dirichlet_alpha: None,
        }
    }

    /// Build the `TrainConfig` for `c` clients (same s*-vs-C structure
    /// as [`VisionPreset::config`]).
    pub fn config(&self, c: usize, vc: VarCorrection, full: bool, seed: u64) -> TrainConfig {
        let rounds = if full { self.rounds_full } else { self.rounds_scaled };
        let local_iters = match self.iters_over_c {
            // s* = k/C at paper scale; the scaled CPU runs keep the
            // 1/C structure at a fifth of the budget (k=240 ⇒ 48).
            Some(k) => {
                let budget = if full { k } else { (k / 5).max(1) };
                (budget / c).max(2)
            }
            None => {
                if full {
                    100
                } else {
                    16
                }
            }
        };
        TrainConfig {
            rounds,
            local_iters,
            lr: LrSchedule::Cosine { start: self.lr_start, end: self.lr_end, total: rounds },
            opt: self.optimizer,
            var_correction: vc,
            rank: RankConfig {
                initial_rank: self.initial_rank,
                max_rank: self.max_rank,
                tau: self.tau,
            },
            seed,
            eval_every: (rounds / 4).max(1),
            participation: 1.0,
            straggler_jitter: 0.0,
            dropout: 0.0,
            executor: ExecutorKind::Serial,
            codec: CodecKind::DenseF32,
            kernel_threads: 0,
            ..TrainConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_all_four_figures() {
        let ps = vision_presets();
        let figs: Vec<&str> = ps.iter().map(|p| p.figure).collect();
        assert_eq!(figs, vec!["fig5", "fig6", "fig7", "fig8"]);
        // ViT uses Adam (Table 2).
        assert!(matches!(ps[3].optimizer, OptimizerKind::Adam { .. }));
    }

    #[test]
    fn iters_scale_with_clients() {
        let p = &vision_presets()[0];
        let c1 = p.config(1, VarCorrection::None, false, 0);
        let c4 = p.config(4, VarCorrection::None, false, 0);
        assert_eq!(c1.local_iters, 4 * c4.local_iters);
        // AlexNet uses a fixed s*.
        let a = &vision_presets()[1];
        assert_eq!(
            a.config(1, VarCorrection::None, false, 0).local_iters,
            a.config(8, VarCorrection::None, false, 0).local_iters
        );
    }

    #[test]
    fn mlp_presets_mirror_fig5_and_fig6_structure() {
        let ps = mlp_presets();
        assert_eq!(ps.len(), 2);
        let fig5 = &ps[0];
        assert_eq!(fig5.figure, "fig5_mlp");
        assert!(fig5.hidden.len() >= 2, "acceptance: ≥ 2 hidden layers");
        // Fig 5: s* ∝ 1/C with momentum SGD.
        assert!(fig5.iters_over_c.is_some());
        let c1 = fig5.config(1, VarCorrection::None, false, 0);
        let c4 = fig5.config(4, VarCorrection::None, false, 0);
        assert_eq!(c1.local_iters, 4 * c4.local_iters);
        // Fig 6: fixed s*, momentum-free SGD.
        let fig6 = &ps[1];
        assert!(fig6.iters_over_c.is_none());
        assert_eq!(
            fig6.config(1, VarCorrection::None, false, 0).local_iters,
            fig6.config(8, VarCorrection::None, false, 0).local_iters
        );
        // Ranks stay feasible for every hidden layer.
        for p in &ps {
            let opts = p.options(2, false, 0);
            let min_dim = opts
                .hidden
                .iter()
                .chain(std::iter::once(&opts.d_in))
                .copied()
                .min()
                .unwrap();
            assert!(p.initial_rank <= min_dim / 2, "{}: initial rank too large", p.figure);
        }
    }

    #[test]
    fn fig5_mlp_comm_saving_headroom() {
        // Static geometry check behind the fig5_mlp/fig6_mlp ">50% comm
        // saving" acceptance gate, in the *tightest* regime (no-vc vs
        // FedAvg; the vc modes only add to both sides in FeDLRT's
        // favor). Worst case: rank pinned at the cap, augmented 2r.
        for p in mlp_presets() {
            let mut dims: Vec<(usize, usize)> = Vec::new();
            let mut prev = p.d_in;
            for &h in p.hidden {
                dims.push((prev, h));
                prev = h;
            }
            let r = p.max_rank;
            let dense_w: usize = dims.iter().map(|&(m, n)| m * n).sum();
            let factor_w: usize = dims.iter().map(|&(m, n)| m * r + n * r).sum();
            for c in [1usize, 2, 4, 8, 32] {
                // FeDLRT: U,V,S_diag + Ū,V̄ down; G_U,G_V + S̃ (2r×2r) up.
                let lrt_down = factor_w + p.hidden.len() * r + factor_w;
                let lrt_up = c * (factor_w + dims.len() * 4 * r * r);
                let lrt = lrt_down + lrt_up;
                // FedAvg: W down, C·W up.
                let avg = dense_w + c * dense_w;
                assert!(
                    (lrt as f64) < 0.5 * avg as f64,
                    "{} C={c}: fedlrt {lrt} floats ≥ 50% of fedavg {avg}",
                    p.figure
                );
            }
        }
    }

    #[test]
    fn rank_cap_fits_artifact_padding() {
        // max_rank=32 ⇒ augmented 64 = r_pad of the vision artifacts.
        for p in vision_presets() {
            let cfg = p.config(2, VarCorrection::Full, false, 0);
            assert!(2 * cfg.rank.max_rank <= 64);
        }
    }
}
