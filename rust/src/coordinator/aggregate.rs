//! Pluggable server-side aggregation: the trusting weighted mean of the
//! paper's eq. (10), plus the classical robust alternatives that keep
//! descent alive when some uploads are poisoned (byzantine clients,
//! undetected corruption).
//!
//! The aggregators operate **in coefficient space** — they combine the
//! clients' uploaded low-rank coefficient updates (and dense deltas)
//! *before* the variance-correction refresh and the augmentation/
//! truncation steps, so the basis pipeline downstream is untouched.
//!
//! Contracts (property-tested in `tests/coordinator_props.rs`):
//!
//! * **Bitwise-legacy mean.** [`Aggregator::Mean`] routes through the
//!   exact `acc.axpy(weight, x)` fold the coordinators have always
//!   used — same arithmetic, same order, zero staging — so faults-off
//!   mean runs reproduce pre-PR trajectories bitwise.
//! * **Reduction to the mean.** On outlier-free inputs (all updates
//!   equal) every aggregator returns the weighted mean to floating-point
//!   accuracy.
//! * **Permutation invariance.** Client order does not change a robust
//!   aggregate (sorting keys break value ties by nothing — equal values
//!   are interchangeable in the statistics below).
//! * **Self-normalization.** The robust variants divide by the
//!   *surviving* weight mass (trim/clip discard or shrink mass), so the
//!   caller must hand them the same normalized weights it would hand
//!   the mean, and the result lives on the same scale.
//!
//! Robustness rationale: with a `fault_fraction` ≤ the trim fraction,
//! the trimmed mean and the weighted median have bounded sensitivity to
//! arbitrarily-corrupted uploads (breakdown point α resp. 1/2), while
//! norm-clipping bounds each client's pull by a multiple of the typical
//! update norm — the three standard points on the robustness/efficiency
//! trade-off curve.

use crate::tensor::Matrix;

/// Server-side aggregation rule for client coefficient updates.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Aggregator {
    /// Weighted arithmetic mean (the paper's eq. 10) — bitwise-legacy
    /// default.
    #[default]
    Mean,
    /// Coordinate-wise α-trimmed weighted mean: per coordinate, drop the
    /// ⌊α·K⌋ smallest and largest values (capped so at least one
    /// survives), then take the weighted mean of the survivors.
    TrimmedMean {
        /// Fraction trimmed from *each* tail, in [0, 0.5).
        trim: f64,
    },
    /// Coordinate-wise weighted median (lower weighted median: the
    /// smallest value whose cumulative weight reaches half the total).
    Median,
    /// Weighted mean of norm-clipped updates: each update's Frobenius
    /// norm is capped at `mult` × the weighted-median norm.
    NormClip {
        /// Clip radius as a multiple of the weighted-median norm.
        mult: f64,
    },
}

impl Aggregator {
    /// The bitwise-legacy path?
    pub fn is_mean(&self) -> bool {
        matches!(self, Aggregator::Mean)
    }

    /// Stable identifier used in config echo, JSONL rows, and the CLI.
    pub fn label(&self) -> String {
        match self {
            Aggregator::Mean => "mean".to_string(),
            Aggregator::TrimmedMean { trim } => format!("trimmed:{trim}"),
            Aggregator::Median => "median".to_string(),
            Aggregator::NormClip { mult } => format!("clip:{mult}"),
        }
    }

    /// Parse a CLI spec: `mean` | `trimmed[:α]` | `median` | `clip[:c]`
    /// (defaults α = 0.2, c = 2).
    pub fn parse(s: &str) -> anyhow::Result<Aggregator> {
        let (name, knob) = match s.split_once(':') {
            Some((n, k)) => (n, Some(k)),
            None => (s, None),
        };
        let num = |default: f64| -> anyhow::Result<f64> {
            match knob {
                None => Ok(default),
                Some(k) => k
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad aggregator knob '{k}'")),
            }
        };
        match name {
            "mean" => {
                anyhow::ensure!(knob.is_none(), "mean takes no knob");
                Ok(Aggregator::Mean)
            }
            "trimmed" => {
                let trim = num(0.2)?;
                anyhow::ensure!(
                    (0.0..0.5).contains(&trim),
                    "trim fraction {trim} outside [0, 0.5)"
                );
                Ok(Aggregator::TrimmedMean { trim })
            }
            "median" => {
                anyhow::ensure!(knob.is_none(), "median takes no knob");
                Ok(Aggregator::Median)
            }
            "clip" => {
                let mult = num(2.0)?;
                anyhow::ensure!(
                    mult.is_finite() && mult > 0.0,
                    "clip multiple {mult} must be > 0"
                );
                Ok(Aggregator::NormClip { mult })
            }
            _ => anyhow::bail!(
                "unknown aggregator '{s}' (want mean | trimmed[:a] | median | clip[:c])"
            ),
        }
    }
}

/// Sum `xs` left to right — the blessed plan-order float reduction for
/// aggregation code. Bitwise identical to `xs.iter().sum::<f64>()`
/// today; the point of the named helper is that the reduction *order*
/// is part of its contract (fedlint rule D3 flags ad-hoc sums, whose
/// order silently reorders under refactors and breaks trajectory
/// reproducibility).
pub fn plan_order_sum(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
    }
    acc
}

/// Accumulator for one round's aggregation over a fixed set of `slots`
/// (parallel tensors — e.g. FeDLRT's per-layer coefficient updates plus
/// the dense head).
///
/// Usage mirrors the legacy fold exactly:
///
/// ```text
/// let mut robust = RobustAccum::new(cfg.aggregator, accs.len());
/// for client { for slot { robust.push(slot, &mut accs[slot], w_c, &x_c); } }
/// robust.finish(&mut accs);
/// ```
///
/// For [`Aggregator::Mean`], `push` performs the legacy
/// `acc.axpy(w, x)` immediately and `finish` is a no-op — bitwise
/// identity with pre-PR code. The robust variants stage `(w, x)` per
/// slot and reduce in `finish`, **adding** the aggregate into each
/// slot's accumulator (so callers that pre-seed the accumulator — e.g.
/// with a server term — keep working).
pub struct RobustAccum {
    agg: Aggregator,
    staged: Vec<Vec<(f64, Matrix)>>,
}

impl RobustAccum {
    pub fn new(agg: Aggregator, slots: usize) -> RobustAccum {
        let staged = if agg.is_mean() { Vec::new() } else { vec![Vec::new(); slots] };
        RobustAccum { agg, staged }
    }

    /// Fold one client's update for `slot` with aggregation weight
    /// `weight` (normalized over the surviving roster, as for the mean).
    pub fn push(&mut self, slot: usize, acc: &mut Matrix, weight: f64, x: &Matrix) {
        if self.agg.is_mean() {
            acc.axpy(weight, x);
        } else {
            self.staged[slot].push((weight, x.clone()));
        }
    }

    /// Reduce all staged updates into their accumulators (no-op for the
    /// mean, which already folded in `push`).
    pub fn finish(self, accs: &mut [Matrix]) {
        if self.agg.is_mean() {
            return;
        }
        debug_assert_eq!(self.staged.len(), accs.len(), "slot count mismatch");
        for (staged, acc) in self.staged.into_iter().zip(accs.iter_mut()) {
            reduce_into(self.agg, staged, acc);
        }
    }
}

/// Reduce one slot's staged `(weight, update)` pairs under `agg`,
/// adding the aggregate into `acc`.
fn reduce_into(agg: Aggregator, staged: Vec<(f64, Matrix)>, acc: &mut Matrix) {
    if staged.is_empty() {
        return;
    }
    match agg {
        Aggregator::Mean => {
            for (w, x) in &staged {
                acc.axpy(*w, x);
            }
        }
        Aggregator::TrimmedMean { trim } => {
            let k = staged.len();
            // Cap so at least one value survives the two-sided cut.
            let cut = ((trim * k as f64).floor() as usize).min((k - 1) / 2);
            let mut col: Vec<(f64, f64)> = Vec::with_capacity(k);
            for i in 0..acc.data().len() {
                col.clear();
                col.extend(staged.iter().map(|(w, x)| (x.data()[i], *w)));
                col.sort_by(|a, b| a.0.total_cmp(&b.0));
                let kept = &col[cut..k - cut];
                let wsum: f64 = kept.iter().map(|(_, w)| w).sum();
                if wsum > 0.0 {
                    let s: f64 = kept.iter().map(|(v, w)| v * w).sum();
                    acc.data_mut()[i] += s / wsum;
                }
            }
        }
        Aggregator::Median => {
            let k = staged.len();
            let mut col: Vec<(f64, f64)> = Vec::with_capacity(k);
            for i in 0..acc.data().len() {
                col.clear();
                col.extend(staged.iter().map(|(w, x)| (x.data()[i], *w)));
                acc.data_mut()[i] += weighted_median(&mut col);
            }
        }
        Aggregator::NormClip { mult } => {
            // Clip radius: mult × weighted-median Frobenius norm.
            let mut norms: Vec<(f64, f64)> = staged
                .iter()
                .map(|(w, x)| (frob(x), *w))
                .collect();
            let radius = mult * weighted_median(&mut norms);
            let wsum: f64 = staged.iter().map(|(w, _)| w).sum();
            if wsum <= 0.0 {
                return;
            }
            for (w, x) in &staged {
                let n = frob(x);
                let s = if n > radius && n > 0.0 { radius / n } else { 1.0 };
                acc.axpy(w * s / wsum, x);
            }
        }
    }
}

/// Lower weighted median of `(value, weight)` pairs: the smallest value
/// whose cumulative weight reaches half the total. Sorts in place.
fn weighted_median(pairs: &mut [(f64, f64)]) -> f64 {
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let total: f64 = pairs.iter().map(|(_, w)| w).sum();
    let mut cum = 0.0;
    for (v, w) in pairs.iter() {
        cum += w;
        if cum >= total / 2.0 {
            return *v;
        }
    }
    pairs.last().map(|(v, _)| *v).unwrap_or(0.0)
}

fn frob(m: &Matrix) -> f64 {
    m.data().iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Aggregator; 4] = [
        Aggregator::Mean,
        Aggregator::TrimmedMean { trim: 0.25 },
        Aggregator::Median,
        Aggregator::NormClip { mult: 2.0 },
    ];

    fn run(agg: Aggregator, updates: &[(f64, Matrix)]) -> Matrix {
        let mut acc = Matrix::zeros(updates[0].1.rows(), updates[0].1.cols());
        let mut r = RobustAccum::new(agg, 1);
        for (w, x) in updates {
            r.push(0, &mut acc, *w, x);
        }
        r.finish(std::slice::from_mut(&mut acc));
        acc
    }

    fn mat(vals: &[f64]) -> Matrix {
        Matrix::from_vec(1, vals.len(), vals.to_vec())
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        assert_eq!(Aggregator::parse("mean").unwrap(), Aggregator::Mean);
        assert_eq!(
            Aggregator::parse("trimmed").unwrap(),
            Aggregator::TrimmedMean { trim: 0.2 }
        );
        assert_eq!(
            Aggregator::parse("trimmed:0.3").unwrap(),
            Aggregator::TrimmedMean { trim: 0.3 }
        );
        assert_eq!(Aggregator::parse("median").unwrap(), Aggregator::Median);
        assert_eq!(Aggregator::parse("clip").unwrap(), Aggregator::NormClip { mult: 2.0 });
        assert_eq!(Aggregator::parse("clip:3.5").unwrap(), Aggregator::NormClip { mult: 3.5 });
        for bad in ["", "avg", "trimmed:0.6", "trimmed:x", "clip:0", "mean:1", "median:2"] {
            assert!(Aggregator::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        for agg in ALL {
            assert_eq!(Aggregator::parse(&agg.label()).unwrap(), agg);
        }
    }

    #[test]
    fn mean_path_is_the_legacy_axpy_fold_bitwise() {
        let updates: Vec<(f64, Matrix)> = (0..5)
            .map(|c| (0.1 + 0.05 * c as f64, mat(&[c as f64 * 0.3, -(c as f64), 1.0 / (c + 1) as f64])))
            .collect();
        // Legacy fold.
        let mut legacy = Matrix::zeros(1, 3);
        for (w, x) in &updates {
            legacy.axpy(*w, x);
        }
        let got = run(Aggregator::Mean, &updates);
        for (a, b) in legacy.data().iter().zip(got.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn robust_aggregators_resist_a_poisoned_update() {
        // 4 honest clients around 1.0, one adversary at 1000.
        let updates = vec![
            (0.2, mat(&[1.0])),
            (0.2, mat(&[1.1])),
            (0.2, mat(&[0.9])),
            (0.2, mat(&[1.0])),
            (0.2, mat(&[1000.0])),
        ];
        let mean = run(Aggregator::Mean, &updates).data()[0];
        assert!(mean > 100.0, "undefended mean is dragged away");
        for agg in [
            Aggregator::TrimmedMean { trim: 0.25 },
            Aggregator::Median,
            Aggregator::NormClip { mult: 2.0 },
        ] {
            let v = run(agg, &updates).data()[0];
            assert!(
                (v - 1.0).abs() < 2.0,
                "{} must stay near the honest cluster, got {v}",
                agg.label()
            );
        }
    }

    #[test]
    fn robust_finish_adds_into_a_preseeded_accumulator() {
        let updates = vec![(0.5, mat(&[2.0, 4.0])), (0.5, mat(&[2.0, 4.0]))];
        let mut acc = mat(&[10.0, 20.0]);
        let mut r = RobustAccum::new(Aggregator::Median, 1);
        for (w, x) in &updates {
            r.push(0, &mut acc, *w, x);
        }
        r.finish(std::slice::from_mut(&mut acc));
        assert!((acc.data()[0] - 12.0).abs() < 1e-12);
        assert!((acc.data()[1] - 24.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_median_respects_weights() {
        let mut pairs = vec![(0.0, 0.1), (1.0, 0.8), (5.0, 0.1)];
        assert_eq!(weighted_median(&mut pairs), 1.0);
        let mut heavy_tail = vec![(0.0, 0.2), (10.0, 0.8)];
        assert_eq!(weighted_median(&mut heavy_tail), 10.0);
        let mut single = vec![(3.0, 1.0)];
        assert_eq!(weighted_median(&mut single), 3.0);
    }

    #[test]
    fn trim_cap_keeps_at_least_one_value() {
        // K = 2 with trim 0.45: ⌊0.9⌋ = 0 cut; K = 3 with trim 0.4:
        // ⌊1.2⌋ = 1 cut per side leaves exactly the median.
        let two = vec![(0.5, mat(&[1.0])), (0.5, mat(&[3.0]))];
        let v = run(Aggregator::TrimmedMean { trim: 0.45 }, &two).data()[0];
        assert!((v - 2.0).abs() < 1e-12);
        let three = vec![(1.0 / 3.0, mat(&[1.0])), (1.0 / 3.0, mat(&[2.0])), (1.0 / 3.0, mat(&[900.0]))];
        let v = run(Aggregator::TrimmedMean { trim: 0.4 }, &three).data()[0];
        assert!((v - 2.0).abs() < 1e-12);
    }
}
