//! FeDLR-style baseline ([31]: "communication-efficient federated
//! learning with dual-side low-rank compression").
//!
//! The *other* school of federated low-rank methods (paper §2,
//! category 1): train **dense** weights on the clients, compress only
//! for transport with truncated SVDs on both directions:
//!
//! ```text
//! server: P,Σ,Q ← svd_r(Wᵗ);        broadcast (P, Σ, Q)       [O(nr) down]
//! client: W_c ← P Σ Qᵀ;  s* dense GD steps on W_c             [O(s*·b·n²)]
//!         P_c,Σ_c,Q_c ← svd_r(W_c); upload (P_c, Σ_c, Q_c)    [O(nr) up, O(n³) SVD]
//! server: W^{t+1} ← mean_c P_c Σ_c Q_cᵀ                        [O(n²) + next svd O(n³)]
//! ```
//!
//! Communication matches FeDLRT's order (`O(nr)`), but client compute
//! and memory stay `O(n²)`–`O(n³)` (the full matrix is trained and
//! factorized locally), the server pays an `n×n` SVD, and each
//! compression step *loses information* the next round cannot recover —
//! the drift/accuracy gap FeDLRT's shared-basis design eliminates.
//! This is the executable counterpart of Table 1's FeDLR row.

use crate::client::{ClientStates, CorrectionEngine, DriftState, GradMode, LocalUpdate};
use crate::comm::{sync_gate, FaultRoundStats, Network};
use crate::engine::{ClientExecutor, Executor, RoundPlan};
use crate::linalg::svd;
use crate::lowrank::LowRank;
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::aggregate::RobustAccum;
use super::config::TrainConfig;

/// Run the FeDLR-style dual-side-compression baseline. Single low-rank
/// layer problems (the §4.1 comparisons).
pub fn run_fedlr<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
) -> RunRecord {
    run_fedlr_obs(problem, cfg, experiment, &Recorder::new())
}

/// [`run_fedlr`] with an explicit telemetry [`Recorder`].
pub fn run_fedlr_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    let spec = problem.spec();
    assert!(
        spec.dense_shapes.is_empty() && spec.lr_shapes.len() == 1,
        "FeDLR baseline supports single-layer problems"
    );
    let (m, n) = spec.lr_shapes[0];
    let c_num = problem.num_clients();
    let mut rng = Rng::new(cfg.seed);

    // Server state: the DENSE weight matrix.
    let mut w = Matrix::randn(m, n, &mut rng).scale((1.0 / m as f64).sqrt());

    let mut net = Network::with_codec(c_num, cfg.codec);
    net.fault = cfg.fault;
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    let mut record = RunRecord::new("fedlr", experiment, c_num, cfg.seed);
    record.config = cfg.to_json();
    // Cross-round client state (batch cursors + drift variates) and the
    // drift-correction engine — see `run_fedlrt`. FeDLR clients train
    // the reconstructed *dense* matrix, so drift states live in the
    // fixed m×n space and never need basis projection (the per-round
    // SVD compresses the weights, not the training space).
    let mut states = ClientStates::new(c_num);
    let mut engine = CorrectionEngine::new(cfg.correction);

    for t in 0..cfg.rounds {
        let watch = Stopwatch::start();
        obs.begin_round(t);
        let lr_t = cfg.lr.at(t);
        let sp_plan = obs.span(Phase::Io);
        let mut plan = RoundPlan::build(cfg, c_num, t, |c| problem.client_weight(c));
        // Unreliable-transport gate: drop/corrupt/retry uploads and
        // enforce the round quorum (DESIGN.md §Fault model). `None`
        // whenever faults and the net policy are both inactive.
        let gate = sync_gate(&cfg.fault, &cfg.net_policy, cfg.seed, t as u64, &mut plan, &mut net);
        if gate.as_ref().is_some_and(|g| g.skip) {
            drop(sp_plan);
            // Quorum miss: record the round (evaluated on the untouched
            // server weights) and move on without updating any state.
            net.set_active_clients(0);
            let fault = FaultRoundStats::skipped_from_comm(net.end_round());
            let sp_eval = obs.span(Phase::Eval);
            let w_eval = Weights { dense: vec![], lr: vec![LrWeight::Dense(w.clone())] };
            let global_loss = problem.global_loss(&w_eval);
            let dist_to_opt = problem.distance_to_optimum(&w_eval);
            let eval_metric = problem.eval_metric(&w_eval);
            drop(sp_eval);
            let round_obs = obs.end_round();
            record.rounds.push(RoundMetrics {
                round: t,
                global_loss,
                ranks: vec![0], // no compression ran this round
                comm_floats: 0,
                comm_floats_lr: 0,
                bytes_down: 0,
                bytes_up: 0,
                comm_floats_per_client: 0.0,
                dist_to_opt,
                eval_metric,
                wall_s: watch.elapsed_s(),
                client_wall_s: 0.0,
                client_serial_s: 0.0,
                phase_s: round_obs.phase_s,
                latency: round_obs.latency,
                staleness: round_obs.staleness,
                virtual_s: 0.0,
                fault,
            });
            continue;
        }
        net.set_active_clients(plan.len());
        drop(sp_plan);
        // Batch-schedule cursors for this round's participants, fetched
        // once so the executor closure borrows immutably.
        let steps0: Vec<u64> =
            plan.tasks.iter().map(|task| states.step0(task.client_id)).collect();

        // Server-side compression for the downlink (full n×n SVD!).
        let sp_svd = obs.span(Phase::TruncateSvd);
        let dec = svd(&w);
        let theta = cfg.rank.tau * dec.sigma_fro();
        let r_dn = dec.rank_for_tolerance(theta).clamp(1, cfg.rank.max_rank);
        let (p, sig, q) = dec.truncate(r_dn);
        drop(sp_svd);
        // Downlink through the wire codec: clients reconstruct from the
        // decoded factors.
        let sp_bc = obs.span(Phase::Broadcast);
        let p_bc = net.broadcast_mat("P", &p);
        let sig_bc = net.broadcast_vec("Sigma", &sig);
        let q_bc = net.broadcast_mat("Q", &q);
        let w_compressed =
            crate::tensor::matmul_nt(&crate::tensor::matmul(&p_bc, &Matrix::diag(&sig_bc)), &q_bc);
        // SCAFFOLD's server control variate rides the downlink at full
        // size (the clients train dense), erasing FeDLR's O(nr)
        // communication advantage — measured, not assumed.
        let ctrl_bc: Option<DriftState> =
            engine.broadcast_ctrl(&mut net, &[(m, n)], &[]);
        drop(sp_bc);

        // Clients: reconstruct, dense local training, compress upload —
        // one hermetic work item per client.
        let sp_train = obs.span(Phase::ClientTrain);
        let correction = engine.kind();
        let drift_pre: Vec<Option<DriftState>> = if engine.is_stateful() {
            plan.tasks.iter().map(|task| states.drift_cloned(task.client_id)).collect()
        } else {
            vec![None; plan.len()]
        };
        let report = executor.execute(&plan, |task| {
            // One weight set per client per round, trained in place by
            // the shared `client::LocalUpdate` driver (GradMode::Dense —
            // the seed's loop bitwise). Faults corrupt the dense matrix
            // *before* the on-device compression, like a real device.
            let mut wts =
                Weights { dense: vec![], lr: vec![LrWeight::Dense(w_compressed.clone())] };
            let driver = LocalUpdate {
                opt: cfg.opt,
                lr_t,
                iters: task.local_iters,
                step0: steps0[task.ordinal],
                mode: GradMode::Dense,
                vc_lr: &[],
                vc_dense: &[],
                g_bar: None,
                capture_first_grad: false,
                correction,
                drift_in: drift_pre[task.ordinal].as_ref(),
                ctrl: ctrl_bc.as_ref(),
                fault: task.fault,
                fault_seed: task.seed,
            };
            let out = driver.run(problem, task.client_id, &mut wts);
            let w_c = match wts.lr.pop() {
                Some(LrWeight::Dense(m)) => m,
                _ => unreachable!("dense client state"),
            };
            // Client-side compression (another full SVD, on-device).
            let dec_c = svd(&w_c);
            let theta_c = cfg.rank.tau * dec_c.sigma_fro();
            let r_up = dec_c.rank_for_tolerance(theta_c).clamp(1, cfg.rank.max_rank);
            (dec_c.truncate(r_up), out.drift_out, out.ctrl_delta)
        });
        obs.record_exec("local", &plan, &report.timing);
        let client_wall_s = report.wall_s;
        let client_serial_s = report.serial_s;
        drop(sp_train);
        let sp_agg = obs.span(Phase::Aggregate);
        // Each client ships its compressed triple {P_c, Σ_c, Q_c} as one
        // coalesced message at its *actual* upload rank (byte-exact — the
        // old accounting charged everyone a uniform upper bound); the
        // server reconstructs from the decoded factors in plan order.
        let mut w_next = Matrix::zeros(m, n);
        // Robust aggregation over the reconstructed per-client dense
        // matrices; Mean stays the legacy axpy fold, bitwise.
        let mut robust = RobustAccum::new(cfg.aggregator, 1);
        let mut ctrl_delta_sum: Option<Matrix> = None;
        for (task, ((pc, sc, qc), drift_out, ctrl_delta)) in
            plan.tasks.iter().zip(&report.results)
        {
            if let Some(gt) = &gate {
                net.set_upload_copies(gt.copies[task.ordinal]);
            }
            let [pc_dec, sc_d, qc_dec] = net
                .aggregate_batch_n("factor_triple_c", [pc.data(), sc.as_slice(), qc.data()]);
            let pc_d = Matrix::from_vec(pc.rows(), pc.cols(), pc_dec);
            let qc_d = Matrix::from_vec(qc.rows(), qc.cols(), qc_dec);
            let w_c_approx =
                crate::tensor::matmul_nt(&crate::tensor::matmul(&pc_d, &Matrix::diag(&sc_d)), &qc_d);
            robust.push(0, &mut w_next, task.weight, &w_c_approx);
            // Drift states persist as-is (fixed m×n space); SCAFFOLD
            // deltas go up *uncompressed* — the variate is not low rank.
            if let Some(st) = drift_out {
                states.set_drift(task.client_id, st.clone());
            }
            if let Some(delta) = ctrl_delta {
                let dec = net.aggregate_mat("ctrl", &delta.lr[0]);
                match ctrl_delta_sum.as_mut() {
                    Some(sum) => sum.axpy(1.0, &dec),
                    None => ctrl_delta_sum = Some(dec),
                }
            }
        }
        if gate.is_some() {
            net.set_upload_copies(1);
        }
        robust.finish(std::slice::from_mut(&mut w_next));
        net.end_round_trip();
        states.advance(&plan);
        w = w_next;
        // SCAFFOLD server fold: c ← c + (1/N) Σ δ_c over the full
        // population (non-participants contribute zero deltas).
        if let Some(sum) = ctrl_delta_sum {
            let inv = 1.0 / c_num as f64;
            let mut ctrl = engine.ctrl().expect("broadcast initialized ctrl").clone();
            ctrl.lr[0].axpy(inv, &sum);
            engine.set_ctrl(ctrl);
        }
        drop(sp_agg);

        // Metrics — rank reported as the numerical rank of the average
        // (which is generally r_up·C before the next truncation: the
        // "average of low-rank matrices is not low rank" effect).
        let sp_io = obs.span(Phase::Io);
        let comm = net.end_round();
        let (comm_floats, comm_per_client) = (comm.total_floats(), comm.per_client_floats());
        let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
        let fault = FaultRoundStats::from_comm(comm);
        drop(sp_io);
        let sp_eval = obs.span(Phase::Eval);
        let w_eval = Weights { dense: vec![], lr: vec![LrWeight::Dense(w.clone())] };
        let global_loss = problem.global_loss(&w_eval);
        let dist_to_opt = problem.distance_to_optimum(&w_eval);
        let eval_metric = problem.eval_metric(&w_eval);
        drop(sp_eval);
        let round_obs = obs.end_round();
        record.rounds.push(RoundMetrics {
            round: t,
            global_loss,
            ranks: vec![r_dn],
            comm_floats,
            comm_floats_lr: comm_floats,
            bytes_down,
            bytes_up,
            comm_floats_per_client: comm_per_client,
            dist_to_opt,
            eval_metric,
            wall_s: watch.elapsed_s(),
            client_wall_s,
            client_serial_s,
            phase_s: round_obs.phase_s,
            latency: round_obs.latency,
            staleness: round_obs.staleness,
            virtual_s: 0.0,
            fault,
        });
    }

    record
}

/// Numerical rank helper exposed for the baseline's tests.
pub fn average_rank_inflation(ws: &[LowRank]) -> usize {
    let mut acc = Matrix::zeros(ws[0].m(), ws[0].n());
    for f in ws {
        acc.axpy(1.0 / ws.len() as f64, &f.to_dense());
    }
    crate::linalg::numerical_rank(&acc, 1e-10)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{RankConfig, VarCorrection};
    use crate::coordinator::fedlrt::run_fedlrt;
    use crate::models::least_squares::LeastSquares;
    use crate::opt::LrSchedule;

    fn cfg(rounds: usize) -> TrainConfig {
        TrainConfig {
            rounds,
            local_iters: 10,
            lr: LrSchedule::Constant(2e-2),
            var_correction: VarCorrection::Simplified,
            rank: RankConfig { initial_rank: 4, max_rank: 6, tau: 0.05 },
            seed: 13,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn fedlr_descends_on_homogeneous_lsq() {
        let mut rng = Rng::new(1101);
        let prob = LeastSquares::homogeneous(10, 3, 400, 2, &mut rng);
        let rec = run_fedlr(&prob, &cfg(25), "t");
        assert!(
            rec.final_loss() < rec.rounds[0].global_loss * 0.3,
            "{} -> {}",
            rec.rounds[0].global_loss,
            rec.final_loss()
        );
    }

    #[test]
    fn average_of_low_rank_is_not_low_rank() {
        // The §3 argument for shared bases, verified numerically: C
        // independent rank-r factorizations average to rank ≈ C·r.
        let mut rng = Rng::new(1103);
        let ws: Vec<LowRank> =
            (0..3).map(|_| LowRank::random_init(12, 12, 2, &mut rng)).collect();
        let rank = average_rank_inflation(&ws);
        assert!(rank >= 5, "average rank {rank} should be ≈ C·r = 6");
    }

    #[test]
    fn fedlrt_beats_fedlr_on_drifted_clients() {
        // Heterogeneous targets: FeDLR's per-round compressions lose the
        // off-subspace components every round; shared-basis FeDLRT keeps
        // a consistent manifold and reaches a lower loss.
        let mut rng = Rng::new(1107);
        let prob = LeastSquares::heterogeneous(8, 320, 4, &mut rng);
        let l_star = prob.min_loss();
        let mut c = cfg(30);
        c.rank = RankConfig { initial_rank: 4, max_rank: 8, tau: 1e-4 };
        c.lr = LrSchedule::Constant(5e-3);
        c.local_iters = 20;
        let lr_gap = run_fedlr(&prob, &c, "t").final_loss() - l_star;
        let lrt_gap = run_fedlrt(&prob, &c, "t").final_loss() - l_star;
        assert!(
            lrt_gap < lr_gap,
            "FeDLRT gap {lrt_gap:.3e} should beat FeDLR gap {lr_gap:.3e}"
        );
    }

    #[test]
    fn fedlr_comm_is_factor_sized() {
        // Per round: down ≤ (m+n+1)·max_rank, up ≤ C·(m+n+1)·max_rank.
        let mut rng = Rng::new(1109);
        let prob = LeastSquares::homogeneous(10, 3, 200, 3, &mut rng);
        let rec = run_fedlr(&prob, &cfg(3), "t");
        for r in &rec.rounds {
            let bound = (10 + 10 + 1) * 6 * (1 + 3) as u64;
            assert!(r.comm_floats <= bound, "{} > {bound}", r.comm_floats);
        }
    }
}
