//! Dense federated baselines: FedAvg (Algorithm 3) and FedLin
//! (Algorithm 4, Mitra et al. 2021).
//!
//! Both train the *full* weight matrices — the `O(n²)` rows of Table 1 —
//! and serve as the accuracy/communication reference points for every
//! figure in the paper. FedLin adds the gradient-correction round:
//!
//! ```text
//! FedAvg:  broadcast Wᵗ → s* local SGD steps → aggregate mean
//! FedLin:  broadcast Wᵗ → aggregate G_W,c → broadcast G_W
//!          → s* corrected steps (∇L_c(W_c) + (G_W − G_W,c)) → aggregate
//! ```

use crate::client::{ClientStates, CorrectionEngine, DriftState, GradMode, LocalUpdate};
use crate::comm::{sync_gate, FaultRoundStats, Network};
use crate::engine::{ClientExecutor, Executor, RoundPlan};
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrWant, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::aggregate::RobustAccum;
use super::config::TrainConfig;

/// Which dense baseline to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseAlgo {
    FedAvg,
    FedLin,
}

impl DenseAlgo {
    pub fn label(&self) -> &'static str {
        match self {
            DenseAlgo::FedAvg => "fedavg",
            DenseAlgo::FedLin => "fedlin",
        }
    }
}

/// Run FedAvg or FedLin on `problem` (default telemetry recorder).
pub fn run_dense<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    algo: DenseAlgo,
    experiment: &str,
) -> RunRecord {
    run_dense_obs(problem, cfg, algo, experiment, &Recorder::new())
}

/// [`run_dense`] with an explicit telemetry [`Recorder`].
pub fn run_dense_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    algo: DenseAlgo,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    let spec = problem.spec();
    let c_num = problem.num_clients();
    let mut rng = Rng::new(cfg.seed);

    // All trainables dense; low-rank-capable layers are plain matrices.
    let mut lr_w: Vec<Matrix> = spec
        .lr_shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale((1.0 / m as f64).sqrt()))
        .collect();
    let mut dense: Vec<Matrix> = spec
        .dense_shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale((1.0 / m.max(1) as f64).sqrt()))
        .collect();

    let mut net = Network::with_codec(c_num, cfg.codec);
    net.fault = cfg.fault;
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    let mut record = RunRecord::new(algo.label(), experiment, c_num, cfg.seed);
    record.config = cfg.to_json();
    // Cross-round client state (batch cursors + drift variates) and the
    // drift-correction engine — see `run_fedlrt`. Dense baselines train
    // in the full matrix space, so drift states never need basis
    // projection: they persist and fold as-is.
    let mut states = ClientStates::new(c_num);
    let mut engine = CorrectionEngine::new(cfg.correction);

    for t in 0..cfg.rounds {
        let watch = Stopwatch::start();
        obs.begin_round(t);
        let lr_t = cfg.lr.at(t);
        let sp_plan = obs.span(Phase::Io);
        let mut plan = RoundPlan::build(cfg, c_num, t, |c| problem.client_weight(c));
        // Unreliable-transport gate: drop/corrupt/retry uploads and
        // enforce the round quorum (DESIGN.md §Fault model). `None`
        // whenever faults and the net policy are both inactive.
        let gate = sync_gate(&cfg.fault, &cfg.net_policy, cfg.seed, t as u64, &mut plan, &mut net);
        if gate.as_ref().is_some_and(|g| g.skip) {
            drop(sp_plan);
            // Quorum miss: record the round (evaluated on the untouched
            // server weights) and move on without updating any state.
            net.set_active_clients(0);
            let fault = FaultRoundStats::skipped_from_comm(net.end_round());
            let sp_eval = obs.span(Phase::Eval);
            let should_eval = t % cfg.eval_every == 0 || t + 1 == cfg.rounds;
            let w_eval = Weights {
                dense: dense.clone(),
                lr: lr_w.iter().cloned().map(LrWeight::Dense).collect(),
            };
            let global_loss =
                if should_eval { problem.global_loss(&w_eval) } else { f64::NAN };
            let dist_to_opt =
                if should_eval { problem.distance_to_optimum(&w_eval) } else { None };
            let eval_metric = if should_eval { problem.eval_metric(&w_eval) } else { None };
            drop(sp_eval);
            let round_obs = obs.end_round();
            record.rounds.push(RoundMetrics {
                round: t,
                global_loss,
                ranks: lr_w.iter().map(|w| w.rows().min(w.cols())).collect(),
                comm_floats: 0,
                comm_floats_lr: 0,
                bytes_down: 0,
                bytes_up: 0,
                comm_floats_per_client: 0.0,
                dist_to_opt,
                eval_metric,
                wall_s: watch.elapsed_s(),
                client_wall_s: 0.0,
                client_serial_s: 0.0,
                phase_s: round_obs.phase_s,
                latency: round_obs.latency,
                staleness: round_obs.staleness,
                virtual_s: 0.0,
                fault,
            });
            continue;
        }
        let a_num = plan.len();
        net.set_active_clients(a_num);
        drop(sp_plan);
        // Batch-schedule cursors for this round's participants, fetched
        // once so the executor closures borrow immutably.
        let steps0: Vec<u64> =
            plan.tasks.iter().map(|task| states.step0(task.client_id)).collect();
        let mut client_wall_s = 0.0;
        let mut client_serial_s = 0.0;

        // Broadcast the full weights through the wire codec; clients
        // train on the decoded copies.
        let sp_bc = obs.span(Phase::Broadcast);
        let lr_bc: Vec<Matrix> = lr_w.iter().map(|w| net.broadcast_mat("W_lr", w)).collect();
        let dense_bc: Vec<Matrix> =
            dense.iter().map(|w| net.broadcast_mat("W_dense", w)).collect();
        // SCAFFOLD's server control variate rides the same broadcast —
        // full-size here, so its byte cost shows the dense method's
        // true 2× downlink overhead.
        let ctrl_bc: Option<DriftState> = engine.broadcast_ctrl(
            &mut net,
            &lr_w.iter().map(|w| w.shape()).collect::<Vec<_>>(),
            &dense.iter().map(|w| w.shape()).collect::<Vec<_>>(),
        );
        drop(sp_bc);

        // FedLin: one extra round trip for the global gradient — the
        // whole correction block is the `variance_correction` phase.
        let sp_vc = obs.span(Phase::VarianceCorrection);
        let (vc_lr_all, vc_dense_all): (Vec<Vec<Option<Matrix>>>, Vec<Vec<Option<Matrix>>>) =
            match algo {
            DenseAlgo::FedAvg => (
                vec![vec![None; lr_w.len()]; a_num],
                vec![vec![None; dense.len()]; a_num],
            ),
            DenseAlgo::FedLin => {
                let w_t = Weights {
                    dense: dense_bc.clone(),
                    lr: lr_bc.iter().cloned().map(LrWeight::Dense).collect(),
                };
                let report = executor.execute(&plan, |task| {
                    problem.grad(task.client_id, &w_t, LrWant::Dense, steps0[task.ordinal])
                });
                obs.record_exec("vc_grad", &plan, &report.timing);
                client_wall_s += report.wall_s;
                client_serial_s += report.serial_s;
                let per_client = report.results;
                // Mean gradients: each participating client's upload is
                // decoded on receive; the mean goes back down through
                // the codec too.
                let mut mean_lr: Vec<Matrix> =
                    lr_w.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
                let mut mean_d: Vec<Matrix> =
                    dense.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
                // The global-gradient fold stays a weighted mean even
                // under robust aggregation (it is a control signal, not
                // the model update); retransmitted copies still bill.
                for (task, g) in plan.tasks.iter().zip(&per_client) {
                    if let Some(gt) = &gate {
                        net.set_upload_copies(gt.copies[task.ordinal]);
                    }
                    for (acc, gl) in mean_lr.iter_mut().zip(&g.lr) {
                        acc.axpy(task.weight, &net.aggregate_mat("G_W_lr", gl.dense()));
                    }
                    for (acc, gd) in mean_d.iter_mut().zip(&g.dense) {
                        acc.axpy(task.weight, &net.aggregate_mat("G_W_dense", gd));
                    }
                }
                if gate.is_some() {
                    net.set_upload_copies(1);
                }
                let mean_lr_bc: Vec<Matrix> =
                    mean_lr.iter().map(|m| net.broadcast_mat("G_W_lr", m)).collect();
                let mean_d_bc: Vec<Matrix> =
                    mean_d.iter().map(|m| net.broadcast_mat("G_W_dense", m)).collect();
                net.end_round_trip();
                (0..a_num)
                    .map(|c| {
                        let v_lr: Vec<Option<Matrix>> = mean_lr_bc
                            .iter()
                            .zip(&per_client[c].lr)
                            .map(|(gm, gc)| Some(gm.sub(gc.dense())))
                            .collect();
                        let v_d: Vec<Option<Matrix>> = mean_d_bc
                            .iter()
                            .zip(&per_client[c].dense)
                            .map(|(gm, gc)| Some(gm.sub(gc)))
                            .collect();
                        (v_lr, v_d)
                    })
                    .unzip()
            }
        };
        drop(sp_vc);

        // Local iterations as executor work items, then aggregate the
        // weighted mean in plan order (executor-independent bitwise).
        // The loop itself lives in `client::LocalUpdate` (GradMode::Dense
        // keeps the legacy lr-then-dense step order); drift states need
        // no space mapping here, so stored clones pass straight through.
        let sp_local = obs.span(Phase::ClientTrain);
        let correction = engine.kind();
        let drift_pre: Vec<Option<DriftState>> = if engine.is_stateful() {
            plan.tasks.iter().map(|task| states.drift_cloned(task.client_id)).collect()
        } else {
            vec![None; a_num]
        };
        let report = executor.execute(&plan, |task| {
            let mut w_c = Weights {
                dense: dense_bc.clone(),
                lr: lr_bc.iter().cloned().map(LrWeight::Dense).collect(),
            };
            let driver = LocalUpdate {
                opt: cfg.opt,
                lr_t,
                iters: task.local_iters,
                step0: steps0[task.ordinal],
                mode: GradMode::Dense,
                vc_lr: &vc_lr_all[task.ordinal],
                vc_dense: &vc_dense_all[task.ordinal],
                g_bar: None,
                capture_first_grad: false,
                correction,
                drift_in: drift_pre[task.ordinal].as_ref(),
                ctrl: ctrl_bc.as_ref(),
                fault: task.fault,
                fault_seed: task.seed,
            };
            let out = driver.run(problem, task.client_id, &mut w_c);
            let Weights { dense: dense_c, lr } = w_c;
            let lr_c: Vec<Matrix> = lr.into_iter().map(|lw| match lw {
                LrWeight::Dense(m) => m,
                LrWeight::Factored(_) => unreachable!("dense baseline weights"),
            }).collect();
            (lr_c, dense_c, out.drift_out, out.ctrl_delta)
        });
        obs.record_exec("local", &plan, &report.timing);
        client_wall_s += report.wall_s;
        client_serial_s += report.serial_s;
        drop(sp_local);
        let sp_agg = obs.span(Phase::Aggregate);
        let mut lr_accum: Vec<Matrix> =
            lr_w.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
        let mut dense_accum: Vec<Matrix> =
            dense.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
        // Each client's trained weights upload through the codec; the
        // server averages the decoded tensors in plan order. Drift
        // states persist as-is (full matrix space, no basis to track);
        // SCAFFOLD deltas bill uplink bytes and fold below.
        // Robust aggregation over the decoded client weights; Mean
        // stays the legacy axpy fold, bitwise.
        let mut robust_lr = RobustAccum::new(cfg.aggregator, lr_w.len());
        let mut robust_d = RobustAccum::new(cfg.aggregator, dense.len());
        let mut ctrl_delta_sum: Option<DriftState> = None;
        for (task, (lr_c, dense_c, drift_out, ctrl_delta)) in
            plan.tasks.iter().zip(&report.results)
        {
            if let Some(gt) = &gate {
                net.set_upload_copies(gt.copies[task.ordinal]);
            }
            for (l, w) in lr_c.iter().enumerate() {
                let dec = net.aggregate_mat("W_lr", w);
                robust_lr.push(l, &mut lr_accum[l], task.weight, &dec);
            }
            for (dl, w) in dense_c.iter().enumerate() {
                let dec = net.aggregate_mat("W_dense", w);
                robust_d.push(dl, &mut dense_accum[dl], task.weight, &dec);
            }
            if let Some(st) = drift_out {
                states.set_drift(task.client_id, st.clone());
            }
            if let Some(delta) = ctrl_delta {
                let lr: Vec<Matrix> =
                    delta.lr.iter().map(|m| net.aggregate_mat("ctrl", m)).collect();
                let dn: Vec<Matrix> =
                    delta.dense.iter().map(|m| net.aggregate_mat("ctrl_dense", m)).collect();
                match ctrl_delta_sum.as_mut() {
                    Some(sum) => {
                        for (a, b) in sum.lr.iter_mut().zip(&lr) {
                            a.axpy(1.0, b);
                        }
                        for (a, b) in sum.dense.iter_mut().zip(&dn) {
                            a.axpy(1.0, b);
                        }
                    }
                    None => ctrl_delta_sum = Some(DriftState { lr, dense: dn }),
                }
            }
        }
        if gate.is_some() {
            net.set_upload_copies(1);
        }
        robust_lr.finish(&mut lr_accum);
        robust_d.finish(&mut dense_accum);
        net.end_round_trip();
        states.advance(&plan);
        lr_w = lr_accum;
        dense = dense_accum;
        // SCAFFOLD server fold: c ← c + (1/N) Σ δ_c over the full
        // population (non-participants contribute zero deltas).
        if let Some(sum) = ctrl_delta_sum {
            let inv = 1.0 / c_num as f64;
            let mut ctrl = engine.ctrl().expect("broadcast initialized ctrl").clone();
            for (a, b) in ctrl.lr.iter_mut().zip(&sum.lr) {
                a.axpy(inv, b);
            }
            for (a, b) in ctrl.dense.iter_mut().zip(&sum.dense) {
                a.axpy(inv, b);
            }
            engine.set_ctrl(ctrl);
        }
        drop(sp_agg);

        // Metrics.
        let sp_io = obs.span(Phase::Io);
        let comm = net.end_round();
        let (comm_floats, comm_per_client) = (comm.total_floats(), comm.per_client_floats());
        let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
        let comm_floats_lr = comm.floats_matching(|l| l.ends_with("_lr"));
        let fault = FaultRoundStats::from_comm(comm);
        drop(sp_io);
        let sp_eval = obs.span(Phase::Eval);
        let should_eval = t % cfg.eval_every == 0 || t + 1 == cfg.rounds;
        let w_eval = Weights {
            dense: dense.clone(),
            lr: lr_w.iter().cloned().map(LrWeight::Dense).collect(),
        };
        let global_loss = if should_eval { problem.global_loss(&w_eval) } else { f64::NAN };
        let dist_to_opt =
            if should_eval { problem.distance_to_optimum(&w_eval) } else { None };
        let eval_metric = if should_eval { problem.eval_metric(&w_eval) } else { None };
        drop(sp_eval);
        let round_obs = obs.end_round();
        record.rounds.push(RoundMetrics {
            round: t,
            global_loss,
            ranks: lr_w.iter().map(|w| w.rows().min(w.cols())).collect(),
            comm_floats,
            comm_floats_lr,
            bytes_down,
            bytes_up,
            comm_floats_per_client: comm_per_client,
            dist_to_opt,
            eval_metric,
            wall_s: watch.elapsed_s(),
            client_wall_s,
            client_serial_s,
            phase_s: round_obs.phase_s,
            latency: round_obs.latency,
            staleness: round_obs.staleness,
            virtual_s: 0.0,
            fault,
        });
    }

    record
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::least_squares::LeastSquares;
    use crate::models::quadratic::Quadratic;
    use crate::opt::LrSchedule;

    fn cfg(rounds: usize, iters: usize) -> TrainConfig {
        TrainConfig {
            rounds,
            local_iters: iters,
            lr: LrSchedule::Constant(5e-2),
            seed: 7,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn fedavg_converges_homogeneous_quadratic() {
        // Identical targets ⇒ FedAvg finds the exact minimizer.
        let mut rng = Rng::new(901);
        let base = Quadratic::random(6, 2, 1, &mut rng);
        let prob = Quadratic {
            targets: vec![base.targets[0].clone(); 4],
            alphas: vec![1.0; 4],
            ..base
        };
        let rec = run_dense(&prob, &cfg(60, 5), DenseAlgo::FedAvg, "t");
        assert!(rec.final_loss() < 1e-6, "loss {}", rec.final_loss());
    }

    #[test]
    fn fedlin_beats_fedavg_on_heterogeneous() {
        // The Fig-1 effect: client drift stalls FedAvg above the global
        // minimum; FedLin's variance correction closes the gap.
        let mut rng = Rng::new(903);
        let prob = LeastSquares::heterogeneous(6, 200, 4, &mut rng);
        let l_star = prob.min_loss();
        let c = TrainConfig {
            rounds: 40,
            local_iters: 50,
            lr: LrSchedule::Constant(5e-3),
            seed: 3,
            ..TrainConfig::default()
        };
        let gap_avg = run_dense(&prob, &c, DenseAlgo::FedAvg, "t").final_loss() - l_star;
        let gap_lin = run_dense(&prob, &c, DenseAlgo::FedLin, "t").final_loss() - l_star;
        assert!(
            gap_lin < gap_avg * 0.5,
            "fedlin gap {gap_lin} vs fedavg gap {gap_avg} (L* = {l_star})"
        );
    }

    #[test]
    fn fedlin_costs_double_communication() {
        // Table 1: FedAvg O(2n²) vs FedLin O(4n²) per round.
        let mut rng = Rng::new(907);
        let prob = Quadratic::random(8, 2, 3, &mut rng);
        let avg = run_dense(&prob, &cfg(3, 2), DenseAlgo::FedAvg, "t").total_comm_floats();
        let lin = run_dense(&prob, &cfg(3, 2), DenseAlgo::FedLin, "t").total_comm_floats();
        // FedLin adds C uploads + 1 broadcast of G_W per round.
        assert!(lin > avg, "lin {lin} > avg {avg}");
        let n2 = 8 * 8u64;
        assert_eq!(lin - avg, 3 * (3 * n2 + n2)); // 3 rounds × (C·n² up + n² down)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(909);
        let prob = Quadratic::random(6, 2, 2, &mut rng);
        let a = run_dense(&prob, &cfg(4, 3), DenseAlgo::FedLin, "t");
        let b = run_dense(&prob, &cfg(4, 3), DenseAlgo::FedLin, "t");
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
        }
    }
}
