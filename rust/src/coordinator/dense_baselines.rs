//! Dense federated baselines: FedAvg (Algorithm 3) and FedLin
//! (Algorithm 4, Mitra et al. 2021).
//!
//! Both train the *full* weight matrices — the `O(n²)` rows of Table 1 —
//! and serve as the accuracy/communication reference points for every
//! figure in the paper. FedLin adds the gradient-correction round:
//!
//! ```text
//! FedAvg:  broadcast Wᵗ → s* local SGD steps → aggregate mean
//! FedLin:  broadcast Wᵗ → aggregate G_W,c → broadcast G_W
//!          → s* corrected steps (∇L_c(W_c) + (G_W − G_W,c)) → aggregate
//! ```

use crate::comm::Network;
use crate::engine::{ClientExecutor, Executor, RoundPlan};
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrWant, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::opt::ClientOptimizer;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::config::TrainConfig;

/// Which dense baseline to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseAlgo {
    FedAvg,
    FedLin,
}

impl DenseAlgo {
    pub fn label(&self) -> &'static str {
        match self {
            DenseAlgo::FedAvg => "fedavg",
            DenseAlgo::FedLin => "fedlin",
        }
    }
}

/// Run FedAvg or FedLin on `problem` (default telemetry recorder).
pub fn run_dense<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    algo: DenseAlgo,
    experiment: &str,
) -> RunRecord {
    run_dense_obs(problem, cfg, algo, experiment, &Recorder::new())
}

/// [`run_dense`] with an explicit telemetry [`Recorder`].
pub fn run_dense_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    algo: DenseAlgo,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    let spec = problem.spec();
    let c_num = problem.num_clients();
    let mut rng = Rng::new(cfg.seed);

    // All trainables dense; low-rank-capable layers are plain matrices.
    let mut lr_w: Vec<Matrix> = spec
        .lr_shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale((1.0 / m as f64).sqrt()))
        .collect();
    let mut dense: Vec<Matrix> = spec
        .dense_shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, &mut rng).scale((1.0 / m.max(1) as f64).sqrt()))
        .collect();

    let mut net = Network::with_codec(c_num, cfg.codec);
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    let mut record = RunRecord::new(algo.label(), experiment, c_num, cfg.seed);
    record.config = cfg.to_json();
    // Per-client local-step counters (see `run_fedlrt`): straggler-
    // shortened rounds resume their batch schedule instead of skipping.
    let mut next_step: Vec<u64> = vec![0; c_num];

    for t in 0..cfg.rounds {
        let watch = Stopwatch::start();
        obs.begin_round(t);
        let lr_t = cfg.lr.at(t);
        let sp_plan = obs.span(Phase::Io);
        let plan = RoundPlan::build(cfg, c_num, t, |c| problem.client_weight(c));
        let a_num = plan.len();
        net.set_active_clients(a_num);
        drop(sp_plan);
        let mut client_wall_s = 0.0;
        let mut client_serial_s = 0.0;

        // Broadcast the full weights through the wire codec; clients
        // train on the decoded copies.
        let sp_bc = obs.span(Phase::Broadcast);
        let lr_bc: Vec<Matrix> = lr_w.iter().map(|w| net.broadcast_mat("W_lr", w)).collect();
        let dense_bc: Vec<Matrix> =
            dense.iter().map(|w| net.broadcast_mat("W_dense", w)).collect();
        drop(sp_bc);

        // FedLin: one extra round trip for the global gradient — the
        // whole correction block is the `variance_correction` phase.
        let sp_vc = obs.span(Phase::VarianceCorrection);
        let corrections: Option<Vec<(Vec<Matrix>, Vec<Matrix>)>> = match algo {
            DenseAlgo::FedAvg => None,
            DenseAlgo::FedLin => {
                let w_t = Weights {
                    dense: dense_bc.clone(),
                    lr: lr_bc.iter().cloned().map(LrWeight::Dense).collect(),
                };
                let report = executor.execute(&plan, |task| {
                    problem.grad(task.client_id, &w_t, LrWant::Dense, next_step[task.client_id])
                });
                obs.record_exec("vc_grad", &plan, &report.timing);
                client_wall_s += report.wall_s;
                client_serial_s += report.serial_s;
                let per_client = report.results;
                // Mean gradients: each participating client's upload is
                // decoded on receive; the mean goes back down through
                // the codec too.
                let mut mean_lr: Vec<Matrix> =
                    lr_w.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
                let mut mean_d: Vec<Matrix> =
                    dense.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
                for (task, g) in plan.tasks.iter().zip(&per_client) {
                    for (acc, gl) in mean_lr.iter_mut().zip(&g.lr) {
                        acc.axpy(task.weight, &net.aggregate_mat("G_W_lr", gl.dense()));
                    }
                    for (acc, gd) in mean_d.iter_mut().zip(&g.dense) {
                        acc.axpy(task.weight, &net.aggregate_mat("G_W_dense", gd));
                    }
                }
                let mean_lr_bc: Vec<Matrix> =
                    mean_lr.iter().map(|m| net.broadcast_mat("G_W_lr", m)).collect();
                let mean_d_bc: Vec<Matrix> =
                    mean_d.iter().map(|m| net.broadcast_mat("G_W_dense", m)).collect();
                net.end_round_trip();
                Some(
                    (0..a_num)
                        .map(|c| {
                            let v_lr: Vec<Matrix> = mean_lr_bc
                                .iter()
                                .zip(&per_client[c].lr)
                                .map(|(gm, gc)| gm.sub(gc.dense()))
                                .collect();
                            let v_d: Vec<Matrix> = mean_d_bc
                                .iter()
                                .zip(&per_client[c].dense)
                                .map(|(gm, gc)| gm.sub(gc))
                                .collect();
                            (v_lr, v_d)
                        })
                        .collect(),
                )
            }
        };
        drop(sp_vc);

        // Local iterations as executor work items, then aggregate the
        // weighted mean in plan order (executor-independent bitwise).
        // The client's weight set is assembled once and trained in
        // place — the seed re-cloned every n×n matrix into a fresh
        // `Weights` on every local iteration.
        let sp_local = obs.span(Phase::ClientTrain);
        let report = executor.execute(&plan, |task| {
            let c = task.client_id;
            let step0_c = next_step[c];
            let mut w_c = Weights {
                dense: dense_bc.clone(),
                lr: lr_bc.iter().cloned().map(LrWeight::Dense).collect(),
            };
            let mut opt_lr: Vec<ClientOptimizer> =
                (0..w_c.lr.len()).map(|_| ClientOptimizer::new(cfg.opt)).collect();
            let mut opt_d: Vec<ClientOptimizer> =
                (0..w_c.dense.len()).map(|_| ClientOptimizer::new(cfg.opt)).collect();
            for s in 0..task.local_iters {
                let g = problem.grad(c, &w_c, LrWant::Dense, step0_c + s as u64);
                for l in 0..w_c.lr.len() {
                    let corr = corrections.as_ref().map(|cs| &cs[task.ordinal].0[l]);
                    opt_lr[l].step(w_c.lr[l].as_dense_mut(), g.lr[l].dense(), lr_t, corr);
                }
                for (dl, w) in w_c.dense.iter_mut().enumerate() {
                    let corr = corrections.as_ref().map(|cs| &cs[task.ordinal].1[dl]);
                    opt_d[dl].step(w, &g.dense[dl], lr_t, corr);
                }
            }
            let Weights { dense: dense_c, lr } = w_c;
            let lr_c: Vec<Matrix> = lr.into_iter().map(|lw| match lw {
                LrWeight::Dense(m) => m,
                LrWeight::Factored(_) => unreachable!("dense baseline weights"),
            }).collect();
            (lr_c, dense_c)
        });
        obs.record_exec("local", &plan, &report.timing);
        client_wall_s += report.wall_s;
        client_serial_s += report.serial_s;
        drop(sp_local);
        let sp_agg = obs.span(Phase::Aggregate);
        let mut lr_accum: Vec<Matrix> =
            lr_w.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
        let mut dense_accum: Vec<Matrix> =
            dense.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect();
        // Each client's trained weights upload through the codec; the
        // server averages the decoded tensors in plan order.
        for (task, (lr_c, dense_c)) in plan.tasks.iter().zip(&report.results) {
            for (l, w) in lr_c.iter().enumerate() {
                lr_accum[l].axpy(task.weight, &net.aggregate_mat("W_lr", w));
            }
            for (dl, w) in dense_c.iter().enumerate() {
                dense_accum[dl].axpy(task.weight, &net.aggregate_mat("W_dense", w));
            }
        }
        net.end_round_trip();
        for task in &plan.tasks {
            next_step[task.client_id] += task.local_iters as u64;
        }
        lr_w = lr_accum;
        dense = dense_accum;
        drop(sp_agg);

        // Metrics.
        let sp_io = obs.span(Phase::Io);
        let comm = net.end_round();
        let (comm_floats, comm_per_client) = (comm.total_floats(), comm.per_client_floats());
        let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
        let comm_floats_lr = comm.floats_matching(|l| l.ends_with("_lr"));
        drop(sp_io);
        let sp_eval = obs.span(Phase::Eval);
        let should_eval = t % cfg.eval_every == 0 || t + 1 == cfg.rounds;
        let w_eval = Weights {
            dense: dense.clone(),
            lr: lr_w.iter().cloned().map(LrWeight::Dense).collect(),
        };
        let global_loss = if should_eval { problem.global_loss(&w_eval) } else { f64::NAN };
        let dist_to_opt =
            if should_eval { problem.distance_to_optimum(&w_eval) } else { None };
        let eval_metric = if should_eval { problem.eval_metric(&w_eval) } else { None };
        drop(sp_eval);
        let round_obs = obs.end_round();
        record.rounds.push(RoundMetrics {
            round: t,
            global_loss,
            ranks: lr_w.iter().map(|w| w.rows().min(w.cols())).collect(),
            comm_floats,
            comm_floats_lr,
            bytes_down,
            bytes_up,
            comm_floats_per_client: comm_per_client,
            dist_to_opt,
            eval_metric,
            wall_s: watch.elapsed_s(),
            client_wall_s,
            client_serial_s,
            phase_s: round_obs.phase_s,
            latency: round_obs.latency,
            staleness: round_obs.staleness,
            virtual_s: 0.0,
        });
    }

    record
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::least_squares::LeastSquares;
    use crate::models::quadratic::Quadratic;
    use crate::opt::LrSchedule;

    fn cfg(rounds: usize, iters: usize) -> TrainConfig {
        TrainConfig {
            rounds,
            local_iters: iters,
            lr: LrSchedule::Constant(5e-2),
            seed: 7,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn fedavg_converges_homogeneous_quadratic() {
        // Identical targets ⇒ FedAvg finds the exact minimizer.
        let mut rng = Rng::new(901);
        let base = Quadratic::random(6, 2, 1, &mut rng);
        let prob = Quadratic {
            targets: vec![base.targets[0].clone(); 4],
            alphas: vec![1.0; 4],
            ..base
        };
        let rec = run_dense(&prob, &cfg(60, 5), DenseAlgo::FedAvg, "t");
        assert!(rec.final_loss() < 1e-6, "loss {}", rec.final_loss());
    }

    #[test]
    fn fedlin_beats_fedavg_on_heterogeneous() {
        // The Fig-1 effect: client drift stalls FedAvg above the global
        // minimum; FedLin's variance correction closes the gap.
        let mut rng = Rng::new(903);
        let prob = LeastSquares::heterogeneous(6, 200, 4, &mut rng);
        let l_star = prob.min_loss();
        let c = TrainConfig {
            rounds: 40,
            local_iters: 50,
            lr: LrSchedule::Constant(5e-3),
            seed: 3,
            ..TrainConfig::default()
        };
        let gap_avg = run_dense(&prob, &c, DenseAlgo::FedAvg, "t").final_loss() - l_star;
        let gap_lin = run_dense(&prob, &c, DenseAlgo::FedLin, "t").final_loss() - l_star;
        assert!(
            gap_lin < gap_avg * 0.5,
            "fedlin gap {gap_lin} vs fedavg gap {gap_avg} (L* = {l_star})"
        );
    }

    #[test]
    fn fedlin_costs_double_communication() {
        // Table 1: FedAvg O(2n²) vs FedLin O(4n²) per round.
        let mut rng = Rng::new(907);
        let prob = Quadratic::random(8, 2, 3, &mut rng);
        let avg = run_dense(&prob, &cfg(3, 2), DenseAlgo::FedAvg, "t").total_comm_floats();
        let lin = run_dense(&prob, &cfg(3, 2), DenseAlgo::FedLin, "t").total_comm_floats();
        // FedLin adds C uploads + 1 broadcast of G_W per round.
        assert!(lin > avg, "lin {lin} > avg {avg}");
        let n2 = 8 * 8u64;
        assert_eq!(lin - avg, 3 * (3 * n2 + n2)); // 3 rounds × (C·n² up + n² down)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(909);
        let prob = Quadratic::random(6, 2, 2, &mut rng);
        let a = run_dense(&prob, &cfg(4, 3), DenseAlgo::FedLin, "t");
        let b = run_dense(&prob, &cfg(4, 3), DenseAlgo::FedLin, "t");
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits());
        }
    }
}
