//! Naive FeDLRT (Algorithm 6) — the "what goes wrong without shared
//! bases" baseline.
//!
//! Each client augments its *own* bases with its *local* gradients and
//! optimizes its own coefficients. The per-client manifolds diverge, so
//! server aggregation must reconstruct the full weight matrix
//! `W* = (1/C) Σ_c Ũ_c S̃*_c Ṽ_cᵀ` — which is generally **not** low rank —
//! and recover a factorization with a full `n×n` SVD (the `O(n³)` rows of
//! Table 1 for FeDLR-style schemes). Communication also grows: full
//! factor triples travel upstream instead of small coefficient matrices.

use crate::comm::Network;
use crate::engine::{ClientExecutor, Executor, RoundPlan};
use crate::linalg::svd;
use crate::lowrank::{augment_basis, LowRank};
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrGrad, LrWant, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::opt::ClientOptimizer;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::config::TrainConfig;

/// Run Algorithm 6. Only supports problems whose trainables are a single
/// low-rank layer (the convex tests it is benchmarked on).
pub fn run_fedlrt_naive<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
) -> RunRecord {
    run_fedlrt_naive_obs(problem, cfg, experiment, &Recorder::new())
}

/// [`run_fedlrt_naive`] with an explicit telemetry [`Recorder`].
pub fn run_fedlrt_naive_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    let spec = problem.spec();
    assert!(
        spec.dense_shapes.is_empty() && spec.lr_shapes.len() == 1,
        "naive FeDLRT baseline supports single-layer problems"
    );
    let (m, n) = spec.lr_shapes[0];
    let c_num = problem.num_clients();
    let mut rng = Rng::new(cfg.seed);

    let r0 = cfg.rank.initial_rank.min(m.min(n) / 2).max(1);
    let mut fac = LowRank::random_init(m, n, r0, &mut rng);
    fac.s.scale_inplace((1.0 / m as f64).sqrt());

    let mut net = Network::with_codec(c_num, cfg.codec);
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    let mut record = RunRecord::new("fedlrt_naive", experiment, c_num, cfg.seed);
    record.config = cfg.to_json();
    // Per-client local-step counters (see `run_fedlrt`): straggler-
    // shortened rounds resume their batch schedule instead of skipping.
    let mut next_step: Vec<u64> = vec![0; c_num];

    for t in 0..cfg.rounds {
        let watch = Stopwatch::start();
        obs.begin_round(t);
        let lr_t = cfg.lr.at(t);
        let sp_plan = obs.span(Phase::Io);
        let plan = RoundPlan::build(cfg, c_num, t, |c| problem.client_weight(c));
        net.set_active_clients(plan.len());
        drop(sp_plan);

        // Broadcast the current global factors through the wire codec;
        // clients work on the decoded copies (S is diagonal, so only
        // its diagonal travels).
        let sp_bc = obs.span(Phase::Broadcast);
        let u_bc = net.broadcast_mat("U", &fac.u);
        let v_bc = net.broadcast_mat("V", &fac.v);
        let s_diag: Vec<f64> = (0..fac.rank()).map(|i| fac.s[(i, i)]).collect();
        let s_bc = Matrix::diag(&net.broadcast_vec("S_diag", &s_diag));
        let fac_c = LowRank { u: u_bc, s: s_bc, v: v_bc };
        drop(sp_bc);

        // Per-client: local augmentation (own QR on own gradients) and
        // local coefficient iterations — no coordination until upload,
        // so each client is one hermetic work item.
        let sp_train = obs.span(Phase::ClientTrain);
        let report = executor.execute(&plan, |task| {
            let c = task.client_id;
            let step0_c = next_step[c];
            let w_c = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac_c.clone())] };
            let g = problem.grad(c, &w_c, LrWant::Factors, step0_c);
            let (g_u, g_v) = match &g.lr[0] {
                LrGrad::Factors { g_u, g_v, .. } => (g_u.clone(), g_v.clone()),
                _ => unreachable!(),
            };
            // Algorithm 6 lines 7–9: client-local augmentation. The
            // local factorization is trained in place (only S̃ changes
            // between iterations) through the allocation-free
            // `grad_coeff_into` fast path where the problem offers one.
            let aug = augment_basis(&fac_c, &g_u, &g_v, 2 * fac_c.rank());
            let r2 = aug.rank();
            let mut w_loc = Weights {
                dense: vec![],
                lr: vec![LrWeight::Factored(LowRank {
                    u: aug.u_tilde,
                    s: aug.s_tilde,
                    v: aug.v_tilde,
                })],
            };
            let mut g_coeff = vec![Matrix::zeros(r2, r2)];
            let mut opt = ClientOptimizer::new(cfg.opt);
            for s in 0..task.local_iters {
                let step = step0_c + s as u64;
                if problem.grad_coeff_into(c, &w_loc, step, &mut g_coeff, &mut []).is_none() {
                    let gg = problem.grad(c, &w_loc, LrWant::Coeff, step);
                    g_coeff[0].copy_from(gg.lr[0].coeff());
                }
                let fac_loc = w_loc.lr[0].as_factored_mut();
                opt.step(&mut fac_loc.s, &g_coeff[0], lr_t, None);
            }
            // The client uploads its full factor triple — bases
            // diverged, so the server cannot reuse shared ones.
            let fac_out = match w_loc.lr.pop() {
                Some(LrWeight::Factored(f)) => f,
                _ => unreachable!("factored client state"),
            };
            (fac_out.u, fac_out.s, fac_out.v)
        });
        obs.record_exec("local", &plan, &report.timing);
        let client_wall_s = report.wall_s;
        let client_serial_s = report.serial_s;
        drop(sp_train);
        let sp_agg = obs.span(Phase::Aggregate);
        // Every participating client ships its factor triple
        // {Ũ_c, S̃_c, Ṽ_c} as one coalesced message through the wire
        // codec; the server reconstructs the dense average from the
        // *decoded* triples in plan order (executor-independent
        // bitwise).
        let mut w_star = Matrix::zeros(m, n);
        for (task, (u_t, s_t, v_t)) in plan.tasks.iter().zip(&report.results) {
            let mut parts = net
                .aggregate_batch("factor_triple_c", &[u_t.data(), s_t.data(), v_t.data()])
                .into_iter();
            let u_d = Matrix::from_vec(u_t.rows(), u_t.cols(), parts.next().unwrap());
            let s_d = Matrix::from_vec(s_t.rows(), s_t.cols(), parts.next().unwrap());
            let v_d = Matrix::from_vec(v_t.rows(), v_t.cols(), parts.next().unwrap());
            let w_c_dense = LowRank { u: u_d, s: s_d, v: v_d }.to_dense();
            w_star.axpy(task.weight, &w_c_dense);
        }
        net.end_round_trip();
        for task in &plan.tasks {
            next_step[task.client_id] += task.local_iters as u64;
        }
        drop(sp_agg);

        // Server: full n×n SVD to recover a low-rank factorization —
        // the O(n³) cost shared bases avoid.
        let sp_svd = obs.span(Phase::TruncateSvd);
        let dec = svd(&w_star);
        let theta = cfg.rank.tau
            * dec.sigma.iter().map(|x| x * x).sum::<f64>().sqrt();
        let r1 = dec.rank_for_tolerance(theta).clamp(1, cfg.rank.max_rank);
        let (u, sig, v) = dec.truncate(r1);
        fac = LowRank { u, s: Matrix::diag(&sig), v };
        drop(sp_svd);

        // Metrics.
        let sp_io = obs.span(Phase::Io);
        let comm = net.end_round();
        let (comm_floats, comm_per_client) = (comm.total_floats(), comm.per_client_floats());
        let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
        let comm_floats_lr = comm_floats; // single-layer problems only
        drop(sp_io);
        let sp_eval = obs.span(Phase::Eval);
        let w_eval = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
        let global_loss = problem.global_loss(&w_eval);
        let dist_to_opt = problem.distance_to_optimum(&w_eval);
        let eval_metric = problem.eval_metric(&w_eval);
        drop(sp_eval);
        let round_obs = obs.end_round();
        record.rounds.push(RoundMetrics {
            round: t,
            global_loss,
            ranks: vec![fac.rank()],
            comm_floats,
            comm_floats_lr,
            bytes_down,
            bytes_up,
            comm_floats_per_client: comm_per_client,
            dist_to_opt,
            eval_metric,
            wall_s: watch.elapsed_s(),
            client_wall_s,
            client_serial_s,
            phase_s: round_obs.phase_s,
            latency: round_obs.latency,
            staleness: round_obs.staleness,
            virtual_s: 0.0,
        });
    }

    record
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{RankConfig, VarCorrection};
    use crate::coordinator::fedlrt::run_fedlrt;
    use crate::models::quadratic::Quadratic;
    use crate::opt::LrSchedule;

    fn cfg() -> TrainConfig {
        TrainConfig {
            rounds: 20,
            local_iters: 4,
            lr: LrSchedule::Constant(5e-2),
            var_correction: VarCorrection::None,
            rank: RankConfig { initial_rank: 2, max_rank: 6, tau: 0.05 },
            seed: 11,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn naive_descends_but_costs_more_communication() {
        let mut rng = Rng::new(1001);
        let prob = Quadratic::random(10, 2, 4, &mut rng);
        let naive = run_fedlrt_naive(&prob, &cfg(), "t");
        let shared = run_fedlrt(&prob, &cfg(), "t");
        assert!(naive.final_loss() < naive.rounds[0].global_loss);
        // Shared-basis FeDLRT uploads r²-sized coefficients; naive
        // uploads full factor triples — strictly more floats.
        assert!(
            naive.total_comm_floats() > shared.total_comm_floats(),
            "naive {} vs shared {}",
            naive.total_comm_floats(),
            shared.total_comm_floats()
        );
    }
}
