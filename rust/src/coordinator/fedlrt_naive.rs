//! Naive FeDLRT (Algorithm 6) — the "what goes wrong without shared
//! bases" baseline.
//!
//! Each client augments its *own* bases with its *local* gradients and
//! optimizes its own coefficients. The per-client manifolds diverge, so
//! server aggregation must reconstruct the full weight matrix
//! `W* = (1/C) Σ_c Ũ_c S̃*_c Ṽ_cᵀ` — which is generally **not** low rank —
//! and recover a factorization with a full `n×n` SVD (the `O(n³)` rows of
//! Table 1 for FeDLR-style schemes). Communication also grows: full
//! factor triples travel upstream instead of small coefficient matrices.

use crate::client::{
    change_coords, ClientStates, CorrectionEngine, DriftState, GradMode, LocalUpdate,
};
use crate::comm::{sync_gate, FaultRoundStats, Network};
use crate::engine::{ClientExecutor, Executor, RoundPlan};
use crate::linalg::svd;
use crate::lowrank::{augment_basis, LowRank};
use crate::metrics::{RoundMetrics, RunRecord};
use crate::models::{FedProblem, LrGrad, LrWant, LrWeight, Weights};
use crate::obsv::{Phase, Recorder};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::aggregate::RobustAccum;
use super::config::TrainConfig;

/// Run Algorithm 6. Only supports problems whose trainables are a single
/// low-rank layer (the convex tests it is benchmarked on).
pub fn run_fedlrt_naive<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
) -> RunRecord {
    run_fedlrt_naive_obs(problem, cfg, experiment, &Recorder::new())
}

/// [`run_fedlrt_naive`] with an explicit telemetry [`Recorder`].
pub fn run_fedlrt_naive_obs<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    experiment: &str,
    obs: &Recorder,
) -> RunRecord {
    let spec = problem.spec();
    assert!(
        spec.dense_shapes.is_empty() && spec.lr_shapes.len() == 1,
        "naive FeDLRT baseline supports single-layer problems"
    );
    let (m, n) = spec.lr_shapes[0];
    let c_num = problem.num_clients();
    let mut rng = Rng::new(cfg.seed);

    let r0 = cfg.rank.initial_rank.min(m.min(n) / 2).max(1);
    let mut fac = LowRank::random_init(m, n, r0, &mut rng);
    fac.s.scale_inplace((1.0 / m as f64).sqrt());

    let mut net = Network::with_codec(c_num, cfg.codec);
    net.fault = cfg.fault;
    let executor = Executor::from_kind(cfg.executor);
    cfg.apply_kernel_threads();
    let mut record = RunRecord::new("fedlrt_naive", experiment, c_num, cfg.seed);
    record.config = cfg.to_json();
    // Cross-round client state (batch cursors + drift variates) and the
    // drift-correction engine — see `run_fedlrt`.
    let mut states = ClientStates::new(c_num);
    let mut engine = CorrectionEngine::new(cfg.correction);

    for t in 0..cfg.rounds {
        let watch = Stopwatch::start();
        obs.begin_round(t);
        let lr_t = cfg.lr.at(t);
        let sp_plan = obs.span(Phase::Io);
        let mut plan = RoundPlan::build(cfg, c_num, t, |c| problem.client_weight(c));
        // Transport gate: filter to delivered clients, skip below quorum
        // (see `run_fedlrt`); `None` leaves the plan bitwise-untouched.
        let gate =
            sync_gate(&cfg.fault, &cfg.net_policy, cfg.seed, t as u64, &mut plan, &mut net);
        if gate.as_ref().is_some_and(|g| g.skip) {
            drop(sp_plan);
            net.set_active_clients(0);
            let fault = FaultRoundStats::skipped_from_comm(net.end_round());
            let sp_eval = obs.span(Phase::Eval);
            let w_eval = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
            let global_loss = problem.global_loss(&w_eval);
            let dist_to_opt = problem.distance_to_optimum(&w_eval);
            let eval_metric = problem.eval_metric(&w_eval);
            drop(sp_eval);
            let round_obs = obs.end_round();
            record.rounds.push(RoundMetrics {
                round: t,
                global_loss,
                ranks: vec![fac.rank()],
                comm_floats: 0,
                comm_floats_lr: 0,
                bytes_down: 0,
                bytes_up: 0,
                comm_floats_per_client: 0.0,
                dist_to_opt,
                eval_metric,
                wall_s: watch.elapsed_s(),
                client_wall_s: 0.0,
                client_serial_s: 0.0,
                phase_s: round_obs.phase_s,
                latency: round_obs.latency,
                staleness: round_obs.staleness,
                virtual_s: 0.0,
                fault,
            });
            continue;
        }
        net.set_active_clients(plan.len());
        drop(sp_plan);

        // Broadcast the current global factors through the wire codec;
        // clients work on the decoded copies (S is diagonal, so only
        // its diagonal travels).
        let sp_bc = obs.span(Phase::Broadcast);
        let u_bc = net.broadcast_mat("U", &fac.u);
        let v_bc = net.broadcast_mat("V", &fac.v);
        let s_diag: Vec<f64> = (0..fac.rank()).map(|i| fac.s[(i, i)]).collect();
        let s_bc = Matrix::diag(&net.broadcast_vec("S_diag", &s_diag));
        let fac_c = LowRank { u: u_bc, s: s_bc, v: v_bc };
        // SCAFFOLD only: the server control variate rides with the
        // factor broadcast, billed in the non-augmented r-space; each
        // client embeds the decoded copy into its own local augmented
        // space.
        let ctrl_bc: Option<DriftState> =
            engine.broadcast_ctrl(&mut net, &[(fac.rank(), fac.rank())], &[]);
        drop(sp_bc);

        // Per-client: local augmentation (own QR on own gradients) and
        // local coefficient iterations — no coordination until upload,
        // so each client is one hermetic work item.
        let sp_train = obs.span(Phase::ClientTrain);
        let correction = engine.kind();
        // Batch cursors and drift states pre-fetched per ordinal (the
        // executor closure takes immutable borrows only); states are in
        // the server r-space and get embedded into each client's *own*
        // augmented space inside the task.
        let steps0: Vec<u64> =
            plan.tasks.iter().map(|task| states.step0(task.client_id)).collect();
        let drift_pre: Vec<Option<DriftState>> = if engine.is_stateful() {
            plan.tasks.iter().map(|task| states.drift_cloned(task.client_id)).collect()
        } else {
            vec![None; plan.len()]
        };
        let report = executor.execute(&plan, |task| {
            let c = task.client_id;
            let w_c = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac_c.clone())] };
            let g = problem.grad(c, &w_c, LrWant::Factors, steps0[task.ordinal]);
            let (g_u, g_v) = match &g.lr[0] {
                LrGrad::Factors { g_u, g_v, .. } => (g_u.clone(), g_v.clone()),
                _ => unreachable!(),
            };
            // Algorithm 6 lines 7–9: client-local augmentation, then the
            // shared local-update driver on the local coefficients (the
            // allocation-free `grad_coeff_into` fast path where the
            // problem offers one). Drift inputs are zero-padded into the
            // client's own augmented space.
            let aug = augment_basis(&fac_c, &g_u, &g_v, 2 * fac_c.rank());
            let r2 = aug.rank();
            let mut w_loc = Weights {
                dense: vec![],
                lr: vec![LrWeight::Factored(LowRank {
                    u: aug.u_tilde,
                    s: aug.s_tilde,
                    v: aug.v_tilde,
                })],
            };
            let embed_loc = |st: &DriftState| DriftState {
                lr: vec![st.lr[0].embed(r2, r2)],
                dense: vec![],
            };
            let drift_loc = drift_pre[task.ordinal].as_ref().map(|st| embed_loc(st));
            let ctrl_loc = ctrl_bc.as_ref().map(|ct| embed_loc(ct));
            let driver = LocalUpdate {
                opt: cfg.opt,
                lr_t,
                iters: task.local_iters,
                step0: steps0[task.ordinal],
                mode: GradMode::Coeff,
                vc_lr: &[],
                vc_dense: &[],
                g_bar: None,
                capture_first_grad: false,
                correction,
                drift_in: drift_loc.as_ref(),
                ctrl: ctrl_loc.as_ref(),
                fault: task.fault,
                fault_seed: task.seed,
            };
            let out = driver.run(problem, c, &mut w_loc);
            // The client uploads its full factor triple — bases
            // diverged, so the server cannot reuse shared ones.
            let fac_out = match w_loc.lr.pop() {
                Some(LrWeight::Factored(f)) => f,
                _ => unreachable!("factored client state"),
            };
            (fac_out.u, fac_out.s, fac_out.v, out.drift_out, out.ctrl_delta)
        });
        obs.record_exec("local", &plan, &report.timing);
        let client_wall_s = report.wall_s;
        let client_serial_s = report.serial_s;
        drop(sp_train);
        let sp_agg = obs.span(Phase::Aggregate);
        // Every participating client ships its factor triple
        // {Ũ_c, S̃_c, Ṽ_c} as one coalesced message through the wire
        // codec; the server reconstructs the dense average from the
        // *decoded* triples in plan order (executor-independent
        // bitwise).
        let mut w_star = Matrix::zeros(m, n);
        // Robust aggregation operates on the reconstructed per-client
        // dense matrices (this baseline has no shared coefficient
        // space); Mean stays the legacy axpy fold, bitwise.
        let mut robust = RobustAccum::new(cfg.aggregator, 1);
        // Stateful corrections: outputs live in each client's local
        // augmented space, so they carry their decoded basis along for
        // the projection into the new server basis after the SVD.
        let mut drift_staged: Vec<(usize, DriftState, Matrix, Matrix)> = Vec::new();
        let mut ctrl_deltas: Vec<(Matrix, Matrix, Matrix)> = Vec::new();
        for (task, (u_t, s_t, v_t, drift_out, ctrl_delta)) in
            plan.tasks.iter().zip(&report.results)
        {
            if let Some(gt) = &gate {
                net.set_upload_copies(gt.copies[task.ordinal]);
            }
            let [u_dec, s_dec, v_dec] = net
                .aggregate_batch_n("factor_triple_c", [u_t.data(), s_t.data(), v_t.data()]);
            let u_d = Matrix::from_vec(u_t.rows(), u_t.cols(), u_dec);
            let s_d = Matrix::from_vec(s_t.rows(), s_t.cols(), s_dec);
            let v_d = Matrix::from_vec(v_t.rows(), v_t.cols(), v_dec);
            if let Some(st) = drift_out {
                drift_staged.push((task.client_id, st.clone(), u_d.clone(), v_d.clone()));
            }
            if let Some(delta) = ctrl_delta {
                // SCAFFOLD uplink, billed through the codec.
                let dec = net.aggregate_mat("ctrl", &delta.lr[0]);
                ctrl_deltas.push((dec, u_d.clone(), v_d.clone()));
            }
            let w_c_dense = LowRank { u: u_d, s: s_d, v: v_d }.to_dense();
            robust.push(0, &mut w_star, task.weight, &w_c_dense);
        }
        if gate.is_some() {
            net.set_upload_copies(1);
        }
        robust.finish(std::slice::from_mut(&mut w_star));
        net.end_round_trip();
        states.advance(&plan);
        drop(sp_agg);

        // Server: full n×n SVD to recover a low-rank factorization —
        // the O(n³) cost shared bases avoid.
        let sp_svd = obs.span(Phase::TruncateSvd);
        let old_basis: Option<(Matrix, Matrix)> =
            engine.is_stateful().then(|| (fac.u.clone(), fac.v.clone()));
        let dec = svd(&w_star);
        let theta = cfg.rank.tau * dec.sigma_fro();
        let r1 = dec.rank_for_tolerance(theta).clamp(1, cfg.rank.max_rank);
        let (u, sig, v) = dec.truncate(r1);
        fac = LowRank { u, s: Matrix::diag(&sig), v };
        // Carry drift variates across the server's full-SVD basis
        // refresh: stored states project old → new, participants'
        // outputs project out of their own (decoded) local bases, and
        // the SCAFFOLD variate folds per-client deltas the same way.
        if engine.is_stateful() {
            let (old_u, old_v) = old_basis.expect("saved above");
            states.for_each_drift(|_, st| {
                st.lr[0] = change_coords(&fac.u, &fac.v, &old_u, &old_v, &st.lr[0]);
            });
            for (id, st, u_d, v_d) in drift_staged {
                let proj = change_coords(&fac.u, &fac.v, &u_d, &v_d, &st.lr[0]);
                states.set_drift(id, DriftState { lr: vec![proj], dense: vec![] });
            }
            if engine.is_scaffold() {
                let old_ctrl =
                    engine.ctrl().expect("ctrl is ensured by the round broadcast").clone();
                let mut new_ctrl =
                    change_coords(&fac.u, &fac.v, &old_u, &old_v, &old_ctrl.lr[0]);
                let inv = 1.0 / c_num as f64;
                for (delta, u_d, v_d) in &ctrl_deltas {
                    new_ctrl.axpy(inv, &change_coords(&fac.u, &fac.v, u_d, v_d, delta));
                }
                engine.set_ctrl(DriftState { lr: vec![new_ctrl], dense: vec![] });
            }
        }
        drop(sp_svd);

        // Metrics.
        let sp_io = obs.span(Phase::Io);
        let comm = net.end_round();
        let (comm_floats, comm_per_client) = (comm.total_floats(), comm.per_client_floats());
        let (bytes_down, bytes_up) = (comm.bytes_down, comm.bytes_up);
        let comm_floats_lr = comm_floats; // single-layer problems only
        let fault = FaultRoundStats::from_comm(comm);
        drop(sp_io);
        let sp_eval = obs.span(Phase::Eval);
        let w_eval = Weights { dense: vec![], lr: vec![LrWeight::Factored(fac.clone())] };
        let global_loss = problem.global_loss(&w_eval);
        let dist_to_opt = problem.distance_to_optimum(&w_eval);
        let eval_metric = problem.eval_metric(&w_eval);
        drop(sp_eval);
        let round_obs = obs.end_round();
        record.rounds.push(RoundMetrics {
            round: t,
            global_loss,
            ranks: vec![fac.rank()],
            comm_floats,
            comm_floats_lr,
            bytes_down,
            bytes_up,
            comm_floats_per_client: comm_per_client,
            dist_to_opt,
            eval_metric,
            wall_s: watch.elapsed_s(),
            client_wall_s,
            client_serial_s,
            phase_s: round_obs.phase_s,
            latency: round_obs.latency,
            staleness: round_obs.staleness,
            virtual_s: 0.0,
            fault,
        });
    }

    record
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{RankConfig, VarCorrection};
    use crate::coordinator::fedlrt::run_fedlrt;
    use crate::models::quadratic::Quadratic;
    use crate::opt::LrSchedule;

    fn cfg() -> TrainConfig {
        TrainConfig {
            rounds: 20,
            local_iters: 4,
            lr: LrSchedule::Constant(5e-2),
            var_correction: VarCorrection::None,
            rank: RankConfig { initial_rank: 2, max_rank: 6, tau: 0.05 },
            seed: 11,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn naive_descends_but_costs_more_communication() {
        let mut rng = Rng::new(1001);
        let prob = Quadratic::random(10, 2, 4, &mut rng);
        let naive = run_fedlrt_naive(&prob, &cfg(), "t");
        let shared = run_fedlrt(&prob, &cfg(), "t");
        assert!(naive.final_loss() < naive.rounds[0].global_loss);
        // Shared-basis FeDLRT uploads r²-sized coefficients; naive
        // uploads full factor triples — strictly more floats.
        assert!(
            naive.total_comm_floats() > shared.total_comm_floats(),
            "naive {} vs shared {}",
            naive.total_comm_floats(),
            shared.total_comm_floats()
        );
    }
}
