//! Training configuration shared by all federated algorithms.

use crate::client::Correction;
use crate::comm::{CodecKind, FaultModel, NetPolicy};
use crate::coordinator::aggregate::Aggregator;
use crate::engine::{ExecutorKind, ScenarioConfig, TimingModel};
use crate::opt::{LrSchedule, OptimizerKind, SgdConfig};
use crate::util::json::Json;

/// Variance-correction mode for FeDLRT (§3.1) and FedLin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarCorrection {
    /// No correction — FedAvg-style local iterations (eq. 7).
    None,
    /// Full correction on the augmented coefficients (eq. 8, Algorithm 1
    /// with var_cor = true; costs a third communication round).
    Full,
    /// Simplified correction on the non-augmented block only (eq. 9,
    /// Algorithm 5; folds into the basis-gradient round — two rounds).
    Simplified,
}

impl VarCorrection {
    pub fn label(&self) -> &'static str {
        match self {
            VarCorrection::None => "no_vc",
            VarCorrection::Full => "full_vc",
            VarCorrection::Simplified => "simpl_vc",
        }
    }
}

/// Federation schedule: when client updates are folded into the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Lockstep synchronous rounds (the paper's setting): every sampled
    /// client's update is awaited before aggregation.
    Sync,
    /// FedBuff-style buffered asynchrony: aggregate as soon as K
    /// coefficient updates have arrived; stragglers are discarded or
    /// held per [`AsyncConfig::max_staleness`] / [`AsyncConfig::hold_stale`].
    FedBuff,
    /// Staleness-weighted asynchrony: every arrival is consumed,
    /// down-weighted by `1/(1+staleness)^p`.
    AsyncStale,
}

impl Schedule {
    pub fn label(&self) -> &'static str {
        match self {
            Schedule::Sync => "sync",
            Schedule::FedBuff => "fedbuff",
            Schedule::AsyncStale => "async",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Schedule> {
        match s {
            "sync" => Ok(Schedule::Sync),
            "fedbuff" => Ok(Schedule::FedBuff),
            "async" | "stale" | "async_stale" => Ok(Schedule::AsyncStale),
            other => Err(anyhow::anyhow!("unknown schedule '{other}' (sync|fedbuff|async)")),
        }
    }
}

/// Knobs of the event-driven async server (ignored under
/// [`Schedule::Sync`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncConfig {
    /// Buffer size K: updates consumed per aggregation.
    pub buffer_k: usize,
    /// In-flight dispatch slots (concurrent clients).
    pub concurrency: usize,
    /// Staleness-weight exponent `p` in `1/(1+σ)^p`
    /// ([`Schedule::AsyncStale`] only).
    pub staleness_p: f64,
    /// FedBuff staleness bound: arrivals with `σ > max_staleness` are
    /// discarded (or held, see `hold_stale`). 0 = unbounded.
    pub max_staleness: u64,
    /// FedBuff policy for over-stale arrivals: `true` admits them to
    /// the buffer anyway (never lose data, accept the staleness),
    /// `false` discards them on arrival.
    pub hold_stale: bool,
    /// Refresh the shared low-rank basis (re-orthogonalize + truncate
    /// via the small SVD) every this many aggregations. 1 = every
    /// aggregation.
    pub basis_every: usize,
    /// Server-side step size applied to the aggregated update.
    pub server_lr: f64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            buffer_k: 8,
            concurrency: 16,
            staleness_p: 1.0,
            max_staleness: 0,
            hold_stale: false,
            basis_every: 1,
            server_lr: 1.0,
        }
    }
}

/// Low-rank behaviour of FeDLRT.
#[derive(Debug, Clone, Copy)]
pub struct RankConfig {
    /// Initial rank `r` of every low-rank layer.
    pub initial_rank: usize,
    /// Hard cap on the rank *after truncation*; augmentation may touch
    /// `2·max_rank` transiently. Keeps static AOT shapes valid.
    pub max_rank: usize,
    /// Relative truncation tolerance `τ` (ϑ = τ‖S̃*‖, §4.1).
    pub tau: f64,
}

impl Default for RankConfig {
    fn default() -> Self {
        RankConfig { initial_rank: 8, max_rank: 32, tau: 0.01 }
    }
}

/// Complete configuration of a federated training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Aggregation rounds `T`.
    pub rounds: usize,
    /// Local iterations `s*` per round.
    pub local_iters: usize,
    /// Learning-rate schedule (per aggregation round).
    pub lr: LrSchedule,
    /// Client optimizer (SGD+momentum or Adam; Table 2).
    pub opt: OptimizerKind,
    /// Variance correction mode.
    pub var_correction: VarCorrection,
    /// Low-rank settings (ignored by dense baselines).
    pub rank: RankConfig,
    /// RNG seed (weights init + any stochasticity).
    pub seed: u64,
    /// Evaluate global loss every `eval_every` rounds (1 = every round).
    pub eval_every: usize,
    /// Fraction of clients sampled per round (client selection, à la
    /// [26, 6, 29]); 1.0 = full participation (the paper's analysis
    /// setting). Sampled deterministically from `seed` per round.
    pub participation: f64,
    /// Straggler model: client `c` runs `s*·(1 − jitter·u_{t,c})` local
    /// iterations (u uniform per round/client). 0.0 = the paper's
    /// uniform `s*`; footnote 3 notes the analysis extends to
    /// client-dependent counts.
    pub straggler_jitter: f64,
    /// Probability a *sampled* client drops out of the round after the
    /// broadcast (device churn). 0.0 = nobody drops; the round always
    /// keeps at least one client. See [`crate::engine::RoundPlan`].
    pub dropout: f64,
    /// Client execution engine: serial reference semantics or a thread
    /// pool. Bitwise-identical trajectories either way (the engine's
    /// determinism contract); only wall-clock changes.
    pub executor: ExecutorKind,
    /// Wire codec every transfer is serialized with. The reference
    /// `DenseF32` preserves the seed's `floats × 4` accounting and
    /// trajectories exactly; `F16Cast`/`QuantizeInt8` trade accuracy
    /// for bytes (decode-on-receive — see [`crate::comm::wire`]).
    pub codec: CodecKind,
    /// Worker threads for the large-matmul kernels (CLI
    /// `--kernel-threads`). `0` = leave the process-wide default alone
    /// (the `FEDLRT_KERNEL_THREADS` env var, or 1). Kernel results are
    /// bitwise independent of this value — the row-panel determinism
    /// contract of [`crate::tensor::ops`] — so it only moves wall-clock.
    pub kernel_threads: usize,
    /// Federation schedule. [`Schedule::Sync`] is the lockstep round
    /// loop every existing coordinator runs; the async schedules route
    /// through `coordinator::async_server` instead. Under async
    /// schedules, `rounds` counts *aggregations*.
    pub schedule: Schedule,
    /// Async-server knobs (ignored under [`Schedule::Sync`]).
    pub async_cfg: AsyncConfig,
    /// Virtual-clock timing model for the async event simulator
    /// (arrival / compute / link distributions + heterogeneity).
    pub timing: TimingModel,
    /// Registered client population for async schedules. 0 = use the
    /// problem's `num_clients()`. May vastly exceed the problem's data
    /// shards (clients map onto shards modulo `num_clients()`), which
    /// is how a 10-shard problem simulates 10^6 registered clients.
    pub population: usize,
    /// Client drift-correction strategy layered on the local loop
    /// (`--correction`; see [`crate::client::drift`]). Composes with
    /// `var_correction` — FeDLRT's variance correction is a fixed
    /// per-round gradient shift, this is a per-client strategy.
    /// [`Correction::None`] keeps the legacy loop bitwise.
    pub correction: Correction,
    /// Hostile-scenario knobs (`--scenario`; churn, correlated
    /// dropout, faults, label skew). The default `calm` preset is
    /// structurally inactive.
    pub scenario: ScenarioConfig,
    /// Unreliable-transport model (`--loss-prob`, `--corrupt-prob`,
    /// `--dup-prob`, `--net-delay`; see [`crate::comm::faults`]). The
    /// default is structurally inactive: no fate draws, no checksum
    /// framing, bitwise-legacy wire bytes.
    pub fault: FaultModel,
    /// Server transport policy (`--timeout`, `--retries`, `--quorum`).
    /// Inactive by default.
    pub net_policy: NetPolicy,
    /// Server-side aggregation rule (`--aggregator`). The default
    /// [`Aggregator::Mean`] is the legacy axpy fold, bitwise.
    pub aggregator: Aggregator,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            rounds: 100,
            local_iters: 10,
            lr: LrSchedule::Constant(1e-3),
            opt: OptimizerKind::Sgd(SgdConfig::default()),
            var_correction: VarCorrection::Full,
            rank: RankConfig::default(),
            seed: 0,
            eval_every: 1,
            participation: 1.0,
            straggler_jitter: 0.0,
            dropout: 0.0,
            executor: ExecutorKind::Serial,
            codec: CodecKind::DenseF32,
            kernel_threads: 0,
            schedule: Schedule::Sync,
            async_cfg: AsyncConfig::default(),
            timing: TimingModel::default(),
            population: 0,
            correction: Correction::None,
            scenario: ScenarioConfig::default(),
            fault: FaultModel::default(),
            net_policy: NetPolicy::default(),
            aggregator: Aggregator::Mean,
        }
    }
}

impl TrainConfig {
    /// Apply the kernel-thread choice to the process-wide knob (no-op
    /// when 0 = inherit). Coordinators call this at run start.
    pub fn apply_kernel_threads(&self) {
        if self.kernel_threads > 0 {
            crate::tensor::set_kernel_threads(self.kernel_threads);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("rounds", self.rounds)
            .set("local_iters", self.local_iters)
            .set("var_correction", self.var_correction.label())
            .set("initial_rank", self.rank.initial_rank)
            .set("max_rank", self.rank.max_rank)
            .set("tau", self.rank.tau)
            .set("seed", self.seed)
            .set("participation", self.participation)
            .set("straggler_jitter", self.straggler_jitter)
            .set("dropout", self.dropout)
            .set("executor", self.executor.label())
            .set("codec", self.codec.label())
            .set("kernel_threads", self.kernel_threads)
            .set("schedule", self.schedule.label())
            .set("correction", self.correction.label());
        if self.correction.knob() != 0.0 {
            o.set("correction_knob", self.correction.knob());
        }
        if self.scenario.is_active() {
            o.set("scenario", self.scenario.name)
                .set("churn", self.scenario.churn)
                .set("correlated_dropout", self.scenario.correlated_dropout)
                .set("fault_fraction", self.scenario.fault_fraction);
            if let Some(alpha) = self.scenario.dirichlet_alpha {
                o.set("dirichlet_alpha", alpha);
            }
        }
        // Transport faults/policy echo only when active; the aggregator
        // key only when not the legacy mean — default runs keep the
        // legacy echo byte-identical.
        if self.fault.is_active() || self.net_policy.is_active() {
            o.set("loss_prob", self.fault.loss_prob)
                .set("corrupt_prob", self.fault.corrupt_prob)
                .set("dup_prob", self.fault.dup_prob)
                .set("net_delay", self.fault.delay.label())
                .set("timeout", self.net_policy.timeout)
                .set("retries", self.net_policy.retries as usize)
                .set("quorum", self.net_policy.quorum);
        }
        if !self.aggregator.is_mean() {
            o.set("aggregator", self.aggregator.label());
        }
        if self.schedule != Schedule::Sync {
            o.set("buffer_k", self.async_cfg.buffer_k)
                .set("concurrency", self.async_cfg.concurrency)
                .set("staleness_p", self.async_cfg.staleness_p)
                .set("max_staleness", self.async_cfg.max_staleness as usize)
                .set("hold_stale", self.async_cfg.hold_stale)
                .set("basis_every", self.async_cfg.basis_every)
                .set("server_lr", self.async_cfg.server_lr)
                .set("timing", self.timing.label())
                .set("population", self.population);
        }
        match self.opt {
            OptimizerKind::Sgd(sgd) => {
                o.set("optimizer", "sgd")
                    .set("momentum", sgd.momentum)
                    .set("weight_decay", sgd.weight_decay);
            }
            OptimizerKind::Adam { weight_decay } => {
                o.set("optimizer", "adam").set("weight_decay", weight_decay);
            }
        }
        match self.lr {
            LrSchedule::Constant(l) => {
                o.set("lr", l);
            }
            LrSchedule::Cosine { start, end, total } => {
                o.set("lr_start", start).set("lr_end", end).set("lr_total", total);
            }
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(VarCorrection::None.label(), "no_vc");
        assert_eq!(VarCorrection::Full.label(), "full_vc");
        assert_eq!(VarCorrection::Simplified.label(), "simpl_vc");
    }

    #[test]
    fn config_json_echo() {
        let cfg = TrainConfig::default();
        let j = cfg.to_json();
        assert_eq!(j.usize_or("rounds", 0), 100);
        assert_eq!(j.str_or("var_correction", ""), "full_vc");
        assert_eq!(j.str_or("codec", ""), "dense");
        assert_eq!(j.usize_or("kernel_threads", 99), 0);
        assert_eq!(j.str_or("schedule", ""), "sync");
        // Async knobs stay out of sync-run config echoes.
        assert_eq!(j.usize_or("buffer_k", 777), 777);
    }

    #[test]
    fn correction_and_scenario_echoes() {
        // Defaults: correction label present, scenario knobs absent.
        let j = TrainConfig::default().to_json();
        assert_eq!(j.str_or("correction", ""), "none");
        assert_eq!(j.str_or("scenario", "absent"), "absent");
        let cfg = TrainConfig {
            correction: Correction::FedProx { mu: 0.1 },
            scenario: ScenarioConfig::parse("byzantine").unwrap(),
            ..TrainConfig::default()
        };
        let j = cfg.to_json();
        assert_eq!(j.str_or("correction", ""), "fedprox");
        assert_eq!(j.str_or("scenario", ""), "byzantine");
    }

    #[test]
    fn fault_and_aggregator_echoes_stay_out_of_default_configs() {
        // Legacy echo: none of the new keys appear on a default config.
        let j = TrainConfig::default().to_json();
        assert_eq!(j.str_or("aggregator", "absent"), "absent");
        assert!((j.f64_or("loss_prob", -1.0) - -1.0).abs() < 1e-12);
        assert_eq!(j.usize_or("quorum", 777), 777);
        // Active transport: the whole fault/policy block appears.
        let cfg = TrainConfig {
            fault: FaultModel { loss_prob: 0.1, ..FaultModel::default() },
            net_policy: NetPolicy { retries: 2, quorum: 3, ..NetPolicy::default() },
            aggregator: Aggregator::TrimmedMean { trim: 0.2 },
            ..TrainConfig::default()
        };
        let j = cfg.to_json();
        assert!((j.f64_or("loss_prob", 0.0) - 0.1).abs() < 1e-12);
        assert_eq!(j.usize_or("retries", 0), 2);
        assert_eq!(j.usize_or("quorum", 0), 3);
        assert_eq!(j.str_or("aggregator", ""), "trimmed:0.2");
        // Policy-only activation echoes the block too.
        let cfg = TrainConfig {
            net_policy: NetPolicy { timeout: 5.0, ..NetPolicy::default() },
            ..TrainConfig::default()
        };
        assert!((cfg.to_json().f64_or("timeout", 0.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn schedule_parse_label_roundtrip() {
        for s in [Schedule::Sync, Schedule::FedBuff, Schedule::AsyncStale] {
            assert_eq!(Schedule::parse(s.label()).unwrap(), s);
        }
        assert_eq!(Schedule::parse("stale").unwrap(), Schedule::AsyncStale);
        assert!(Schedule::parse("semi-sync").is_err());
    }

    #[test]
    fn async_config_echoed_for_async_schedules() {
        let cfg = TrainConfig {
            schedule: Schedule::FedBuff,
            population: 1_000_000,
            ..TrainConfig::default()
        };
        let j = cfg.to_json();
        assert_eq!(j.str_or("schedule", ""), "fedbuff");
        assert_eq!(j.usize_or("buffer_k", 0), AsyncConfig::default().buffer_k);
        assert_eq!(j.usize_or("population", 0), 1_000_000);
        assert!(j.str_or("timing", "").contains("arrival=constant:1"));
    }
}
