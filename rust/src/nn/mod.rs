//! Federated neural-network problems over the PJRT runtime (the §4.2
//! vision benchmarks).
//!
//! [`NnProblem`] implements [`FedProblem`] by routing every gradient and
//! evaluation call through the AOT-compiled JAX/Pallas artifacts. The
//! coordinator's dynamic ranks are reconciled with the artifacts' static
//! shapes by exact zero-padding to `r_pad` (DESIGN.md §Static-shape AOT):
//! the coordinator may use any rank `r ≤ r_pad/2` (so the augmented rank
//! `2r ≤ r_pad` still fits).

pub mod experiment;

use anyhow::{anyhow, Result};

use crate::data::{dirichlet_partition, schedule, uniform_partition, VisionDataset};
use crate::models::{FedProblem, Grads, LrGrad, LrWant, LrWeight, ProblemSpec, Weights};
use crate::runtime::{Executable, HostTensor, ModelEntry, Runtime};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Options for constructing an [`NnProblem`].
#[derive(Debug, Clone)]
pub struct NnOptions {
    /// Model config name from the artifact manifest.
    pub config: String,
    pub num_clients: usize,
    pub train_n: usize,
    pub test_n: usize,
    /// Cap on samples used for the per-round global-loss estimate
    /// (full test set is always used for accuracy).
    pub eval_cap: usize,
    pub seed: u64,
    /// Feature-augmentation on training batches (paper's flips).
    pub augment: bool,
    /// Label-skew heterogeneity: `None` = the paper's uniform shards;
    /// `Some(alpha)` = Dirichlet(α) label skew (smaller α ⇒ more skew).
    pub dirichlet_alpha: Option<f64>,
}

impl Default for NnOptions {
    fn default() -> Self {
        NnOptions {
            config: "test_tiny".into(),
            num_clients: 4,
            train_n: 2048,
            test_n: 512,
            eval_cap: 1024,
            seed: 0,
            augment: true,
            dirichlet_alpha: None,
        }
    }
}

/// A federated NN training problem backed by AOT artifacts.
pub struct NnProblem {
    entry: ModelEntry,
    grad_factors: Executable,
    grad_coeff: Executable,
    grad_dense: Executable,
    eval_factors: Executable,
    eval_dense: Executable,
    dataset: VisionDataset,
    shards: Vec<Vec<usize>>,
    opts: NnOptions,
}

impl NnProblem {
    /// Build the problem: load artifacts, synthesize + partition data.
    pub fn new(runtime: &mut Runtime, opts: NnOptions) -> Result<NnProblem> {
        let entry = runtime
            .manifest
            .configs
            .get(&opts.config)
            .ok_or_else(|| anyhow!("no config '{}' in manifest", opts.config))?
            .clone();
        // Compile all five functions up front (owned by this problem).
        let grad_factors = runtime.compile(&opts.config, "grad_factors")?;
        let grad_coeff = runtime.compile(&opts.config, "grad_coeff")?;
        let grad_dense = runtime.compile(&opts.config, "grad_dense")?;
        let eval_factors = runtime.compile(&opts.config, "eval_factors")?;
        let eval_dense = runtime.compile(&opts.config, "eval_dense")?;

        let dataset = VisionDataset::synthesize(
            entry.d_in,
            entry.classes,
            opts.train_n,
            opts.test_n,
            opts.seed,
        );
        let mut rng = Rng::new(opts.seed ^ 0x5A4D);
        let shards = match opts.dirichlet_alpha {
            None => uniform_partition(opts.train_n, opts.num_clients, &mut rng),
            Some(alpha) => dirichlet_partition(
                &dataset.train.y,
                entry.classes,
                opts.num_clients,
                alpha,
                entry.batch,
                &mut rng,
            ),
        };
        // Every client must fill at least one batch.
        for s in &shards {
            assert!(
                s.len() >= entry.batch,
                "shard of {} samples < batch {}",
                s.len(),
                entry.batch
            );
        }
        Ok(NnProblem {
            entry,
            grad_factors,
            grad_coeff,
            grad_dense,
            eval_factors,
            eval_dense,
            dataset,
            shards,
            opts,
        })
    }

    /// Recommended rank cap compatible with the artifacts' padding.
    pub fn max_rank(&self) -> usize {
        self.entry.r_pad / 2
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// Training batch for client `c` at local step counter `step`.
    ///
    /// The schedule comes from [`crate::data::schedule`] (shared with
    /// `MlpProblem` so both backends sample identically): `⌈len/b⌉`
    /// batches per epoch, the tail cycled into the final batch instead
    /// of dropped.
    fn batch(&self, c: usize, step: u64) -> (HostTensor, HostTensor) {
        let shard = &self.shards[c];
        let b = self.entry.batch;
        let (epoch, bi) = schedule::batch_slot(shard.len(), b, step);
        let d = self.entry.d_in;
        let mut x = vec![0f32; b * d];
        let mut y = vec![0i32; b];
        for k in 0..b {
            let idx = shard[schedule::sample_index(shard.len(), b, bi, k)];
            if self.opts.augment {
                self.dataset.augmented_row(idx, epoch, &mut x[k * d..(k + 1) * d]);
            } else {
                for (j, v) in self.dataset.train.x.row(idx).iter().enumerate() {
                    x[k * d + j] = *v as f32;
                }
            }
            y[k] = self.dataset.train.y[idx];
        }
        (HostTensor::f32(&[b, d], x), HostTensor::i32(&[b], y))
    }

    /// Build artifact inputs from coordinator weights (factored form),
    /// padding factors to `r_pad`.
    fn factored_inputs(&self, w: &Weights, x: HostTensor, y: HostTensor) -> Vec<HostTensor> {
        let r_pad = self.entry.r_pad;
        let mut dense_iter = w.dense.iter();
        let mut lr_idx = 0usize;
        let mut inputs = Vec::with_capacity(self.entry.params_factored.len() + 2);
        for spec in &self.entry.params_factored {
            let t = if spec.name.ends_with(".u") {
                let f = w.lr[lr_idx].as_factored();
                HostTensor::f32(&[f.m(), r_pad], pad_cols(&f.u, r_pad))
            } else if spec.name.ends_with(".s") {
                let f = w.lr[lr_idx].as_factored();
                HostTensor::f32(&[r_pad, r_pad], pad_square(&f.s, r_pad))
            } else if spec.name.ends_with(".v") {
                let f = w.lr[lr_idx].as_factored();
                lr_idx += 1; // v is the last factor of this layer
                HostTensor::f32(&[f.n(), r_pad], pad_cols(&f.v, r_pad))
            } else {
                let d = dense_iter.next().expect("missing dense weight");
                HostTensor::f32(&[d.rows(), d.cols()], d.to_f32())
            };
            inputs.push(t);
        }
        inputs.push(x);
        inputs.push(y);
        inputs
    }

    fn dense_inputs(&self, w: &Weights, x: HostTensor, y: HostTensor) -> Vec<HostTensor> {
        let mut dense_iter = w.dense.iter();
        let mut lr_iter = w.lr.iter();
        let mut inputs = Vec::with_capacity(self.entry.params_dense.len() + 2);
        for spec in &self.entry.params_dense {
            let is_lr_w = spec.name.starts_with("lr") && spec.name.ends_with(".w");
            let t = if is_lr_w {
                let m = lr_iter.next().expect("missing lr weight").as_dense();
                HostTensor::f32(&[m.rows(), m.cols()], m.to_f32())
            } else {
                let d = dense_iter.next().expect("missing dense weight");
                HostTensor::f32(&[d.rows(), d.cols()], d.to_f32())
            };
            inputs.push(t);
        }
        inputs.push(x);
        inputs.push(y);
        inputs
    }

    /// Evaluate `(mean loss, accuracy)` over a split via the eval artifact.
    fn evaluate(&self, w: &Weights, on_test: bool, cap: usize) -> (f64, f64) {
        let factored = matches!(w.lr.first(), Some(LrWeight::Factored(_)));
        let exe = if factored { &self.eval_factors } else { &self.eval_dense };
        let split = if on_test { &self.dataset.test } else { &self.dataset.train };
        let e = self.entry.eval_batch;
        let d = self.entry.d_in;
        let n = split.len().min(cap.max(e));
        let num_batches = (n / e).max(1);
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut count = 0usize;
        for bi in 0..num_batches {
            let mut x = vec![0f32; e * d];
            let mut y = vec![0i32; e];
            for k in 0..e {
                let idx = (bi * e + k) % split.len();
                for (j, v) in split.x.row(idx).iter().enumerate() {
                    x[k * d + j] = *v as f32;
                }
                y[k] = split.y[idx];
            }
            let inputs_x = HostTensor::f32(&[e, d], x);
            let inputs_y = HostTensor::i32(&[e], y);
            let inputs = if factored {
                self.factored_inputs(w, inputs_x, inputs_y)
            } else {
                self.dense_inputs(w, inputs_x, inputs_y)
            };
            let out = exe.call(&inputs).expect("eval artifact failed");
            loss_sum += out[0][0] as f64;
            correct += out[1][0] as f64;
            count += e;
        }
        (loss_sum / count as f64, correct / count as f64)
    }
}

/// Pad an `m×r` matrix to `m×r_pad` with zero columns (flat f32).
fn pad_cols(m: &Matrix, r_pad: usize) -> Vec<f32> {
    let (rows, r) = m.shape();
    assert!(r <= r_pad, "rank {r} exceeds artifact padding {r_pad}");
    let mut out = vec![0f32; rows * r_pad];
    for i in 0..rows {
        for j in 0..r {
            out[i * r_pad + j] = m[(i, j)] as f32;
        }
    }
    out
}

/// Pad an `r×r` matrix into the top-left of `r_pad×r_pad` (flat f32).
fn pad_square(m: &Matrix, r_pad: usize) -> Vec<f32> {
    let r = m.rows();
    assert!(r <= r_pad);
    let mut out = vec![0f32; r_pad * r_pad];
    for i in 0..r {
        for j in 0..r {
            out[i * r_pad + j] = m[(i, j)] as f32;
        }
    }
    out
}

/// Slice the leading `rows×r` block out of a flat `rows×r_pad` f32 grad.
fn unpad_cols(flat: &[f32], rows: usize, r_pad: usize, r: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, r);
    for i in 0..rows {
        for j in 0..r {
            m[(i, j)] = flat[i * r_pad + j] as f64;
        }
    }
    m
}

impl FedProblem for NnProblem {
    fn spec(&self) -> ProblemSpec {
        let mut dense_shapes = Vec::new();
        for spec in &self.entry.params_factored {
            if !spec.name.ends_with(".u")
                && !spec.name.ends_with(".s")
                && !spec.name.ends_with(".v")
            {
                dense_shapes.push((spec.shape[0], spec.shape[1]));
            }
        }
        let lr_shapes = vec![(self.entry.n_core, self.entry.n_core); self.entry.num_lr];
        ProblemSpec { dense_shapes, lr_shapes }
    }

    fn num_clients(&self) -> usize {
        self.opts.num_clients
    }

    fn grad(&self, c: usize, w: &Weights, want: LrWant, step: u64) -> Grads {
        let (x, y) = self.batch(c, step);
        let r_pad = self.entry.r_pad;
        match want {
            LrWant::Factors => {
                let inputs = self.factored_inputs(w, x, y);
                let out = self.grad_factors.call(&inputs).expect("grad_factors failed");
                let loss = out[0][0] as f64;
                // Outputs follow params_factored order after the loss.
                let mut dense = Vec::new();
                let mut lr: Vec<LrGrad> = Vec::new();
                let mut cur: Option<(Matrix, Matrix)> = None; // (g_u, g_s) awaiting g_v
                let mut lr_idx = 0usize;
                for (oi, spec) in self.entry.params_factored.iter().enumerate() {
                    let flat = &out[1 + oi];
                    if spec.name.ends_with(".u") {
                        let r = w.lr[lr_idx].as_factored().rank();
                        let g_u = unpad_cols(flat, spec.shape[0], r_pad, r);
                        cur = Some((g_u, Matrix::zeros(0, 0)));
                    } else if spec.name.ends_with(".s") {
                        let r = w.lr[lr_idx].as_factored().rank();
                        let g_s_full = Matrix::from_f32(r_pad, r_pad, flat);
                        let g_s = g_s_full.block(r, r);
                        if let Some((_, slot)) = cur.as_mut() {
                            *slot = g_s;
                        }
                    } else if spec.name.ends_with(".v") {
                        let r = w.lr[lr_idx].as_factored().rank();
                        let g_v = unpad_cols(flat, spec.shape[0], r_pad, r);
                        let (g_u, g_s) = cur.take().unwrap();
                        lr.push(LrGrad::Factors { g_u, g_v, g_s });
                        lr_idx += 1;
                    } else {
                        dense.push(Matrix::from_f32(spec.shape[0], spec.shape[1], flat));
                    }
                }
                Grads { loss, dense, lr }
            }
            LrWant::Coeff => {
                let inputs = self.factored_inputs(w, x, y);
                let out = self.grad_coeff.call(&inputs).expect("grad_coeff failed");
                let loss = out[0][0] as f64;
                let mut dense = Vec::new();
                let mut lr = Vec::new();
                let mut lr_idx = 0usize;
                let mut oi = 0usize;
                for spec in &self.entry.params_factored {
                    if spec.name.ends_with(".u") || spec.name.ends_with(".v") {
                        continue; // not an output of grad_coeff
                    }
                    let flat = &out[1 + oi];
                    oi += 1;
                    if spec.name.ends_with(".s") {
                        let r = w.lr[lr_idx].as_factored().rank();
                        let g_s = Matrix::from_f32(r_pad, r_pad, flat).block(r, r);
                        lr.push(LrGrad::Coeff(g_s));
                        lr_idx += 1;
                    } else {
                        dense.push(Matrix::from_f32(spec.shape[0], spec.shape[1], flat));
                    }
                }
                Grads { loss, dense, lr }
            }
            LrWant::Dense => {
                let inputs = self.dense_inputs(w, x, y);
                let out = self.grad_dense.call(&inputs).expect("grad_dense failed");
                let loss = out[0][0] as f64;
                let mut dense = Vec::new();
                let mut lr = Vec::new();
                for (oi, spec) in self.entry.params_dense.iter().enumerate() {
                    let flat = &out[1 + oi];
                    let m = Matrix::from_f32(spec.shape[0], spec.shape[1], flat);
                    if spec.name.starts_with("lr") && spec.name.ends_with(".w") {
                        lr.push(LrGrad::Dense(m));
                    } else {
                        dense.push(m);
                    }
                }
                Grads { loss, dense, lr }
            }
        }
    }

    fn global_loss(&self, w: &Weights) -> f64 {
        self.evaluate(w, false, self.opts.eval_cap).0
    }

    fn eval_metric(&self, w: &Weights) -> Option<f64> {
        Some(self.evaluate(w, true, usize::MAX).1)
    }
}

#[cfg(test)]
mod tests {
    // Runtime-backed tests live in `rust/tests/runtime_nn.rs` (they need
    // `make artifacts` to have run); unit-testable pieces:
    use super::*;

    #[test]
    fn padding_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 3, &mut rng);
        let flat = pad_cols(&m, 6);
        assert_eq!(flat.len(), 30);
        let back = unpad_cols(&flat, 5, 6, 3);
        assert!(back.sub(&m).max_abs() < 1e-6);
        // Zero padding in the extra columns.
        for i in 0..5 {
            for j in 3..6 {
                assert_eq!(flat[i * 6 + j], 0.0);
            }
        }
    }

    #[test]
    fn square_padding_top_left() {
        let m = Matrix::diag(&[1.0, 2.0]);
        let flat = pad_square(&m, 4);
        assert_eq!(flat[0], 1.0);
        assert_eq!(flat[5], 2.0);
        assert_eq!(flat.iter().filter(|&&x| x != 0.0).count(), 2);
    }
}
