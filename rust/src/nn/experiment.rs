//! Shared driver for the §4.2 vision-benchmark reproductions (Figs 5–8).
//!
//! Each figure compares FeDLRT variants against their dense counterparts
//! over a sweep of client counts, reporting compression ratio,
//! communication-cost reduction, and validation accuracy. This module
//! hosts the experiment loop so the per-figure benches and the CLI share
//! one implementation.

use crate::coordinator::presets::VisionPreset;
use crate::coordinator::{run_dense, run_fedlrt, DenseAlgo, VarCorrection};
use crate::metrics::RunRecord;
use crate::nn::{NnOptions, NnProblem};
use crate::runtime::Runtime;

/// One comparison row of a vision figure.
#[derive(Debug, Clone)]
pub struct VisionRow {
    pub clients: usize,
    pub fedlrt_acc: f64,
    pub dense_acc: f64,
    /// Trained-model compression: dense params / factored params of the
    /// low-rank layers.
    pub compression: f64,
    /// Communication saving of FeDLRT vs the dense baseline (1 − ratio).
    pub comm_saving: f64,
    pub fedlrt_rank: usize,
    pub fedlrt: RunRecord,
    pub dense: RunRecord,
}

/// Run one (figure, variance-mode) sweep over client counts.
///
/// `vc` selects the FeDLRT variant; the dense baseline is FedAvg when
/// `vc == None` (paper's top rows) and FedLin otherwise.
pub fn run_vision_sweep(
    preset: &VisionPreset,
    clients: &[usize],
    vc: VarCorrection,
    full: bool,
    seed: u64,
) -> anyhow::Result<Vec<VisionRow>> {
    let dense_algo =
        if vc == VarCorrection::None { DenseAlgo::FedAvg } else { DenseAlgo::FedLin };
    let mut rows = Vec::new();
    for &c in clients {
        let mut rt = Runtime::new(Runtime::default_dir())?;
        let train_n = if full { 12_800 } else { 2_048 };
        let opts = NnOptions {
            config: preset.model.into(),
            num_clients: c,
            train_n,
            test_n: if full { 2_560 } else { 512 },
            eval_cap: if full { 2_048 } else { 512 },
            seed,
            augment: true,
            dirichlet_alpha: None,
        };
        let problem = NnProblem::new(&mut rt, opts)?;
        let cfg = preset.config(c, vc, full, seed);
        let fedlrt = run_fedlrt(&problem, &cfg, preset.figure);
        let dense = run_dense(&problem, &cfg, dense_algo, preset.figure);

        let entry = problem.entry();
        let n = entry.n_core as f64;
        let r = fedlrt.final_rank() as f64;
        let compression = (n * n) / (2.0 * n * r + r * r);
        // Paper footnote 6: savings are reported for the compressed
        // (fully connected low-rank) layers; dense backbone/head traffic
        // is identical across methods and excluded.
        let comm_saving = 1.0
            - fedlrt.total_comm_floats_lr() as f64
                / dense.total_comm_floats_lr().max(1) as f64;
        rows.push(VisionRow {
            clients: c,
            fedlrt_acc: fedlrt.final_metric().unwrap_or(f64::NAN),
            dense_acc: dense.final_metric().unwrap_or(f64::NAN),
            compression,
            comm_saving,
            fedlrt_rank: fedlrt.final_rank(),
            fedlrt,
            dense,
        });
    }
    Ok(rows)
}

/// Pretty-print a sweep in the figures' format.
pub fn print_rows(title: &str, dense_label: &str, rows: &[VisionRow]) {
    println!("\n{title}");
    println!(
        "{:>3} | {:>10} {:>12} | {:>12} {:>12} | {:>6}",
        "C", "compress", "comm saving", "fedlrt acc", dense_label, "rank"
    );
    for row in rows {
        println!(
            "{:>3} | {:>9.1}x {:>11.1}% | {:>12.4} {:>12.4} | {:>6}",
            row.clients,
            row.compression,
            100.0 * row.comm_saving,
            row.fedlrt_acc,
            row.dense_acc,
            row.fedlrt_rank,
        );
    }
}

/// The qualitative checks every vision figure must satisfy.
pub fn assert_figure_shape(rows: &[VisionRow], classes: usize) {
    let chance = 1.0 / classes as f64;
    for row in rows {
        assert!(
            row.comm_saving > 0.5,
            "C={}: comm saving {:.2} should be large",
            row.clients,
            row.comm_saving
        );
        assert!(row.compression > 1.0, "C={}: no compression", row.clients);
        assert!(
            row.fedlrt_acc > chance,
            "C={}: FeDLRT accuracy {:.3} at or below chance",
            row.clients,
            row.fedlrt_acc
        );
        // FeDLRT tracks the dense baseline (paper: "matches well").
        // The scaled CPU runs are short, so we allow a loose band.
        assert!(
            row.fedlrt_acc > row.dense_acc - 0.25,
            "C={}: FeDLRT acc {:.3} collapsed vs dense {:.3}",
            row.clients,
            row.fedlrt_acc,
            row.dense_acc
        );
    }
}
