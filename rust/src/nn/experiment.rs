//! Shared driver for the §4.2 vision-benchmark reproductions (Figs 5–8).
//!
//! Each figure compares FeDLRT variants against their dense counterparts
//! over a sweep of client counts, reporting compression ratio,
//! communication-cost reduction, and validation accuracy. The core
//! comparison ([`compare_backends`]) is generic over any
//! `FedProblem + Sync` backend; two sweep drivers instantiate it:
//!
//! * [`run_mlp_sweep`] — the native Rust [`MlpProblem`] backend
//!   (offline, no artifacts; the default §4.2 path);
//! * [`run_vision_sweep`] — the PJRT artifact-backed [`NnProblem`]
//!   (optional; requires `make artifacts`).

use crate::coordinator::presets::{MlpPreset, VisionPreset};
use crate::coordinator::{run_dense, run_fedlrt, DenseAlgo, TrainConfig, VarCorrection};
use crate::metrics::RunRecord;
use crate::models::mlp::MlpProblem;
use crate::models::FedProblem;
use crate::nn::{NnOptions, NnProblem};
use crate::runtime::Runtime;

/// One comparison row of a vision figure.
#[derive(Debug, Clone)]
pub struct VisionRow {
    pub clients: usize,
    pub fedlrt_acc: f64,
    pub dense_acc: f64,
    /// Trained-model compression: dense params / factored params of the
    /// low-rank layers (at the final per-layer ranks).
    pub compression: f64,
    /// Communication saving of FeDLRT vs the dense baseline (1 − ratio).
    pub comm_saving: f64,
    pub fedlrt_rank: usize,
    pub fedlrt: RunRecord,
    pub dense: RunRecord,
}

/// Run FeDLRT and its dense counterpart on `problem` and assemble the
/// figure row. The dense baseline is FedAvg when `vc == None` (paper's
/// top rows) and FedLin otherwise.
pub fn compare_backends<P: FedProblem + Sync>(
    problem: &P,
    cfg: &TrainConfig,
    figure: &str,
    clients: usize,
) -> VisionRow {
    let dense_algo = if cfg.var_correction == VarCorrection::None {
        DenseAlgo::FedAvg
    } else {
        DenseAlgo::FedLin
    };
    let fedlrt = run_fedlrt(problem, cfg, figure);
    let dense = run_dense(problem, cfg, dense_algo, figure);

    // Compression from the problem's own layer shapes at the final
    // per-layer ranks (works for any number of low-rank layers).
    let spec = problem.spec();
    let final_ranks: Vec<usize> =
        fedlrt.rounds.last().map(|r| r.ranks.clone()).unwrap_or_default();
    let dense_lr: f64 = spec.lr_shapes.iter().map(|&(m, n)| (m * n) as f64).sum();
    let fac_lr: f64 = spec
        .lr_shapes
        .iter()
        .zip(&final_ranks)
        .map(|(&(m, n), &r)| (m * r + r * r + n * r) as f64)
        .sum();
    let compression = dense_lr / fac_lr.max(1.0);
    // Paper footnote 6: savings are reported for the compressed
    // (fully connected low-rank) layers; dense backbone/head traffic
    // is identical across methods and excluded.
    let comm_saving = 1.0
        - fedlrt.total_comm_floats_lr() as f64 / dense.total_comm_floats_lr().max(1) as f64;
    VisionRow {
        clients,
        fedlrt_acc: fedlrt.final_metric().unwrap_or(f64::NAN),
        dense_acc: dense.final_metric().unwrap_or(f64::NAN),
        compression,
        comm_saving,
        fedlrt_rank: fedlrt.final_rank(),
        fedlrt,
        dense,
    }
}

/// Run one (figure, variance-mode) sweep over client counts on the
/// native MLP backend — the offline §4.2 path.
pub fn run_mlp_sweep(
    preset: &MlpPreset,
    clients: &[usize],
    vc: VarCorrection,
    full: bool,
    seed: u64,
) -> Vec<VisionRow> {
    clients
        .iter()
        .map(|&c| {
            let problem = MlpProblem::new(preset.options(c, full, seed));
            let cfg = preset.config(c, vc, full, seed);
            compare_backends(&problem, &cfg, preset.figure, c)
        })
        .collect()
}

/// Run one (figure, variance-mode) sweep over client counts on the PJRT
/// artifact-backed backend (requires `make artifacts`).
pub fn run_vision_sweep(
    preset: &VisionPreset,
    clients: &[usize],
    vc: VarCorrection,
    full: bool,
    seed: u64,
) -> anyhow::Result<Vec<VisionRow>> {
    let mut rows = Vec::new();
    for &c in clients {
        let mut rt = Runtime::new(Runtime::default_dir())?;
        let train_n = if full { 12_800 } else { 2_048 };
        let opts = NnOptions {
            config: preset.model.into(),
            num_clients: c,
            train_n,
            test_n: if full { 2_560 } else { 512 },
            eval_cap: if full { 2_048 } else { 512 },
            seed,
            augment: true,
            dirichlet_alpha: None,
        };
        let problem = NnProblem::new(&mut rt, opts)?;
        let cfg = preset.config(c, vc, full, seed);
        rows.push(compare_backends(&problem, &cfg, preset.figure, c));
    }
    Ok(rows)
}

/// Pretty-print a sweep in the figures' format.
pub fn print_rows(title: &str, dense_label: &str, rows: &[VisionRow]) {
    println!("\n{title}");
    println!(
        "{:>3} | {:>10} {:>12} | {:>12} {:>12} | {:>6}",
        "C", "compress", "comm saving", "fedlrt acc", dense_label, "rank"
    );
    for row in rows {
        println!(
            "{:>3} | {:>9.1}x {:>11.1}% | {:>12.4} {:>12.4} | {:>6}",
            row.clients,
            row.compression,
            100.0 * row.comm_saving,
            row.fedlrt_acc,
            row.dense_acc,
            row.fedlrt_rank,
        );
    }
}

/// The qualitative checks every vision figure must satisfy.
pub fn assert_figure_shape(rows: &[VisionRow], classes: usize) {
    let chance = 1.0 / classes as f64;
    for row in rows {
        assert!(
            row.comm_saving > 0.5,
            "C={}: comm saving {:.2} should be large",
            row.clients,
            row.comm_saving
        );
        assert!(row.compression > 1.0, "C={}: no compression", row.clients);
        assert!(
            row.fedlrt_acc > chance,
            "C={}: FeDLRT accuracy {:.3} at or below chance",
            row.clients,
            row.fedlrt_acc
        );
        // FeDLRT tracks the dense baseline (paper: "matches well").
        // The scaled CPU runs are short, so we allow a loose band.
        assert!(
            row.fedlrt_acc > row.dense_acc - 0.25,
            "C={}: FeDLRT acc {:.3} collapsed vs dense {:.3}",
            row.clients,
            row.fedlrt_acc,
            row.dense_acc
        );
    }
}
