//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive experiments via
//! this module: warmup, repeated timing, and robust statistics.

use crate::util::{mean, median, stddev, Stopwatch};

/// Timing statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms/iter (median {:.3}, min {:.3}, ±{:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.min_s * 1e3,
            self.stddev_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let w = Stopwatch::start();
        f();
        times.push(w.elapsed_s());
    }
    BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_s: mean(&times),
        median_s: median(&times),
        stddev_s: stddev(&times),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// True when FEDLRT_BENCH_FULL=1 — run paper-scale parameters.
pub fn full_scale() -> bool {
    std::env::var("FEDLRT_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop-ish", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_s >= 0.0 && s.min_s <= s.mean_s + 1e-12);
        assert!(s.report().contains("noop-ish"));
    }
}
