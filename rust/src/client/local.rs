//! The one client inner loop: batch schedule, gradient fast path,
//! optimizer stepping, drift correction, and fault injection.
//!
//! Before this layer, the `s*`-iteration local training loop was
//! copy-pasted across all five coordinators (fedlrt, fedlrt_naive,
//! fedlr, dense_baselines, async_server) — five near-identical blocks
//! of `grad_coeff_into` calls, per-layer optimizer stepping, and
//! batch-counter bookkeeping. [`LocalUpdate`] is that loop, once,
//! parameterized by:
//!
//! * [`GradMode`] — coefficient-space training (FeDLRT family: dense
//!   params step before the low-rank coefficients, gradients come from
//!   the allocation-free [`FedProblem::grad_coeff_into`] fast path with
//!   a `grad(LrWant::Coeff)` fallback) vs dense-space training
//!   (FedAvg/FedLin/FeDLR: one `grad(LrWant::Dense)` per step, low-rank
//!   layers step before dense params) — each reproducing its legacy
//!   loop bitwise;
//! * fixed per-round variance-correction extras (`vc_lr`/`vc_dense`,
//!   FedLin eq. 9) and/or a broadcast mean gradient (`g_bar`) from
//!   which FedLin-style extras are derived at the first local step (the
//!   async server's variant);
//! * a [`DriftCorrection`] strategy (FedProx/FedDyn/SCAFFOLD) composed
//!   *additively* with the variance-correction extra;
//! * a [`ClientFault`] applied to the trained tensors after the loop —
//!   so byzantine/noisy clients corrupt exactly what they upload (and,
//!   deliberately, their own correction state: a compromised device
//!   poisons its variates too).
//!
//! The `Correction::None` + `ClientFault::None` path takes literal
//! `None` extras and skips every hook, keeping the legacy bitwise
//! trajectories (regression-pinned in `tests/client_layer.rs`).

use crate::engine::ClientFault;
use crate::models::{FedProblem, LrWant, Weights};
use crate::opt::{ClientOptimizer, OptimizerKind};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

use super::drift::{make_strategy, Correction, DriftState};

/// Which gradient form the local loop trains with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMode {
    /// Low-rank layers are factored; train the coefficients `S̃` via the
    /// `grad_coeff_into` fast path (dense params step first — the
    /// FeDLRT family's historical order).
    Coeff,
    /// Low-rank layers are dense matrices; train via `grad(Dense)`
    /// (low-rank layers step first — the dense baselines' order).
    Dense,
}

/// Everything a client run hands back besides the trained weights
/// (which are mutated in place).
#[derive(Debug, Default)]
pub struct LocalOutcome {
    /// Loss at the first local step (the coordinators' between-eval
    /// estimate); `0.0` when no iterations ran.
    pub first_loss: f64,
    /// First-step gradients `(lr, dense)` when requested
    /// (`capture_first_grad`) — the async server's `g_c(w)` upload.
    pub g_first: Option<(Vec<Matrix>, Vec<Matrix>)>,
    /// Updated per-client drift state to persist (FedDyn/SCAFFOLD), in
    /// the local training space.
    pub drift_out: Option<DriftState>,
    /// SCAFFOLD control-variate delta for uplink, in the local space.
    pub ctrl_delta: Option<DriftState>,
}

/// Fault RNG stream salt: disjoint from the plan/timing salts
/// (`0x5E1E_C700`, `0xD809_0FF1`, `0x57A6_6000`, `0xD15C_A7C4`) so a
/// faulty client's noise never correlates with its scheduling draws.
const SALT_FAULT_STREAM: u64 = 0xFA01_7557;

/// One client's local update for one round/dispatch: the driver that
/// replaces the five hand-rolled coordinator loops.
///
/// Construct per task (cheap — all fields are scalars or borrows),
/// then [`LocalUpdate::run`] against the client's assembled round
/// weights.
pub struct LocalUpdate<'a> {
    /// Client optimizer family (fresh instances per tensor, per round —
    /// local optimizer state resets at each aggregation, as the paper
    /// prescribes).
    pub opt: OptimizerKind,
    /// Learning rate for this round.
    pub lr_t: f64,
    /// Local iterations `s*_c` (straggler model already applied).
    pub iters: usize,
    /// First batch-schedule step — the client's persistent `next_step`
    /// counter (see [`crate::client::ClientStates`]).
    pub step0: u64,
    pub mode: GradMode,
    /// Fixed per-round variance-correction extras, one per low-rank
    /// layer (empty slice = none).
    pub vc_lr: &'a [Option<Matrix>],
    /// Same for dense tensors.
    pub vc_dense: &'a [Option<Matrix>],
    /// Broadcast mean gradient `(lr, dense)`: when present, FedLin-style
    /// extras `ḡ − g_c` are derived from the first local step's own
    /// gradient (the async server's correction form; `Coeff` mode only).
    pub g_bar: Option<(&'a [Matrix], &'a [Matrix])>,
    /// Capture the first step's gradients in the outcome (`Coeff` mode
    /// only).
    pub capture_first_grad: bool,
    /// Drift-correction strategy (normalize before passing — the driver
    /// trusts `Correction::None` to mean structurally off).
    pub correction: Correction,
    /// The client's stored correction state, mapped into the local
    /// training space by the coordinator.
    pub drift_in: Option<&'a DriftState>,
    /// Decoded SCAFFOLD server control variate, local space.
    pub ctrl: Option<&'a DriftState>,
    /// Fault injected into the upload (from the round plan).
    pub fault: ClientFault,
    /// Task RNG seed — the fault noise stream derives from it.
    pub fault_seed: u64,
}

fn lr_param<'w>(w: &'w Weights, l: usize, mode: GradMode) -> &'w Matrix {
    match mode {
        GradMode::Coeff => &w.lr[l].as_factored().s,
        GradMode::Dense => w.lr[l].as_dense(),
    }
}

fn lr_param_mut<'w>(w: &'w mut Weights, l: usize, mode: GradMode) -> &'w mut Matrix {
    match mode {
        GradMode::Coeff => &mut w.lr[l].as_factored_mut().s,
        GradMode::Dense => w.lr[l].as_dense_mut(),
    }
}

/// Clone the trained tensors into a [`DriftState`]-shaped snapshot.
fn snapshot(w: &Weights, mode: GradMode) -> DriftState {
    DriftState {
        lr: (0..w.lr.len()).map(|l| lr_param(w, l, mode).clone()).collect(),
        dense: w.dense.clone(),
    }
}

impl LocalUpdate<'_> {
    /// Run the local loop against `w_c` (the client's decoded round
    /// weights), mutating it in place and returning the side outputs.
    pub fn run<P: FedProblem + ?Sized>(
        &self,
        problem: &P,
        client: usize,
        w_c: &mut Weights,
    ) -> LocalOutcome {
        let num_lr = w_c.lr.len();
        let num_dense = w_c.dense.len();
        let mut strat = make_strategy(self.correction, self.drift_in, self.ctrl);
        let active = strat.active();
        // Initial-weights snapshot: needed by proximal anchors,
        // post-round state updates, and the byzantine fault.
        let needs_w0 =
            strat.needs_w0() || matches!(self.fault, ClientFault::Byzantine { .. });
        let w0: Option<DriftState> = if needs_w0 { Some(snapshot(w_c, self.mode)) } else { None };
        // Strategy scratch — one buffer per tensor, reused across
        // steps. Never allocated on the inactive path.
        let mut scratch_lr: Vec<Matrix> = if active {
            (0..num_lr)
                .map(|l| {
                    let p = lr_param(w_c, l, self.mode);
                    Matrix::zeros(p.rows(), p.cols())
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut scratch_dense: Vec<Matrix> = if active {
            w_c.dense.iter().map(|d| Matrix::zeros(d.rows(), d.cols())).collect()
        } else {
            Vec::new()
        };
        // Corrections derived from a broadcast mean gradient at s = 0
        // (async path); all-`None` otherwise, so lookups fall through to
        // the fixed `vc_*` slices.
        let mut dyn_vc_lr: Vec<Option<Matrix>> = vec![None; num_lr];
        let mut dyn_vc_dense: Vec<Option<Matrix>> = vec![None; num_dense];

        let mut opt_s: Vec<ClientOptimizer> =
            (0..num_lr).map(|_| ClientOptimizer::new(self.opt)).collect();
        let mut opt_d: Vec<ClientOptimizer> =
            (0..num_dense).map(|_| ClientOptimizer::new(self.opt)).collect();
        let mut first_loss = 0.0;
        let mut g_first: Option<(Vec<Matrix>, Vec<Matrix>)> = None;

        match self.mode {
            GradMode::Coeff => {
                // Gradient buffers reused across all s* iterations (the
                // allocation-free fast path writes into them).
                let mut g_coeff: Vec<Matrix> = (0..num_lr)
                    .map(|l| {
                        let p = lr_param(w_c, l, GradMode::Coeff);
                        Matrix::zeros(p.rows(), p.cols())
                    })
                    .collect();
                let mut g_dense: Vec<Matrix> =
                    w_c.dense.iter().map(|d| Matrix::zeros(d.rows(), d.cols())).collect();
                for s in 0..self.iters {
                    let step = self.step0 + s as u64;
                    let loss = match problem.grad_coeff_into(
                        client,
                        w_c,
                        step,
                        &mut g_coeff,
                        &mut g_dense,
                    ) {
                        Some(l0) => l0,
                        None => {
                            let g = problem.grad(client, w_c, LrWant::Coeff, step);
                            for (buf, gl) in g_coeff.iter_mut().zip(&g.lr) {
                                buf.copy_from(gl.coeff());
                            }
                            for (buf, gd) in g_dense.iter_mut().zip(&g.dense) {
                                buf.copy_from(gd);
                            }
                            g.loss
                        }
                    };
                    if s == 0 {
                        first_loss = loss;
                        if self.capture_first_grad {
                            g_first = Some((g_coeff.clone(), g_dense.clone()));
                        }
                        if let Some((gb_lr, gb_dense)) = self.g_bar {
                            for (slot, (gb, gc)) in
                                dyn_vc_lr.iter_mut().zip(gb_lr.iter().zip(&g_coeff))
                            {
                                *slot = Some(gb.sub(gc));
                            }
                            for (slot, (gb, gc)) in
                                dyn_vc_dense.iter_mut().zip(gb_dense.iter().zip(&g_dense))
                            {
                                *slot = Some(gb.sub(gc));
                            }
                        }
                    }
                    // Dense params first, then coefficients — the
                    // FeDLRT family's historical step order.
                    for (dl, gd) in g_dense.iter().enumerate() {
                        let vc = dyn_vc_dense[dl]
                            .as_ref()
                            .or_else(|| self.vc_dense.get(dl).and_then(|o| o.as_ref()));
                        let extra = if active
                            && strat.dense_term(
                                dl,
                                &w_c.dense[dl],
                                &w0.as_ref().unwrap().dense[dl],
                                &mut scratch_dense[dl],
                            ) {
                            if let Some(v) = vc {
                                scratch_dense[dl].axpy(1.0, v);
                            }
                            Some(&scratch_dense[dl])
                        } else {
                            vc
                        };
                        opt_d[dl].step(&mut w_c.dense[dl], gd, self.lr_t, extra);
                    }
                    for l in 0..num_lr {
                        let vc = dyn_vc_lr[l]
                            .as_ref()
                            .or_else(|| self.vc_lr.get(l).and_then(|o| o.as_ref()));
                        let extra = if active
                            && strat.lr_term(
                                l,
                                &w_c.lr[l].as_factored().s,
                                &w0.as_ref().unwrap().lr[l],
                                &mut scratch_lr[l],
                            ) {
                            if let Some(v) = vc {
                                scratch_lr[l].axpy(1.0, v);
                            }
                            Some(&scratch_lr[l])
                        } else {
                            vc
                        };
                        let fac_c = w_c.lr[l].as_factored_mut();
                        opt_s[l].step(&mut fac_c.s, &g_coeff[l], self.lr_t, extra);
                    }
                }
            }
            GradMode::Dense => {
                for s in 0..self.iters {
                    let step = self.step0 + s as u64;
                    let g = problem.grad(client, w_c, LrWant::Dense, step);
                    if s == 0 {
                        first_loss = g.loss;
                    }
                    // Low-rank layers first, then dense params — the
                    // dense baselines' historical step order.
                    for l in 0..num_lr {
                        let vc = self.vc_lr.get(l).and_then(|o| o.as_ref());
                        let extra = if active
                            && strat.lr_term(
                                l,
                                w_c.lr[l].as_dense(),
                                &w0.as_ref().unwrap().lr[l],
                                &mut scratch_lr[l],
                            ) {
                            if let Some(v) = vc {
                                scratch_lr[l].axpy(1.0, v);
                            }
                            Some(&scratch_lr[l])
                        } else {
                            vc
                        };
                        opt_s[l].step(
                            w_c.lr[l].as_dense_mut(),
                            g.lr[l].dense(),
                            self.lr_t,
                            extra,
                        );
                    }
                    for (dl, gd) in g.dense.iter().enumerate() {
                        let vc = self.vc_dense.get(dl).and_then(|o| o.as_ref());
                        let extra = if active
                            && strat.dense_term(
                                dl,
                                &w_c.dense[dl],
                                &w0.as_ref().unwrap().dense[dl],
                                &mut scratch_dense[dl],
                            ) {
                            if let Some(v) = vc {
                                scratch_dense[dl].axpy(1.0, v);
                            }
                            Some(&scratch_dense[dl])
                        } else {
                            vc
                        };
                        opt_d[dl].step(&mut w_c.dense[dl], gd, self.lr_t, extra);
                    }
                }
            }
        }

        // Fault injection: corrupt the trained tensors *before* the
        // strategy's post-round update, so a compromised device also
        // poisons its own variates (it uploads both).
        self.apply_fault(w_c, w0.as_ref());

        let (drift_out, ctrl_delta) = if strat.stateful() {
            let end = snapshot(w_c, self.mode);
            let upd = strat.finish(
                w0.as_ref().expect("stateful strategies snapshot w0"),
                &end,
                self.iters,
                self.lr_t,
            );
            (upd.state, upd.ctrl_delta)
        } else {
            (None, None)
        };
        LocalOutcome { first_loss, g_first, drift_out, ctrl_delta }
    }

    fn apply_fault(&self, w_c: &mut Weights, w0: Option<&DriftState>) {
        match self.fault {
            ClientFault::None => {}
            ClientFault::Noisy { sigma } => {
                let mut rng = Rng::new(self.fault_seed ^ SALT_FAULT_STREAM);
                for l in 0..w_c.lr.len() {
                    for x in lr_param_mut(w_c, l, self.mode).data_mut() {
                        *x += sigma * rng.normal();
                    }
                }
                for d in w_c.dense.iter_mut() {
                    for x in d.data_mut() {
                        *x += sigma * rng.normal();
                    }
                }
            }
            ClientFault::Byzantine { scale } => {
                let w0 = w0.expect("byzantine fault snapshots w0");
                for l in 0..w_c.lr.len() {
                    let anchor = &w0.lr[l];
                    for (x, &x0) in
                        lr_param_mut(w_c, l, self.mode).data_mut().iter_mut().zip(anchor.data())
                    {
                        *x = x0 - scale * (*x - x0);
                    }
                }
                for (d, anchor) in w_c.dense.iter_mut().zip(&w0.dense) {
                    for (x, &x0) in d.data_mut().iter_mut().zip(anchor.data()) {
                        *x = x0 - scale * (*x - x0);
                    }
                }
            }
        }
    }
}
