//! The drift-correction strategy family: FedProx, FedDyn, SCAFFOLD.
//!
//! FeDLRT's variance correction (eq. 9) removes the *gradient estimate*
//! drift between clients; this module adds the orthogonal, widely used
//! *local objective* corrections that fight client drift during the
//! `s*` local iterations themselves:
//!
//! * **FedProx** (Li et al.): proximal term `μ/2 ‖S̃_c − S̃‖_F²` added to
//!   the local objective — a stateless pull toward the broadcast point,
//!   entering the optimizer as the additive gradient `μ(S̃_c − S̃)`.
//! * **FedDyn** (Acar et al., arXiv:2111.04263): dynamic regularization
//!   with per-client state `h_c`; local gradient modifier
//!   `−h_c + α(S̃_c − S̃)`, post-round update `h_c ← h_c − α(S̃_c^K − S̃)`.
//! * **SCAFFOLD** (Karimireddy et al.): control variates — server `c`
//!   and per-client `c_c`; local gradient modifier `strength·(c − c_c)`
//!   (constant over the round), post-round
//!   `c_c ← c_c + strength·(−c + (S̃ − S̃_c^K)/(K·η))`, with the delta
//!   uploaded so the server can fold `c ← c + (1/N) Σ δ_c`. Both
//!   directions travel through the real wire codecs so the extra byte
//!   cost is *measured*, not assumed.
//!
//! All three operate in whatever parameter space the coordinator trains
//! in: the augmented coefficient space `S̃ ∈ ℝ^{2r×2r}` for FeDLRT, the
//! full matrix space for the dense baselines. Strategies are
//! deliberately ignorant of bases — carrying state across a server
//! basis refresh is the *coordinator's* job, via the r×r
//! change-of-coordinates projection [`change_coords`] (the same map the
//! async server applies to stale ΔS updates; see DESIGN.md §Client
//! update layer for the space bookkeeping rule).
//!
//! The neutral settings (μ = 0, α = 0, strength = 0) are collapsed to
//! [`Correction::None`] by [`Correction::normalized`], so a "zero
//! correction" is *structurally* disabled: the driver passes literal
//! `None` extras to the optimizer, preserving both the allocation-free
//! SGD fast path and bitwise-exact trajectories (a `Some(zeros)` extra
//! would route through the general path and can flip `-0.0` signs).

use crate::comm::Network;
use crate::tensor::{matmul, matmul_tn, Matrix};

/// Which drift correction a run uses (`--correction`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Correction {
    /// No correction — bitwise-identical to the pre-refactor loops.
    #[default]
    None,
    /// Proximal term `μ/2 ‖w − w₀‖²` toward the broadcast point.
    FedProx { mu: f64 },
    /// Dynamic regularization with per-client state `h_c`.
    FedDyn { alpha: f64 },
    /// Server/client control variates, scaled by `strength`
    /// (`strength = 1` is the textbook method).
    Scaffold { strength: f64 },
}

impl Correction {
    /// Short label for result rows and config echoes.
    pub fn label(&self) -> &'static str {
        match self {
            Correction::None => "none",
            Correction::FedProx { .. } => "fedprox",
            Correction::FedDyn { .. } => "feddyn",
            Correction::Scaffold { .. } => "scaffold",
        }
    }

    /// The strategy's knob value (μ / α / strength; 0 for `None`).
    pub fn knob(&self) -> f64 {
        match *self {
            Correction::None => 0.0,
            Correction::FedProx { mu } => mu,
            Correction::FedDyn { alpha } => alpha,
            Correction::Scaffold { strength } => strength,
        }
    }

    /// Collapse neutral settings to `None`: FedProx μ=0, FedDyn α=0 and
    /// SCAFFOLD strength=0 modify no gradient, so they are *structurally*
    /// disabled rather than fed through as zero matrices. This is what
    /// makes "neutral knob ≡ none" hold bitwise (see module docs).
    pub fn normalized(&self) -> Correction {
        if self.knob() == 0.0 {
            Correction::None
        } else {
            *self
        }
    }

    /// Parse `--correction` syntax: `none`, `fedprox[:μ]`, `feddyn[:α]`,
    /// `scaffold[:strength]`.
    pub fn parse(s: &str) -> Result<Correction, String> {
        let (name, knob) = match s.split_once(':') {
            Some((n, k)) => {
                let v: f64 = k
                    .parse()
                    .map_err(|_| format!("bad correction knob '{k}' in '{s}'"))?;
                (n, Some(v))
            }
            None => (s, None),
        };
        match name {
            "none" => Ok(Correction::None),
            "fedprox" => Ok(Correction::FedProx { mu: knob.unwrap_or(0.1) }),
            "feddyn" => Ok(Correction::FedDyn { alpha: knob.unwrap_or(0.1) }),
            "scaffold" => Ok(Correction::Scaffold { strength: knob.unwrap_or(1.0) }),
            _ => Err(format!(
                "unknown correction '{s}' (expected none|fedprox[:mu]|feddyn[:alpha]|scaffold[:strength])"
            )),
        }
    }
}

/// A per-client (or server-side) correction state: one matrix per
/// low-rank layer — in the space the owning coordinator currently
/// trains that layer in — plus one per dense parameter tensor.
#[derive(Debug, Clone, Default)]
pub struct DriftState {
    pub lr: Vec<Matrix>,
    pub dense: Vec<Matrix>,
}

impl DriftState {
    /// All-zero state at the given shapes.
    pub fn zeros(lr_shapes: &[(usize, usize)], dense_shapes: &[(usize, usize)]) -> DriftState {
        DriftState {
            lr: lr_shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect(),
            dense: dense_shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect(),
        }
    }

    /// Total float count (for wire accounting by callers).
    pub fn float_count(&self) -> u64 {
        self.lr
            .iter()
            .chain(self.dense.iter())
            .map(|m| (m.rows() * m.cols()) as u64)
            .sum()
    }
}

/// What a strategy hands back after the local loop.
#[derive(Debug, Default)]
pub struct CorrectionUpdate {
    /// Updated per-client state to persist (FedDyn `h_c`, SCAFFOLD
    /// `c_c`), in the local training space.
    pub state: Option<DriftState>,
    /// SCAFFOLD's control-variate delta `c_c⁺ − c_c`, to be uploaded
    /// through the codec and folded into the server variate.
    pub ctrl_delta: Option<DriftState>,
}

/// A pluggable local-objective modifier, driven by
/// [`crate::client::LocalUpdate`] around the inner loop.
///
/// Contract: [`DriftCorrection::lr_term`] / `dense_term` write the
/// additive gradient term for the current iterate into `buf` and return
/// `true`, or return `false` to signal "no term" — in which case the
/// driver passes its variance-correction extra through *untouched*
/// (literal `None` when there is none), which is what keeps the
/// inactive path bitwise-identical to the legacy loops. `w0` is the
/// decoded broadcast parameter the local run started from.
pub trait DriftCorrection {
    /// Whether any per-step term may be produced. `false` short-circuits
    /// all per-step strategy work in the driver.
    fn active(&self) -> bool;

    /// Whether the driver must snapshot the initial weights (`w0` for
    /// proximal anchors and post-round updates).
    fn needs_w0(&self) -> bool {
        self.active()
    }

    /// Whether [`DriftCorrection::finish`] must be called with the
    /// final iterate (strategies that persist state or upload deltas).
    fn stateful(&self) -> bool {
        false
    }

    /// Write the term for low-rank layer `l` at current coefficient
    /// `cur` (started from `w0`) into `buf`; `false` = no term.
    fn lr_term(&mut self, _l: usize, _cur: &Matrix, _w0: &Matrix, _buf: &mut Matrix) -> bool {
        false
    }

    /// Write the term for dense tensor `dl` into `buf`; `false` = no term.
    fn dense_term(&mut self, _dl: usize, _cur: &Matrix, _w0: &Matrix, _buf: &mut Matrix) -> bool {
        false
    }

    /// Post-loop hook: `w0`/`end` are the initial and final local
    /// iterates, `iters` the local steps actually run at learning rate
    /// `lr_t`.
    fn finish(
        &mut self,
        _w0: &DriftState,
        _end: &DriftState,
        _iters: usize,
        _lr_t: f64,
    ) -> CorrectionUpdate {
        CorrectionUpdate::default()
    }
}

/// The `Correction::None` strategy: every hook is a no-op, the driver
/// takes the legacy bitwise path.
pub struct NoCorrection;

impl DriftCorrection for NoCorrection {
    fn active(&self) -> bool {
        false
    }
}

/// FedProx: stateless proximal pull `μ(w − w₀)` toward the broadcast.
pub struct FedProx {
    pub mu: f64,
}

impl DriftCorrection for FedProx {
    fn active(&self) -> bool {
        true
    }

    fn lr_term(&mut self, _l: usize, cur: &Matrix, w0: &Matrix, buf: &mut Matrix) -> bool {
        buf.copy_from(cur);
        buf.axpy(-1.0, w0);
        buf.scale_inplace(self.mu);
        true
    }

    fn dense_term(&mut self, _dl: usize, cur: &Matrix, w0: &Matrix, buf: &mut Matrix) -> bool {
        buf.copy_from(cur);
        buf.axpy(-1.0, w0);
        buf.scale_inplace(self.mu);
        true
    }
}

/// FedDyn: gradient modifier `−h_c + α(w − w₀)`; after the round
/// `h_c ← h_c − α(w_K − w₀)`. `h = None` means a fresh client (all-zero
/// state) — the update then materializes it.
pub struct FedDyn {
    pub alpha: f64,
    pub h: Option<DriftState>,
}

impl FedDyn {
    fn term(&self, stored: Option<&Matrix>, cur: &Matrix, w0: &Matrix, buf: &mut Matrix) {
        buf.copy_from(cur);
        buf.axpy(-1.0, w0);
        buf.scale_inplace(self.alpha);
        if let Some(h) = stored {
            buf.axpy(-1.0, h);
        }
    }
}

impl DriftCorrection for FedDyn {
    fn active(&self) -> bool {
        true
    }

    fn stateful(&self) -> bool {
        true
    }

    fn lr_term(&mut self, l: usize, cur: &Matrix, w0: &Matrix, buf: &mut Matrix) -> bool {
        let stored = self.h.as_ref().map(|h| &h.lr[l]);
        self.term(stored, cur, w0, buf);
        true
    }

    fn dense_term(&mut self, dl: usize, cur: &Matrix, w0: &Matrix, buf: &mut Matrix) -> bool {
        let stored = self.h.as_ref().map(|h| &h.dense[dl]);
        self.term(stored, cur, w0, buf);
        true
    }

    fn finish(
        &mut self,
        w0: &DriftState,
        end: &DriftState,
        _iters: usize,
        _lr_t: f64,
    ) -> CorrectionUpdate {
        let upd = |stored: Option<&Matrix>, end_m: &Matrix, w0_m: &Matrix| {
            let mut d = end_m.sub(w0_m);
            d.scale_inplace(-self.alpha);
            if let Some(h) = stored {
                d.axpy(1.0, h);
            }
            d
        };
        let lr = end
            .lr
            .iter()
            .enumerate()
            .map(|(l, e)| upd(self.h.as_ref().map(|h| &h.lr[l]), e, &w0.lr[l]))
            .collect();
        let dense = end
            .dense
            .iter()
            .enumerate()
            .map(|(dl, e)| upd(self.h.as_ref().map(|h| &h.dense[dl]), e, &w0.dense[dl]))
            .collect();
        CorrectionUpdate { state: Some(DriftState { lr, dense }), ctrl_delta: None }
    }
}

/// SCAFFOLD: constant per-round gradient modifier `strength·(c − c_c)`,
/// precomputed at construction; post-round the client variate moves to
/// `c_c + strength·((w₀ − w_K)/(K·η) − c)` and the delta is reported for
/// uplink.
pub struct Scaffold {
    strength: f64,
    /// Server variate `c` (decoded broadcast), in the local space.
    c: DriftState,
    /// Client variate `c_c`; `None` = fresh client (zeros).
    ci: Option<DriftState>,
    term_lr: Vec<Matrix>,
    term_dense: Vec<Matrix>,
}

impl Scaffold {
    pub fn new(strength: f64, c: DriftState, ci: Option<DriftState>) -> Scaffold {
        let term = |cm: &Matrix, cim: Option<&Matrix>| {
            let mut t = cm.clone();
            if let Some(ci) = cim {
                t.axpy(-1.0, ci);
            }
            t.scale_inplace(strength);
            t
        };
        let term_lr = c
            .lr
            .iter()
            .enumerate()
            .map(|(l, cm)| term(cm, ci.as_ref().map(|s| &s.lr[l])))
            .collect();
        let term_dense = c
            .dense
            .iter()
            .enumerate()
            .map(|(dl, cm)| term(cm, ci.as_ref().map(|s| &s.dense[dl])))
            .collect();
        Scaffold { strength, c, ci, term_lr, term_dense }
    }
}

impl DriftCorrection for Scaffold {
    fn active(&self) -> bool {
        true
    }

    fn stateful(&self) -> bool {
        true
    }

    fn lr_term(&mut self, l: usize, _cur: &Matrix, _w0: &Matrix, buf: &mut Matrix) -> bool {
        buf.copy_from(&self.term_lr[l]);
        true
    }

    fn dense_term(&mut self, dl: usize, _cur: &Matrix, _w0: &Matrix, buf: &mut Matrix) -> bool {
        buf.copy_from(&self.term_dense[dl]);
        true
    }

    fn finish(
        &mut self,
        w0: &DriftState,
        end: &DriftState,
        iters: usize,
        lr_t: f64,
    ) -> CorrectionUpdate {
        if iters == 0 || lr_t == 0.0 {
            // No local progress to estimate a gradient from; the
            // variates stay put.
            return CorrectionUpdate::default();
        }
        let inv = 1.0 / (iters as f64 * lr_t);
        let delta = |w0_m: &Matrix, end_m: &Matrix, c_m: &Matrix| {
            // strength·((w₀ − w_K)/(K·η) − c)
            let mut d = w0_m.sub(end_m);
            d.scale_inplace(inv);
            d.axpy(-1.0, c_m);
            d.scale_inplace(self.strength);
            d
        };
        let d_lr: Vec<Matrix> = w0
            .lr
            .iter()
            .zip(&end.lr)
            .zip(&self.c.lr)
            .map(|((a, b), c)| delta(a, b, c))
            .collect();
        let d_dense: Vec<Matrix> = w0
            .dense
            .iter()
            .zip(&end.dense)
            .zip(&self.c.dense)
            .map(|((a, b), c)| delta(a, b, c))
            .collect();
        let new_state = |old: Option<&DriftState>| {
            let lr = d_lr
                .iter()
                .enumerate()
                .map(|(l, d)| {
                    let mut s = d.clone();
                    if let Some(o) = old {
                        s.axpy(1.0, &o.lr[l]);
                    }
                    s
                })
                .collect();
            let dense = d_dense
                .iter()
                .enumerate()
                .map(|(dl, d)| {
                    let mut s = d.clone();
                    if let Some(o) = old {
                        s.axpy(1.0, &o.dense[dl]);
                    }
                    s
                })
                .collect();
            DriftState { lr, dense }
        };
        let state = new_state(self.ci.as_ref());
        CorrectionUpdate {
            state: Some(state),
            ctrl_delta: Some(DriftState { lr: d_lr, dense: d_dense }),
        }
    }
}

/// Build the strategy instance for one client task. `drift_in` is the
/// client's stored state and `ctrl` the decoded server control variate,
/// both already mapped into the local training space by the coordinator
/// (see DESIGN.md §Client update layer).
pub fn make_strategy(
    kind: Correction,
    drift_in: Option<&DriftState>,
    ctrl: Option<&DriftState>,
) -> Box<dyn DriftCorrection> {
    match kind {
        Correction::None => Box::new(NoCorrection),
        Correction::FedProx { mu } => Box::new(FedProx { mu }),
        Correction::FedDyn { alpha } => Box::new(FedDyn { alpha, h: drift_in.cloned() }),
        Correction::Scaffold { strength } => {
            let c = ctrl
                .expect("scaffold local update requires the broadcast server control variate")
                .clone();
            Box::new(Scaffold::new(strength, c, drift_in.cloned()))
        }
    }
}

/// Change of coordinates for an r×r coefficient-space tensor between
/// two factorizations of the same layer:
/// `(U_curᵀ U_disp) · X · (V_dispᵀ V_cur)`.
///
/// This is exactly the projection the async server applies to stale ΔS
/// updates across basis refreshes (`coordinator::async_server` now
/// delegates here); the drift-correction layer reuses it to carry
/// FedDyn/SCAFFOLD state whenever the server basis changes — stored
/// state lives in the *current* server space at all times, and both
/// ends of a basis change project through this map.
pub fn change_coords(
    u_cur: &Matrix,
    v_cur: &Matrix,
    u_disp: &Matrix,
    v_disp: &Matrix,
    x: &Matrix,
) -> Matrix {
    matmul(&matmul_tn(u_cur, u_disp), &matmul(x, &matmul_tn(v_disp, v_cur)))
}

/// Server-side home of the drift-correction configuration and, for
/// SCAFFOLD, the server control variate `c`. Coordinator-agnostic: the
/// coordinators own billing (their wire topologies differ) and basis
/// bookkeeping; the engine owns the normalized kind and the variate's
/// storage.
pub struct CorrectionEngine {
    kind: Correction,
    ctrl: Option<DriftState>,
}

impl CorrectionEngine {
    pub fn new(kind: Correction) -> CorrectionEngine {
        CorrectionEngine { kind: kind.normalized(), ctrl: None }
    }

    /// The normalized correction kind this run uses.
    pub fn kind(&self) -> Correction {
        self.kind
    }

    pub fn is_active(&self) -> bool {
        self.kind != Correction::None
    }

    /// Whether per-client state must be stored and projected
    /// (FedDyn / SCAFFOLD).
    pub fn is_stateful(&self) -> bool {
        matches!(self.kind, Correction::FedDyn { .. } | Correction::Scaffold { .. })
    }

    pub fn is_scaffold(&self) -> bool {
        matches!(self.kind, Correction::Scaffold { .. })
    }

    /// The current server control variate, if any.
    pub fn ctrl(&self) -> Option<&DriftState> {
        self.ctrl.as_ref()
    }

    /// Lazily initialize (at the given shapes) and return the server
    /// control variate. Only meaningful under SCAFFOLD.
    pub fn ensure_ctrl(
        &mut self,
        lr_shapes: &[(usize, usize)],
        dense_shapes: &[(usize, usize)],
    ) -> &DriftState {
        if self.ctrl.is_none() {
            self.ctrl = Some(DriftState::zeros(lr_shapes, dense_shapes));
        }
        self.ctrl.as_ref().unwrap()
    }

    /// Replace the stored server variate (after the coordinator folded
    /// deltas and/or projected it into a new basis).
    pub fn set_ctrl(&mut self, ctrl: DriftState) {
        self.ctrl = Some(ctrl);
    }

    /// Broadcast the server variate through the wire codec (billing
    /// downlink bytes) and return the *decoded* copy clients see.
    /// Returns `None` unless the run is SCAFFOLD.
    pub fn broadcast_ctrl(
        &mut self,
        net: &mut Network,
        lr_shapes: &[(usize, usize)],
        dense_shapes: &[(usize, usize)],
    ) -> Option<DriftState> {
        if !self.is_scaffold() {
            return None;
        }
        let ctrl = self.ensure_ctrl(lr_shapes, dense_shapes);
        let lr = ctrl.lr.iter().map(|m| net.broadcast_mat("ctrl", m)).collect();
        let dense = ctrl.dense.iter().map(|m| net.broadcast_mat("ctrl_dense", m)).collect();
        Some(DriftState { lr, dense })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_knob_roundtrip() {
        assert_eq!(Correction::parse("none").unwrap(), Correction::None);
        assert_eq!(
            Correction::parse("fedprox:0.05").unwrap(),
            Correction::FedProx { mu: 0.05 }
        );
        assert_eq!(Correction::parse("fedprox").unwrap(), Correction::FedProx { mu: 0.1 });
        assert_eq!(Correction::parse("feddyn:0.2").unwrap(), Correction::FedDyn { alpha: 0.2 });
        assert_eq!(
            Correction::parse("scaffold:0.5").unwrap(),
            Correction::Scaffold { strength: 0.5 }
        );
        assert!(Correction::parse("fedavg").is_err());
        assert!(Correction::parse("fedprox:x").is_err());
        for s in ["none", "fedprox", "feddyn", "scaffold"] {
            assert_eq!(Correction::parse(s).unwrap().label(), s);
        }
    }

    #[test]
    fn neutral_knobs_normalize_to_none() {
        assert_eq!(Correction::FedProx { mu: 0.0 }.normalized(), Correction::None);
        assert_eq!(Correction::FedDyn { alpha: 0.0 }.normalized(), Correction::None);
        assert_eq!(Correction::Scaffold { strength: 0.0 }.normalized(), Correction::None);
        assert_eq!(
            Correction::FedProx { mu: 0.3 }.normalized(),
            Correction::FedProx { mu: 0.3 }
        );
    }

    #[test]
    fn fedprox_pulls_toward_anchor() {
        let mut s = FedProx { mu: 0.5 };
        let w0 = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let cur = Matrix::from_vec(1, 2, vec![3.0, 0.0]);
        let mut buf = Matrix::zeros(1, 2);
        assert!(s.lr_term(0, &cur, &w0, &mut buf));
        assert_eq!(buf.data(), &[1.0, -1.0]);
        assert!(!s.stateful());
    }

    #[test]
    fn feddyn_state_accumulates_negative_displacement() {
        let mut s = FedDyn { alpha: 0.5, h: None };
        let w0 = DriftState { lr: vec![Matrix::zeros(1, 1)], dense: vec![] };
        let end = DriftState { lr: vec![Matrix::from_vec(1, 1, vec![2.0])], dense: vec![] };
        // Fresh client: term = α(cur − w0) with no stored h.
        let mut buf = Matrix::zeros(1, 1);
        s.lr_term(0, &end.lr[0], &w0.lr[0], &mut buf);
        assert_eq!(buf.data(), &[1.0]);
        // h⁺ = −α(end − w0) = −1.
        let upd = s.finish(&w0, &end, 3, 0.1);
        let h = upd.state.unwrap();
        assert_eq!(h.lr[0].data(), &[-1.0]);
        // Second round with stored h: term gains −h = +1.
        let mut s2 = FedDyn { alpha: 0.5, h: Some(h) };
        s2.lr_term(0, &end.lr[0], &w0.lr[0], &mut buf);
        assert_eq!(buf.data(), &[2.0]);
    }

    #[test]
    fn scaffold_delta_matches_textbook_update() {
        // K=2 steps at η=0.25, w0=0, w_K=1 ⇒ (w0−wK)/(Kη) = −2.
        // c = 0.5 ⇒ δ = strength·(−2 − 0.5) = −2.5 at strength 1.
        let c = DriftState { lr: vec![Matrix::from_vec(1, 1, vec![0.5])], dense: vec![] };
        let mut s = Scaffold::new(1.0, c, None);
        let w0 = DriftState { lr: vec![Matrix::zeros(1, 1)], dense: vec![] };
        let end = DriftState { lr: vec![Matrix::from_vec(1, 1, vec![1.0])], dense: vec![] };
        // Term for a fresh client is strength·(c − 0) = 0.5.
        let mut buf = Matrix::zeros(1, 1);
        s.lr_term(0, &end.lr[0], &w0.lr[0], &mut buf);
        assert_eq!(buf.data(), &[0.5]);
        let upd = s.finish(&w0, &end, 2, 0.25);
        assert_eq!(upd.ctrl_delta.as_ref().unwrap().lr[0].data(), &[-2.5]);
        // Fresh client: c_c⁺ = 0 + δ.
        assert_eq!(upd.state.unwrap().lr[0].data(), &[-2.5]);
    }

    #[test]
    fn change_coords_is_identity_on_same_basis() {
        let mut rng = crate::util::rng::Rng::new(5);
        let f = crate::lowrank::LowRank::random_init(8, 6, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng);
        let y = change_coords(&f.u, &f.v, &f.u, &f.v, &x);
        // Orthonormal bases ⇒ UᵀU = VᵀV = I up to fp error.
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn engine_normalizes_and_stores_ctrl() {
        let e = CorrectionEngine::new(Correction::Scaffold { strength: 0.0 });
        assert!(!e.is_active());
        let mut e = CorrectionEngine::new(Correction::Scaffold { strength: 1.0 });
        assert!(e.is_scaffold() && e.is_stateful());
        assert!(e.ctrl().is_none());
        e.ensure_ctrl(&[(3, 3)], &[(2, 1)]);
        let c = e.ctrl().unwrap();
        assert_eq!(c.lr[0].shape(), (3, 3));
        assert_eq!(c.dense[0].shape(), (2, 1));
        assert_eq!(c.float_count(), 11);
        let e = CorrectionEngine::new(Correction::FedDyn { alpha: 0.1 });
        assert!(e.is_stateful() && !e.is_scaffold());
        let e = CorrectionEngine::new(Correction::FedProx { mu: 0.1 });
        assert!(e.is_active() && !e.is_stateful());
    }
}
