//! Persistent per-client state for the synchronous coordinators.
//!
//! Every sync coordinator needs the same two pieces of cross-round
//! client memory: the mini-batch schedule cursor (`next_step`, so a
//! client's stochastic gradient stream resumes where its last
//! participation stopped) and, with a stateful drift correction, its
//! FedDyn/SCAFFOLD variate. Before this layer each coordinator carried
//! its own `vec![0u64; c_num]` counter plus an identical
//! post-aggregation advance loop; [`ClientStates`] replaces all four
//! copies with one wrapper over the sharded [`ClientRegistry`] (the
//! same store the async path uses), so sync and async client state
//! live behind one abstraction and one byte-accounting regime.
//!
//! Bitwise note: a fresh record's `next_step` is `0`, exactly like the
//! zero-initialized vectors it replaces, and [`ClientStates::advance`]
//! walks the plan in task order, exactly like the legacy loops — the
//! schedule every client sees is unchanged (pinned by
//! `tests/client_layer.rs`).

use crate::engine::{ClientRecord, ClientRegistry, RoundPlan};

use super::drift::DriftState;

/// Cross-round client state (batch cursors + drift variates) for the
/// synchronous round loop.
#[derive(Debug)]
pub struct ClientStates {
    reg: ClientRegistry,
}

impl ClientStates {
    pub fn new(num_clients: usize) -> ClientStates {
        ClientStates { reg: ClientRegistry::new(num_clients, ClientRegistry::DEFAULT_SHARD) }
    }

    fn blank(_c: usize) -> ClientRecord {
        ClientRecord::default()
    }

    /// The client's first batch-schedule step for this round.
    pub fn step0(&mut self, client: usize) -> u64 {
        self.reg.get_or_init(client, Self::blank).next_step
    }

    /// Advance every participant's batch cursor by its local iteration
    /// count — the single replacement for the per-coordinator
    /// `next_step[c] += s*` loops (called once, after aggregation).
    pub fn advance(&mut self, plan: &RoundPlan) {
        for task in &plan.tasks {
            self.reg.get_or_init(task.client_id, Self::blank).next_step +=
                task.local_iters as u64;
        }
    }

    /// Clone of the client's stored drift state, if any.
    pub fn drift_cloned(&mut self, client: usize) -> Option<DriftState> {
        self.reg.get_or_init(client, Self::blank).drift.as_deref().cloned()
    }

    /// Store (replace) the client's drift state.
    pub fn set_drift(&mut self, client: usize, state: DriftState) {
        self.reg.get_or_init(client, Self::blank).drift = Some(Box::new(state));
    }

    /// Visit every stored drift state in client-id order — how the
    /// coordinators project *all* client variates through a server
    /// basis change, participants or not (the state-across-refresh rule
    /// in DESIGN.md §Client update layer).
    pub fn for_each_drift(&mut self, mut f: impl FnMut(usize, &mut DriftState)) {
        self.reg.for_each_materialized(|id, rec| {
            if let Some(d) = rec.drift.as_deref_mut() {
                f(id, d);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    #[test]
    fn fresh_cursor_is_zero_and_advances_in_plan_order() {
        use crate::coordinator::TrainConfig;
        let cfg = TrainConfig { local_iters: 3, ..TrainConfig::default() };
        let plan = RoundPlan::build(&cfg, 8, 0, |_| 1.0);
        let mut st = ClientStates::new(8);
        for t in &plan.tasks {
            assert_eq!(st.step0(t.client_id), 0);
        }
        st.advance(&plan);
        for t in &plan.tasks {
            assert_eq!(st.step0(t.client_id), t.local_iters as u64);
        }
    }

    #[test]
    fn drift_state_round_trips_and_iterates_in_id_order() {
        let mut st = ClientStates::new(600); // spans multiple shards
        for &c in &[5usize, 300, 599] {
            let mut d = DriftState::zeros(&[(2, 2)], &[]);
            d.lr[0] = Matrix::from_vec(2, 2, vec![c as f64; 4]);
            st.set_drift(c, d);
        }
        assert!(st.drift_cloned(7).is_none());
        assert_eq!(st.drift_cloned(300).unwrap().lr[0][(0, 0)], 300.0);
        let mut seen = Vec::new();
        st.for_each_drift(|id, d| {
            d.lr[0].scale_inplace(2.0);
            seen.push(id);
        });
        assert_eq!(seen, vec![5, 300, 599]);
        assert_eq!(st.drift_cloned(599).unwrap().lr[0][(0, 0)], 2.0 * 599.0);
    }
}
