//! The client-update layer: one local training loop for all
//! coordinators, with pluggable drift correction.
//!
//! FeDLRT's five coordinators (fedlrt, fedlrt_naive, fedlr,
//! dense_baselines, async_server) used to each carry a hand-rolled copy
//! of the client inner loop — the `s*` mini-batch iterations of eq. 7/8
//! plus batch-cursor bookkeeping. This module factors that loop into
//! three pieces:
//!
//! * [`LocalUpdate`] ([`local`]) — the driver: batch schedule,
//!   `grad_coeff_into` fast path, per-tensor optimizer stepping in each
//!   family's historical order, variance-correction extras, and the
//!   [`crate::engine::ClientFault`] hook. With [`Correction::None`] it
//!   reproduces every legacy loop bitwise.
//! * [`DriftCorrection`] ([`drift`]) — the strategy family for
//!   heterogeneous fleets: [`Correction::FedProx`] (proximal anchor),
//!   [`Correction::FedDyn`] (per-client dynamic regularizer), and
//!   [`Correction::Scaffold`] (control variates over real wire codecs,
//!   so their byte cost shows up in `bytes_up`/`bytes_down`). All
//!   corrections act in the space the client trains in — for FeDLRT
//!   that is the augmented coefficient space — and persistent state is
//!   carried across server basis refreshes by the r×r
//!   change-of-coordinates projection ([`change_coords`]; see DESIGN.md
//!   §Client update layer for the exact rule).
//! * [`ClientStates`] ([`state`]) — cross-round client memory (batch
//!   cursors + drift variates) over the sharded
//!   [`crate::engine::ClientRegistry`], shared by all sync
//!   coordinators.

pub mod drift;
pub mod local;
pub mod state;

pub use drift::{
    change_coords, make_strategy, Correction, CorrectionEngine, CorrectionUpdate,
    DriftCorrection, DriftState,
};
pub use local::{GradMode, LocalOutcome, LocalUpdate};
pub use state::ClientStates;
